"""Pytest bootstrap for the repo.

Provides a minimal deterministic stand-in for ``hypothesis`` when the real
package is absent (slim CI images): ``@given`` replays a fixed number of
pseudo-random examples seeded by the test name, so the property tests still
collect and exercise the invariants. With hypothesis installed this module
is a no-op and the real engine runs.

Also implements the CI ``chaos-smoke`` legs: with ``REPRO_CHAOS=loss`` or
``REPRO_CHAOS=dup`` in the environment, every ``run_ranks`` call that does
not already carry a fault plan gets a seeded 10% drop / duplication plan
injected — the whole host-runtime suite then runs on a lossy transport and
must still pass unchanged (reliable delivery is invisible to correct
callers). The per-run RecoveryReports are accumulated and written as a JSON
artifact (``REPRO_CHAOS_OUT``, default ``chaos_report.json``) at session
end.
"""

import functools
import inspect
import json
import os
import random
import sys
import types

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover — exercised only on slim images
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def _lists(elements, *, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10
        return _Strategy(lambda rng: [elements.draw(rng)
                                      for _ in range(rng.randint(min_size,
                                                                 hi))])

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", 10)
                rng = random.Random(fn.__qualname__)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # hide the drawn params from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            del wrapper.__wrapped__
            return wrapper
        return deco

    def _settings(**kwargs):
        def deco(fn):
            fn._stub_max_examples = kwargs.get("max_examples", 10)
            return fn
        return deco

    class _HealthCheck:
        too_slow = "too_slow"
        data_too_large = "data_too_large"
        filter_too_much = "filter_too_much"

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.sampled_from = _sampled_from
    st_mod.lists = _lists

    def _unstubbed(name):
        # PEP 562 module __getattr__: an unstubbed strategy must fail at
        # the use site with a pointer here, not as a silent None or a
        # bare AttributeError deep inside @given
        raise AttributeError(
            f"hypothesis stub: strategies.{name} is not stubbed — the real "
            "hypothesis is absent and conftest.py's stand-in only provides "
            "integers, sampled_from, lists; extend the stub or install "
            "hypothesis")

    st_mod.__getattr__ = _unstubbed

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = _given
    hyp_mod.settings = _settings
    hyp_mod.HealthCheck = _HealthCheck
    hyp_mod.strategies = st_mod
    hyp_mod._is_repro_stub = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod


_CHAOS = os.environ.get("REPRO_CHAOS")
_chaos_reports = []

if _CHAOS in ("loss", "dup"):
    sys.path.insert(0, os.path.join(os.path.dirname(
        os.path.abspath(__file__)), "src"))
    import repro.core as _core
    import repro.core.runtime as _core_runtime
    from repro.core.faults import FaultPlan as _ChaosPlan

    _orig_run_ranks = _core_runtime.run_ranks

    def _chaos_run_ranks(n_ranks, main, **kw):
        # never override an explicit plan (the fault tests drive their
        # own schedules), and single-rank worlds have no transport
        if kw.get("faults") is not None or n_ranks < 2:
            return _orig_run_ranks(n_ranks, main, **kw)
        kw["faults"] = _ChaosPlan(
            seed=20260808,
            drop=0.10 if _CHAOS == "loss" else 0.0,
            duplicate=0.10 if _CHAOS == "dup" else 0.0)
        results, report = _orig_run_ranks(n_ranks, main, **kw)
        _chaos_reports.append(report.to_dict())
        return results

    _core_runtime.run_ranks = _chaos_run_ranks
    _core.run_ranks = _chaos_run_ranks


def pytest_sessionfinish(session, exitstatus):
    if _CHAOS and _chaos_reports:
        out = os.environ.get("REPRO_CHAOS_OUT", "chaos_report.json")
        agg = {}
        for r in _chaos_reports:
            for k, v in r.items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    agg[k] = agg.get(k, 0) + v
        with open(out, "w") as f:
            json.dump({"mode": _CHAOS, "runs": len(_chaos_reports),
                       "totals": agg, "reports": _chaos_reports}, f, indent=2)
