"""Pytest bootstrap for the repo.

Provides a minimal deterministic stand-in for ``hypothesis`` when the real
package is absent (slim CI images): ``@given`` replays a fixed number of
pseudo-random examples seeded by the test name, so the property tests still
collect and exercise the invariants. With hypothesis installed this module
is a no-op and the real engine runs.
"""

import functools
import inspect
import random
import sys
import types

try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover — exercised only on slim images
    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda rng: rng.choice(elements))

    def _lists(elements, *, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 10
        return _Strategy(lambda rng: [elements.draw(rng)
                                      for _ in range(rng.randint(min_size,
                                                                 hi))])

    def _given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", 10)
                rng = random.Random(fn.__qualname__)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # hide the drawn params from pytest's fixture resolution
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            del wrapper.__wrapped__
            return wrapper
        return deco

    def _settings(**kwargs):
        def deco(fn):
            fn._stub_max_examples = kwargs.get("max_examples", 10)
            return fn
        return deco

    class _HealthCheck:
        too_slow = "too_slow"
        data_too_large = "data_too_large"
        filter_too_much = "filter_too_much"

    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.sampled_from = _sampled_from
    st_mod.lists = _lists

    def _unstubbed(name):
        # PEP 562 module __getattr__: an unstubbed strategy must fail at
        # the use site with a pointer here, not as a silent None or a
        # bare AttributeError deep inside @given
        raise AttributeError(
            f"hypothesis stub: strategies.{name} is not stubbed — the real "
            "hypothesis is absent and conftest.py's stand-in only provides "
            "integers, sampled_from, lists; extend the stub or install "
            "hypothesis")

    st_mod.__getattr__ = _unstubbed

    hyp_mod = types.ModuleType("hypothesis")
    hyp_mod.given = _given
    hyp_mod.settings = _settings
    hyp_mod.HealthCheck = _HealthCheck
    hyp_mod.strategies = st_mod
    hyp_mod._is_repro_stub = True

    sys.modules["hypothesis"] = hyp_mod
    sys.modules["hypothesis.strategies"] = st_mod
