"""Pure-jnp oracle for causal GQA flash attention."""

from __future__ import annotations

import jax.numpy as jnp


def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
            causal: bool = True, scale: float | None = None) -> jnp.ndarray:
    """q: [B, Hq, Lq, D]; k/v: [B, Hkv, Lk, D]; Hq % Hkv == 0 (GQA).

    Softmax in f32 regardless of input dtype (matches the kernel).
    """
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    scale = scale if scale is not None else d ** -0.5

    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * scale
    if causal:
        # queries are the last lq positions of the lk-long sequence
        qpos = jnp.arange(lq)[:, None] + (lk - lq)
        kpos = jnp.arange(lk)[None, :]
        logits = jnp.where(kpos <= qpos, logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", probs,
                      vx.astype(jnp.float32)).astype(q.dtype)
