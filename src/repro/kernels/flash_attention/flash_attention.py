"""Causal GQA flash attention (forward) — Pallas TPU kernel.

IO-aware attention for the prefill shapes: never materializes the [Lq, Lk]
score matrix in HBM. Grid = (batch·q_heads, Lq/bq, Lk/bk) with the KV block
dimension innermost (sequential), carrying the online-softmax state
(running max m, normalizer l, unnormalized accumulator acc) in VMEM scratch
across KV steps — the standard FlashAttention recurrence re-tiled for the
TPU memory hierarchy (HBM -> VMEM tiles -> MXU for the two matmuls, VPU for
the rescaling).

GQA is folded into the BlockSpec index maps: the q-head axis indexes K/V by
`h // group`, so no repeated KV ever leaves HBM. Causal masking skips fully
masked KV blocks via a cheap in-kernel predicate (the grid is still dense —
Mosaic handles `pl.when` efficiently; a sparse grid is a further
optimization recorded in EXPERIMENTS §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, kv_steps: int, bq: int, bk: int,
               lk: int, lq: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions (queries are the trailing lq positions of lk)
    q_start = qi * bq + (lk - lq)
    k_start = ki * bk

    run = (not causal) or (k_start <= q_start + bq - 1)

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32)               # [bq, d]
        k = k_ref[0].astype(jnp.float32)               # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_prev = m_ref[...]                            # [bq, 1]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # [bq, bk]
        alpha = jnp.exp(m_prev - m_new)                # [bq, 1]
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)               # [bk, d]
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == kv_steps - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, bq: int = 512, bk: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hq, Lq, D]; k/v: [B, Hkv, Lk, D] -> [B, Hq, Lq, D]."""
    b, hq, lq, d = q.shape
    _, hkv, lk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    bq, bk = min(bq, lq), min(bk, lk)
    assert lq % bq == 0 and lk % bk == 0, (lq, bq, lk, bk)
    scale = d ** -0.5
    kv_steps = lk // bk

    qf = q.reshape(b * hq, lq, d)
    kf = k.reshape(b * hkv, lk, d)
    vf = v.reshape(b * hkv, lk, d)

    def q_map(h, qi, ki):
        return (h, qi, 0)

    def kv_map(h, qi, ki):
        return (h // group, ki, 0)

    out = pl.pallas_call(
        functools.partial(_fa_kernel, scale=scale, causal=causal,
                          kv_steps=kv_steps, bq=bq, bk=bk, lk=lk, lq=lq),
        grid=(b * hq, lq // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max
            pltpu.VMEM((bq, 1), jnp.float32),   # normalizer
            pltpu.VMEM((bq, d), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, lq, d)
