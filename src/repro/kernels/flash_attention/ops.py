"""Public jit'd wrapper for flash attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .ref import mha_ref


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, bq: int = 512, bk: int = 512) -> jnp.ndarray:
    """Causal GQA attention; Pallas on TPU, jnp oracle elsewhere."""
    lq, lk = q.shape[2], k.shape[2]
    tiles_ok = lq % min(bq, lq) == 0 and lk % min(bk, lk) == 0
    if jax.default_backend() == "tpu" and tiles_ok:
        return flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    return mha_ref(q, k, v, causal=causal)


def task_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                   causal: bool = True, bq: int = 512,
                   bk: int = 512) -> jnp.ndarray:
    """Single-head attention over 2D ``[L, D]`` blocks — the block
    executor's task-body form of :func:`flash_attention`.

    Always the Pallas kernel (Mosaic on TPU, interpret mode elsewhere),
    never the jnp oracle, so a PTG whose task bodies are attention steps
    exercises the kernel end to end. The executor vmaps bodies over each
    wavefront's task table; ``vmap(pallas_call)`` folds that batch into a
    leading grid dimension, one fused launch per wavefront.
    """
    out = flash_attention(q[None, None], k[None, None], v[None, None],
                          causal=causal, bq=bq, bk=bk,
                          interpret=jax.default_backend() != "tpu")
    return out[0, 0]
