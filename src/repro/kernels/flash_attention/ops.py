"""Public jit'd wrapper for flash attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention
from .ref import mha_ref


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, bq: int = 512, bk: int = 512) -> jnp.ndarray:
    """Causal GQA attention; Pallas on TPU, jnp oracle elsewhere."""
    lq, lk = q.shape[2], k.shape[2]
    tiles_ok = lq % min(bq, lq) == 0 and lk % min(bk, lk) == 0
    if jax.default_backend() == "tpu" and tiles_ok:
        return flash_attention(q, k, v, causal=causal, bq=bq, bk=bk)
    return mha_ref(q, k, v, causal=causal)
