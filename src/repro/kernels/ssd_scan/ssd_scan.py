"""Mamba-2 SSD chunked scan — Pallas TPU kernel.

The SSD duality turns the token-by-token recurrence into chunk-level
matmuls (MXU food) plus an O(L/Q) sequential state hand-off:

    per chunk (Q tokens), with cum = cumsum(dt·A) over the chunk:
      intra:  Y  = ((C Bᵀ) ⊙ L) · (dt·x)      L_ij = exp(cum_i − cum_j), j ≤ i
      inter:  Y += (C ⊙ exp(cum)) · h
      state:  h  = exp(cum_Q) · h + Bᵀ · ((dt·x) ⊙ exp(cum_Q − cum))

Grid = (B·H, L/Q) with the chunk dimension innermost (sequential); the
[N, P] state lives in VMEM scratch across chunks — the recurrence never
round-trips HBM. B/C tensors stay grouped ([B·G, L, N]); the head→group
indirection happens in the BlockSpec index map exactly like GQA in the
attention kernels. dt·x and dt·A are cheap elementwise precomputes fused by
XLA outside the kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(xdt_ref, da_ref, b_ref, c_ref, y_ref, state_ref, *,
                q_chunk: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xdt = xdt_ref[0].astype(jnp.float32)          # [Q, P]
    da = da_ref[0].astype(jnp.float32)            # [Q]
    bmat = b_ref[0].astype(jnp.float32)           # [Q, N]
    cmat = c_ref[0].astype(jnp.float32)           # [Q, N]

    cum = jnp.cumsum(da)                          # [Q], inclusive
    # decay matrix L_ij = exp(cum_i - cum_j) for j <= i else 0
    li = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, q_chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, q_chunk), 1)
    lmat = jnp.where(iota_j <= iota_i, jnp.exp(li), 0.0)

    scores = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * lmat
    y = jax.lax.dot(scores, xdt, preferred_element_type=jnp.float32)

    # inter-chunk: carried state
    y += jax.lax.dot(cmat * jnp.exp(cum)[:, None], state_ref[...],
                     preferred_element_type=jnp.float32)

    # state update
    decay_rest = jnp.exp(cum[-1] - cum)           # [Q]
    state_ref[...] = (jnp.exp(cum[-1]) * state_ref[...]
                      + jax.lax.dot_general(
                          bmat, xdt * decay_rest[:, None],
                          (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q_chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
             b: jnp.ndarray, c: jnp.ndarray, d: jnp.ndarray | None = None,
             *, q_chunk: int = 128, interpret: bool = False) -> jnp.ndarray:
    """x: [B, L, H, P]; dt: [B, L, H]; a: [H]; b/c: [B, L, G, N]; d: [H]."""
    bsz, l, h, p = x.shape
    _, _, g, n = b.shape
    assert h % g == 0
    rep = h // g
    q_chunk = min(q_chunk, l)
    assert l % q_chunk == 0, (l, q_chunk)
    chunks = l // q_chunk

    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(bsz * h, l, p)
    da = (dt * a[None, None, :]).transpose(0, 2, 1).reshape(bsz * h, l)
    bf = b.transpose(0, 2, 1, 3).reshape(bsz * g, l, n)
    cf = c.transpose(0, 2, 1, 3).reshape(bsz * g, l, n)

    def xmap(i, ci):
        return (i, ci, 0)

    def bcmap(i, ci):
        # head -> group indirection: i = batch*h + head
        return ((i // h) * g + (i % h) // rep, ci, 0)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, q_chunk=q_chunk),
        grid=(bsz * h, chunks),
        in_specs=[
            pl.BlockSpec((1, q_chunk, p), xmap),
            pl.BlockSpec((1, q_chunk), lambda i, ci: (i, ci)),
            pl.BlockSpec((1, q_chunk, n), bcmap),
            pl.BlockSpec((1, q_chunk, n), bcmap),
        ],
        out_specs=pl.BlockSpec((1, q_chunk, p), xmap),
        out_shape=jax.ShapeDtypeStruct((bsz * h, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xdt, da, bf, cf)
    y = y.reshape(bsz, h, l, p).transpose(0, 2, 1, 3)
    if d is not None:
        y = y + (x * d[None, None, :, None]).astype(y.dtype)
    return y
