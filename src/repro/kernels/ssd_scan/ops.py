"""Public jit'd wrapper for the SSD scan."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import ssd_chunked_ref, ssd_ref
from .ssd_scan import ssd_scan


def ssd(x, dt, a, b, c, d=None, *, q_chunk: int = 128) -> jnp.ndarray:
    """Mamba-2 SSD. Pallas chunked kernel on TPU (serve); differentiable
    chunked-jnp elsewhere / for training; token recurrence as last resort."""
    l = x.shape[1]
    if l % min(q_chunk, l) == 0:
        if jax.default_backend() == "tpu":
            return ssd_scan(x, dt, a, b, c, d, q_chunk=q_chunk)
        return ssd_chunked_ref(x, dt, a, b, c, d, q_chunk=q_chunk)
    return ssd_ref(x, dt, a, b, c, d)
