"""Pure-jnp oracle for the Mamba-2 SSD scan (sequential recurrence).

State h_t [N, P] per (batch, head):

    h_t = exp(dt_t · A_h) · h_{t-1} + B_t ⊗ (dt_t · x_t)
    y_t = C_t · h_t  (+ D_h · x_t)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
            c: jnp.ndarray, d: jnp.ndarray | None = None) -> jnp.ndarray:
    """x: [B, L, H, P]; dt: [B, L, H]; a: [H] (negative);
    b/c: [B, L, G, N] with H % G == 0; d: [H] or None -> y: [B, L, H, P]."""
    bsz, l, h, p = x.shape
    _, _, g, n = b.shape
    rep = h // g
    bx = jnp.repeat(b, rep, axis=2)          # [B, L, H, N]
    cx = jnp.repeat(c, rep, axis=2)

    da = dt * a[None, None, :]               # [B, L, H]
    xdt = x * dt[..., None]                  # [B, L, H, P]

    def step(hstate, inp):
        da_t, b_t, c_t, xdt_t = inp
        hstate = (jnp.exp(da_t)[..., None, None] * hstate
                  + b_t[..., :, None] * xdt_t[..., None, :])
        y_t = jnp.einsum("bhn,bhnp->bhp", c_t, hstate)
        return hstate, y_t

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    inputs = (da.transpose(1, 0, 2).astype(jnp.float32),
              bx.transpose(1, 0, 2, 3).astype(jnp.float32),
              cx.transpose(1, 0, 2, 3).astype(jnp.float32),
              xdt.transpose(1, 0, 2, 3).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h0, inputs)
    y = ys.transpose(1, 0, 2, 3)             # [B, L, H, P]
    if d is not None:
        y = y + x.astype(jnp.float32) * d[None, None, :, None]
    return y.astype(x.dtype)


def ssd_chunked_ref(x, dt, a, b, c, d=None, *, q_chunk: int = 128):
    """Differentiable pure-jnp port of the *chunked* SSD algorithm (the same
    math as the Pallas kernel): O(L/Q) sequential steps of chunk-level
    matmuls instead of an L-step token recurrence. This is the production
    train/prefill path; `ssd_ref` stays as the independent oracle."""
    bsz, l, h, p = x.shape
    _, _, g, n = b.shape
    rep = h // g
    q = min(q_chunk, l)
    assert l % q == 0, (l, q)
    nc = l // q

    bx = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cx = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    da = (dt.astype(jnp.float32) * a[None, None, :]) \
        .reshape(bsz, nc, q, h)
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]) \
        .reshape(bsz, nc, q, h, p)
    bxc = bx.reshape(bsz, nc, q, h, n)
    cxc = cx.reshape(bsz, nc, q, h, n)

    iota = jnp.arange(q)
    tri = iota[:, None] >= iota[None, :]                      # j <= i

    def chunk_step(state, inp):
        da_c, b_c, c_c, xdt_c = inp                 # [B,q,H], [B,q,H,N], ...
        cum = jnp.cumsum(da_c, axis=1)              # [B, q, H]
        lmat = jnp.where(tri[None, :, :, None],
                         jnp.exp(cum[:, :, None] - cum[:, None, :]), 0.0)
        scores = jnp.einsum("bihn,bjhn->bijh", c_c, b_c) * lmat
        y = jnp.einsum("bijh,bjhp->bihp", scores, xdt_c)
        y += jnp.einsum("bihn,bhnp->bihp",
                        c_c * jnp.exp(cum)[..., None], state)
        decay_rest = jnp.exp(cum[:, -1:, :] - cum)  # [B, q, H]
        state = (jnp.exp(cum[:, -1, :])[..., None, None] * state
                 + jnp.einsum("bjhn,bjhp->bhnp",
                              b_c, xdt_c * decay_rest[..., None]))
        return state, y

    from repro.launch.flags import scan_unroll_arg

    h0 = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, ys = jax.lax.scan(
        jax.checkpoint(chunk_step), h0,
        (da.transpose(1, 0, 2, 3), bxc.transpose(1, 0, 2, 3, 4),
         cxc.transpose(1, 0, 2, 3, 4), xdt.transpose(1, 0, 2, 3, 4)),
        unroll=scan_unroll_arg())
    y = ys.transpose(1, 0, 2, 3, 4).reshape(bsz, l, h, p)
    if d is not None:
        y = y + x.astype(jnp.float32) * d[None, None, :, None]
    return y.astype(x.dtype)
