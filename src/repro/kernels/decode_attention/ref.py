"""Pure-jnp oracle for single-token GQA decode attention."""

from __future__ import annotations

import jax.numpy as jnp


def decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               kv_len=None) -> jnp.ndarray:
    """q: [B, Hq, D] (one new token); k/v: [B, Hkv, S, D]; optional kv_len
    [B] masks positions >= kv_len (ragged cache)."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    group = hq // hkv
    kx = jnp.repeat(k, group, axis=1)
    vx = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32),
                        kx.astype(jnp.float32)) * (d ** -0.5)
    if kv_len is not None:
        mask = jnp.arange(s)[None, None, :] < kv_len[:, None, None]
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jnp.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", probs,
                      vx.astype(jnp.float32)).astype(q.dtype)
