"""Flash-decoding GQA attention — Pallas TPU kernel for the decode shapes.

One new token attends to an S-long KV cache (decode_32k / long_500k cells):
pure memory-bound reduction over the cache, so the kernel's job is to
stream K/V through VMEM exactly once at full HBM bandwidth while the whole
q-head *group* of a KV head rides along ([group, D] tile — the GQA analogue
of flash-decoding's head batching; the group dimension feeds the MXU).

Grid = (B·Hkv, S/bs) with the cache-block dimension innermost (sequential);
online-softmax state (m, l, acc) carried in VMEM scratch. A ragged cache
length per batch row masks dead positions in-kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(lens_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, s_steps: int, bs: int,
                   hkv: int):
    si = pl.program_id(1)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    batch = pl.program_id(0) // hkv
    kv_len = lens_ref[batch]
    s0 = si * bs

    @pl.when(s0 < kv_len)
    def _step():
        q = q_ref[0].astype(jnp.float32)            # [group, d]
        k = k_ref[0].astype(jnp.float32)            # [bs, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = s0 + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = alpha * l_ref[...] + p.sum(axis=-1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)            # [bs, d]
        acc_ref[...] = alpha * acc_ref[...] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(si == s_steps - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / l_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: jnp.ndarray | None = None, *, bs: int = 512,
                     interpret: bool = False) -> jnp.ndarray:
    """q: [B, Hq, D]; k/v: [B, Hkv, S, D]; kv_len: int32 [B] or None."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bs = min(bs, s)
    assert s % bs == 0, (s, bs)
    s_steps = s // bs
    if kv_len is None:
        kv_len = jnp.full((b,), s, jnp.int32)

    qf = q.reshape(b, hkv, group, d).reshape(b * hkv, group, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b * hkv, s_steps),
        in_specs=[
            pl.BlockSpec((1, group, d), lambda h, si, lens: (h, 0, 0)),
            pl.BlockSpec((1, bs, d), lambda h, si, lens: (h, si, 0)),
            pl.BlockSpec((1, bs, d), lambda h, si, lens: (h, si, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda h, si, lens: (h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=d ** -0.5, s_steps=s_steps,
                          bs=bs, hkv=hkv),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b * hkv, group, d), q.dtype),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), qf, kf, vf)
    return out.reshape(b, hq, d)
