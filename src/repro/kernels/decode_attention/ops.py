"""Public jit'd wrapper for decode attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention
from .ref import decode_ref


def decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
           kv_len: jnp.ndarray | None = None, *, bs: int = 512) -> jnp.ndarray:
    """Single-token GQA decode; Pallas on TPU, jnp oracle elsewhere."""
    s = k.shape[2]
    if jax.default_backend() == "tpu" and s % min(bs, s) == 0:
        return decode_attention(q, k, v, kv_len, bs=bs)
    return decode_ref(q, k, v, kv_len)
