"""Pure-jnp oracle for the block GEMM kernel."""

import jax.numpy as jnp


def block_gemm_ref(a: jnp.ndarray, b: jnp.ndarray,
                   acc_dtype=jnp.float32) -> jnp.ndarray:
    """C = A @ B with f32 accumulation; result in A's dtype."""
    return jnp.dot(a, b, preferred_element_type=acc_dtype).astype(a.dtype)
