"""Tiled MXU matmul — the paper's GEMM hotspot as a Pallas TPU kernel.

TPU adaptation of the paper's per-task `C_ij += A_ik · B_kj` body: instead of
a cache-blocked CPU GEMM, the block is tiled for VMEM with an explicit
(M/bm, N/bn, K/bk) grid. K is the innermost (sequential) grid dimension so a
VMEM f32 scratch accumulator carries partial sums across K steps — HBM sees
each A/B tile exactly once per (i,j) and the C tile exactly once (written at
the last K step), which pushes arithmetic intensity into the bm·bn·bk regime
the MXU needs. Tile defaults (256, 256, 256) are multiples of the 128×128
MXU systolic array; A+B+acc tiles ≈ 768 KiB of VMEM, leaving room for
double buffering in ~16 MiB/core.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gemm_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def block_gemm(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256,
               bn: int = 256, bk: int = 256,
               interpret: bool = False) -> jnp.ndarray:
    """C[M,N] = A[M,K] @ B[K,N] (f32 accumulate, output in A's dtype)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shape ({m},{k})x({k},{n}) not divisible by tiles ({bm},{bn},{bk})")
    k_steps = k // bk

    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        # f32 VMEM accumulator carried across the sequential K dimension
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
