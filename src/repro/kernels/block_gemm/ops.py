"""Public jit'd wrapper for the block GEMM kernel.

On CPU (this container) the Pallas body runs in interpret mode for
validation; on TPU it compiles to Mosaic. `matmul` auto-selects and falls
back to the jnp oracle for shapes that do not tile cleanly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .block_gemm import block_gemm
from .ref import block_gemm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256, bn: int = 256,
           bk: int = 256) -> jnp.ndarray:
    """Drop-in `a @ b` with the Pallas path where it applies."""
    m, k = a.shape
    _, n = b.shape
    tiles_ok = (m % min(bm, m) == 0 and n % min(bn, n) == 0
                and k % min(bk, k) == 0 and m >= 8 and n >= 128 and k >= 8)
    if _on_tpu() and tiles_ok:
        return block_gemm(a, b, bm=bm, bn=bn, bk=bk)
    return block_gemm_ref(a, b)
