"""Public jit'd wrapper for the block GEMM kernel.

On CPU (this container) the Pallas body runs in interpret mode for
validation; on TPU it compiles to Mosaic. `matmul` auto-selects and falls
back to the jnp oracle for shapes that do not tile cleanly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .block_gemm import block_gemm
from .ref import block_gemm_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256, bn: int = 256,
           bk: int = 256) -> jnp.ndarray:
    """Drop-in `a @ b` with the Pallas path where it applies."""
    m, k = a.shape
    _, n = b.shape
    tiles_ok = (m % min(bm, m) == 0 and n % min(bn, n) == 0
                and k % min(bk, k) == 0 and m >= 8 and n >= 128 and k >= 8)
    if _on_tpu() and tiles_ok:
        return block_gemm(a, b, bm=bm, bn=bn, bk=bk)
    return block_gemm_ref(a, b)


def task_matmul(a: jnp.ndarray, b: jnp.ndarray, *, bm: int = 256,
                bn: int = 256, bk: int = 256) -> jnp.ndarray:
    """Per-task ``a @ b`` body for the block executor's compute step.

    Unlike :func:`matmul` this never falls back to the jnp oracle — it is
    *always* the Pallas kernel (Mosaic on TPU, interpret mode elsewhere),
    so plugging it into ``gemm_bodies(matmul=task_matmul)`` /
    ``cholesky_bodies(matmul=task_matmul)`` exercises the kernel path end
    to end. The executor vmaps task bodies over each wavefront's task
    table, and ``vmap(pallas_call)`` folds the batch into a leading grid
    dimension: all of a wavefront's trailing updates become one fused
    kernel launch. Tile sizes clamp to the block shape, so the paper-scale
    b×b task blocks run as a single-tile grid.
    """
    return block_gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=not _on_tpu())
