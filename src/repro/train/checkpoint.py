"""Sharded checkpointing with elastic restore.

Layout: one directory per step — ``<dir>/step_<n>/`` holding
  manifest.json        tree structure, dtypes, shapes, mesh, step
  arrays/<leaf>.npy    full (unsharded) array per leaf

Save gathers shards host-side (per-host file sets on a real cluster — here
one host holds everything); restore re-shards onto *any* mesh by
re-resolving the sharding rules, so scale-up/scale-down restarts work: the
mesh shape is data, not part of the checkpoint contract.

Durability: writes go to a temp dir, fsync'd, then atomically renamed;
`latest_step` only ever sees complete checkpoints. Async mode double-buffers
the host copy and hands the write to a background thread — the join
semantics mirror the paper's completion protocol (quiesce before shutdown:
``wait()`` drains in-flight writes).
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import numpy as np

import jax


def _key_str(p) -> str:
    for attr in ("key", "idx", "name"):  # DictKey / SequenceKey / GetAttrKey
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def _leaf_paths(tree) -> list:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [("/".join(_key_str(p) for p in path), leaf)
            for path, leaf in flat]


def save(ckpt_dir: str, step: int, tree: Any, *, blocking: bool = True
         ) -> Optional[threading.Thread]:
    """Write a checkpoint; returns the writer thread when non-blocking."""
    host_tree = jax.tree.map(np.asarray, tree)  # device->host (double buffer)

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(os.path.join(tmp, "arrays"), exist_ok=True)
        manifest = {"step": step, "leaves": {}}
        for name, leaf in _leaf_paths(host_tree):
            fname = name.replace("/", "__") + ".npy"
            arr = np.asarray(leaf)
            orig_dtype = str(arr.dtype)
            if arr.dtype not in (np.float64, np.float32, np.float16,
                                 np.int64, np.int32, np.int16, np.int8,
                                 np.uint8, np.uint32, np.uint64, np.bool_):
                # ml_dtypes (bfloat16, fp8) are not npy-native: store the
                # exact bit pattern as uint bytes
                arr = arr.view(np.uint8)
            np.save(os.path.join(tmp, "arrays", fname), arr)
            manifest["leaves"][name] = {
                "file": fname, "shape": list(np.asarray(leaf).shape),
                "dtype": orig_dtype}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish

    if blocking:
        write()
        return None
    t = threading.Thread(target=write, daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; when ``shardings`` is given,
    every leaf is placed sharded (elastic: any mesh shape works)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(final, "manifest.json")) as f:
        manifest = json.load(f)

    import ml_dtypes  # ships with jax

    names = [name for name, _ in _leaf_paths(like)]
    leaves = []
    for name in names:
        meta = manifest["leaves"][name]
        arr = np.load(os.path.join(final, "arrays", meta["file"]))
        want = np.dtype(getattr(ml_dtypes, meta["dtype"], meta["dtype"]))
        if arr.dtype != want:  # bit-pattern stored as uint8
            arr = arr.view(want).reshape(meta["shape"])
        leaves.append(arr)
    restored = jax.tree.unflatten(jax.tree.structure(like), leaves)
    restored = jax.tree.map(
        lambda a, l: a.astype(np.asarray(l).dtype) if hasattr(l, "dtype")
        else a, restored, like)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored


class AsyncCheckpointer:
    """Double-buffered async writer with quiesce-on-exit (the host-level use
    of the completion-detection idea: never shut down with writes in
    flight)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._inflight: list[threading.Thread] = []

    def save(self, step: int, tree: Any) -> None:
        self._inflight = [t for t in self._inflight if t.is_alive()]
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def write_then_gc():
            save(self.ckpt_dir, step, host_tree, blocking=True)
            self._gc()

        t = threading.Thread(target=write_then_gc, daemon=True)
        t.start()
        self._inflight.append(t)

    def wait(self) -> None:
        for t in self._inflight:
            t.join()
        self._inflight.clear()
        self._gc()  # writers may publish out of order; settle retention here

    def _gc(self) -> None:
        if not os.path.isdir(self.ckpt_dir):
            return
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
                       if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                          ignore_errors=True)
