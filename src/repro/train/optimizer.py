"""Optimizers in pure JAX: AdamW (fp32 states) and Adafactor (factored
second moments — the giant-MoE memory policy, see DESIGN.md §5).

Optimizer state trees mirror the param tree, so parameter shardings apply
verbatim (ZeRO: sharded states come for free from FSDP rules).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


def _layer_scanned(fn, p, *rest):
    """Run a per-leaf update under lax.scan over the stacked layer axis when
    the leaf is layer-stacked (ndim >= 3, all operands share the leading
    dim). Bounds optimizer f32 temporaries to ONE layer's worth instead of
    the whole stack (EXPERIMENTS §Perf A5: the 61-layer Adafactor update
    otherwise materializes multi-GiB f32 temps per leaf)."""
    import os

    if os.environ.get("REPRO_OPT_SCAN", "1") != "1":
        return fn(p, *rest)
    lead = p.shape[0] if p.ndim >= 3 else None
    if not lead or any(r.ndim < 1 or r.shape[0] != lead for r in rest):
        return fn(p, *rest)
    from repro.launch.flags import scan_unroll_arg

    def body(_, xs):
        return None, fn(*xs)

    _, out = jax.lax.scan(body, None, (p, *rest), unroll=scan_unroll_arg())
    return out


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def adamw_update(params, grads, state: AdamWState, *, lr=3e-4, b1=0.9,
                 b2=0.95, eps=1e-8, weight_decay=0.1):
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd_leaf(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    def upd(p, g, m, v):
        return _layer_scanned(upd_leaf, p, g, m, v)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step=step, m=new_m, v=new_v)


class AdafactorState(NamedTuple):
    step: jnp.ndarray
    vr: Any     # row second-moment factors (or full v for vectors)
    vc: Any     # col factors (zeros-like placeholder for vectors)


def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params) -> AdafactorState:
    def vr(p):
        return jnp.zeros(p.shape[:-1], jnp.float32) if _factored(p) \
            else jnp.zeros(p.shape, jnp.float32)

    def vc(p):
        return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32) \
            if _factored(p) else jnp.zeros((1,), jnp.float32)

    return AdafactorState(step=jnp.zeros((), jnp.int32),
                          vr=jax.tree.map(vr, params),
                          vc=jax.tree.map(vc, params))


def adafactor_update(params, grads, state: AdafactorState, *, lr=1e-3,
                     decay=0.8, eps=1e-30, clip=1.0):
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** -decay

    def upd_leaf(p, g, vr, vc):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p):
            vr = beta * vr + (1 - beta) * g2.mean(axis=-1)
            vc = beta * vc + (1 - beta) * g2.mean(axis=-2)
            r = vr / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)
            u = g / (jnp.sqrt(r)[..., None] * jnp.sqrt(vc)[..., None, :]
                     + eps)
        else:
            vr = beta * vr + (1 - beta) * g2
            u = g / (jnp.sqrt(vr) + eps)
        norm = jnp.sqrt(jnp.mean(u * u))
        u = u / jnp.maximum(1.0, norm / clip)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), vr, vc

    def upd(p, g, vr, vc):
        # _factored() depends only on rank, which the layer scan preserves
        # (a [L, a, b] leaf scans to [a, b] slices — still factored)
        return _layer_scanned(upd_leaf, p, g, vr, vc)

    out = jax.tree.map(upd, params, grads, state.vr, state.vc)
    pick = lambda i: jax.tree.map(lambda o: o[i], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
    return pick(0), AdafactorState(step=step, vr=pick(1), vc=pick(2))


def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(name)


def opt_state_specs(params_specs, opt_name: str, abstract_params):
    """Sharding specs for the optimizer state, derived from param specs."""
    from jax.sharding import PartitionSpec as P

    if opt_name == "adamw":
        return AdamWState(step=P(), m=params_specs, v=params_specs)

    def vr_spec(spec, p):
        entries = list(spec) + [None] * (p.ndim - len(list(spec)))
        return P(*entries[:-1]) if p.ndim >= 2 else P(*entries)

    def vc_spec(spec, p):
        if p.ndim < 2:
            return P(None)
        entries = list(spec) + [None] * (p.ndim - len(list(spec)))
        return P(*(entries[:-2] + entries[-1:]))

    vr = jax.tree.map(vr_spec, params_specs, abstract_params,
                      is_leaf=lambda x: isinstance(x, P))
    vc = jax.tree.map(vc_spec, params_specs, abstract_params,
                      is_leaf=lambda x: isinstance(x, P))
    from jax.sharding import PartitionSpec
    return AdafactorState(step=PartitionSpec(), vr=vr, vc=vc)
