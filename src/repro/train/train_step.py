"""Jittable train step: loss -> grads -> optimizer update (+ metrics).

Gradient accumulation (REPRO_MICROBATCH=k or the `microbatches` arg) splits
the global batch into k sequential microbatches inside one jitted step: all
activation-side temporaries shrink ~k x for one f32 params-sized
accumulator; compute is unchanged. The standard memory/latency knob at
scale (EXPERIMENTS §Perf A6).
"""

from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import lm_loss
from repro.train.optimizer import make_optimizer


def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4,
                    microbatches: int | None = None):
    _, update = make_optimizer(cfg.optimizer)
    mb = microbatches or int(os.environ.get("REPRO_MICROBATCH", "1"))

    def grads_of(params, batch):
        if mb <= 1:
            return jax.value_and_grad(
                lambda p: lm_loss(cfg, p, batch))(params)
        split = jax.tree.map(
            lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc(carry, mb_batch):
            l, g = jax.value_and_grad(
                lambda p: lm_loss(cfg, p, mb_batch))(params)
            carry = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), carry, g)
            return carry, l

        from repro.launch.flags import scan_unroll_arg

        grads, losses = jax.lax.scan(acc, zero, split,
                                     unroll=scan_unroll_arg())
        grads = jax.tree.map(lambda g: g / mb, grads)
        return losses.mean(), grads

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        params, opt_state = update(params, grads, opt_state, lr=lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def init_train_state(cfg: ModelConfig, key):
    from repro.models.transformer import init_params

    init_opt, _ = make_optimizer(cfg.optimizer)
    params = init_params(cfg, key)
    return params, init_opt(params)
