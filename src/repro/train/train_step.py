"""Jittable train step: loss -> grads -> optimizer update (+ metrics).

Gradient accumulation (REPRO_MICROBATCH=k or the `microbatches` arg) splits
the global batch into k sequential microbatches inside one jitted step: all
activation-side temporaries shrink ~k x for one f32 params-sized
accumulator; compute is unchanged. The standard memory/latency knob at
scale (EXPERIMENTS §Perf A6).
"""

from __future__ import annotations

import os
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import lm_loss
from repro.train.optimizer import make_optimizer


def make_train_step(cfg: ModelConfig, *, lr: float = 3e-4,
                    microbatches: int | None = None):
    _, update = make_optimizer(cfg.optimizer)
    mb = microbatches or int(os.environ.get("REPRO_MICROBATCH", "1"))

    def grads_of(params, batch):
        if mb <= 1:
            return jax.value_and_grad(
                lambda p: lm_loss(cfg, p, batch))(params)
        split = jax.tree.map(
            lambda x: x.reshape(mb, x.shape[0] // mb, *x.shape[1:]), batch)
        zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

        def acc(carry, mb_batch):
            l, g = jax.value_and_grad(
                lambda p: lm_loss(cfg, p, mb_batch))(params)
            carry = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), carry, g)
            return carry, l

        from repro.launch.flags import scan_unroll_arg

        grads, losses = jax.lax.scan(acc, zero, split,
                                     unroll=scan_unroll_arg())
        grads = jax.tree.map(lambda g: g / mb, grads)
        return losses.mean(), grads

    def train_step(params, opt_state, batch):
        loss, grads = grads_of(params, batch)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        params, opt_state = update(params, grads, opt_state, lr=lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_pipeline_train_step(cfg: ModelConfig, mesh, *, lr: float = 3e-4,
                             n_micro: int, axis: str = "pipe"):
    """Pipeline-parallel train step over a ("pipe", "data", "model") mesh.

    The transformer's layer stack is split into ``mesh.shape[axis]`` equal
    stages; microbatches flow through ``repro.dist.pipeline.pipeline_apply``
    (whose stage graph is discovered from the unified ``repro.ptg`` builder
    and lowered to per-wavefront collective permutes), with embedding and
    LM head applied outside the pipeline. Gradients flow back through the
    reversed pipeline by autodiff. Numerically identical to the sequential
    ``lm_loss`` step: same bodies, same microbatch re-assembly order.
    """
    from repro.dist.ctx import suspend_annotations
    from repro.dist.pipeline import pipeline_apply, split_microbatches
    from repro.models.layers import rms_norm
    from repro.models.transformer import _scan_segment, layer_kinds

    kinds = layer_kinds(cfg)
    if set(kinds) != {"dense"}:
        raise ValueError(
            f"pipeline parallelism supports the dense family for now, "
            f"got segments {sorted(kinds)} (family {cfg.family!r})")
    n_stages = mesh.shape[axis]
    n_layers = kinds["dense"]
    if n_layers % n_stages:
        raise ValueError(
            f"{n_layers} layers do not split into {n_stages} equal stages")
    _, update = make_optimizer(cfg.optimizer)

    def stage_fn(stage_p, x):
        return _scan_segment(cfg, "dense", stage_p, x)[0]

    def loss_fn(params, batch):
        with suspend_annotations():   # shard_map below owns the layout
            tokens = batch.get("tokens")
            x = (params["embed"][tokens] if batch.get("embeds") is None
                 else batch["embeds"])
            x = x.astype(jnp.dtype(cfg.compute_dtype))
            stage_params = jax.tree.map(
                lambda a: a.reshape(n_stages, n_layers // n_stages,
                                    *a.shape[1:]),
                params["dense"])
            xs = split_microbatches(x, n_micro)
            ys = pipeline_apply(stage_fn, stage_params, xs,
                                mesh=mesh, axis=axis)
            x = ys.reshape(x.shape)
            x = rms_norm(x, params["final_norm"], cfg.norm_eps)
            head = (params["embed"].T if cfg.tie_embeddings
                    else params["lm_head"])
            logits = x @ head.astype(x.dtype)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        mask = (labels >= 0).astype(jnp.float32)
        return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        params, opt_state = update(params, grads, opt_state, lr=lr)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def init_train_state(cfg: ModelConfig, key):
    from repro.models.transformer import init_params

    init_opt, _ = make_optimizer(cfg.optimizer)
    params = init_params(cfg, key)
    return params, init_opt(params)
