"""Elastic training control: heartbeats, straggler detection, re-mesh.

At 1000+ nodes, failures are routine. The control loop here is
host-level (it orchestrates compiled steps; it is not inside XLA):

  heartbeat  — every host reports (step, wall_time) each step; a host
               silent for `dead_after` seconds is declared failed.
  straggler  — persistent per-step outliers (> `straggler_factor` × the
               rolling median for `patience` consecutive steps) are flagged
               for replacement/drain — the cluster-granularity version of
               the paper's work stealing (within a compiled step the
               schedule is static; between steps, placement is ours).
  re-mesh    — on failure: drop to the survivor set, rebuild the mesh,
               restore the latest checkpoint re-sharded to the new topology
               (repro.train.checkpoint restores to any mesh), and continue.
               PTG mapping functions are pure functions of the *current*
               shard count, so schedules regenerate in O(local tasks).

The decision logic is pure and unit-tested; the transport (who collects
heartbeats) is the same rank-0 pattern as the paper's completion protocol.
"""

from __future__ import annotations

import time
from collections import defaultdict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence


@dataclass
class HeartbeatMonitor:
    n_hosts: int
    dead_after: float = 60.0
    last_seen: Dict[int, float] = field(default_factory=dict)

    def beat(self, host: int, now: Optional[float] = None) -> None:
        self.last_seen[host] = time.monotonic() if now is None else now

    def dead_hosts(self, now: Optional[float] = None) -> List[int]:
        now = time.monotonic() if now is None else now
        return [h for h in range(self.n_hosts)
                if now - self.last_seen.get(h, -1e30) > self.dead_after]


@dataclass
class StragglerDetector:
    straggler_factor: float = 1.5
    patience: int = 3
    window: int = 32
    _times: Dict[int, deque] = field(default_factory=lambda: defaultdict(
        lambda: deque(maxlen=32)))
    _strikes: Dict[int, int] = field(default_factory=lambda: defaultdict(int))

    def record(self, host: int, step_time: float) -> None:
        self._times[host].append(step_time)

    def _median_of_medians(self) -> float:
        meds = sorted(sorted(t)[len(t) // 2] for t in self._times.values()
                      if t)
        return meds[len(meds) // 2] if meds else 0.0

    def stragglers(self) -> List[int]:
        med = self._median_of_medians()
        if med <= 0:
            return []
        out = []
        for host, t in self._times.items():
            if t and t[-1] > self.straggler_factor * med:
                self._strikes[host] += 1
            else:
                self._strikes[host] = 0
            if self._strikes[host] >= self.patience:
                out.append(host)
        return out


@dataclass
class ElasticPlan:
    survivors: List[int]
    mesh_shape: tuple
    restore_step: Optional[int]


@dataclass
class ElasticController:
    """Live decision loop around a training step loop.

    Each step every alive host calls :meth:`beat`; the controller (rank 0
    in a real cluster) calls :meth:`poll` and gets an :class:`ElasticPlan`
    back exactly when the failed set grows — i.e. when the survivor set
    must re-mesh and restore. Hosts never heard from are not declared
    dead (same rule as the runtime's failure detector: a lease only arms
    once the host has proven alive), so a slow cold start is not a
    failure. Deaths are cumulative: once failed, a host stays failed for
    the life of the controller.
    """

    n_hosts: int
    chips_per_host: int
    model_axis: int
    dead_after: float = 60.0

    def __post_init__(self) -> None:
        self.monitor = HeartbeatMonitor(self.n_hosts, self.dead_after)
        self.stragglers = StragglerDetector()
        self.failed: List[int] = []
        self.plans: List[ElasticPlan] = []
        self._pending_admits: List[int] = []

    def admit(self, host: int) -> None:
        """Grow path: announce a new host (or re-admit a failed one). The
        lease arming rule applies unchanged — the admitted host joins the
        mesh only once it has proven alive, i.e. :meth:`poll` emits the
        grow plan at the host's first heartbeat, not at admission. Until
        then it is neither a survivor nor declarable dead (never-seen
        hosts are ignored by the failure detector)."""
        if host >= self.n_hosts:
            self.n_hosts = host + 1
            self.monitor.n_hosts = host + 1
        if host in set(self.failed):
            self.failed.remove(host)
        # a re-admitted host must re-arm its lease from scratch: a stale
        # heartbeat from before its death must not resurrect it
        self.monitor.last_seen.pop(host, None)
        if host not in self._pending_admits:
            self._pending_admits.append(host)

    def beat(self, host: int, step_time: Optional[float] = None,
             now: Optional[float] = None) -> None:
        self.monitor.beat(host, now)
        if step_time is not None:
            self.stragglers.record(host, step_time)

    def declare_failed(self, host: int, now: Optional[float] = None) -> None:
        """Out-of-band death declaration: an authoritative source (the
        runtime's completion-protocol DEATH broadcast) already knows the
        host is gone — don't wait out the lease. Expressed through the
        monitor (an infinitely stale heartbeat) so the next :meth:`poll`
        emits the shrink plan through the one normal path; the never-seen
        rule no longer protects the host because it is now "heard from"."""
        if host in set(self.failed):
            return
        self.monitor.beat(host, -1e30 if now is None else now)

    def alive(self) -> List[int]:
        return [h for h in range(self.n_hosts) if h not in set(self.failed)]

    def poll(self, latest_ckpt: Optional[int],
             now: Optional[float] = None) -> Optional[ElasticPlan]:
        newly = [h for h in self.monitor.dead_hosts(now)
                 if h in self.monitor.last_seen and h not in set(self.failed)]
        grown = [h for h in self._pending_admits
                 if h in self.monitor.last_seen]
        if not newly and not grown:
            return None
        self.failed.extend(newly)
        for h in grown:
            self._pending_admits.remove(h)
        # admitted hosts still waiting on their first heartbeat are not
        # survivors yet — the plan meshes only proven-alive capacity
        plan = plan_remesh(self.n_hosts,
                           list(self.failed) + self._pending_admits,
                           self.chips_per_host, self.model_axis, latest_ckpt)
        self.plans.append(plan)
        return plan


def plan_remesh(n_hosts: int, failed: Sequence[int], chips_per_host: int,
                model_axis: int, latest_ckpt: Optional[int]) -> ElasticPlan:
    """Largest (data × model) mesh that fits the survivor set, keeping the
    model axis fixed (TP width is a property of the arch config) and
    shrinking data parallelism — batch is re-divided by the data pipeline
    (deterministic in (seed, step), so no data is skipped or repeated)."""
    survivors = [h for h in range(n_hosts) if h not in set(failed)]
    chips = len(survivors) * chips_per_host
    if chips < model_axis:
        raise RuntimeError(
            f"survivor set too small: {chips} chips < model axis {model_axis}")
    data = chips // model_axis
    return ElasticPlan(survivors=survivors, mesh_shape=(data, model_axis),
                       restore_step=latest_ckpt)
