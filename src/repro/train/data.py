"""Deterministic, restart-safe data pipeline.

Sources:
- `SyntheticLM`: seeded on (seed, step) so any rank at any restart point
  regenerates the same batch — no data state in checkpoints beyond `step`.
- `PackedBinaryDataset`: memory-mapped uint32 token file (the standard
  pre-tokenized format), sequence-packed, sharded by (host, step).

Both yield {tokens, labels} with next-token labels; -100-style masking uses
label -1 (ignored by lm_loss).
"""

from __future__ import annotations

import os
from typing import Dict, Iterator, Optional

import numpy as np


class SyntheticLM:
    def __init__(self, vocab_size: int, seq_len: int, global_batch: int,
                 *, seed: int = 0, embed_dim: Optional[int] = None,
                 encdec: bool = False, learnable: bool = False):
        self.vocab, self.seq, self.batch = vocab_size, seq_len, global_batch
        self.seed = seed
        self.embed_dim = embed_dim
        self.encdec = encdec
        self.learnable = learnable

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.seed, step))
        if self.learnable:
            # arithmetic progressions mod vocab: next-token is a simple
            # learnable function -> loss visibly drops in a few steps
            start = rng.integers(0, self.vocab, (self.batch, 1))
            stride = rng.integers(1, 7, (self.batch, 1))
            idx = np.arange(self.seq + 1)[None, :]
            toks = ((start + stride * idx) % self.vocab).astype(np.int32)
        else:
            toks = rng.integers(0, self.vocab,
                                (self.batch, self.seq + 1), dtype=np.int32)
        out: Dict[str, np.ndarray] = {}
        if self.embed_dim and not self.encdec:
            out["embeds"] = rng.standard_normal(
                (self.batch, self.seq, self.embed_dim)).astype(np.float32)
        else:
            out["tokens"] = toks[:, :-1]
        if self.encdec:
            out["enc_embeds"] = rng.standard_normal(
                (self.batch, self.seq, self.embed_dim)).astype(np.float32)
            out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PackedBinaryDataset:
    """uint32 token stream on disk; batches are deterministic in step."""

    def __init__(self, path: str, seq_len: int, global_batch: int):
        self.tokens = np.memmap(path, dtype=np.uint32, mode="r")
        self.seq, self.batch = seq_len, global_batch
        self.n_seqs = (len(self.tokens) - 1) // seq_len
        if self.n_seqs < global_batch:
            raise ValueError("dataset smaller than one global batch")

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        idx = (np.arange(self.batch) + step * self.batch) % self.n_seqs
        starts = idx * self.seq
        toks = np.stack([self.tokens[s:s + self.seq + 1] for s in starts])
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    @staticmethod
    def write(path: str, tokens: np.ndarray) -> None:
        tokens.astype(np.uint32).tofile(path)
