"""Tree-path-driven sharding rules: param/cache PartitionSpecs + sanitizing.

Params are plain pytrees (see ``models/transformer.py``); sharding attaches
here by *leaf name*, never inside model code:

- matmul weights are tensor-parallel on the "model" axis — column-parallel
  (last dim) by default, row-parallel (dim -2) for the output projections
  ``wo``/``w_out``/``shared_w_out``; whichever of the two dims the model
  axis actually divides wins, so every architecture in ``configs/`` gets a
  real sharding for its large matrices;
- the embedding shards its vocab dim (falling back to d_model for
  non-divisible vocabularies);
- MoE expert banks are expert-parallel when n_experts divides the model
  axis (deepseek: 256/16) and shard the expert hidden dim otherwise
  (grok: 8 experts, d_ff/16);
- norms, biases, and other small vectors replicate.

Decode caches shard KV heads on "model" when the architecture has enough of
them. An arch with fewer KV heads than the model axis (yi-6b: 4 < 16)
*replicates* KV heads up to the axis (``kv_head_pad``) so the cache keeps
head sharding — the sequence-dim fallback made XLA fully rematerialize the
cache around every per-token ``dynamic_update_slice`` (the `launch.serve`
regression in ROADMAP). Only when no even replication exists does the
sequence fallback remain.

``sanitize_spec`` reconciles an intended spec with a concrete shape and
mesh: axis names the mesh lacks are dropped, and a dim that cannot divide
the assigned axis product drops names rightmost-first (so a ("pod", "data")
batch entry degrades to "pod" before replicating).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

Axes = Union[None, str, Tuple[str, ...]]

# leaf names that always replicate (norm scales, small biases, SSM scalars)
_REPLICATED = {
    "final_norm", "enc_norm", "ln", "ln1", "ln2", "ln_cross",
    "q_ln", "kv_ln", "q_norm", "k_norm", "norm_w",
    "router_bias", "conv_b", "a_log", "d_skip", "dt_bias",
}

# output projections: row-parallel (prefer sharding dim -2)
_ROW_PARALLEL = {"wo", "w_out", "shared_w_out"}


def _matmul_spec(shape: Sequence[int], model_axis: int,
                 *, prefer_last: bool = True) -> P:
    """Shard one of the two trailing matmul dims on "model" — the preferred
    dim if it divides, the other as fallback, the preferred regardless if
    neither does (sanitize_specs drops it against a concrete mesh later)."""
    nd = len(shape)
    dims = (-1, -2) if prefer_last else (-2, -1)
    pick = dims[0]
    for d in dims:
        if shape[d] % model_axis == 0:
            pick = d
            break
    entries = [None] * nd
    entries[pick] = "model"
    return P(*entries)


def param_specs(cfg: ModelConfig, *, model_axis: int = 16) -> Any:
    """PartitionSpec pytree matching ``transformer.abstract_params(cfg)``."""
    from repro.models import transformer as tfm

    abstract = tfm.abstract_params(cfg)

    def rule(path, leaf):
        keys = [p.key if hasattr(p, "key") else str(p) for p in path]
        name = keys[-1]
        nd = leaf.ndim
        if name in _REPLICATED or nd <= 1:
            return P()
        if name == "embed":
            vocab, d = leaf.shape
            return P("model", None) if vocab % model_axis == 0 \
                else P(None, "model")
        if "moe" in keys[:-1] and nd == 4 and name in ("w_in", "w_out",
                                                       "w_gate"):
            # stacked expert banks [L, E, d, f] / [L, E, f, d]
            if leaf.shape[1] % model_axis == 0:       # expert parallelism
                return P(None, "model", None, None)
            return _matmul_spec(leaf.shape, model_axis,
                                prefer_last=name != "w_out")
        if name == "router":
            # [L, d, E]: shard experts when possible, else the input dim
            return _matmul_spec(leaf.shape, model_axis)
        return _matmul_spec(leaf.shape, model_axis,
                            prefer_last=name not in _ROW_PARALLEL)

    return jax.tree_util.tree_map_with_path(rule, abstract)


def kv_head_pad(cfg: ModelConfig, model_axis: int) -> int:
    """Replication factor lifting the KV-head dim to the model axis.

    GQA repeats KV heads across the query-head group anyway, so replicating
    each head ``r`` times (cache laid out as ``repeat(kv, r, axis=heads)``)
    changes no attention output while making the head dim divisible by the
    model axis — head sharding survives, and the per-token cache update
    stays local to the shard instead of rematerializing a sequence-sharded
    buffer. Returns 1 when the cache already shards (Hkv % axis == 0) or no
    even replication exists (axis % Hkv != 0, or the padded group would not
    divide the query heads).

    The trade: the replicated cache is ``r``× larger per device than the
    sequence-sharded fallback it replaces (yi-6b decode_32k: 4×, still
    fitting at 12.9 GB temp per the dryrun memory analysis — the gate any
    new shape must pass). Spend HBM to kill the per-token full
    rematerialization; check the ``fits_16gb`` roofline column when adding
    bigger batch × context cells."""
    hkv = max(cfg.n_kv_heads, 1)
    if hkv % model_axis == 0 or model_axis % hkv != 0:
        return 1
    if cfg.n_heads % model_axis != 0:
        return 1
    return model_axis // hkv


def cache_specs(cfg: ModelConfig, cache: Any, batch_axes: Axes, *,
                model_axis: int = 16) -> Any:
    """Spec pytree matching a ``transformer.DecodeCache`` (or its
    ``eval_shape``): KV caches [L, B, Hkv, S, hd] shard heads on "model"
    when Hkv divides the model axis and fall back to sharding the sequence
    dim otherwise; MLA latent caches [L, B, S, r] and SSM states shard
    their large inner dims."""
    bn = batch_axes
    mla = cfg.attention == "mla"

    def attn_rule(leaf):
        if leaf.ndim == 5:                 # [L, B, Hkv, S, hd]
            if leaf.shape[2] % model_axis == 0:
                return P(None, bn, "model", None, None)
            return P(None, bn, None, "model", None)  # seq fallback
        if leaf.ndim == 4 and mla:         # MLA latents [L, B, S, r]
            return P(None, bn, "model", None)
        return P(*([None] * max(leaf.ndim - 1, 0)), bn) if leaf.ndim else P()

    def ssm_rule(leaf):
        if leaf.ndim == 5:                 # [L, B, nh, N, hd]: shard heads
            return P(None, bn, "model", None, None)
        if leaf.ndim == 4:                 # conv [L, B, d_conv-1, conv_dim]
            return P(None, bn, None, "model")
        return P()

    layers = {}
    for key, sub in cache.layers.items():
        layers[key] = jax.tree.map(ssm_rule if key == "ssm" else attn_rule,
                                   sub)
    return type(cache)(pos=P(), layers=layers)


def sanitize_spec(spec: P, shape: Sequence[int],
                  axis_sizes: Dict[str, int]) -> P:
    """Reconcile ``spec`` with a concrete ``shape``: pad to the shape's
    rank, drop axis names missing from ``axis_sizes``, and for each dim
    drop names rightmost-first until the dim divides the assigned product.
    Single-name tuples collapse to the bare name."""
    entries = list(spec)[: len(shape)]
    entries += [None] * (len(shape) - len(entries))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        names = [n for n in (entry if isinstance(entry, tuple) else (entry,))
                 if n in axis_sizes]
        while names and dim % math.prod(axis_sizes[n] for n in names) != 0:
            names.pop()
        if not names:
            out.append(None)
        elif len(names) == 1:
            out.append(names[0])
        else:
            out.append(tuple(names))
    return P(*out)


def sanitize_specs(specs: Any, abstract: Any, mesh: Mesh) -> Any:
    """Tree-wide :func:`sanitize_spec` of a spec pytree against the matching
    abstract-value pytree and a concrete mesh."""
    sizes = dict(mesh.shape)
    return jax.tree.map(
        lambda s, x: sanitize_spec(s, x.shape, sizes), specs, abstract,
        is_leaf=lambda x: isinstance(x, P))


def named_shardings(mesh: Mesh, specs: Any) -> Any:
    """Spec pytree -> NamedSharding pytree on ``mesh`` (the jit/device_put
    form every launcher needs)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def batch_axis(mesh: Mesh, global_batch: int) -> Axes:
    """The mesh axes the global batch shards over: all data-parallel axes
    present in the mesh (("pod", "data") order), degraded rightmost-first
    until the batch divides — None when it cannot shard at all."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    while axes and global_batch % math.prod(mesh.shape[a] for a in axes) != 0:
        axes.pop()
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)
