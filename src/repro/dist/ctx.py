"""Ambient mesh/sharding context — model code stays mesh-agnostic.

Launchers (`repro.launch.*`) pick a mesh and declare two global policies:
which mesh axes shard the batch (``set_batch_axes``) and whether the
sequence dim is sharded between layers (``set_seq_shard`` — sequence
parallelism, only legal when the model-axis size divides seq_len). Model code
never sees the mesh; it calls ``annotate(x, spec)`` at layout boundaries,
which is the identity until a mesh is active and a *sanitized* sharding
constraint afterwards — so the same forward runs on one CPU device, forced
host devices, or a production pod unchanged.
"""

from __future__ import annotations

import contextlib
from typing import Iterator, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Union[None, str, Tuple[str, ...]]

_state = {"mesh": None, "batch_axes": None, "seq_shard": False}


def get_mesh() -> Optional[Mesh]:
    """The mesh installed by ``use_mesh``, or None outside any context."""
    return _state["mesh"]


@contextlib.contextmanager
def use_mesh(mesh: Mesh) -> Iterator[Mesh]:
    """Install ``mesh`` as the ambient mesh (re-entrant, restores on exit).

    Also enters the mesh's own context so bare-``PartitionSpec`` jax APIs
    resolve axis names while the block is active.
    """
    prev = _state["mesh"]
    _state["mesh"] = mesh
    try:
        with mesh:
            yield mesh
    finally:
        _state["mesh"] = prev


@contextlib.contextmanager
def suspend_annotations() -> Iterator[None]:
    """Trace a region with ``annotate`` as the identity (ambient mesh
    hidden), without leaving the mesh's axis-name context.

    Needed when model code runs *inside* an explicit ``shard_map`` (the
    pipeline-parallel train step): all mesh axes are manual there, so a
    ``with_sharding_constraint`` on the ambient mesh is both illegal and
    meaningless — the shard_map's own specs already fix the layout.
    """
    prev = _state["mesh"]
    _state["mesh"] = None
    try:
        yield
    finally:
        _state["mesh"] = prev


def set_batch_axes(axes: Axes) -> None:
    """Declare the mesh axes the global batch shards over (e.g. ("pod",
    "data")), as computed by :func:`repro.dist.sharding.batch_axis`."""
    _state["batch_axes"] = axes


def batch_axes() -> Axes:
    return _state["batch_axes"]


def set_seq_shard(on: bool) -> None:
    """Enable sequence parallelism for inter-layer activations."""
    _state["seq_shard"] = bool(on)


def seq_shard() -> bool:
    return _state["seq_shard"]


def data_rows() -> int:
    """Number of data-parallel rows = product of the batch-axis sizes (the
    R in the MoE [R, T, D] row decomposition); 1 with no mesh/batch axes."""
    mesh, axes = _state["mesh"], _state["batch_axes"]
    if mesh is None or axes is None:
        return 1
    names = axes if isinstance(axes, tuple) else (axes,)
    rows = 1
    for name in names:
        rows *= mesh.shape.get(name, 1)
    return rows


def act_spec() -> P:
    """Layout of inter-layer activations [B, S, D]: batch over the batch
    axes, sequence over "model" when sequence parallelism is on, D whole."""
    return P(batch_axes(), "model" if _state["seq_shard"] else None, None)


def annotate(x: jax.Array, spec: P) -> jax.Array:
    """Constrain ``x`` to ``spec`` on the ambient mesh; identity without one.

    The spec is sanitized against the concrete shape first (axes the shape
    cannot divide — or that the mesh lacks — are dropped), so annotation
    sites can state the *intended* production layout and still lower on
    small dev meshes and reduced configs.
    """
    mesh = get_mesh()
    if mesh is None:
        return x
    from repro.dist.sharding import sanitize_spec

    spec = sanitize_spec(spec, x.shape, dict(mesh.shape))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
