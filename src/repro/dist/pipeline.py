"""Stage-parallel (pipeline) execution lowered from PTG discovery.

The pipeline is expressed through the unified ``repro.ptg`` builder as the
same kind of parametrized task graph every app declares: task (s, m) =
"stage s applied to microbatch m" writes activation block ("act", s, m)
and reads ("act", s-1, m) (the hand-off), with an ``after`` control edge
(s, m-1) (a stage is a serial resource) — the edge functions are derived,
not hand-written. ``discover`` levels this PTG into the familiar GPipe
trapezoid — wavefront(s, m) = s + m, depth = n_stages + n_micro - 1 — and
its ``comm_plan(w)`` is exactly the set of (s, s+1) stage hand-offs live at
step w, each a fused buffer per (src, dst) pair. The lockstep lowering here
turns every wavefront into compute + one collective permute over that
plan's pairs — with maximal runs of equal permutation folded into
``jax.lax.scan`` (the segmented-scan policy of `core.schedule`, via the
shared ``segment_runs``), so deep pipelines emit O(n_stages) HLO — and the
host PTG runtime, the block executor (`core.schedule`), and this pipeline
all derive communication from one planning layer.

Backward runs by autodiff: the transpose of a collective permute is the
reversed permute, so the gradient pipeline is the forward trapezoid
mirrored — no hand-written schedule needed.
"""

from __future__ import annotations

from typing import Any, Callable, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map  # jax >= 0.5
except ImportError:  # pragma: no cover — older jax keeps it experimental
    from jax.experimental.shard_map import shard_map

from repro.core.discovery import PTG, WavefrontSchedule, segment_runs
from repro.ptg import Graph, IndexSpace


def pipeline_graph(n_stages: int, n_micro: int) -> Graph:
    """The pipeline as a declarative ``repro.ptg`` graph: task (s, m) writes
    activation block ("act", s, m) and reads the previous stage's hand-off
    ("act", s-1, m); the serial-resource edge (s, m-1) is a pure control
    ``after`` edge. Hand-off data deps, stage sequencing, and the single
    seed (0, 0) all derive from those declarations. Task keys stay the
    legacy (stage, micro) tuples. The (stage, micro) space is partitionable
    by stage, so each stage's ``derive_local`` pass 1 enumerates its own
    microbatch row instead of scanning the whole trapezoid."""
    g = Graph("pipeline", n_shards=n_stages, owner=lambda blk: blk[1])
    g.task_type(
        "stage",
        space=IndexSpace(
            lambda: ((s, m) for s in range(n_stages)
                     for m in range(n_micro)),
            lambda shard: ((shard, m) for m in range(n_micro)),
            size=n_stages * n_micro),
        key=lambda s, m: (s, m),
        writes=lambda s, m: ("act", s, m),
        reads=lambda s, m: [("act", s - 1, m)] if s else [],
        after=lambda s, m: [(s, m - 1)] if m else [])
    return g


def pipeline_ptg(n_stages: int, n_micro: int) -> PTG:
    """The pipeline's parametrized task graph; task keys are (stage, micro)."""
    return pipeline_graph(n_stages, n_micro).to_ptg()


def pipeline_schedule(n_stages: int, n_micro: int) -> WavefrontSchedule:
    """Discover + level the pipeline PTG (one shard per stage), through the
    default lazy per-shard derivation — each stage derives only its own
    (s, m) tasks plus the neighbor hand-offs, never the full trapezoid.
    Validation is on: the builder guarantees mutual-inverse edges by
    construction, and ``check_consistency`` re-asserts it over every
    discovered task (cheap at stage-graph sizes)."""
    return pipeline_graph(n_stages, n_micro).to_schedule(validate=True)


def schedule_depth(n_stages: int, n_micro: int) -> int:
    """Pipeline depth in wavefronts — the PTG-derived GPipe bubble:
    n_stages + n_micro - 1."""
    return pipeline_schedule(n_stages, n_micro).n_wavefronts


def split_microbatches(batch: Any, n_micro: int) -> Any:
    """Reshape every leaf [B, ...] -> [n_micro, B // n_micro, ...]."""

    def split(x):
        b = x.shape[0]
        if b % n_micro:
            raise ValueError(f"batch {b} not divisible by {n_micro} microbatches")
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])

    return jax.tree.map(split, batch)


def _stage_perms(sched: WavefrontSchedule) -> List[List[Tuple[int, int]]]:
    """Per-wavefront collective-permute patterns from the schedule's
    classified comm plan (each (src, dst) pair carries one batched buffer).

    The pipeline PTG's hand-offs are the extreme sparse case: every
    wavefront's :class:`~repro.core.discovery.CommPattern` is one partial
    permutation of multiplicity 1 (density ~ 1/n), so the lowering is a
    single ``ppermute`` round — the same sparse path the block executor
    picks below its density threshold. Checked here so a pipeline PTG
    change that breaks the single-round shape fails loudly instead of
    silently dropping hand-offs."""
    perms = []
    for w in range(sched.n_wavefronts):
        pat = sched.comm_pattern(w)
        rounds = pat.rounds()
        if pat.max_pair > 1 or len(rounds) > 1:
            raise ValueError(
                f"wavefront {w}: stage hand-offs must form one multiplicity-1"
                f" permutation round, got {pat.pair_counts}")
        # overlap structure: stage 0 feeds from the host batch (the only
        # halo-independent work per wavefront); every later stage consumes
        # the previous wavefront's hand-off. The lockstep loop below relies
        # on exactly this split.
        for shard, (indep, _dep) in enumerate(sched.halo_split(w)):
            if shard > 0 and indep:
                raise ValueError(
                    f"wavefront {w}: stage {shard} has halo-independent "
                    f"tasks {indep}; pipeline stages must feed from the "
                    "previous stage's hand-off")
        perms.append(list(rounds[0]) if rounds else [])
    return perms


def pipeline_apply(stage_fn: Callable[[Any, jax.Array], jax.Array],
                   stage_params: Any, xs: jax.Array, *, mesh: Mesh,
                   axis: Optional[str] = None,
                   scan_runs: bool = True) -> jax.Array:
    """Run ``n_micro`` microbatches through a stage-parallel pipeline.

    ``stage_params``: pytree whose leaves stack per stage on dim 0 (length =
    mesh axis size); ``xs``: [n_micro, mb, ...] microbatched inputs;
    returns [n_micro, mb, ...] = stage_{S-1}(... stage_0(xs)), numerically
    identical to applying the stages sequentially. Differentiable.

    The lowering uses the block executor's segmentation policy: maximal
    runs of equal hand-off permutation (``segment_runs`` over the per-
    wavefront comm patterns) each become one ``jax.lax.scan``. The GPipe
    trapezoid has ~``2·n_stages`` distinct ramp wavefronts around one
    steady-state run of length ``n_micro - n_stages + 2``, so a *deep*
    pipeline (many microbatches) emits O(n_stages) HLO instead of
    O(n_stages + n_micro) — the stage-graph analogue of the segmented-scan
    executor. ``scan_runs=False`` forces the fully unrolled lowering.
    """
    axis = axis or mesh.axis_names[0]
    n_stages = mesh.shape[axis]
    n_micro = xs.shape[0]
    sched = pipeline_schedule(n_stages, n_micro)
    perms = _stage_perms(sched)

    def run(p_local, xs_full):
        idx = jax.lax.axis_index(axis)
        p = jax.tree.map(lambda a: a[0], p_local)
        recv = jnp.zeros(xs_full.shape[1:], xs_full.dtype)
        outs = jnp.zeros_like(xs_full)

        def wavefront(w, recv, outs, perm):
            m = w - idx                       # microbatch at this stage now
            m_c = jnp.clip(m, 0, n_micro - 1)
            x_in = jnp.where(idx == 0, xs_full[m_c], recv)
            y = stage_fn(p, x_in).astype(xs_full.dtype)
            active = (m >= 0) & (m < n_micro)
            done = active & (idx == n_stages - 1)
            outs = outs.at[m_c].set(jnp.where(done, y, outs[m_c]))
            if perm:                          # the wavefront's fused hand-off
                recv = jax.lax.ppermute(y, axis, perm)
            return recv, outs

        for start, stop in segment_runs([tuple(p_) for p_ in perms]):
            perm = list(perms[start])         # constant within the run
            if not scan_runs or stop - start == 1:
                for w in range(start, stop):
                    recv, outs = wavefront(w, recv, outs, perm)
            else:
                def step(carry, w, _perm=tuple(perms[start])):
                    r, o = wavefront(w, carry[0], carry[1], list(_perm))
                    return (r, o), None

                (recv, outs), _ = jax.lax.scan(
                    step, (recv, outs), jnp.arange(start, stop))
        # only the last stage holds real outputs; broadcast to all shards
        outs = jnp.where(idx == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    return shard_map(run, mesh=mesh, in_specs=(P(axis), P()),
                     out_specs=P())(stage_params, xs)


def pipeline_loss_fn(stage_fn: Callable[[Any, jax.Array], jax.Array],
                     loss_fn: Callable[[jax.Array, jax.Array], jax.Array],
                     *, mesh: Mesh, n_micro: int,
                     axis: Optional[str] = None):
    """``loss(stage_params, batch_x, batch_y)`` through the pipeline —
    microbatches the batch, pipelines the forward, applies ``loss_fn`` on
    the re-assembled outputs; grads flow back through the reversed
    pipeline by autodiff."""

    def loss(stage_params, batch_x, batch_y):
        xs = split_microbatches(batch_x, n_micro)
        ys = pipeline_apply(stage_fn, stage_params, xs, mesh=mesh, axis=axis)
        yh = ys.reshape(batch_x.shape[0], *ys.shape[2:])
        return loss_fn(yh, batch_y)

    return loss
