"""repro.dist — the sharding substrate binding models to a device mesh.

Architecture (PTG → discovery → WavefrontSchedule → dist exchange plan):
an application describes its work as a parametrized task graph
(`core.discovery.PTG`); `discover()` expands the DAG shard-locally via
symbolic active messages and levels it into a `WavefrontSchedule`, whose
``comm_plan(w)`` batches every cross-shard edge of wavefront *w* into one
fused buffer per (src, dst) pair — the compiled analogue of the paper's
large-AM copy avoidance. This package is the layer that binds those
schedules (and ordinary pytree programs) to a concrete ``jax`` device mesh:

- :mod:`repro.dist.ctx` — ambient mesh/sharding context. Model code stays
  mesh-agnostic pytree-in/pytree-out and only calls ``annotate(x, spec)``;
  with no mesh active that is the identity, under ``use_mesh`` it becomes a
  sanitized ``with_sharding_constraint``. Launchers set the batch axes and
  sequence-sharding policy once; ``act_spec()``/``data_rows()`` derive the
  rest.
- :mod:`repro.dist.sharding` — tree-path-driven spec derivation:
  ``param_specs`` walks the abstract parameter pytree and assigns
  tensor-parallel ``PartitionSpec``s by leaf name, ``cache_specs`` shards
  decode caches (KV-head sharding with a sequence-dim fallback when the
  architecture has fewer KV heads than the model axis), and
  ``sanitize_spec``/``sanitize_specs`` drop mesh axes a concrete shape
  cannot divide (rightmost-first inside tuple entries).
- :mod:`repro.dist.pipeline` — stage-parallel execution lowered from the
  *same* discovery layer: the GPipe-style pipeline PTG is leveled by
  ``discover`` and each wavefront's cross-stage transfers are exactly the
  ``comm_plan`` pairs, lowered to one collective permute per wavefront, so
  the host PTG runtime, the block executor, and the pipeline share one
  communication-planning layer.
"""
