"""Serve steps: prefill (prompt forward) and decode (one token vs cache)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return tfm.prefill(cfg, params,
                           tokens=batch.get("tokens"),
                           embeds=batch.get("embeds"),
                           enc_embeds=batch.get("enc_embeds"))
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step; greedy next-token included so the step is a complete
    serving unit (logits never leave the device)."""
    def serve_step(params, token, cache):
        logits, cache = tfm.decode_step(cfg, params, token, cache)
        next_token = jnp.argmax(logits.astype(jnp.float32), axis=-1)
        return next_token.astype(jnp.int32), logits, cache
    return serve_step
