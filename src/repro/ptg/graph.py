"""Declarative PTG builder — one graph definition, two lowerings.

TaskTorrent's headline API is a *single* parametrized task graph
(``set_indegree`` / ``set_task`` / ``set_mapping``, §II-A) from which the
distributed DAG is discovered in parallel. Hand-writing that PTG for the
compiled layer means supplying ``in_deps`` AND ``out_deps`` and keeping
them mutual inverses by eye — get one edge wrong and the payload it should
carry is silently never sent. This module derives both sides from what an
application can state declaratively (the Specx/StarPU data-access model,
arXiv 2308.15964):

- **task types** over typed index spaces (``task_type(name, space=...)``);
- per task, the block it ``writes`` and the blocks it ``reads`` (ordered —
  this is the compute body's operand list), plus optional ``after`` edges
  for pure control sequencing (staged send chains, serial resources);
- a ``Graph``-level ``owner`` mapping blocks to shards ("owner computes":
  a task runs on the shard owning the block it writes).

Dependency derivation runs the classic sequential-semantics access scan
(RAW / WAR / WAW hazards over the program order), recording every edge
**from both ends at once** — so ``in_deps`` and ``out_deps`` are mutual
inverses *by construction*, and ``indegree``, ``operands``, ``block_of``,
and the seed set all fall out of the same declarations. The derived edge
functions reproduce the hand-written specs of every app in this repo
exactly (task-for-task, edge-for-edge, order-for-order — asserted by
``tests/test_ptg_builder.py`` against frozen legacy copies).

Derivation comes in two flavors:

- **lazy per-shard** (:meth:`Graph.derive_local`, the default lowering
  path): each shard derives edges only for its *owned tasks + halo* — the
  frontier one ``reads``/``writes`` overlap away — so no rank ever
  materializes the global edge dicts, matching the paper's claim that the
  DAG is "completely distributed and discovered in parallel";
- **eager global** (:meth:`Graph.build`): the full scan over the whole
  index space, kept as the statically queryable form and as the validation
  oracle the lazy path is proven edge-for-edge identical to
  (``tests/test_lazy_discovery.py``).

One ``Graph`` then lowers to **both** back-ends:

- ``to_taskflow(ctx, store, bodies)`` — the host runtime: a ``Taskflow``
  whose fulfill/active-message wiring is generated from the derived
  out-edges (``run_host`` is the multi-rank convenience wrapper);
- ``to_block_spec()`` / ``to_program()`` — the compiled executor:
  a :class:`~repro.core.schedule.BlockPTGSpec` fed through parallel
  discovery and the classified comm-plan lowering.

For *unbounded* index spaces (where enumeration is impossible) write the
``PTG`` directly with a user-supplied inverse rule and validate it with
:func:`checked_ptg` / :meth:`PTG.check_consistency` — the sampled form of
the same guarantee.
"""

from __future__ import annotations

from typing import (Callable, Dict, Hashable, Iterable, List, Optional,
                    Sequence, Tuple)

import jax.numpy as jnp

from repro.core.discovery import (PTG, WavefrontSchedule, discover,
                                  discover_local, union_ptg)

K = Hashable  # task key (as the app knows it, e.g. ("gemm", i, k, j))
B = Hashable  # block id


class LocalView:
    """One shard's lazily derived slice of a :class:`Graph`'s PTG.

    Produced by :meth:`Graph.derive_local`: edge dicts exist **only** for
    the tasks this shard owns; remote tasks appear solely as keys inside
    those edge lists (plus their ``mapping``, so discovery can route
    fulfillments without asking any other shard). Invariant, asserted by
    ``tests/test_lazy_discovery.py``: for every owned task the stored
    ``in_deps`` / ``out_deps`` / ``operands`` / ``block_of`` / ``type_of``
    / ``mapping`` are value- and order-identical to what the eager
    :meth:`Graph.build` derives for that task.

    ``stats`` quantifies the laziness (what `benchmarks/discovery_scaling`
    tracks): ``n_owned`` / ``n_halo`` scanned tasks, ``derived_edges``
    (edge-list entries stored — the peak, since derivation only appends),
    ``n_relevant_blocks`` (blocks whose access state was tracked),
    ``n_tasks_global`` (index-space size, for the ratio columns), and
    ``pass1_scanned`` (tasks whose access functions pass 1 evaluated:
    the whole space for an opaque callable, only the shard's strip for a
    partitionable :class:`IndexSpace`).
    """

    def __init__(self, graph_name: str, shard: int, n_shards: int):
        self.graph_name = graph_name
        self.shard = shard
        self.n_shards = n_shards
        self.tasks: List[K] = []     # owned tasks, program order
        self.seeds: List[K] = []     # owned zero-indegree tasks, program order
        self.pos: Dict[K, int] = {}  # owned task -> global program position
        self.stats: Dict[str, int] = {}
        self._in: Dict[K, List[K]] = {}
        self._out: Dict[K, List[K]] = {}
        self._operands: Dict[K, List[B]] = {}
        self._block: Dict[K, B] = {}
        self._type: Dict[K, str] = {}
        self._map: Dict[K, int] = {}  # owned AND halo tasks
        self._ext: Dict[K, List[B]] = {}     # owned -> external-read blocks
        self._payload: Dict[K, set] = {}     # owned -> consumers reading it
        # relevant block -> its last writer in program order (owned or halo)
        self.final_writes: Dict[B, K] = {}

    def _get(self, table: Dict[K, object], k: K, what: str):
        try:
            return table[k]
        except KeyError:
            raise KeyError(
                f"task {k!r}: no {what} on shard {self.shard} of graph "
                f"{self.graph_name!r} (not an owned task of this view)")

    def in_deps(self, k: K) -> Sequence[K]:
        """Dependencies of owned task ``k`` (same order as the eager scan:
        RAW in operand order, WAR, WAW, then ``after`` control edges)."""
        return self._get(self._in, k, "in_deps")

    def out_deps(self, k: K) -> Sequence[K]:
        """Consumers owned task ``k`` fulfills (data consumers in program
        order, then control consumers) — may include remote tasks."""
        return self._get(self._out, k, "out_deps")

    def operands(self, k: K) -> Sequence[B]:
        """Blocks owned task ``k`` reads, in compute-body operand order."""
        return self._get(self._operands, k, "operands")

    def block_of(self, k: K) -> B:
        """The single block owned task ``k`` writes."""
        return self._get(self._block, k, "block_of")

    def type_of(self, k: K) -> str:
        """Task-type name of owned task ``k``."""
        return self._get(self._type, k, "type_of")

    def mapping(self, k: K) -> int:
        """Shard of ``k`` — defined for owned tasks *and* the halo tasks
        appearing in this view's edge lists (out-edge routing needs it)."""
        return self._get(self._map, k, "mapping")

    def external_reads(self, k: K) -> Sequence[B]:
        """Distinct operand blocks of owned task ``k`` with no producer
        inside this graph — the reads a one-shot execution satisfies from
        the initial store, and a stream scheduler from a block namespace
        (the previous submission's final writes)."""
        return self._get(self._ext, k, "external_reads")

    def payload_consumers(self, k: K):
        """Consumers of owned task ``k`` that *read* the block it writes —
        exactly the out-edges whose active message must carry the produced
        value (WAR/WAW/control edges carry none). Derived during the local
        scan, so a rank needs no global spec to route payloads."""
        return self._payload.get(k, frozenset())

    def __repr__(self) -> str:
        return (f"LocalView({self.graph_name!r}, shard={self.shard}, "
                f"{len(self.tasks)} owned, "
                f"{self.stats.get('n_halo', 0)} halo, "
                f"{self.stats.get('derived_edges', 0)} edges)")


class IndexSpace:
    """A typed, *partitionable* index space (or program sequence).

    A plain callable space is opaque: :meth:`Graph.derive_local`'s pass 1
    must evaluate every task's accesses across the whole program to find the
    shard's strip — an O(global) term on every rank. An ``IndexSpace``
    additionally knows its own structure (a grid, a triangular Cholesky
    space, a width×depth task grid), so each shard enumerates **only its
    strip** and pass 1 becomes O(owned).

    - ``enum()``           — full enumeration, in this space's program
      order (exactly what the plain callable did);
    - ``owned(shard)``     — only the entries whose *task* lands on
      ``shard`` under the graph's declared owner/mapping. Membership must
      be exact (derive_local cross-checks each yielded task's shard and
      raises on a stray); order is free — pass 1 only builds sets;
    - ``size``             — optional total entry count (stats only).

    Used either as a per-type ``space=`` (entries are index tuples) or as
    the ``Graph.sequence`` program (entries are ``(type_name, *index)``).
    ``enumerate_owned`` returns ``None`` when it cannot partition — e.g.
    under an ``owner_map`` override rebalancing blocks arbitrarily — and
    derivation falls back to the full scan (opaque-space behavior)."""

    def __init__(self, enum: Callable[[], Iterable],
                 owned: Callable[[int], Iterable],
                 size: Optional[int] = None):
        self._enum = enum
        self._owned = owned
        self._size = size

    def __call__(self) -> Iterable:
        return self._enum()

    def enumerate_owned(self, shard: int,
                        owner_map: Optional[Callable] = None
                        ) -> Optional[Iterable]:
        """Entries of ``shard``'s strip, or ``None`` when this space cannot
        partition under ``owner_map`` (strips are derived from the graph's
        *declared* owner; an override invalidates them)."""
        if owner_map is not None:
            return None
        return self._owned(shard)

    def __len__(self) -> int:
        if self._size is None:
            raise TypeError("IndexSpace declared without a size")
        return self._size


class TaskType:
    """One task family: an index space plus block-access declarations.

    ``writes(*idx)`` — the single block the task writes (owner computes);
    ``reads(*idx)``  — blocks read, in the compute body's operand order
                       (include the written block to read-modify-write it);
    ``after(*idx)``  — keys of *earlier* tasks to sequence behind (control
                       edges that carry no data: staged send chains, serial
                       resources);
    ``space()``      — index-tuple enumerator; its order is the sequential
                       program order unless the Graph supplies an
                       interleaved ``sequence``;
    ``key(*idx)``    — task-key override (default ``(name, *idx)``) so
                       existing key shapes survive the migration;
    ``mapping(*idx)``— shard override (default: owner of the written block).
    """

    def __init__(self, name: str, *,
                 writes: Callable[..., B],
                 reads: Optional[Callable[..., Sequence[B]]] = None,
                 after: Optional[Callable[..., Sequence[K]]] = None,
                 space: Optional[Callable[[], Iterable]] = None,
                 key: Optional[Callable[..., K]] = None,
                 mapping: Optional[Callable[..., int]] = None):
        self.name = name
        self.writes = writes
        self.reads = reads
        self.after = after
        self.space = space
        self.key = key
        self.mapping = mapping

    def key_of(self, idx: Tuple) -> K:
        return self.key(*idx) if self.key is not None else (self.name, *idx)


class Graph:
    """Declarative PTG: register task types, then lower to either back-end.

    Lowerings (:meth:`to_block_spec` / :meth:`to_schedule` /
    :meth:`to_program` / :meth:`run_host`) derive the graph **lazily per
    shard** by default (:meth:`derive_local`: owned tasks + halo only —
    the global edge dicts are never materialized). Static queries
    (``tasks``, ``seeds``, ``in_deps(k)``, ...) trigger the eager global
    :meth:`build` instead; after it the derived ``in_deps`` / ``out_deps``
    / ``operands`` / ``block_of`` / ``mapping`` / ``type_of`` behave as
    the pure functions the ``PTG`` contract expects, and ``seeds`` holds
    the zero-indegree tasks in program order. Invariant: both derivations
    agree edge-for-edge (``tests/test_lazy_discovery.py``).
    """

    def __init__(self, name: str, *, n_shards: int,
                 owner: Callable[[B], int],
                 block_shape: Tuple[int, int] = (1, 1),
                 dtype=jnp.float32):
        self.name = name
        self.n_shards = n_shards
        self.owner = owner
        self.block_shape = block_shape
        self.dtype = dtype
        self._types: Dict[str, TaskType] = {}
        self._sequence: Optional[Callable[[], Iterable[Tuple]]] = None
        self._built = False
        self._derived = False  # any derive_local ran -> declarations frozen
        self._views: Optional[List[LocalView]] = None  # default-owner cache

    # ------------------------------------------------------- declaration

    def task_type(self, name: str, **kwargs) -> TaskType:
        """Register a task family (see :class:`TaskType` for the fields)."""
        self._check_mutable()
        if name in self._types:
            raise ValueError(f"task type {name!r} already registered")
        t = TaskType(name, **kwargs)
        self._types[name] = t
        return t

    def sequence(self, program: Callable[[], Iterable[Tuple]]) -> None:
        """Supply the sequential program order explicitly: a callable
        yielding ``(type_name, *index)`` tuples. Needed whenever types must
        interleave for sequential semantics (Cholesky's per-panel potrf /
        trsm / update rounds, Task-Bench's layer order); without it, types
        enumerate whole in registration order."""
        self._check_mutable()
        self._sequence = program

    def _check_mutable(self) -> None:
        """Declarations freeze at the first derivation — eager build OR any
        lazy per-shard derive — so no lowering can ever see stale edges
        (the lazy view cache would otherwise silently drop later
        declarations)."""
        if self._built:
            raise RuntimeError(f"graph {self.name!r} is already built")
        if self._derived:
            raise RuntimeError(
                f"graph {self.name!r} is already derived (a lowering or "
                "derive_local ran); declare every task type first")

    def _program_iter(self) -> Iterable[Tuple[TaskType, Tuple]]:
        if self._sequence is not None:
            for entry in self._sequence():
                tname = entry[0]
                if tname not in self._types:
                    raise ValueError(
                        f"sequence yielded unknown task type {tname!r}")
                yield self._types[tname], tuple(entry[1:])
            return
        for t in self._types.values():
            if t.space is None:
                raise ValueError(
                    f"task type {t.name!r} has no index space and the graph "
                    "has no sequence(); one of the two must enumerate it")
            for idx in t.space():
                yield t, idx if isinstance(idx, tuple) else (idx,)

    # -------------------------------------------------------- derivation

    def build(self) -> "Graph":
        """Derive the full edge structure (idempotent).

        Sequential-semantics access scan, exactly the STF inference
        (``repro.core.stf``) but producing a *keyed, statically queryable*
        PTG instead of an eagerly-scheduled DAG: for each task in program
        order, RAW edges from the last writer of each read block, then
        WAR/WAW edges guarding the written block, then declared ``after``
        control edges. Each edge is recorded in the producer's out-list and
        the consumer's in-list in the same step — mutual inverse by
        construction.
        """
        if self._built:
            return self
        self._in: Dict[K, List[K]] = {}
        self._operands: Dict[K, List[B]] = {}
        self._block: Dict[K, B] = {}
        self._type: Dict[K, str] = {}
        self._map: Dict[K, int] = {}
        self._tasks: List[K] = []

        last_writer: Dict[B, K] = {}
        readers: Dict[B, List[K]] = {}          # readers since last write
        out_data: Dict[K, List[K]] = {}
        out_after: Dict[K, List[K]] = {}

        for t, idx in self._program_iter():
            k = t.key_of(idx)
            if k in self._in:
                raise ValueError(f"duplicate task key {k!r}")
            blk_w = t.writes(*idx)
            rds = list(t.reads(*idx)) if t.reads is not None else []

            deps: List[K] = []
            seen = {k}                           # never self-depend
            def _add(d):
                if d is not None and d not in seen:
                    seen.add(d)
                    deps.append(d)
            for blk in rds:                      # RAW, in operand order
                _add(last_writer.get(blk))
            for r in readers.get(blk_w, ()):     # WAR
                _add(r)
            _add(last_writer.get(blk_w))         # WAW
            for d in deps:
                out_data.setdefault(d, []).append(k)

            if t.after is not None:
                for d in t.after(*idx):
                    if d not in self._in:
                        raise ValueError(
                            f"task {k!r}: after-edge {d!r} does not name an "
                            "earlier task (sequential semantics require "
                            "control edges to point backwards)")
                    if d not in seen:
                        seen.add(d)
                        deps.append(d)
                        out_after.setdefault(d, []).append(k)

            self._in[k] = deps
            self._operands[k] = rds
            self._block[k] = blk_w
            self._type[k] = t.name
            self._map[k] = (t.mapping(*idx) if t.mapping is not None
                            else self.owner(blk_w))
            self._tasks.append(k)

            last_writer[blk_w] = k
            readers[blk_w] = [k] if blk_w in rds else []
            for blk in rds:
                if blk != blk_w:
                    readers.setdefault(blk, []).append(k)

        # data consumers first (in program order), then control consumers —
        # matching the convention of the hand-written specs this replaces.
        self._out: Dict[K, List[K]] = {
            k: out_data.get(k, []) + out_after.get(k, [])
            for k in self._tasks}
        self._seeds: List[K] = [k for k in self._tasks if not self._in[k]]
        self._built = True
        return self

    def _owned_program_iter(self, shard: int,
                            owner_map: Optional[Callable[[B], int]]
                            ) -> Optional[Iterable[Tuple[TaskType, Tuple]]]:
        """Strip enumeration for :meth:`derive_local`'s pass 1: yield only
        ``shard``'s owned ``(type, index)`` pairs, via the
        :class:`IndexSpace` protocol. Returns ``None`` — meaning *fall back
        to the full scan* — unless every space (or the sequence) is
        partitionable under ``owner_map``."""
        if self._sequence is not None:
            own = getattr(self._sequence, "enumerate_owned", None)
            if own is None:
                return None
            entries = own(shard, owner_map)
            if entries is None:
                return None

            def gen():
                for entry in entries:
                    tname = entry[0]
                    if tname not in self._types:
                        raise ValueError(
                            f"owned strip yielded unknown task type {tname!r}")
                    yield self._types[tname], tuple(entry[1:])
            return gen()
        strips = []
        for t in self._types.values():
            own = getattr(t.space, "enumerate_owned", None)
            if own is None:
                return None
            entries = own(shard, owner_map)
            if entries is None:
                return None
            strips.append((t, entries))

        def gen():
            for t, entries in strips:
                for idx in entries:
                    yield t, idx if isinstance(idx, tuple) else (idx,)
        return gen()

    # ------------------------------------------- lazy per-shard derivation

    def derive_local(self, shard: int,
                     owner_map: Optional[Callable[[B], int]] = None
                     ) -> LocalView:
        """Derive ``shard``'s slice of the PTG without building the global
        graph: the same sequential-semantics access scan as :meth:`build`,
        but with per-block state (last writer, readers-since-write) and
        edge lists materialized **only** for the shard's owned tasks plus
        their halo — the frontier reachable through one ``reads``/``writes``
        overlap. Peak derived state is O(owned + halo), never O(global
        edges); this is the paper's "the DAG is discovered piece by piece,
        in parallel" applied to derivation itself.

        ``owner_map`` overrides the graph's ``owner`` for this derivation
        (e.g. a rebalanced or ragged block distribution); tasks without an
        explicit ``TaskType.mapping`` follow it. Returns a
        :class:`LocalView`; feed one view per shard to
        :func:`repro.core.discovery.discover_local` (what
        :meth:`to_schedule` / :meth:`to_block_spec` do by default).

        Why two passes: the halo block set (blocks owned tasks read) must
        be known *before* the scan — a halo block's last writer may precede
        the owned reader in program order, and a single pass would have
        skipped it. Pass 1 therefore fixes the owned-task and relevant-block
        sets; pass 2 runs the restricted scan. Correctness of the
        restriction: every edge incident to an owned task flows through a
        block that is relevant here (the task's written block, a block it
        reads, or an owned block a remote task touches), and no owned task
        ever touches an irrelevant block — so the per-block state
        trajectories, and hence the derived edges, match the global scan
        exactly.

        Pass 1's cost depends on the space: an opaque callable space forces
        the full O(global) relevance filter (evaluate every task's
        ``writes`` to test ownership), but a partitionable
        :class:`IndexSpace` lets the shard enumerate **only its strip** —
        O(owned) — and the filter disappears (``stats["pass1_scanned"]``
        records which happened). A strip entry mapping to the wrong shard
        raises immediately: a silently wrong strip would drop edges.
        """
        owner = owner_map if owner_map is not None else self.owner
        n = self.n_shards
        self._derived = True  # freeze declarations (see _check_mutable)

        # ---- pass 1: owned task keys + the halo/override block set
        owned_keys: set = set()
        extra_blocks: set = set()   # halo blocks + override-written blocks
        n_global = 0
        pass1_scanned = 0
        strip = self._owned_program_iter(shard, owner_map)
        if strip is not None:
            for t, idx in strip:
                pass1_scanned += 1
                blk_w = t.writes(*idx)
                t_shard = (t.mapping(*idx) if t.mapping is not None
                           else owner(blk_w)) % n
                if t_shard != shard:
                    raise ValueError(
                        f"index-space strip for shard {shard} yielded task "
                        f"{t.key_of(idx)!r} mapped to shard {t_shard} — the "
                        "space's enumerate_owned disagrees with the owner "
                        "mapping")
                owned_keys.add(t.key_of(idx))
                extra_blocks.add(blk_w)
                if t.reads is not None:
                    extra_blocks.update(t.reads(*idx))
        else:
            for t, idx in self._program_iter():
                n_global += 1
                pass1_scanned += 1
                blk_w = t.writes(*idx)
                t_shard = (t.mapping(*idx) if t.mapping is not None
                           else owner(blk_w)) % n
                if t_shard != shard:
                    continue
                owned_keys.add(t.key_of(idx))
                extra_blocks.add(blk_w)  # covers mapping-override ownership
                if t.reads is not None:
                    extra_blocks.update(t.reads(*idx))

        def rel(blk: B) -> bool:
            return blk in extra_blocks or owner(blk) % n == shard

        # ---- pass 2: restricted access scan (mirrors build() exactly on
        # the relevant-block subspace)
        view = LocalView(self.name, shard, n)
        last_writer: Dict[B, K] = {}
        readers: Dict[B, List[K]] = {}
        out_data: Dict[K, List[K]] = {}
        out_after: Dict[K, List[K]] = {}
        scanned: set = set()
        derived_edges = 0

        n_pass2 = 0
        for pos, (t, idx) in enumerate(self._program_iter()):
            n_pass2 += 1
            k = t.key_of(idx)
            owned = k in owned_keys
            blk_w = t.writes(*idx)
            rds = list(t.reads(*idx)) if t.reads is not None else []
            afters = (list(t.after(*idx)) if t.after is not None else [])
            if not owned and not (
                    rel(blk_w) or any(rel(b) for b in rds)
                    or any(d in owned_keys for d in afters)):
                continue
            if k in scanned:
                raise ValueError(f"duplicate task key {k!r}")
            scanned.add(k)

            deps: List[K] = []
            seen = {k}                           # never self-depend

            def _add(d):
                if d is not None and d not in seen:
                    seen.add(d)
                    deps.append(d)
            ext: List[B] = []
            for blk in rds:                      # RAW, in operand order
                p = last_writer.get(blk)
                _add(p)
                if p is not None and p in owned_keys:
                    # k reads p's written block: the p->k AM carries it
                    view._payload.setdefault(p, set()).add(k)
                elif p is None and owned and blk not in ext:
                    # no producer in this graph: an external input (every
                    # writer of a relevant block is scanned, so a missing
                    # last_writer here is global, not a restriction artifact)
                    ext.append(blk)
            for r in readers.get(blk_w, ()):     # WAR
                _add(r)
            _add(last_writer.get(blk_w))         # WAW
            for d in deps:
                if d in owned_keys:
                    out_data.setdefault(d, []).append(k)

            for d in afters:
                if d in owned_keys and d not in scanned:
                    raise ValueError(
                        f"task {k!r}: after-edge {d!r} does not name an "
                        "earlier task (sequential semantics require "
                        "control edges to point backwards)")
                if d not in seen:
                    seen.add(d)
                    deps.append(d)
                    if d in owned_keys:
                        out_after.setdefault(d, []).append(k)

            t_shard = (t.mapping(*idx) if t.mapping is not None
                       else owner(blk_w))
            view._map[k] = t_shard               # owned AND halo routing
            if owned:
                view._in[k] = deps
                derived_edges += len(deps)
                view._operands[k] = rds
                view._block[k] = blk_w
                view._type[k] = t.name
                view._ext[k] = ext
                view.pos[k] = pos
                view.tasks.append(k)

            if rel(blk_w):
                last_writer[blk_w] = k
                readers[blk_w] = [k] if blk_w in rds else []
            for blk in rds:
                if blk != blk_w and rel(blk):
                    readers.setdefault(blk, []).append(k)

        # data consumers first (program order), then control consumers —
        # the same convention as build()
        for k in view.tasks:
            out = out_data.get(k, []) + out_after.get(k, [])
            view._out[k] = out
            derived_edges += len(out)
        view.seeds = [k for k in view.tasks if not view._in[k]]
        # end-of-scan writer state: for every relevant block, the task whose
        # write survives the whole program — sound because every task that
        # touches a relevant block is scanned. The stream scheduler publishes
        # exactly these values into the submission's block namespace.
        view.final_writes = dict(last_writer)
        view.stats = {
            "n_owned": len(view.tasks),
            "n_halo": len(scanned) - len(view.tasks),
            "n_tasks_global": n_global or n_pass2,
            "derived_edges": derived_edges,
            "n_relevant_blocks": len(set(last_writer) | set(readers)),
            # tasks whose access functions pass 1 actually evaluated:
            # == n_tasks_global for an opaque space (the O(global)
            # relevance filter), == n_owned-ish for a partitionable
            # IndexSpace strip — the ratio discovery_scaling tracks.
            "pass1_scanned": pass1_scanned,
        }
        return view

    def local_views(self, owner_map: Optional[Callable[[B], int]] = None
                    ) -> List[LocalView]:
        """One :class:`LocalView` per shard (:meth:`derive_local` for every
        shard; the default-owner result is cached). On a real distributed
        system each rank would derive only its own view — deriving all of
        them here is the single-host emulation of that, and the per-view
        ``stats`` are what the distributed ranks would each pay."""
        if owner_map is not None:
            return [self.derive_local(s, owner_map)
                    for s in range(self.n_shards)]
        if self._views is None:
            self._views = [self.derive_local(s)
                           for s in range(self.n_shards)]
        return self._views

    # ---------------------------------------------------- derived queries

    def _get(self, table: str, k: K):
        self.build()
        try:
            return getattr(self, table)[k]
        except KeyError:
            raise KeyError(f"unknown task {k!r} in graph {self.name!r}")

    def in_deps(self, k: K) -> Sequence[K]:
        """Tasks ``k`` depends on — RAW in operand order, then WAR, WAW,
        and ``after`` control edges (mutual inverse of :meth:`out_deps`)."""
        return self._get("_in", k)

    def out_deps(self, k: K) -> Sequence[K]:
        """Tasks whose promises ``k`` fulfills — data consumers in program
        order, then control consumers (mutual inverse of :meth:`in_deps`)."""
        return self._get("_out", k)

    def operands(self, k: K) -> Sequence[B]:
        """Blocks ``k`` reads, in the compute body's operand order."""
        return self._get("_operands", k)

    def block_of(self, k: K) -> B:
        """The single block ``k`` writes ("owner computes" anchor)."""
        return self._get("_block", k)

    def type_of(self, k: K) -> str:
        """Name of the :class:`TaskType` that declared ``k``."""
        return self._get("_type", k)

    def mapping(self, k: K) -> int:
        """Shard ``k`` runs on: its ``TaskType.mapping`` override, else the
        owner of the block it writes."""
        return self._get("_map", k)

    def indegree(self, k: K) -> int:
        """``len(in_deps(k))`` — the promise count the runtime counts down."""
        return len(self._get("_in", k))

    @property
    def tasks(self) -> List[K]:
        """All task keys in sequential program order."""
        self.build()
        return self._tasks

    @property
    def seeds(self) -> List[K]:
        """Zero-indegree tasks in program order — the discovery roots."""
        self.build()
        return self._seeds

    @property
    def n_tasks(self) -> int:
        self.build()
        return len(self.tasks)

    # ---------------------------------------------------------- lowerings

    def to_ptg(self) -> PTG:
        """The statically queryable PTG (consistent by construction)."""
        self.build()
        return PTG(in_deps=self.in_deps, out_deps=self.out_deps,
                   mapping=self.mapping, type_of=self.type_of)

    def to_block_spec(self, *, block_shape: Optional[Tuple[int, int]] = None,
                      dtype=None, lazy: bool = True):
        """Lower to the compiled layer's application contract
        (:class:`~repro.core.schedule.BlockPTGSpec`) — feed it to
        ``build_block_program`` / ``run_host_ptg`` exactly like a
        hand-written spec.

        ``lazy=True`` (the default) derives one :class:`LocalView` per
        shard (:meth:`derive_local`) instead of building the global edge
        dicts: the spec's ``ptg`` / ``operands`` / ``block_of`` dispatch
        every query to the owning shard's view, its ``seeds`` are the
        per-view seeds merged back into global program order, and
        ``spec.views`` routes ``build_block_program`` through
        :func:`~repro.core.discovery.discover_local`. ``lazy=False`` keeps
        the eager global derivation — the validation oracle the lazy path
        is tested against (edge-for-edge, ``tests/test_lazy_discovery.py``).
        """
        from repro.core.schedule import BlockPTGSpec

        if not lazy:
            self.build()
            return BlockPTGSpec(
                ptg=self.to_ptg(), seeds=self.seeds, n_shards=self.n_shards,
                block_shape=block_shape or self.block_shape,
                block_of=self.block_of, operands=self.operands,
                owner=self.owner, dtype=dtype or self.dtype)

        views = self.local_views()
        home: Dict[K, LocalView] = {k: v for v in views for k in v.tasks}

        def _view(k: K) -> LocalView:
            try:
                return home[k]
            except KeyError:
                raise KeyError(f"unknown task {k!r} in graph {self.name!r}")

        ptg = union_ptg(views, home=home)
        seeds = [k for _, k in sorted(
            ((v.pos[k], k) for v in views for k in v.seeds),
            key=lambda e: e[0])]
        return BlockPTGSpec(
            ptg=ptg, seeds=seeds, n_shards=self.n_shards,
            block_shape=block_shape or self.block_shape,
            block_of=lambda k: _view(k).block_of(k),
            operands=lambda k: _view(k).operands(k),
            owner=self.owner, dtype=dtype or self.dtype, views=views)

    def to_program(self, *, validate: bool = False, lazy: bool = True):
        """Discover + lower to a :class:`~repro.core.schedule.BlockProgram`
        (per-wavefront tables + classified comm plan), ready for
        ``auto_executor``. ``lazy`` selects the derivation
        (:meth:`to_block_spec`); the resulting program is identical either
        way."""
        from repro.core.schedule import build_block_program

        return build_block_program(self.to_block_spec(lazy=lazy),
                                   validate=validate)

    def executor(self, bodies, mesh, axis: str = "shards", *,
                 validate: bool = False, **policy):
        """One-call compiled lowering: discover, build the program, and
        return its jittable executor under the shared auto policy
        (``BlockProgram.plan_lowering``) — unrolled below ``unroll_cap``,
        segmented scan above it (sparse exchanges at scan-sized HLO), pure
        dense scan only for genuinely dense or fragmented schedules.
        ``policy`` kwargs (``unroll_cap``/``comm``/``overlap``/
        ``segment_cap``/``density_threshold``) pass through to
        ``auto_executor``."""
        return self.to_program(validate=validate).auto_executor(
            bodies, mesh, axis, **policy)

    def to_schedule(self, *, validate: bool = False,
                    lazy: bool = True) -> WavefrontSchedule:
        """Just the parallel-discovery schedule (wavefronts + comm plan).
        ``lazy=True`` (default) discovers through per-shard
        :class:`LocalView`'s (``discover_local``); ``lazy=False`` through
        the eagerly built global PTG — identical schedules either way."""
        if lazy:
            return discover_local(self.local_views(), self.n_shards,
                                  validate=validate)
        self.build()
        return discover(self.to_ptg(), self.seeds, self.n_shards,
                        validate=validate)

    def to_taskflow(self, ctx, store, bodies, *, name: Optional[str] = None):
        """Host-runtime lowering for one emulated rank: a wired
        :class:`~repro.core.taskflow.Taskflow` whose task bodies compute on
        ``store`` and whose cross-rank out-edges send active messages, all
        generated from the derived edges. Returns ``(taskflow, seed_fn)``;
        call ``seed_fn()`` to fulfill this rank's seeds, then join the
        threadpool."""
        from repro.linalg.host_exec import wire_taskflow

        return wire_taskflow(ctx, self.to_block_spec(), store, bodies,
                             name=name or self.name)

    def run_host(self, blocks, bodies, *, n_threads: int = 2,
                 timeout: float = 120.0, faults=None, transport=None):
        """Execute on the host TaskTorrent runtime (async tasks + active
        messages) across ``n_shards`` emulated ranks; returns the written
        blocks gathered to the host. ``transport`` picks the comm backend
        the ranks run on (``inproc`` threads by default; ``multiproc``
        puts every rank in its own OS process).

        With ``faults`` (a :class:`~repro.core.faults.FaultPlan`) the run
        goes through the fault-tolerant host runtime and returns
        ``(blocks, RecoveryReport)``: shard adoption after a declared death
        re-runs :meth:`derive_local` for the moved shard only — the view's
        ``derived_edges`` over the all-shards total is the report's
        ``rederived_frac``, the measured lazy-recovery payoff."""
        from repro.linalg.host_exec import run_host_ptg

        spec = self.to_block_spec()
        if faults is None:
            return run_host_ptg(spec, blocks, bodies,
                                n_threads=n_threads, timeout=timeout,
                                transport=transport)
        total = sum(v.stats.get("derived_edges", 0)
                    for v in self.local_views())
        return run_host_ptg(spec, blocks, bodies,
                            n_threads=n_threads, timeout=timeout,
                            faults=faults, rederive=self.derive_local,
                            total_edges=total, transport=transport)

    def __repr__(self) -> str:
        state = (f"{len(self._tasks)} tasks, {len(self._seeds)} seeds"
                 if self._built else "unbuilt")
        return (f"Graph({self.name!r}, n_shards={self.n_shards}, "
                f"types={list(self._types)}, {state})")


def checked_ptg(in_deps: Callable[[K], Sequence[K]],
                out_deps: Callable[[K], Sequence[K]],
                mapping: Callable[[K], int],
                type_of: Callable[[K], str] = lambda k: "task",
                *, sample_keys: Sequence[K] = ()) -> PTG:
    """Wrap user-supplied edge rules (the unbounded-index-space escape
    hatch, where enumeration — and therefore the :class:`Graph` builder —
    is impossible) into a PTG, validating the mutual-inverse property on
    ``sample_keys`` up front. ``discover(..., validate=True)`` re-checks
    every task it actually expands."""
    ptg = PTG(in_deps=in_deps, out_deps=out_deps, mapping=mapping,
              type_of=type_of)
    if sample_keys:
        ptg.check_consistency(sample_keys)
    return ptg
