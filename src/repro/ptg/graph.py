"""Declarative PTG builder — one graph definition, two lowerings.

TaskTorrent's headline API is a *single* parametrized task graph
(``set_indegree`` / ``set_task`` / ``set_mapping``, §II-A) from which the
distributed DAG is discovered in parallel. Hand-writing that PTG for the
compiled layer means supplying ``in_deps`` AND ``out_deps`` and keeping
them mutual inverses by eye — get one edge wrong and the payload it should
carry is silently never sent. This module derives both sides from what an
application can state declaratively (the Specx/StarPU data-access model,
arXiv 2308.15964):

- **task types** over typed index spaces (``task_type(name, space=...)``);
- per task, the block it ``writes`` and the blocks it ``reads`` (ordered —
  this is the compute body's operand list), plus optional ``after`` edges
  for pure control sequencing (staged send chains, serial resources);
- a ``Graph``-level ``owner`` mapping blocks to shards ("owner computes":
  a task runs on the shard owning the block it writes).

Dependency derivation runs the classic sequential-semantics access scan
(RAW / WAR / WAW hazards over the program order) across the enumerated
index space, recording every edge **from both ends at once** — so
``in_deps`` and ``out_deps`` are mutual inverses *by construction*, and
``indegree``, ``operands``, ``block_of``, and the seed set all fall out of
the same declarations. The derived edge functions reproduce the
hand-written specs of every app in this repo exactly (task-for-task,
edge-for-edge, order-for-order — asserted by ``tests/test_ptg_builder.py``
against frozen legacy copies).

One ``Graph`` then lowers to **both** back-ends:

- ``to_taskflow(ctx, store, bodies)`` — the host runtime: a ``Taskflow``
  whose fulfill/active-message wiring is generated from the derived
  out-edges (``run_host`` is the multi-rank convenience wrapper);
- ``to_block_spec()`` / ``to_program()`` — the compiled executor:
  a :class:`~repro.core.schedule.BlockPTGSpec` fed through parallel
  discovery and the classified comm-plan lowering.

For *unbounded* index spaces (where enumeration is impossible) write the
``PTG`` directly with a user-supplied inverse rule and validate it with
:func:`checked_ptg` / :meth:`PTG.check_consistency` — the sampled form of
the same guarantee.
"""

from __future__ import annotations

from typing import (Callable, Dict, Hashable, Iterable, List, Optional,
                    Sequence, Tuple)

import jax.numpy as jnp

from repro.core.discovery import PTG, WavefrontSchedule, discover

K = Hashable  # task key (as the app knows it, e.g. ("gemm", i, k, j))
B = Hashable  # block id


class TaskType:
    """One task family: an index space plus block-access declarations.

    ``writes(*idx)`` — the single block the task writes (owner computes);
    ``reads(*idx)``  — blocks read, in the compute body's operand order
                       (include the written block to read-modify-write it);
    ``after(*idx)``  — keys of *earlier* tasks to sequence behind (control
                       edges that carry no data: staged send chains, serial
                       resources);
    ``space()``      — index-tuple enumerator; its order is the sequential
                       program order unless the Graph supplies an
                       interleaved ``sequence``;
    ``key(*idx)``    — task-key override (default ``(name, *idx)``) so
                       existing key shapes survive the migration;
    ``mapping(*idx)``— shard override (default: owner of the written block).
    """

    def __init__(self, name: str, *,
                 writes: Callable[..., B],
                 reads: Optional[Callable[..., Sequence[B]]] = None,
                 after: Optional[Callable[..., Sequence[K]]] = None,
                 space: Optional[Callable[[], Iterable]] = None,
                 key: Optional[Callable[..., K]] = None,
                 mapping: Optional[Callable[..., int]] = None):
        self.name = name
        self.writes = writes
        self.reads = reads
        self.after = after
        self.space = space
        self.key = key
        self.mapping = mapping

    def key_of(self, idx: Tuple) -> K:
        return self.key(*idx) if self.key is not None else (self.name, *idx)


class Graph:
    """Declarative PTG: register task types, then lower to either back-end.

    The graph is finalized lazily (first query or lowering triggers
    :meth:`build`); after that the derived ``in_deps`` / ``out_deps`` /
    ``operands`` / ``block_of`` / ``mapping`` / ``type_of`` behave as the
    pure functions the ``PTG`` contract expects, and ``seeds`` holds the
    zero-indegree tasks in program order.
    """

    def __init__(self, name: str, *, n_shards: int,
                 owner: Callable[[B], int],
                 block_shape: Tuple[int, int] = (1, 1),
                 dtype=jnp.float32):
        self.name = name
        self.n_shards = n_shards
        self.owner = owner
        self.block_shape = block_shape
        self.dtype = dtype
        self._types: Dict[str, TaskType] = {}
        self._sequence: Optional[Callable[[], Iterable[Tuple]]] = None
        self._built = False

    # ------------------------------------------------------- declaration

    def task_type(self, name: str, **kwargs) -> TaskType:
        """Register a task family (see :class:`TaskType` for the fields)."""
        if self._built:
            raise RuntimeError(f"graph {self.name!r} is already built")
        if name in self._types:
            raise ValueError(f"task type {name!r} already registered")
        t = TaskType(name, **kwargs)
        self._types[name] = t
        return t

    def sequence(self, program: Callable[[], Iterable[Tuple]]) -> None:
        """Supply the sequential program order explicitly: a callable
        yielding ``(type_name, *index)`` tuples. Needed whenever types must
        interleave for sequential semantics (Cholesky's per-panel potrf /
        trsm / update rounds, Task-Bench's layer order); without it, types
        enumerate whole in registration order."""
        if self._built:
            raise RuntimeError(f"graph {self.name!r} is already built")
        self._sequence = program

    def _program_iter(self) -> Iterable[Tuple[TaskType, Tuple]]:
        if self._sequence is not None:
            for entry in self._sequence():
                tname = entry[0]
                if tname not in self._types:
                    raise ValueError(
                        f"sequence yielded unknown task type {tname!r}")
                yield self._types[tname], tuple(entry[1:])
            return
        for t in self._types.values():
            if t.space is None:
                raise ValueError(
                    f"task type {t.name!r} has no index space and the graph "
                    "has no sequence(); one of the two must enumerate it")
            for idx in t.space():
                yield t, idx if isinstance(idx, tuple) else (idx,)

    # -------------------------------------------------------- derivation

    def build(self) -> "Graph":
        """Derive the full edge structure (idempotent).

        Sequential-semantics access scan, exactly the STF inference
        (``repro.core.stf``) but producing a *keyed, statically queryable*
        PTG instead of an eagerly-scheduled DAG: for each task in program
        order, RAW edges from the last writer of each read block, then
        WAR/WAW edges guarding the written block, then declared ``after``
        control edges. Each edge is recorded in the producer's out-list and
        the consumer's in-list in the same step — mutual inverse by
        construction.
        """
        if self._built:
            return self
        self._in: Dict[K, List[K]] = {}
        self._operands: Dict[K, List[B]] = {}
        self._block: Dict[K, B] = {}
        self._type: Dict[K, str] = {}
        self._map: Dict[K, int] = {}
        self._tasks: List[K] = []

        last_writer: Dict[B, K] = {}
        readers: Dict[B, List[K]] = {}          # readers since last write
        out_data: Dict[K, List[K]] = {}
        out_after: Dict[K, List[K]] = {}

        for t, idx in self._program_iter():
            k = t.key_of(idx)
            if k in self._in:
                raise ValueError(f"duplicate task key {k!r}")
            blk_w = t.writes(*idx)
            rds = list(t.reads(*idx)) if t.reads is not None else []

            deps: List[K] = []
            seen = {k}                           # never self-depend
            def _add(d):
                if d is not None and d not in seen:
                    seen.add(d)
                    deps.append(d)
            for blk in rds:                      # RAW, in operand order
                _add(last_writer.get(blk))
            for r in readers.get(blk_w, ()):     # WAR
                _add(r)
            _add(last_writer.get(blk_w))         # WAW
            for d in deps:
                out_data.setdefault(d, []).append(k)

            if t.after is not None:
                for d in t.after(*idx):
                    if d not in self._in:
                        raise ValueError(
                            f"task {k!r}: after-edge {d!r} does not name an "
                            "earlier task (sequential semantics require "
                            "control edges to point backwards)")
                    if d not in seen:
                        seen.add(d)
                        deps.append(d)
                        out_after.setdefault(d, []).append(k)

            self._in[k] = deps
            self._operands[k] = rds
            self._block[k] = blk_w
            self._type[k] = t.name
            self._map[k] = (t.mapping(*idx) if t.mapping is not None
                            else self.owner(blk_w))
            self._tasks.append(k)

            last_writer[blk_w] = k
            readers[blk_w] = [k] if blk_w in rds else []
            for blk in rds:
                if blk != blk_w:
                    readers.setdefault(blk, []).append(k)

        # data consumers first (in program order), then control consumers —
        # matching the convention of the hand-written specs this replaces.
        self._out: Dict[K, List[K]] = {
            k: out_data.get(k, []) + out_after.get(k, [])
            for k in self._tasks}
        self._seeds: List[K] = [k for k in self._tasks if not self._in[k]]
        self._built = True
        return self

    # ---------------------------------------------------- derived queries

    def _get(self, table: str, k: K):
        self.build()
        try:
            return getattr(self, table)[k]
        except KeyError:
            raise KeyError(f"unknown task {k!r} in graph {self.name!r}")

    def in_deps(self, k: K) -> Sequence[K]:
        return self._get("_in", k)

    def out_deps(self, k: K) -> Sequence[K]:
        return self._get("_out", k)

    def operands(self, k: K) -> Sequence[B]:
        return self._get("_operands", k)

    def block_of(self, k: K) -> B:
        return self._get("_block", k)

    def type_of(self, k: K) -> str:
        return self._get("_type", k)

    def mapping(self, k: K) -> int:
        return self._get("_map", k)

    def indegree(self, k: K) -> int:
        return len(self._get("_in", k))

    @property
    def tasks(self) -> List[K]:
        """All task keys in sequential program order."""
        self.build()
        return self._tasks

    @property
    def seeds(self) -> List[K]:
        """Zero-indegree tasks in program order — the discovery roots."""
        self.build()
        return self._seeds

    @property
    def n_tasks(self) -> int:
        self.build()
        return len(self.tasks)

    # ---------------------------------------------------------- lowerings

    def to_ptg(self) -> PTG:
        """The statically queryable PTG (consistent by construction)."""
        self.build()
        return PTG(in_deps=self.in_deps, out_deps=self.out_deps,
                   mapping=self.mapping, type_of=self.type_of)

    def to_block_spec(self, *, block_shape: Optional[Tuple[int, int]] = None,
                      dtype=None):
        """Lower to the compiled layer's application contract
        (:class:`~repro.core.schedule.BlockPTGSpec`) — feed it to
        ``build_block_program`` / ``run_host_ptg`` exactly like a
        hand-written spec."""
        from repro.core.schedule import BlockPTGSpec

        self.build()
        return BlockPTGSpec(
            ptg=self.to_ptg(), seeds=self.seeds, n_shards=self.n_shards,
            block_shape=block_shape or self.block_shape,
            block_of=self.block_of, operands=self.operands,
            owner=self.owner, dtype=dtype or self.dtype)

    def to_program(self, *, validate: bool = False):
        """Discover + lower to a :class:`~repro.core.schedule.BlockProgram`
        (per-wavefront tables + classified comm plan), ready for
        ``auto_executor``."""
        from repro.core.schedule import build_block_program

        return build_block_program(self.to_block_spec(), validate=validate)

    def executor(self, bodies, mesh, axis: str = "shards", *,
                 validate: bool = False, **policy):
        """One-call compiled lowering: discover, build the program, and
        return its jittable executor under the shared auto policy
        (``BlockProgram.plan_lowering``) — unrolled below ``unroll_cap``,
        segmented scan above it (sparse exchanges at scan-sized HLO), pure
        dense scan only for genuinely dense or fragmented schedules.
        ``policy`` kwargs (``unroll_cap``/``comm``/``overlap``/
        ``segment_cap``/``density_threshold``) pass through to
        ``auto_executor``."""
        return self.to_program(validate=validate).auto_executor(
            bodies, mesh, axis, **policy)

    def to_schedule(self, *, validate: bool = False) -> WavefrontSchedule:
        """Just the parallel-discovery schedule (wavefronts + comm plan)."""
        self.build()
        return discover(self.to_ptg(), self.seeds, self.n_shards,
                        validate=validate)

    def to_taskflow(self, ctx, store, bodies, *, name: Optional[str] = None):
        """Host-runtime lowering for one emulated rank: a wired
        :class:`~repro.core.taskflow.Taskflow` whose task bodies compute on
        ``store`` and whose cross-rank out-edges send active messages, all
        generated from the derived edges. Returns ``(taskflow, seed_fn)``;
        call ``seed_fn()`` to fulfill this rank's seeds, then join the
        threadpool."""
        from repro.linalg.host_exec import wire_taskflow

        return wire_taskflow(ctx, self.to_block_spec(), store, bodies,
                             name=name or self.name)

    def run_host(self, blocks, bodies, *, n_threads: int = 2,
                 timeout: float = 120.0):
        """Execute on the host TaskTorrent runtime (async tasks + active
        messages) across ``n_shards`` emulated ranks; returns the written
        blocks gathered to the host."""
        from repro.linalg.host_exec import run_host_ptg

        return run_host_ptg(self.to_block_spec(), blocks, bodies,
                            n_threads=n_threads, timeout=timeout)

    def __repr__(self) -> str:
        state = (f"{len(self._tasks)} tasks, {len(self._seeds)} seeds"
                 if self._built else "unbuilt")
        return (f"Graph({self.name!r}, n_shards={self.n_shards}, "
                f"types={list(self._types)}, {state})")


def checked_ptg(in_deps: Callable[[K], Sequence[K]],
                out_deps: Callable[[K], Sequence[K]],
                mapping: Callable[[K], int],
                type_of: Callable[[K], str] = lambda k: "task",
                *, sample_keys: Sequence[K] = ()) -> PTG:
    """Wrap user-supplied edge rules (the unbounded-index-space escape
    hatch, where enumeration — and therefore the :class:`Graph` builder —
    is impossible) into a PTG, validating the mutual-inverse property on
    ``sample_keys`` up front. ``discover(..., validate=True)`` re-checks
    every task it actually expands."""
    ptg = PTG(in_deps=in_deps, out_deps=out_deps, mapping=mapping,
              type_of=type_of)
    if sample_keys:
        ptg.check_consistency(sample_keys)
    return ptg
