"""repro.ptg — the unified declarative PTG front-end.

Declare a parametrized task graph once (task types + reads/writes access
patterns + owner mapping); the builder derives ``in_deps`` / ``out_deps`` /
``operands`` / ``block_of`` / ``indegree`` / seeds with the mutual-inverse
property guaranteed by construction, and the same :class:`Graph` lowers to

- the **host runtime** (``Graph.to_taskflow`` / ``Graph.run_host``:
  Taskflow + active-message wiring generated from the derived out-edges);
- the **compiled executor** (``Graph.to_block_spec`` / ``Graph.to_program``:
  parallel discovery -> wavefront schedule -> shard_map lowering).

See ``src/repro/ptg/graph.py`` for the model and README's "Declaring a
PTG" for the migration guide.
"""

from .graph import Graph, TaskType, checked_ptg

__all__ = ["Graph", "TaskType", "checked_ptg"]
