"""repro.ptg — the unified declarative PTG front-end.

Declare a parametrized task graph once (task types + reads/writes access
patterns + owner mapping); the builder derives ``in_deps`` / ``out_deps`` /
``operands`` / ``block_of`` / ``indegree`` / seeds with the mutual-inverse
property guaranteed by construction, and the same :class:`Graph` lowers to

- the **host runtime** (``Graph.to_taskflow`` / ``Graph.run_host``:
  Taskflow + active-message wiring generated from the derived out-edges);
- the **compiled executor** (``Graph.to_block_spec`` / ``Graph.to_program``:
  parallel discovery -> wavefront schedule -> shard_map lowering).

Derivation itself is distributed by default: ``Graph.derive_local`` gives
each shard its own lazily derived slice (owned tasks + halo only), so no
rank ever materializes the global edge dicts — ``Graph.build`` remains the
eager oracle. See docs/ptg_guide.md for the full guide and
docs/architecture.md for the pipeline.
"""

from .graph import Graph, IndexSpace, LocalView, TaskType, checked_ptg

__all__ = ["Graph", "IndexSpace", "LocalView", "TaskType", "checked_ptg"]
