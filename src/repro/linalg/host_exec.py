"""Run a BlockPTGSpec on the *host* TaskTorrent runtime (async, AM-driven).

This is the paper's example program (§II-A3) generalized: every rank owns its
blocks, a Taskflow executes tasks whose bodies compute on numpy blocks, and
each cross-rank out-dependency sends an active message carrying the produced
block which stores the payload and fulfills the remote promise.

The exact same :class:`~repro.core.schedule.BlockPTGSpec` also lowers to the
compiled SPMD executor — tests assert both backends agree with the oracle,
which is the reproduction's core correctness claim: one PTG, two runtimes.
"""

from __future__ import annotations

from typing import Callable, Dict, Hashable

import numpy as np

from repro.core import run_ranks
from repro.core.schedule import BlockPTGSpec

K = Hashable


def run_host_ptg(
    spec: BlockPTGSpec,
    blocks: Dict[Hashable, np.ndarray],
    bodies: Dict[str, Callable[..., np.ndarray]],
    *,
    n_threads: int = 2,
    timeout: float = 120.0,
) -> Dict[Hashable, np.ndarray]:
    """Execute the PTG on ``spec.n_shards`` emulated ranks; returns all
    written blocks (gathered to the host)."""
    ptg, n = spec.ptg, spec.n_shards

    def main(ctx):
        rank = ctx.rank
        # rank-local store: owned blocks + halo copies received via AM
        store: Dict[Hashable, np.ndarray] = {
            blk: np.array(arr) for blk, arr in blocks.items()
            if spec.owner(blk) % n == rank
        }
        tf = ctx.taskflow("ptg")
        am_holder = {}

        tf.set_indegree(lambda k: max(len(ptg.in_deps(k)), 1))
        # distributed mapping -> rank; thread mapping spreads dep management
        tf.set_mapping(lambda k: hash(k) % ctx.tp.n_threads)

        def body(k):
            ops = [store[blk] for blk in spec.operands(k)]
            out = np.asarray(bodies[ptg.type_of(k)](*ops))
            store[spec.block_of(k)] = out
            for d in ptg.out_deps(k):
                dest = ptg.mapping(d) % n
                if dest == rank:
                    tf.fulfill_promise(d)
                else:
                    # the AM carries the block iff the consumer reads it
                    payload = (out if spec.block_of(k) in set(spec.operands(d))
                               else None)
                    am_holder["am"].send(dest, d, spec.block_of(k), payload)

        tf.set_task(body)

        def on_am(d, blk, payload):
            if payload is not None:
                store[blk] = np.asarray(payload)
            tf.fulfill_promise(d)

        am_holder["am"] = ctx.comm.make_active_msg(on_am)

        for k in spec.seeds:
            if ptg.mapping(k) % n == rank:
                tf.fulfill_promise(k)
        ctx.tp.join()
        # return only owned blocks (halo copies are transient)
        return {blk: arr for blk, arr in store.items()
                if spec.owner(blk) % n == rank}

    results = run_ranks(n, main, n_threads=n_threads, timeout=timeout)
    merged: Dict[Hashable, np.ndarray] = {}
    for r in results:
        merged.update(r)
    return merged
