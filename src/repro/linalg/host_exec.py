"""Run a BlockPTGSpec on the *host* TaskTorrent runtime (async, AM-driven).

This is the paper's example program (§II-A3) generalized: every rank owns its
blocks, a Taskflow executes tasks whose bodies compute on numpy blocks, and
each cross-rank out-dependency sends an active message carrying the produced
block which stores the payload and fulfills the remote promise.

The exact same :class:`~repro.core.schedule.BlockPTGSpec` also lowers to the
compiled SPMD executor — tests assert both backends agree with the oracle,
which is the reproduction's core correctness claim: one PTG, two runtimes.
``wire_taskflow`` is the per-rank wiring generator; it is also what
``repro.ptg.Graph.to_taskflow`` emits, so declaratively-built graphs and
hand-written specs share one host lowering.
"""

from __future__ import annotations

from typing import Callable, Dict, Hashable, Tuple

import numpy as np

from repro.core import run_ranks
from repro.core.schedule import BlockPTGSpec
from repro.core.taskflow import Taskflow

K = Hashable


def as_numpy_bodies(bodies: Dict[str, Callable]) -> Dict[str, Callable]:
    """Adapt jnp compute bodies (the compiled executor's) to the host
    runtime's numpy stores: operands go in as jax arrays, results come out
    as numpy — so one ``bodies`` dict serves both back-ends."""
    import jax.numpy as jnp

    return {t: (lambda fn: (lambda *args: np.asarray(
        fn(*map(jnp.asarray, args)))))(fn) for t, fn in bodies.items()}


def wire_taskflow(
    ctx,
    spec: BlockPTGSpec,
    store: Dict[Hashable, np.ndarray],
    bodies: Dict[str, Callable[..., np.ndarray]],
    *,
    name: str = "ptg",
) -> Tuple[Taskflow, Callable[[], None]]:
    """Generate one rank's host-runtime wiring for ``spec``.

    Builds a :class:`Taskflow` whose
    - ``indegree`` comes from the spec's in-edges (seeds carry one
      synthetic dependency, fulfilled by the seed function);
    - task body gathers operands from ``store``, runs the type's compute
      body, stores the written block, and walks the *derived out-edges*:
      local consumers get ``fulfill_promise``, remote consumers get a
      one-sided active message carrying the block iff they read it.

    Returns ``(taskflow, seed_fn)``; the caller seeds and joins:

        tf, seed = wire_taskflow(ctx, spec, store, bodies)
        seed()
        ctx.tp.join()
    """
    ptg, n = spec.ptg, spec.n_shards
    rank = ctx.rank
    tf = ctx.taskflow(name)
    am_holder = {}

    tf.set_indegree(lambda k: max(len(ptg.in_deps(k)), 1))
    # distributed mapping -> rank; thread mapping spreads dep management
    tf.set_mapping(lambda k: hash(k) % ctx.tp.n_threads)

    def body(k):
        ops = [store[blk] for blk in spec.operands(k)]
        out = np.asarray(bodies[ptg.type_of(k)](*ops))
        store[spec.block_of(k)] = out
        for d in ptg.out_deps(k):
            dest = ptg.mapping(d) % n
            if dest == rank:
                tf.fulfill_promise(d)
            else:
                # the AM carries the block iff the consumer reads it
                payload = (out if spec.block_of(k) in set(spec.operands(d))
                           else None)
                am_holder["am"].send(dest, d, spec.block_of(k), payload)

    tf.set_task(body)

    def on_am(d, blk, payload):
        if payload is not None:
            store[blk] = np.asarray(payload)
        tf.fulfill_promise(d)

    am_holder["am"] = ctx.comm.make_active_msg(on_am)

    def seed():
        for k in spec.seeds:
            if ptg.mapping(k) % n == rank:
                tf.fulfill_promise(k)

    return tf, seed


def run_host_ptg(
    spec: BlockPTGSpec,
    blocks: Dict[Hashable, np.ndarray],
    bodies: Dict[str, Callable[..., np.ndarray]],
    *,
    n_threads: int = 2,
    timeout: float = 120.0,
) -> Dict[Hashable, np.ndarray]:
    """Execute the PTG on ``spec.n_shards`` emulated ranks; returns all
    written blocks (gathered to the host)."""
    n = spec.n_shards

    def main(ctx):
        rank = ctx.rank
        # rank-local store: owned blocks + halo copies received via AM
        store: Dict[Hashable, np.ndarray] = {
            blk: np.array(arr) for blk, arr in blocks.items()
            if spec.owner(blk) % n == rank
        }
        _, seed = wire_taskflow(ctx, spec, store, bodies)
        seed()
        ctx.tp.join()
        # return only owned blocks (halo copies are transient)
        return {blk: arr for blk, arr in store.items()
                if spec.owner(blk) % n == rank}

    results = run_ranks(n, main, n_threads=n_threads, timeout=timeout)
    merged: Dict[Hashable, np.ndarray] = {}
    for r in results:
        merged.update(r)
    return merged
