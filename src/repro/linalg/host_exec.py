"""Run a BlockPTGSpec on the *host* TaskTorrent runtime (async, AM-driven).

This is the paper's example program (§II-A3) generalized: every rank owns its
blocks, a Taskflow executes tasks whose bodies compute on numpy blocks, and
each cross-rank out-dependency sends an active message carrying the produced
block which stores the payload and fulfills the remote promise.

The exact same :class:`~repro.core.schedule.BlockPTGSpec` also lowers to the
compiled SPMD executor — tests assert both backends agree with the oracle,
which is the reproduction's core correctness claim: one PTG, two runtimes.
``wire_taskflow`` is the per-rank wiring generator; it is also what
``repro.ptg.Graph.to_taskflow`` emits, so declaratively-built graphs and
hand-written specs share one host lowering.

Fault-tolerant mode (``run_host_ptg(..., faults=FaultPlan(...))``) swaps the
per-rank wiring for a :class:`_FaultHost`, which adds the recovery half of
the runtime on top of the reliable transport in ``core.messages``:

- **one dispatcher AM per rank**, registered up front — adoption must not
  register new AMs mid-run (registration order is the global AM identity,
  §II-B2), so every hosted shard shares the dispatcher;
- **application-level dedup** keyed ``(consumer task, producer task)``:
  transport retransmits are deduped by seq, but *recovery re-execution*
  legitimately re-produces the same fulfillment from a different host, and
  it must decrement each promise exactly once;
- a **send log** of cross-shard fulfillments. When a death declaration
  reassigns shards, every survivor replays its logged sends to the moved
  shards — payloads re-read from the block store, which is sound because
  communicated blocks are single-assignment (the block contract
  ``core.schedule`` checks): the stored value IS the value every consumer
  must observe;
- **adoption**: the assigned survivor re-derives the dead shard's
  :class:`~repro.ptg.graph.LocalView` (the ``rederive`` hook —
  O(owned + halo), the lazy-discovery payoff), seeds its initial blocks,
  wires it as a second Taskflow on the same threadpool, and re-executes it
  from the seeds; upstream state arrives via the survivors' replays and
  every re-produced cross-shard fulfillment is deduped at its consumer.
  Deterministic bodies + single assignment make the result bit-identical
  to the fault-free run.

Misrouted AMs (sent on a stale route while a declaration propagates) are
forwarded along the receiver's current route — and logged, so a further
move replays them too.
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Hashable, List, Optional, Tuple

import numpy as np

from repro.core import run_ranks
from repro.core.faults import FaultPlan
from repro.core.schedule import BlockPTGSpec
from repro.core.taskflow import Taskflow

K = Hashable


def as_numpy_bodies(bodies: Dict[str, Callable]) -> Dict[str, Callable]:
    """Adapt jnp compute bodies (the compiled executor's) to the host
    runtime's numpy stores: operands go in as jax arrays, results come out
    as numpy — so one ``bodies`` dict serves both back-ends."""
    import jax.numpy as jnp

    return {t: (lambda fn: (lambda *args: np.asarray(
        fn(*map(jnp.asarray, args)))))(fn) for t, fn in bodies.items()}


def wire_taskflow(
    ctx,
    spec: BlockPTGSpec,
    store: Dict[Hashable, np.ndarray],
    bodies: Dict[str, Callable[..., np.ndarray]],
    *,
    name: str = "ptg",
) -> Tuple[Taskflow, Callable[[], None]]:
    """Generate one rank's host-runtime wiring for ``spec``.

    Builds a :class:`Taskflow` whose
    - ``indegree`` comes from the spec's in-edges (seeds carry one
      synthetic dependency, fulfilled by the seed function);
    - task body gathers operands from ``store``, runs the type's compute
      body, stores the written block, and walks the *derived out-edges*:
      local consumers get ``fulfill_promise``, remote consumers get a
      one-sided active message carrying the block iff they read it.

    Returns ``(taskflow, seed_fn)``; the caller seeds and joins:

        tf, seed = wire_taskflow(ctx, spec, store, bodies)
        seed()
        ctx.tp.join()
    """
    ptg, n = spec.ptg, spec.n_shards
    rank = ctx.rank
    tf = ctx.taskflow(name)
    am_holder = {}

    tf.set_indegree(lambda k: max(len(ptg.in_deps(k)), 1))
    # distributed mapping -> rank; thread mapping spreads dep management
    tf.set_mapping(lambda k: hash(k) % ctx.tp.n_threads)

    def body(k):
        ops = [store[blk] for blk in spec.operands(k)]
        out = np.asarray(bodies[ptg.type_of(k)](*ops))
        store[spec.block_of(k)] = out
        for d in ptg.out_deps(k):
            dest = ptg.mapping(d) % n
            if dest == rank:
                tf.fulfill_promise(d)
            else:
                # the AM carries the block iff the consumer reads it
                payload = (out if spec.block_of(k) in set(spec.operands(d))
                           else None)
                am_holder["am"].send(dest, d, spec.block_of(k), payload)

    tf.set_task(body)

    def on_am(d, blk, payload):
        if payload is not None:
            store[blk] = np.asarray(payload)
        tf.fulfill_promise(d)

    am_holder["am"] = ctx.comm.make_active_msg(on_am)

    def seed():
        for k in spec.seeds:
            if ptg.mapping(k) % n == rank:
                tf.fulfill_promise(k)

    return tf, seed


class _SpecEdges:
    """Edge queries for one shard answered by the global spec — the
    fallback adopter path when no ``rederive`` hook is available (the spec
    dispatches any task's queries, so hosting a foreign shard just works;
    it only forgoes the measured fresh re-derivation)."""

    def __init__(self, spec: BlockPTGSpec, shard: int):
        self._spec = spec
        self._ptg = spec.ptg
        self._n = spec.n_shards
        self.seeds = [k for k in spec.seeds
                      if self._ptg.mapping(k) % self._n == shard]

    def in_deps(self, k):
        return self._ptg.in_deps(k)

    def out_deps(self, k):
        return self._ptg.out_deps(k)

    def mapping(self, k):
        return self._ptg.mapping(k)

    def type_of(self, k):
        return self._ptg.type_of(k)

    def operands(self, k):
        return self._spec.operands(k)

    def block_of(self, k):
        return self._spec.block_of(k)


class _FaultHost:
    """One rank's fault-tolerant host runtime: its own shard plus any shard
    it adopts after a death declaration (see module docstring)."""

    def __init__(self, ctx, spec: BlockPTGSpec, blocks, bodies,
                 rederive: Optional[Callable] = None):
        self.ctx = ctx
        self.rank = ctx.rank
        self.spec = spec
        self.n = spec.n_shards
        self.bodies = bodies
        self.blocks_init = blocks  # global initial blocks (adoption seeds)
        self.rederive = rederive
        self.report = ctx.comm.world.report
        self.lock = threading.RLock()
        # shard -> hosting rank; identical on every rank (driven by the
        # DEATH assignment broadcast). Task->shard (spec.ptg.mapping) is
        # immutable; only shard->host moves.
        self.route: List[int] = list(range(self.n))
        self.hosted: Dict[int, Tuple[Taskflow, object]] = {}
        self.applied: set = set()  # (consumer, producer) fulfillments seen
        # (dest_shard, consumer, producer, block, has_payload)
        self.sendlog: List[tuple] = []
        self.store: Dict[Hashable, np.ndarray] = {
            blk: np.array(arr) for blk, arr in blocks.items()
            if spec.owner(blk) % self.n == self.rank}
        # the single dispatcher AM — registered before any fault can strike
        self.am = ctx.comm.make_active_msg(self._on_am)
        self._wire_shard(self.rank, self._edges_for(self.rank, fresh=False),
                         adopted=False)
        ctx.comm.on_reconfigure = self._reconfigure

    # ------------------------------------------------------------ wiring

    def _edges_for(self, shard: int, *, fresh: bool):
        if fresh and self.rederive is not None:
            view = self.rederive(shard)  # fresh LocalView: O(owned + halo)
            self.report.note_rederived(
                shard, view.stats.get("derived_edges", 0))
            return view
        if fresh:
            self.report.note_rederived(shard, 0)
        return _SpecEdges(self.spec, shard)

    def _shard_of(self, k) -> int:
        return self.spec.ptg.mapping(k) % self.n

    def _wire_shard(self, shard: int, E, *, adopted: bool) -> Taskflow:
        tf = self.ctx.taskflow(f"ptg@s{shard}")
        tf.set_indegree(lambda k: max(len(E.in_deps(k)), 1))
        tf.set_mapping(lambda k: hash(k) % self.ctx.tp.n_threads)

        def body(k):
            ops = [self.store[blk] for blk in E.operands(k)]
            out = np.asarray(self.bodies[E.type_of(k)](*ops))
            blk = E.block_of(k)
            self.store[blk] = out
            if adopted:
                self.report.bump("reexecuted_tasks")
            for d in E.out_deps(k):
                ds = E.mapping(d) % self.n
                if ds == shard:
                    tf.fulfill_promise(d)
                else:
                    # consumer-side read set answered by the global spec
                    # (the producer's derived edge carries it on a real
                    # distributed system)
                    payload = (out if blk in set(self.spec.operands(d))
                               else None)
                    self._deliver(ds, d, k, blk, payload)

        tf.set_task(body)
        with self.lock:
            self.hosted[shard] = (tf, E)
        return tf

    def seed(self) -> None:
        tf, E = self.hosted[self.rank]
        for k in E.seeds:
            tf.fulfill_promise(k)

    # --------------------------------------------------------- data plane

    def _deliver(self, ds: int, d, k, blk, payload) -> None:
        """Route one cross-shard fulfillment (and log it for replay)."""
        with self.lock:
            self.sendlog.append((ds, d, k, blk, payload is not None))
            tgt = self.route[ds]
        if tgt == self.rank:
            self._apply(d, k, blk, payload)
        else:
            self.am.send(tgt, d, k, blk, payload)

    def _on_am(self, d, k, blk, payload) -> None:
        self._apply(d, k, blk, payload)

    def _apply(self, d, k, blk, payload) -> None:
        """Deliver one cross-shard fulfillment to a locally hosted shard,
        exactly once per (consumer, producer) pair."""
        ds = self._shard_of(d)
        with self.lock:
            entry = self.hosted.get(ds)
            if entry is not None:
                if (d, k) in self.applied:
                    return  # re-execution or replay duplicate
                self.applied.add((d, k))
                if payload is not None:
                    self.store[blk] = np.asarray(payload)
                tf = entry[0]
        if entry is None:
            # Stale route: we got traffic for a shard we don't host — e.g.
            # a survivor's replay raced ahead of our own DEATH processing.
            # Cache the payload (single assignment: this IS the block's
            # final value) and forward along our route; the forward is
            # logged, so if our route is itself stale (the fenced dead
            # rank), our reconfigure replays it from the cached value.
            if payload is not None:
                with self.lock:
                    self.store[blk] = np.asarray(payload)
            self.report.bump("forwarded_ams")
            self._deliver(ds, d, k, blk, payload)
            return
        tf.fulfill_promise(d)

    # ---------------------------------------------------------- recovery

    def _reconfigure(self, newly_dead, assignment, epoch) -> None:
        """Death declaration applied (progress thread): adopt what is ours,
        retarget the routes, replay logged sends to every moved shard."""
        with self.lock:
            changed = [s for s, h in assignment.items()
                       if self.route[s] != h]
            mine = [s for s in changed if assignment[s] == self.rank]
        # Wire adopted shards BEFORE exposing the new route: _apply checks
        # `hosted` first, so a route that says "me" always finds its
        # taskflow. Until the route flips, inbound traffic for these shards
        # forwards into the fenced void — and is replayed below.
        for s in mine:
            E = self._edges_for(s, fresh=True)
            for blk, arr in self.blocks_init.items():
                if self.spec.owner(blk) % self.n == s:
                    with self.lock:
                        # keep an already-received halo copy: communicated
                        # blocks are single-assignment, so it already holds
                        # the only value it will ever hold
                        self.store.setdefault(blk, np.array(arr))
            tf = self._wire_shard(s, E, adopted=True)
            for k in E.seeds:
                tf.fulfill_promise(k)
        with self.lock:
            for s, h in assignment.items():
                self.route[s] = h
            entries = [e for e in self.sendlog if e[0] in set(changed)]
        for ds, d, k, blk, has_payload in entries:
            payload = self.store.get(blk) if has_payload else None
            if has_payload and payload is None:
                # a forwarded entry whose payload never lived here; the
                # producer's host (or its re-execution) replays it
                continue
            with self.lock:
                tgt = self.route[ds]
            if tgt == self.rank:
                self._apply(d, k, blk, payload)
            else:
                self.report.bump("replayed_sends")
                self.am.send(tgt, d, k, blk, payload)

    # ------------------------------------------------------------ results

    def owned_blocks(self) -> Dict[Hashable, np.ndarray]:
        with self.lock:
            hosted = set(self.hosted)
        return {blk: arr for blk, arr in self.store.items()
                if self.spec.owner(blk) % self.n in hosted}


def run_host_ptg(
    spec: BlockPTGSpec,
    blocks: Dict[Hashable, np.ndarray],
    bodies: Dict[str, Callable[..., np.ndarray]],
    *,
    n_threads: int = 2,
    timeout: float = 120.0,
    faults: Optional[FaultPlan] = None,
    rederive: Optional[Callable] = None,
    total_edges: Optional[int] = None,
    transport: Optional[str] = None,
):
    """Execute the PTG on ``spec.n_shards`` emulated ranks; returns all
    written blocks (gathered to the host) — or ``(blocks, RecoveryReport)``
    when a :class:`~repro.core.faults.FaultPlan` is given. ``rederive``
    (shard -> LocalView) lets adoption re-derive only the moved shard;
    ``total_edges`` is the eager-edge denominator for ``rederived_frac``.
    ``transport`` picks the comm backend (``inproc``/``multiproc``) the
    ranks run on."""
    n = spec.n_shards

    if faults is None:
        def main(ctx):
            rank = ctx.rank
            # rank-local store: owned blocks + halo copies received via AM
            store: Dict[Hashable, np.ndarray] = {
                blk: np.array(arr) for blk, arr in blocks.items()
                if spec.owner(blk) % n == rank
            }
            _, seed = wire_taskflow(ctx, spec, store, bodies)
            seed()
            ctx.tp.join()
            # return only owned blocks (halo copies are transient)
            return {blk: arr for blk, arr in store.items()
                    if spec.owner(blk) % n == rank}

        results = run_ranks(n, main, n_threads=n_threads, timeout=timeout,
                            transport=transport)
        merged: Dict[Hashable, np.ndarray] = {}
        for r in results:
            merged.update(r)
        return merged

    def main(ctx):
        host = _FaultHost(ctx, spec, blocks, bodies, rederive)
        host.seed()
        ctx.tp.join()
        return host.owned_blocks()

    results, report = run_ranks(n, main, n_threads=n_threads,
                                timeout=timeout, faults=faults,
                                transport=transport)
    report.total_edges = total_edges
    merged = {}
    for r in results:
        if r:  # killed ranks return None; their shards report elsewhere
            merged.update(r)
    return merged, report
