"""Distributed block GEMM as a PTG — the paper's §III-B benchmark app.

Two mappings, as in the paper:

- **2D block-cyclic** (`gemm_2d_spec`): C_ij owned by shard
  (i mod pr, j mod pc); contributions A_ik·B_kj are sequenced in k on the
  owner of C_ij — the exact `gemm_Cikj` PTG of the paper (indegree
  ``k == 0 ? 2 : 3``), with send tasks broadcasting A along grid rows and B
  along grid columns via (compiled) active messages.
- **3D DNS** (`gemm_3d_spec`): the k-range is sliced into q slabs; each slab
  plane computes a partial product which a reduction chain sums into C —
  less comm per plane, one extra reduction stage (paper Fig 7a-b/d).

``staged=True`` threads a chain through the send tasks so the A_ik / B_kj
broadcasts happen at wavefront k instead of all at wavefront 0: the
compiled schedule then overlaps each step's exchange with the previous
step's compute and needs O(nb/p) message buffers instead of O(nb²/p²) —
a beyond-paper scheduling optimization measured in §Perf.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.discovery import PTG
from repro.core.schedule import BlockPTGSpec, BlockProgram, build_block_program


# ------------------------------------------------------------- 2D mapping

def gemm_2d_spec(nb: int, pr: int, pc: int, b: int, *, staged: bool = False,
                 dtype=jnp.float32) -> BlockPTGSpec:
    """nb×nb blocks of size b×b on a pr×pc shard grid."""

    def owner(blk) -> int:
        kind, r, c = blk
        return (r % pr) * pc + (c % pc)

    def mapping(k):
        if k[0] == "gemm":                       # ("gemm", i, kk, j)
            _, i, _, j = k
            return owner(("C", i, j))
        _, i, kk = k                             # ("sa"|"sb", row, col)
        return owner(("A" if k[0] == "sa" else "B", i, kk))

    def _step(t) -> int:
        # the k-step a send task belongs to: sa(i, k) -> k; sb(k, j) -> k
        return t[2] if t[0] == "sa" else t[1]

    def in_deps(t):
        if t[0] == "gemm":
            _, i, kk, j = t
            deps = [("sa", i, kk), ("sb", kk, j)]
            if kk > 0:
                deps.append(("gemm", i, kk - 1, j))
            return deps
        if staged and _step(t) > 0:              # send chain: step k waits k-1
            return [("sa", t[1], t[2] - 1) if t[0] == "sa"
                    else ("sb", t[1] - 1, t[2])]
        return []

    def out_deps(t):
        if t[0] == "gemm":
            _, i, kk, j = t
            return [("gemm", i, kk + 1, j)] if kk + 1 < nb else []
        if t[0] == "sa":
            _, i, kk = t
            out = [("gemm", i, kk, j) for j in range(nb)]
            if staged and kk + 1 < nb:
                out.append(("sa", i, kk + 1))
        else:
            _, kk, j = t
            out = [("gemm", i, kk, j) for i in range(nb)]
            if staged and kk + 1 < nb:
                out.append(("sb", kk + 1, j))
        return out

    def block_of(t):
        if t[0] == "gemm":
            return ("C", t[1], t[3])
        return ("A", t[1], t[2]) if t[0] == "sa" else ("B", t[1], t[2])

    def operands(t):
        if t[0] == "gemm":
            _, i, kk, j = t
            return [("C", i, j), ("A", i, kk), ("B", kk, j)]
        return [block_of(t)]                     # identity "send" body

    def type_of(t):
        return t[0]

    if staged:
        seeds = [("sa", i, 0) for i in range(nb)] + \
                [("sb", 0, j) for j in range(nb)]
    else:
        seeds = [("sa", i, kk) for i in range(nb) for kk in range(nb)] + \
                [("sb", kk, j) for kk in range(nb) for j in range(nb)]

    return BlockPTGSpec(
        ptg=PTG(in_deps, out_deps, mapping, type_of),
        seeds=seeds, n_shards=pr * pc, block_shape=(b, b),
        block_of=block_of, operands=operands, owner=owner, dtype=dtype)


# ------------------------------------------------------------- 3D mapping

def gemm_3d_spec(nb: int, q: int, b: int, *, dtype=jnp.float32) -> BlockPTGSpec:
    """DNS mapping on a q×q×q grid: slab l owns k in [l·nb/q, (l+1)·nb/q)."""
    assert nb % q == 0, "nb must divide into q slabs"
    kb = nb // q  # blocks per slab

    def shard(l, r, c) -> int:
        return l * q * q + (r % q) * q + (c % q)

    def slab(kk: int) -> int:
        return kk // kb

    def owner(blk) -> int:
        kind = blk[0]
        if kind == "A":
            _, i, kk = blk
            return shard(slab(kk), i, kk)
        if kind == "B":
            _, kk, j = blk
            return shard(slab(kk), kk, j)
        if kind in ("P", "Pf"):                  # partial C per slab
            _, i, j, l = blk
            return shard(l, i, j)
        _, i, j = blk                            # final C on slab 0
        return shard(0, i, j)

    def mapping(t):
        return owner(block_of(t))

    def block_of(t):
        tt = t[0]
        if tt == "gemm":
            _, i, kk, j = t
            return ("P", i, j, slab(kk))
        if tt == "sa":
            return ("A", t[1], t[2])
        if tt == "sb":
            return ("B", t[1], t[2])
        if tt == "fin":                          # ("fin", i, j, l)
            return ("Pf", t[1], t[2], t[3])
        return ("C", t[1], t[2])                 # ("red", i, j, l)

    def operands(t):
        tt = t[0]
        if tt == "gemm":
            _, i, kk, j = t
            return [("P", i, j, slab(kk)), ("A", i, kk), ("B", kk, j)]
        if tt in ("sa", "sb"):
            return [block_of(t)]
        if tt == "fin":
            return [("P", t[1], t[2], t[3])]
        _, i, j, l = t                           # red: C += Pf_l
        return [("C", i, j), ("Pf", i, j, l)]

    def in_deps(t):
        tt = t[0]
        if tt == "gemm":
            _, i, kk, j = t
            deps = [("sa", i, kk), ("sb", kk, j)]
            if kk % kb > 0:
                deps.append(("gemm", i, kk - 1, j))
            return deps
        if tt in ("sa", "sb"):
            return []
        if tt == "fin":
            _, i, j, l = t
            return [("gemm", i, (l + 1) * kb - 1, j)]
        _, i, j, l = t                           # red
        deps = [("fin", i, j, l)]
        if l > 0:
            deps.append(("red", i, j, l - 1))
        return deps

    def out_deps(t):
        tt = t[0]
        if tt == "gemm":
            _, i, kk, j = t
            if kk % kb + 1 < kb:
                return [("gemm", i, kk + 1, j)]
            return [("fin", i, j, slab(kk))]
        if tt == "sa":
            _, i, kk = t
            return [("gemm", i, kk, j) for j in range(nb)]
        if tt == "sb":
            _, kk, j = t
            return [("gemm", i, kk, j) for i in range(nb)]
        if tt == "fin":
            _, i, j, l = t
            return [("red", i, j, l)]
        _, i, j, l = t                           # red
        return [("red", i, j, l + 1)] if l + 1 < q else []

    def type_of(t):
        return t[0]

    seeds = [("sa", i, kk) for i in range(nb) for kk in range(nb)] + \
            [("sb", kk, j) for kk in range(nb) for j in range(nb)]
    return BlockPTGSpec(
        ptg=PTG(in_deps, out_deps, mapping, type_of),
        seeds=seeds, n_shards=q ** 3, block_shape=(b, b),
        block_of=block_of, operands=operands, owner=owner, dtype=dtype)


# --------------------------------------------------- program + executor

def gemm_2d_program(nb: int, pr: int, pc: int, b: int, *,
                    staged: bool = False, dtype=jnp.float32) -> BlockProgram:
    """Discover + lower the 2D GEMM PTG onto the shared comm-planning layer
    (classified per-wavefront patterns, dense and sparse exchange tables)."""
    return build_block_program(
        gemm_2d_spec(nb, pr, pc, b, staged=staged, dtype=dtype))


def gemm_3d_program(nb: int, q: int, b: int, *, dtype=jnp.float32
                    ) -> BlockProgram:
    return build_block_program(gemm_3d_spec(nb, q, b, dtype=dtype))


def gemm_executor(prog: BlockProgram, mesh, axis: str = "shards", *,
                  matmul=None, unroll_cap: int = 64):
    """Sparsity-aware GEMM executor. The eager 2D mapping's wavefront-0
    broadcast is dense (all_to_all); the staged variant's per-k panel sends
    are sparse (ppermute rounds) and overlap with the k-1 rank updates —
    the compiled form of the paper's AM/compute overlap."""
    return prog.auto_executor(gemm_bodies(matmul), mesh, axis,
                              unroll_cap=unroll_cap)


# ------------------------------------------------------------ bodies/oracle

def gemm_bodies(matmul=None) -> Dict[str, object]:
    """Per-block compute bodies; ``matmul`` is pluggable (jnp or Pallas)."""
    mm = matmul if matmul is not None else lambda a, b: a @ b

    return {
        "sa": lambda a: a,
        "sb": lambda b_: b_,
        "gemm": lambda c, a, b_: c + mm(a, b_),
        "fin": lambda p: p,
        "red": lambda c, pf: c + pf,
    }


def make_blocks(key, nb: int, b: int, *, with_partials: Tuple[int, ...] = (),
                seed: int = 0) -> Dict[Tuple, np.ndarray]:
    """Random A/B blocks, zero C blocks (and zero 3D partials if requested)."""
    rng = np.random.default_rng(seed)
    blocks: Dict[Tuple, np.ndarray] = {}
    for i in range(nb):
        for j in range(nb):
            blocks[("A", i, j)] = rng.standard_normal((b, b)).astype(np.float32)
            blocks[("B", i, j)] = rng.standard_normal((b, b)).astype(np.float32)
            blocks[("C", i, j)] = np.zeros((b, b), np.float32)
            for l in with_partials:
                blocks[("P", i, j, l)] = np.zeros((b, b), np.float32)
    return blocks


def assemble(blocks: Dict[Tuple, np.ndarray], kind: str, nb: int, b: int):
    out = np.zeros((nb * b, nb * b), np.float32)
    for i in range(nb):
        for j in range(nb):
            out[i * b:(i + 1) * b, j * b:(j + 1) * b] = blocks[(kind, i, j)]
    return out
