"""Distributed block GEMM as a declarative PTG — the paper's §III-B app.

Two mappings, as in the paper, both declared once through the unified
``repro.ptg`` front-end (task types + reads/writes access patterns); all
edge functions — including the per-k accumulation chains and the broadcast
out-edges of the send tasks — are *derived*, not hand-written:

- **2D block-cyclic** (`gemm_2d_graph`): C_ij owned by shard
  (i mod pr, j mod pc); contributions A_ik·B_kj sequence in k on the owner
  of C_ij automatically, because every k-step read-modify-writes the same
  C block — the exact `gemm_Cikj` PTG of the paper (indegree
  ``k == 0 ? 2 : 3``), with send tasks broadcasting A along grid rows and
  B along grid columns via (compiled) active messages.
- **3D DNS** (`gemm_3d_graph`): the k-range is sliced into q slabs; each
  slab plane accumulates a partial product which a reduction chain sums
  into C — less comm per plane, one extra reduction stage (Fig 7a-b/d).

``staged=True`` adds an ``after`` control chain through the send tasks so
the A_ik / B_kj broadcasts happen at wavefront k instead of all at
wavefront 0: the compiled schedule then overlaps each step's exchange with
the previous step's compute and needs O(nb/p) message buffers instead of
O(nb²/p²) — a beyond-paper scheduling optimization measured in §Perf.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.schedule import BlockPTGSpec, BlockProgram, build_block_program
from repro.ptg import Graph, IndexSpace


def _res(p: int, r: int, n: int):
    """Indices in [0, n) congruent to r mod p — one block-cyclic residue
    class, the strip a shard owns along one grid dimension."""
    return range(r % p, n, p)


# ------------------------------------------------------------- 2D mapping

def gemm_2d_graph(nb: int, pr: int, pc: int, b: int, *, staged: bool = False,
                  dtype=jnp.float32) -> Graph:
    """nb×nb blocks of size b×b on a pr×pc shard grid, declared once."""

    def owner(blk) -> int:
        kind, r, c = blk
        return (r % pr) * pc + (c % pc)

    g = Graph("gemm2d", n_shards=pr * pc, owner=owner,
              block_shape=(b, b), dtype=dtype)
    # partitionable grid spaces: each type's written block fixes a block-
    # cyclic residue class per shard, so derive_local's pass 1 enumerates
    # only the shard's strip instead of relevance-filtering the whole grid
    g.task_type(
        "sa",
        space=IndexSpace(
            lambda: ((i, kk) for i in range(nb) for kk in range(nb)),
            lambda s: ((i, kk) for i in _res(pr, s // pc, nb)
                       for kk in _res(pc, s % pc, nb)),
            size=nb * nb),
        writes=lambda i, kk: ("A", i, kk),
        reads=lambda i, kk: [("A", i, kk)],          # identity "send" body
        after=(lambda i, kk: [("sa", i, kk - 1)] if kk else [])
        if staged else None)
    g.task_type(
        "sb",
        space=IndexSpace(
            lambda: ((kk, j) for kk in range(nb) for j in range(nb)),
            lambda s: ((kk, j) for kk in _res(pr, s // pc, nb)
                       for j in _res(pc, s % pc, nb)),
            size=nb * nb),
        writes=lambda kk, j: ("B", kk, j),
        reads=lambda kk, j: [("B", kk, j)],
        after=(lambda kk, j: [("sb", kk - 1, j)] if kk else [])
        if staged else None)
    g.task_type(
        "gemm",
        space=IndexSpace(
            lambda: ((i, kk, j) for i in range(nb)
                     for kk in range(nb) for j in range(nb)),
            lambda s: ((i, kk, j) for i in _res(pr, s // pc, nb)
                       for kk in range(nb) for j in _res(pc, s % pc, nb)),
            size=nb ** 3),
        writes=lambda i, kk, j: ("C", i, j),         # RMW => k-chain derived
        reads=lambda i, kk, j: [("C", i, j), ("A", i, kk), ("B", kk, j)])
    return g


def gemm_2d_spec(nb: int, pr: int, pc: int, b: int, *, staged: bool = False,
                 dtype=jnp.float32, lazy: bool = True) -> BlockPTGSpec:
    """Spec via lazy per-shard derivation by default; ``lazy=False`` is the
    eager global-scan oracle (identical program either way)."""
    return gemm_2d_graph(nb, pr, pc, b, staged=staged,
                         dtype=dtype).to_block_spec(lazy=lazy)


# ------------------------------------------------------------- 3D mapping

def gemm_3d_graph(nb: int, q: int, b: int, *, dtype=jnp.float32) -> Graph:
    """DNS mapping on a q×q×q grid: slab l owns k in [l·nb/q, (l+1)·nb/q)."""
    assert nb % q == 0, "nb must divide into q slabs"
    kb = nb // q  # blocks per slab

    def shard(l, r, c) -> int:
        return l * q * q + (r % q) * q + (c % q)

    def slab(kk: int) -> int:
        return kk // kb

    def owner(blk) -> int:
        kind = blk[0]
        if kind == "A":
            _, i, kk = blk
            return shard(slab(kk), i, kk)
        if kind == "B":
            _, kk, j = blk
            return shard(slab(kk), kk, j)
        if kind in ("P", "Pf"):                  # partial C per slab
            _, i, j, l = blk
            return shard(l, i, j)
        _, i, j = blk                            # final C on slab 0
        return shard(0, i, j)

    g = Graph("gemm3d", n_shards=q ** 3, owner=owner,
              block_shape=(b, b), dtype=dtype)

    def grid(s):
        """Shard id -> (slab, row residue, col residue)."""
        return s // (q * q), (s // q) % q, s % q

    def slab_ks(l: int, r: int):
        """k indices inside slab l congruent to r mod q."""
        lo = l * kb
        return range(lo + (r - lo) % q, lo + kb, q)

    g.task_type(
        "sa",
        space=IndexSpace(
            lambda: ((i, kk) for i in range(nb) for kk in range(nb)),
            lambda s: ((i, kk) for i in _res(q, grid(s)[1], nb)
                       for kk in slab_ks(grid(s)[0], grid(s)[2])),
            size=nb * nb),
        writes=lambda i, kk: ("A", i, kk),
        reads=lambda i, kk: [("A", i, kk)])
    g.task_type(
        "sb",
        space=IndexSpace(
            lambda: ((kk, j) for kk in range(nb) for j in range(nb)),
            lambda s: ((kk, j) for kk in slab_ks(grid(s)[0], grid(s)[1])
                       for j in _res(q, grid(s)[2], nb)),
            size=nb * nb),
        writes=lambda kk, j: ("B", kk, j),
        reads=lambda kk, j: [("B", kk, j)])
    g.task_type(
        "gemm",                                  # slab-local k-chain on P
        space=IndexSpace(
            lambda: ((i, kk, j) for i in range(nb)
                     for kk in range(nb) for j in range(nb)),
            lambda s: ((i, kk, j) for i in _res(q, grid(s)[1], nb)
                       for kk in range(grid(s)[0] * kb,
                                       (grid(s)[0] + 1) * kb)
                       for j in _res(q, grid(s)[2], nb)),
            size=nb ** 3),
        writes=lambda i, kk, j: ("P", i, j, slab(kk)),
        reads=lambda i, kk, j: [("P", i, j, slab(kk)),
                                ("A", i, kk), ("B", kk, j)])
    g.task_type(
        "fin",                                   # close the slab's partial
        space=IndexSpace(
            lambda: ((i, j, l) for i in range(nb)
                     for j in range(nb) for l in range(q)),
            lambda s: ((i, j, grid(s)[0]) for i in _res(q, grid(s)[1], nb)
                       for j in _res(q, grid(s)[2], nb)),
            size=nb * nb * q),
        writes=lambda i, j, l: ("Pf", i, j, l),
        reads=lambda i, j, l: [("P", i, j, l)])
    g.task_type(
        "red",                                   # C += Pf_l reduction chain
        space=IndexSpace(
            lambda: ((i, j, l) for i in range(nb)
                     for j in range(nb) for l in range(q)),
            lambda s: (((i, j, l) for i in _res(q, grid(s)[1], nb)
                        for j in _res(q, grid(s)[2], nb) for l in range(q))
                       if grid(s)[0] == 0 else iter(())),
            size=nb * nb * q),
        writes=lambda i, j, l: ("C", i, j),
        reads=lambda i, j, l: [("C", i, j), ("Pf", i, j, l)])
    return g


def gemm_3d_spec(nb: int, q: int, b: int, *, dtype=jnp.float32,
                 lazy: bool = True) -> BlockPTGSpec:
    return gemm_3d_graph(nb, q, b, dtype=dtype).to_block_spec(lazy=lazy)


# --------------------------------------------------- program + executor

def gemm_2d_program(nb: int, pr: int, pc: int, b: int, *,
                    staged: bool = False, dtype=jnp.float32) -> BlockProgram:
    """Discover + lower the 2D GEMM PTG onto the shared comm-planning layer
    (classified per-wavefront patterns, dense and sparse exchange tables)."""
    return build_block_program(
        gemm_2d_spec(nb, pr, pc, b, staged=staged, dtype=dtype))


def gemm_3d_program(nb: int, q: int, b: int, *, dtype=jnp.float32
                    ) -> BlockProgram:
    return build_block_program(gemm_3d_spec(nb, q, b, dtype=dtype))


def gemm_executor(prog: BlockProgram, mesh, axis: str = "shards", *,
                  matmul=None, unroll_cap: int = 64, **policy):
    """Sparsity-aware GEMM executor. The eager 2D mapping's wavefront-0
    broadcast is dense (all_to_all); the staged variant's per-k panel sends
    are sparse (ppermute rounds) and overlap with the k-1 rank updates —
    the compiled form of the paper's AM/compute overlap. ``policy`` kwargs
    (``comm``/``overlap``/``segment_cap``/``density_threshold``) pass
    through to ``BlockProgram.auto_executor``; past ``unroll_cap`` deep
    staged schedules keep their sparse per-k sends via the segmented
    scan instead of cliffing to the dense scan."""
    return prog.auto_executor(gemm_bodies(matmul), mesh, axis,
                              unroll_cap=unroll_cap, **policy)


# ------------------------------------------------------------ bodies/oracle

def gemm_bodies(matmul=None) -> Dict[str, object]:
    """Per-block compute bodies; ``matmul`` is pluggable (jnp or Pallas)."""
    mm = matmul if matmul is not None else lambda a, b: a @ b

    return {
        "sa": lambda a: a,
        "sb": lambda b_: b_,
        "gemm": lambda c, a, b_: c + mm(a, b_),
        "fin": lambda p: p,
        "red": lambda c, pf: c + pf,
    }


def make_blocks(key, nb: int, b: int, *, with_partials: Tuple[int, ...] = (),
                seed: int = 0) -> Dict[Tuple, np.ndarray]:
    """Random A/B blocks, zero C blocks (and zero 3D partials if requested)."""
    rng = np.random.default_rng(seed)
    blocks: Dict[Tuple, np.ndarray] = {}
    for i in range(nb):
        for j in range(nb):
            blocks[("A", i, j)] = rng.standard_normal((b, b)).astype(np.float32)
            blocks[("B", i, j)] = rng.standard_normal((b, b)).astype(np.float32)
            blocks[("C", i, j)] = np.zeros((b, b), np.float32)
            for l in with_partials:
                blocks[("P", i, j, l)] = np.zeros((b, b), np.float32)
    return blocks


def assemble(blocks: Dict[Tuple, np.ndarray], kind: str, nb: int, b: int):
    out = np.zeros((nb * b, nb * b), np.float32)
    for i in range(nb):
        for j in range(nb):
            out[i * b:(i + 1) * b, j * b:(j + 1) * b] = blocks[(kind, i, j)]
    return out
