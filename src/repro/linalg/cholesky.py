"""Distributed blocked Cholesky as a declarative PTG — the paper's §III-C
flagship app, declared once through the unified ``repro.ptg`` front-end.

Right-looking variant of Algorithm 1, in the PTG form of Fig 8:

    potrf(k):        L_kk   = chol(A_kk)
    trsm(i,k):       L_ik   = A_ik · L_kk^{-T}                (i > k)
    syrk(k,i):       A_ii  -= L_ik · L_ikᵀ                    (i > k)
    gemm(k,i,j):     A_ij  -= L_ik · L_jkᵀ                    (i > j > k)

Each task type declares only the blocks it reads and the block it writes;
the whole dependency web of Fig 8 — panel broadcasts, trailing-update
chains, the syrk→potrf hand-off down the diagonal — is *derived* by the
builder from those access patterns over the factorization's sequential
program order (``Graph.sequence``), with in/out edges mutual inverses by
construction.

Blocks are 2D block-cyclic on a pr×pc grid. Factor blocks L_ik get fresh
block ids (single assignment) because they cross shards: potrf/trsm results
are exactly the payloads the paper ships via (large) active messages, while
the A_ij update accumulations stay owner-local (read-modify-write).
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.schedule import BlockPTGSpec, BlockProgram, build_block_program
from repro.ptg import Graph, IndexSpace


def cholesky_graph(nb: int, pr: int, pc: int, b: int,
                   dtype=jnp.float32) -> Graph:
    def owner(blk) -> int:
        _, i, j = blk
        return (i % pr) * pc + (j % pc)

    g = Graph("cholesky", n_shards=pr * pc, owner=owner,
              block_shape=(b, b), dtype=dtype)
    g.task_type("potrf",
                writes=lambda k: ("L", k, k),
                reads=lambda k: [("A", k, k)])
    g.task_type("trsm",
                writes=lambda i, k: ("L", i, k),
                reads=lambda i, k: [("A", i, k), ("L", k, k)])
    g.task_type("syrk",
                writes=lambda k, i: ("A", i, i),
                reads=lambda k, i: [("A", i, i), ("L", i, k)])
    g.task_type("gemm",
                writes=lambda k, i, j: ("A", i, j),
                reads=lambda k, i, j: [("A", i, j), ("L", i, k), ("L", j, k)])

    def program():
        # the right-looking factorization's sequential order: the access
        # scan over this order reproduces Fig 8's PTG edge-for-edge
        for k in range(nb):
            yield ("potrf", k)
            for i in range(k + 1, nb):
                yield ("trsm", i, k)
            for i in range(k + 1, nb):
                yield ("syrk", k, i)
            for i in range(k + 1, nb):
                for j in range(k + 1, i):
                    yield ("gemm", k, i, j)

    def res(lo: int, hi: int, p: int, r: int):
        """Indices in [lo, hi) congruent to r mod p."""
        return range(lo + (r - lo) % p, hi, p)

    def owned(shard):
        # the triangular space partitions by block-cyclic residue: each
        # task type's written block fixes a (row mod pr, col mod pc)
        # residue class, so the shard walks only its own rows/columns —
        # O(owned) instead of the O(nb³) full triangle
        r0, c0 = divmod(shard, pc)
        for k in range(nb):
            if k % pr == r0 and k % pc == c0:
                yield ("potrf", k)                       # writes L_kk
            if k % pc == c0:
                for i in res(k + 1, nb, pr, r0):
                    yield ("trsm", i, k)                 # writes L_ik
            for i in res(k + 1, nb, pr, r0):
                if i % pc == c0:
                    yield ("syrk", k, i)                 # writes A_ii
            for i in res(k + 1, nb, pr, r0):
                for j in res(k + 1, i, pc, c0):
                    yield ("gemm", k, i, j)              # writes A_ij

    n_tasks = (nb + 2 * (nb * (nb - 1) // 2)
               + nb * (nb - 1) * (nb - 2) // 6)
    g.sequence(IndexSpace(program, owned, size=n_tasks))
    return g


def cholesky_spec(nb: int, pr: int, pc: int, b: int,
                  dtype=jnp.float32, *, lazy: bool = True) -> BlockPTGSpec:
    """Spec via lazy per-shard derivation by default; ``lazy=False`` is the
    eager global-scan oracle (identical program either way)."""
    return cholesky_graph(nb, pr, pc, b, dtype=dtype).to_block_spec(lazy=lazy)


def cholesky_program(nb: int, pr: int, pc: int, b: int,
                     dtype=jnp.float32) -> BlockProgram:
    """Discover + lower the Cholesky PTG onto the shared comm-planning
    layer. Its panel broadcasts (potrf -> column trsms, trsm -> trailing
    updates) activate only O(grid) of the n² shard pairs per wavefront, so
    the classified plan lowers them to ppermute rounds — the wire carries
    ~10x less padding than the dense all_to_all (see comm_stats)."""
    return build_block_program(cholesky_spec(nb, pr, pc, b, dtype=dtype))


def cholesky_executor(prog: BlockProgram, mesh, axis: str = "shards", *,
                      matmul=None, trsm=None, unroll_cap: int = 64,
                      **policy):
    """Sparsity-aware Cholesky executor with compute/comm overlap: wavefront
    w's panel broadcast is issued before w+1's halo-independent trailing
    updates (owner-local A_ij accumulations), the paper's Fig 9 overlap.
    ``policy`` kwargs (``comm``/``overlap``/``segment_cap``/
    ``density_threshold``) pass through to ``auto_executor``, whose ladder
    is: unrolled below ``unroll_cap``; segmented scan when the exact comm
    signatures form few runs; **union-cover scan** when they fragment (deep
    Cholesky's panel broadcasts change shape every panel) but the union
    permutation cover's wire still beats the dense scan's; the pure dense
    scan only as the loudly-reported last resort. ``matmul``/``trsm`` are
    pluggable bodies — pass e.g. ``repro.kernels.block_gemm.ops.task_matmul``
    to run the trailing updates as a fused Pallas kernel per wavefront
    (the jnp default stays the numerical oracle)."""
    return prog.auto_executor(cholesky_bodies(matmul, trsm), mesh, axis,
                              unroll_cap=unroll_cap, **policy)


def cholesky_bodies(matmul=None, trsm=None) -> Dict[str, object]:
    """Per-block bodies; matmul/trsm pluggable (jnp fallback or Pallas)."""
    mm = matmul if matmul is not None else lambda a, b: a @ b

    def _trsm(a, l_kk):
        # Solve X · L_kkᵀ = A_ik  =>  X = A_ik · L_kk^{-T}
        return jax.scipy.linalg.solve_triangular(
            l_kk, a.T, lower=True, trans="N").T

    return {
        "potrf": lambda a: jnp.linalg.cholesky(a),
        "trsm": trsm if trsm is not None else _trsm,
        "syrk": lambda a, l: a - mm(l, l.T),
        "gemm": lambda a, li, lj: a - mm(li, lj.T),
    }


def cholesky_bodies_numpy() -> Dict[str, object]:
    """Fork-safe pure-numpy bodies. The ``multiproc`` transport forks one
    process per rank; calling into an inherited XLA runtime from a forked
    child can deadlock, so cross-process runs use these. Bit-identity
    across transports holds when both sides run the *same* body set."""
    import scipy.linalg as sla

    def _trsm(a, l_kk):
        return sla.solve_triangular(l_kk, a.T, lower=True, trans="N").T

    return {
        "potrf": lambda a: np.linalg.cholesky(a),
        "trsm": _trsm,
        "syrk": lambda a, l: a - l @ l.T,
        "gemm": lambda a, li, lj: a - li @ lj.T,
    }


def make_spd_blocks(nb: int, b: int, seed: int = 0) -> Dict[Tuple, np.ndarray]:
    """Random SPD matrix, returned as lower-triangle blocks {("A", i, j)}."""
    rng = np.random.default_rng(seed)
    n = nb * b
    m = rng.standard_normal((n, n)).astype(np.float32)
    a = (m @ m.T) / n + np.eye(n, dtype=np.float32) * 2.0
    blocks: Dict[Tuple, np.ndarray] = {}
    for i in range(nb):
        for j in range(i + 1):
            blocks[("A", i, j)] = a[i * b:(i + 1) * b, j * b:(j + 1) * b].copy()
    return blocks, a


def assemble_lower(blocks: Dict[Tuple, np.ndarray], nb: int, b: int):
    """Assemble L from ("L", i, k) blocks (strict upper ignored)."""
    out = np.zeros((nb * b, nb * b), np.float32)
    for i in range(nb):
        for k in range(i + 1):
            blk = blocks.get(("L", i, k))
            if blk is not None:
                out[i * b:(i + 1) * b, k * b:(k + 1) * b] = blk
    out[np.triu_indices(nb * b, 1)] = 0.0
    return out
