"""Distributed blocked Cholesky as a PTG — the paper's §III-C benchmark app.

Right-looking variant of Algorithm 1, in the PTG form of Fig 8:

    potrf(k):        L_kk   = chol(A_kk)
    trsm(i,k):       L_ik   = A_ik · L_kk^{-T}                (i > k)
    syrk(k,i):       A_ii  -= L_ik · L_ikᵀ                    (i > k)
    gemm(k,i,j):     A_ij  -= L_ik · L_jkᵀ                    (i > j > k)

Blocks are 2D block-cyclic on a pr×pc grid. Factor blocks L_ik get fresh
block ids (single assignment) because they cross shards: potrf/trsm results
are exactly the payloads the paper ships via (large) active messages, while
the A_ij update accumulations stay owner-local (read-modify-write).

Priorities follow the paper's reference [5] in spirit: tasks on the
critical path (small k first, potrf > trsm > updates) are preferred.
"""

from __future__ import annotations

from typing import Dict, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.discovery import PTG
from repro.core.schedule import BlockPTGSpec, BlockProgram, build_block_program


def cholesky_spec(nb: int, pr: int, pc: int, b: int,
                  dtype=jnp.float32) -> BlockPTGSpec:
    def owner(blk) -> int:
        _, i, j = blk
        return (i % pr) * pc + (j % pc)

    def block_of(t):
        tt = t[0]
        if tt == "potrf":                        # ("potrf", k)
            return ("L", t[1], t[1])
        if tt == "trsm":                         # ("trsm", i, k)
            return ("L", t[1], t[2])
        if tt == "syrk":                         # ("syrk", k, i)
            return ("A", t[2], t[2])
        _, k, i, j = t                           # ("gemm", k, i, j)
        return ("A", i, j)

    def mapping(t):
        return owner(block_of(t))

    def operands(t):
        tt = t[0]
        if tt == "potrf":
            k = t[1]
            return [("A", k, k)]
        if tt == "trsm":
            _, i, k = t
            return [("A", i, k), ("L", k, k)]
        if tt == "syrk":
            _, k, i = t
            return [("A", i, i), ("L", i, k)]
        _, k, i, j = t
        return [("A", i, j), ("L", i, k), ("L", j, k)]

    def in_deps(t):
        tt = t[0]
        if tt == "potrf":
            k = t[1]
            return [] if k == 0 else [("syrk", k - 1, k)]
        if tt == "trsm":
            _, i, k = t
            deps = [("potrf", k)]
            if k > 0:
                deps.append(("gemm", k - 1, i, k))
            return deps
        if tt == "syrk":
            _, k, i = t
            deps = [("trsm", i, k)]
            if k > 0:
                deps.append(("syrk", k - 1, i))
            return deps
        _, k, i, j = t
        deps = [("trsm", i, k), ("trsm", j, k)]
        if k > 0:
            deps.append(("gemm", k - 1, i, j))
        return deps

    def out_deps(t):
        tt = t[0]
        out = []
        if tt == "potrf":
            k = t[1]
            out = [("trsm", i, k) for i in range(k + 1, nb)]
        elif tt == "trsm":
            _, i, k = t
            out.append(("syrk", k, i))
            out.extend(("gemm", k, i, j) for j in range(k + 1, i))
            out.extend(("gemm", k, i2, i) for i2 in range(i + 1, nb))
        elif tt == "syrk":
            _, k, i = t
            out.append(("potrf", i) if i == k + 1 else ("syrk", k + 1, i))
        else:
            _, k, i, j = t
            out.append(("trsm", i, j) if j == k + 1 else ("gemm", k + 1, i, j))
        return out

    def type_of(t):
        return t[0]

    return BlockPTGSpec(
        ptg=PTG(in_deps, out_deps, mapping, type_of),
        seeds=[("potrf", 0)], n_shards=pr * pc, block_shape=(b, b),
        block_of=block_of, operands=operands, owner=owner, dtype=dtype)


def cholesky_program(nb: int, pr: int, pc: int, b: int,
                     dtype=jnp.float32) -> BlockProgram:
    """Discover + lower the Cholesky PTG onto the shared comm-planning
    layer. Its panel broadcasts (potrf -> column trsms, trsm -> trailing
    updates) activate only O(grid) of the n² shard pairs per wavefront, so
    the classified plan lowers them to ppermute rounds — the wire carries
    ~10x less padding than the dense all_to_all (see comm_stats)."""
    return build_block_program(cholesky_spec(nb, pr, pc, b, dtype=dtype))


def cholesky_executor(prog: BlockProgram, mesh, axis: str = "shards", *,
                      matmul=None, trsm=None, unroll_cap: int = 64):
    """Sparsity-aware Cholesky executor with compute/comm overlap: wavefront
    w's panel broadcast is issued before w+1's halo-independent trailing
    updates (owner-local A_ij accumulations), the paper's Fig 9 overlap."""
    return prog.auto_executor(cholesky_bodies(matmul, trsm), mesh, axis,
                              unroll_cap=unroll_cap)


def cholesky_bodies(matmul=None, trsm=None) -> Dict[str, object]:
    """Per-block bodies; matmul/trsm pluggable (jnp fallback or Pallas)."""
    mm = matmul if matmul is not None else lambda a, b: a @ b

    def _trsm(a, l_kk):
        # Solve X · L_kkᵀ = A_ik  =>  X = A_ik · L_kk^{-T}
        return jax.scipy.linalg.solve_triangular(
            l_kk, a.T, lower=True, trans="N").T

    return {
        "potrf": lambda a: jnp.linalg.cholesky(a),
        "trsm": trsm if trsm is not None else _trsm,
        "syrk": lambda a, l: a - mm(l, l.T),
        "gemm": lambda a, li, lj: a - mm(li, lj.T),
    }


def make_spd_blocks(nb: int, b: int, seed: int = 0) -> Dict[Tuple, np.ndarray]:
    """Random SPD matrix, returned as lower-triangle blocks {("A", i, j)}."""
    rng = np.random.default_rng(seed)
    n = nb * b
    m = rng.standard_normal((n, n)).astype(np.float32)
    a = (m @ m.T) / n + np.eye(n, dtype=np.float32) * 2.0
    blocks: Dict[Tuple, np.ndarray] = {}
    for i in range(nb):
        for j in range(i + 1):
            blocks[("A", i, j)] = a[i * b:(i + 1) * b, j * b:(j + 1) * b].copy()
    return blocks, a


def assemble_lower(blocks: Dict[Tuple, np.ndarray], nb: int, b: int):
    """Assemble L from ("L", i, k) blocks (strict upper ignored)."""
    out = np.zeros((nb * b, nb * b), np.float32)
    for i in range(nb):
        for k in range(i + 1):
            blk = blocks.get(("L", i, k))
            if blk is not None:
                out[i * b:(i + 1) * b, k * b:(k + 1) * b] = blk
    out[np.triu_indices(nb * b, 1)] = 0.0
    return out
