"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation (the dry-run contract).

``input_specs(cfg, cell)`` returns (args, arg_specs) for the step kind:
- train:   (params, opt_state, batch)          -> train_step
- prefill: (params, batch)                     -> prefill_step
- decode:  (params, token, cache)              -> serve_step
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeCell
from repro.dist.sharding import (batch_axis, cache_specs, kv_head_pad,
                                 param_specs, sanitize_specs)
from repro.models import transformer as tfm
from repro.train.optimizer import make_optimizer, opt_state_specs


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh):
    """(batch pytree of ShapeDtypeStruct, batch pytree of PartitionSpec)."""
    b, s = cell.global_batch, cell.seq_len
    bn = batch_axis(mesh, b)
    batch: Dict[str, Any] = {}
    spec: Dict[str, Any] = {}
    if cfg.embed_inputs and cfg.family != "encdec":
        batch["embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        spec["embeds"] = P(bn, None, None)
    elif cfg.family == "encdec":
        batch["enc_embeds"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
        spec["enc_embeds"] = P(bn, None, None)
        batch["tokens"] = _sds((b, s), jnp.int32)
        spec["tokens"] = P(bn, None)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
        spec["tokens"] = P(bn, None)
    if cell.kind == "train":
        batch["labels"] = _sds((b, s), jnp.int32)
        spec["labels"] = P(bn, None)
    return batch, spec


def abstract_state(cfg: ModelConfig):
    params = tfm.abstract_params(cfg)
    init_opt, _ = make_optimizer(cfg.optimizer)
    opt = jax.eval_shape(init_opt, params)
    return params, opt


def input_specs(cfg: ModelConfig, cell: ShapeCell, mesh: Mesh
                ) -> Tuple[tuple, tuple]:
    """-> (abstract_args, arg_partition_specs) for the cell's step kind."""
    model_axis = mesh.shape["model"]
    params, opt = abstract_state(cfg)
    p_specs = sanitize_specs(param_specs(cfg, model_axis=model_axis),
                             params, mesh)
    bn = batch_axis(mesh, cell.global_batch)

    if cell.kind == "train":
        batch, b_spec = batch_specs(cfg, cell, mesh)
        o_specs = sanitize_specs(
            opt_state_specs(p_specs, cfg.optimizer, params), opt, mesh)
        return (params, opt, batch), (p_specs, o_specs, b_spec)

    if cell.kind == "prefill":
        batch, b_spec = batch_specs(cfg, cell, mesh)
        return (params, batch), (p_specs, b_spec)

    # decode: one new token against a seq_len-deep cache
    b = cell.global_batch
    enc_out = None
    if cfg.family == "encdec":
        hd, hkv = cfg.head_dim, cfg.n_kv_heads
        enc_out = (_sds((cfg.n_layers, b, hkv, cell.seq_len, hd),
                        jnp.bfloat16),
                   _sds((cfg.n_layers, b, hkv, cell.seq_len, hd),
                        jnp.bfloat16))
    cache = jax.eval_shape(
        lambda: tfm.init_cache(cfg, b, cell.seq_len, enc_out=enc_out,
                               kv_head_pad=kv_head_pad(cfg, model_axis)))
    c_specs = sanitize_specs(
        cache_specs(cfg, cache, bn, model_axis=model_axis), cache, mesh)
    if cfg.embed_inputs and cfg.family != "encdec":
        # decode follows a multimodal prefill; new steps are text tokens
        token = _sds((b,), jnp.int32)
    else:
        token = _sds((b,), jnp.int32)
    return (params, token, cache), (p_specs, P(bn), c_specs)
