import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: the jit'd
step (train/prefill/serve per shape kind) must lower and compile against
the production mesh with ShapeDtypeStruct inputs. Emits one JSON per cell:
memory_analysis (fits-or-not per device), cost_analysis (FLOPs/bytes for
§Roofline), and collective bytes parsed from the partitioned HLO.

Usage:
    python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
    python -m repro.launch.dryrun --all [--multi-pod]   # sweep (sequential)
"""

import argparse   # noqa: E402
import json       # noqa: E402
import re         # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.configs.base import SHAPES, shapes_for          # noqa: E402
from repro.configs.registry import all_archs, get_config   # noqa: E402
from repro.dist.ctx import set_batch_axes, set_seq_shard, use_mesh  # noqa: E402
from repro.dist.sharding import batch_axis, named_shardings  # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.specs import input_specs                 # noqa: E402
from repro.serve.decode import make_prefill_step, make_serve_step  # noqa: E402
from repro.train.train_step import make_train_step         # noqa: E402

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _result_bytes(line: str, kind: str) -> int:
    """Sum byte sizes of the op's result type(s): the text between `=` and
    the op name, e.g. `%ar = (bf16[128,512], bf16[64]) all-reduce(...)`."""
    if "=" not in line:
        return 0
    rhs = line.split("=", 1)[1]
    head = rhs.split(f" {kind}", 1)[0]
    total = 0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective wire bytes (per device), from the partitioned HLO.

    Result-shape bytes approximate bytes moved per device; all-reduce counts
    2x (ring reduce-scatter + all-gather). `fusion`-wrapped collectives do
    not occur post-SPMD for these ops.
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if s.startswith("%") or " = " in s:
            for kind in _COLLECTIVES:
                # match op name, e.g. "all-reduce(" or "all-gather-start("
                if f" {kind}(" in s or f" {kind}-start(" in s:
                    nbytes = _result_bytes(s, kind)
                    if kind == "all-reduce":
                        nbytes *= 2
                    out[kind] += nbytes
                    counts[kind] += 1
                    break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = counts
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             unroll: bool = False) -> dict:
    if unroll:
        # exact costing pass: XLA counts while bodies once, so unroll all
        # scans (see launch/flags.py); slower compile, exact flops/bytes/
        # collectives
        os.environ["REPRO_UNROLL_SCANS"] = "1"
    cfg = get_config(arch)
    cells = {c.name: c for c in shapes_for(cfg)}
    if shape_name not in cells:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention "
                          "(full-attention arch; DESIGN.md §5)"}
    cell = cells[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)

    if cell.kind == "train":
        step = make_train_step(cfg)
    elif cell.kind == "prefill":
        step = make_prefill_step(cfg)
    else:
        step = make_serve_step(cfg)

    t0 = time.time()
    set_batch_axes(batch_axis(mesh, cell.global_batch))
    set_seq_shard(cell.kind != "decode"
                  and cell.seq_len % mesh.shape["model"] == 0)
    # donate the training state / decode cache: the updated copy aliases the
    # input buffer instead of double-buffering it (EXPERIMENTS §Perf A4)
    donate = ()
    if os.environ.get("REPRO_DONATE", "1") == "1":
        donate = (0, 1) if cell.kind == "train" else \
            ((2,) if cell.kind == "decode" else ())
    with use_mesh(mesh):
        args, arg_specs = input_specs(cfg, cell, mesh)
        shardings = named_shardings(mesh, arg_specs)
        jitted = jax.jit(step, in_shardings=shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per device kind
        cost = cost[0] if cost else None
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    n_chips = mesh.devices.size
    result = {
        "arch": arch, "shape": shape_name, "kind": cell.kind,
        "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "status": "ok",
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "n_params": cfg.n_params(), "n_active_params": cfg.n_active_params(),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "per_device": {
            "flops": cost.get("flops", 0.0) if cost else None,
            "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else None,
            "transcendentals": cost.get("transcendentals", 0.0) if cost else 0,
            "argument_bytes": mem.argument_size_in_bytes if mem else None,
            "output_bytes": mem.output_size_in_bytes if mem else None,
            "temp_bytes": mem.temp_size_in_bytes if mem else None,
            "code_bytes": mem.generated_code_size_in_bytes if mem else None,
            "alias_bytes": mem.alias_size_in_bytes if mem else None,
            "collective_bytes": coll,
        },
        "n_chips": int(n_chips),
        "hlo_lines": hlo.count("\n"),
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="exact-cost pass (unrolled scans)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="reports/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    cells = []
    if args.all:
        for arch in all_archs():
            for cell in SHAPES:
                cells.append((arch, cell.name))
    else:
        cells.append((args.arch, args.shape))

    failures = 0
    for arch, shape in cells:
        tag = "multi" if args.multi_pod else "pod"
        if args.unroll:
            tag += "_unrolled"
        out = os.path.join(args.out_dir, f"{arch}__{shape}__{tag}.json")
        if os.path.exists(out):
            print(f"[skip existing] {out}", flush=True)
            continue
        print(f"[dryrun] {arch} x {shape} ({tag}) ...", flush=True)
        try:
            result = run_cell(arch, shape, args.multi_pod, args.unroll)
        except Exception as e:  # recorded, sweep continues
            result = {"arch": arch, "shape": shape, "status": "error",
                      "error": repr(e),
                      "trace": traceback.format_exc()[-3000:]}
            failures += 1
        with open(out, "w") as f:
            json.dump(result, f, indent=2)
        print(f"  -> {result['status']} "
              f"({result.get('compile_s', '-')}s compile)", flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
