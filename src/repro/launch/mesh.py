"""Production meshes.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism across ICI-disjoint pods (DCN), so only
gradient all-reduces cross it.

A FUNCTION, not a module constant: importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2, data: int = 2):
    """Small mesh over forced host devices (tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
