"""Scheduler-service launcher: a resident multi-tenant submission demo.

    python -m repro.launch.scheduler --shards 2 --clients 4 \
        --submissions 8 --verify

Starts one :class:`repro.sched.SchedulerService` (ranks stay resident
between submissions), registers N clients with distinct fair-share
weights, and streams M submissions per client into it concurrently —
cycling through the four Task-Bench dependence patterns plus a blocked
Cholesky as the linalg family. ``--verify`` replays every distinct graph
through the one-shot ``Graph.run_host`` path and checks the stream's
results are bit-identical; the exit prints per-client accounting
(tasks / bytes / wall) and the service's retirement stats (``live_frac``
near 0 means memory tracked the live frontier, not the stream's history).

Chaos mode exercises the survivable-stream machinery:

    python -m repro.launch.scheduler --kill 1:40 --chaos 0.1 --verify

``--kill RANK:AT_MSG`` crashes a resident rank at its AT_MSG-th user AM
send; ``--chaos P`` adds P message loss and duplication on every edge;
``--deadline S`` bounds each submission's life. The exit then prints the
:class:`~repro.core.faults.RecoveryReport` — replayed bus commands and
sends, re-executed tasks, forwarded AMs — plus ``sched_recover_ms``
(death declaration -> the at-death in-flight set drained).
"""

import argparse
import sys
import threading
import time
from pathlib import Path


def run_stream(svc, n_clients: int, n_submissions: int, *, width: int,
               depth: int, nb: int, seed: int = 7,
               deadline: float = None):
    """Drive ``n_clients`` concurrent client threads, each submitting
    ``n_submissions`` mixed PTGs (Task-Bench patterns + Cholesky, each in
    a fresh namespace). Returns ``{client: [(kind, result_blocks)]}``;
    a submission shed by its ``deadline`` yields ``(kind, None)``."""
    from benchmarks.taskbench_scaling import (taskbench_blocks,
                                              taskbench_bodies,
                                              taskbench_graph)
    from repro.linalg.cholesky import (cholesky_bodies,
                                       cholesky_bodies_numpy,
                                       cholesky_graph, make_spd_blocks)

    patterns = ("stencil", "fft", "tree", "random")
    n = svc.n_shards
    tb_blocks = taskbench_blocks(width, depth, seed=seed)
    tb_bodies = taskbench_bodies()
    ch_blocks, _ = make_spd_blocks(nb, 4, seed=seed)
    # forked rank processes must not call into the parent's XLA runtime
    ch_bodies = cholesky_bodies_numpy() \
        if getattr(svc, "transport", None) == "multiproc" \
        else cholesky_bodies()
    results: dict = {}

    def client_thread(name: str, weight: float) -> None:
        from repro.sched import DeadlineExceeded

        c = svc.client(name, weight=weight)
        futs = []
        for j in range(n_submissions):
            ns = f"{name}/{j}"
            if j % len(patterns) == len(patterns) - 1 and j:
                futs.append(("cholesky", c.submit(
                    cholesky_graph(nb, n, 1, 4), ch_blocks, ch_bodies,
                    namespace=ns, deadline=deadline)))
            else:
                p = patterns[j % len(patterns)]
                g, _ = taskbench_graph(p, width, depth, n, seed=seed)
                futs.append((p, c.submit(g, tb_blocks, tb_bodies,
                                         namespace=ns, deadline=deadline)))
        out = []
        for kind, f in futs:
            try:
                out.append((kind, f.result(svc.timeout)))
            except DeadlineExceeded:
                out.append((kind, None))   # cleanly shed, never a hang
        results[name] = out

    threads = [threading.Thread(target=client_thread,
                                args=(f"client{i}", float(i + 1)),
                                daemon=True)
               for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--submissions", type=int, default=8,
                    help="PTGs per client")
    ap.add_argument("--width", type=int, default=4)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--nb", type=int, default=4,
                    help="Cholesky blocks per dimension")
    ap.add_argument("--threads", type=int, default=2,
                    help="worker threads per rank")
    ap.add_argument("--verify", action="store_true",
                    help="check bit-identity against one-shot executions")
    ap.add_argument("--kill", default=None, metavar="RANK:AT_MSG",
                    help="crash a resident rank at its AT_MSG-th AM send")
    ap.add_argument("--chaos", type=float, default=0.0, metavar="P",
                    help="message loss AND duplication probability")
    ap.add_argument("--deadline", type=float, default=None,
                    help="per-submission deadline in seconds")
    ap.add_argument("--seed", type=int, default=0,
                    help="fault-injection RNG seed")
    ap.add_argument("--transport", default=None,
                    choices=("inproc", "multiproc"),
                    help="comm backend the resident ranks run on "
                         "(multiproc = one OS process per rank)")
    args = ap.parse_args()

    # benchmarks/ lives at the repo root, beside src/
    sys.path.insert(0, str(Path(__file__).resolve().parents[3]))

    import numpy as np

    from repro.sched import SchedulerService

    plan = None
    if args.kill or args.chaos:
        from repro.core.faults import FaultPlan

        kill = {}
        if args.kill:
            rank, at = args.kill.split(":")
            kill[int(rank)] = int(at)
        plan = FaultPlan(seed=args.seed, drop=args.chaos,
                         duplicate=args.chaos, kill=kill)

    t0 = time.monotonic()
    with SchedulerService(args.shards, n_threads=args.threads,
                          timeout=300.0, faults=plan,
                          transport=args.transport) as svc:
        results = run_stream(svc, args.clients, args.submissions,
                             width=args.width, depth=args.depth, nb=args.nb,
                             deadline=args.deadline)
    wall = time.monotonic() - t0
    stats = svc.stats()

    total_subs = sum(len(v) for v in results.values())
    print(f"{args.clients} clients x {args.submissions} submissions on "
          f"{args.shards} resident shards: {total_subs} PTGs in {wall:.2f}s")
    for name in sorted(results):
        cs = stats["clients"][name]
        print(f"  {name}: {cs['completed']} completed, {cs['tasks']} tasks, "
              f"{cs['bytes']} bytes, {cs['wall_seconds']:.2f}s wall")
    print(f"retirement: blocks_hwm={stats['blocks_hwm']} / "
          f"blocks_total={stats['blocks_total']} "
          f"(live_frac={stats['live_frac']:.3f})")
    shed = sum(1 for rows in results.values() for _, out in rows
               if out is None)
    if shed:
        print(f"shed: {shed} submissions hit their deadline (clean "
              "DeadlineExceeded, no hangs)")
    if plan is not None and svc.recovery_report is not None:
        r = svc.recovery_report.to_dict()
        cap = svc.capacity()
        print(f"recovery: deaths={r['deaths']} "
              f"bus_replayed={r['bus_replayed']} "
              f"replayed_sends={r['replayed_sends']} "
              f"reexecuted_tasks={r['reexecuted_tasks']} "
              f"forwarded_ams={r['forwarded_ams']} "
              f"retries={r['retries']} dup_suppressed={r['dup_suppressed']}")
        if cap["sched_recover_ms"] is not None:
            print(f"recovery: sched_recover_ms="
                  f"{cap['sched_recover_ms']:.1f} "
                  f"(live_ranks={cap['live_ranks']}/{cap['n_shards']})")

    if args.verify:
        from benchmarks.taskbench_scaling import (taskbench_blocks,
                                                  taskbench_bodies,
                                                  taskbench_graph)
        from repro.linalg.cholesky import (cholesky_bodies,
                                           cholesky_bodies_numpy,
                                           cholesky_graph, make_spd_blocks)

        tb_blocks = taskbench_blocks(args.width, args.depth, seed=7)
        ch_blocks, _ = make_spd_blocks(args.nb, 4, seed=7)
        ch_bodies = cholesky_bodies_numpy() \
            if args.transport == "multiproc" else cholesky_bodies()
        refs = {}
        for kind in {k for rows in results.values() for k, _ in rows}:
            if kind == "cholesky":
                refs[kind] = cholesky_graph(args.nb, args.shards, 1, 4) \
                    .run_host(ch_blocks, ch_bodies,
                              n_threads=args.threads)
            else:
                g, _ = taskbench_graph(kind, args.width, args.depth,
                                       args.shards, seed=7)
                refs[kind] = g.run_host(tb_blocks, taskbench_bodies(),
                                        n_threads=args.threads)
        for name, rows in results.items():
            for kind, out in rows:
                if out is None:
                    continue   # shed by deadline: nothing to compare
                for blk, v in out.items():
                    assert np.array_equal(np.asarray(v),
                                          np.asarray(refs[kind][blk])), \
                        (name, kind, blk)
        print(f"verify: all {total_subs} submissions bit-identical to "
              f"one-shot executions")


if __name__ == "__main__":
    main()
