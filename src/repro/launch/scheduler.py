"""Scheduler-service launcher: a resident multi-tenant submission demo.

    python -m repro.launch.scheduler --shards 2 --clients 4 \
        --submissions 8 --verify

Starts one :class:`repro.sched.SchedulerService` (ranks stay resident
between submissions), registers N clients with distinct fair-share
weights, and streams M submissions per client into it concurrently —
cycling through the four Task-Bench dependence patterns plus a blocked
Cholesky as the linalg family. ``--verify`` replays every distinct graph
through the one-shot ``Graph.run_host`` path and checks the stream's
results are bit-identical; the exit prints per-client accounting
(tasks / bytes / wall) and the service's retirement stats (``live_frac``
near 0 means memory tracked the live frontier, not the stream's history).
"""

import argparse
import sys
import threading
import time
from pathlib import Path


def run_stream(svc, n_clients: int, n_submissions: int, *, width: int,
               depth: int, nb: int, seed: int = 7):
    """Drive ``n_clients`` concurrent client threads, each submitting
    ``n_submissions`` mixed PTGs (Task-Bench patterns + Cholesky, each in
    a fresh namespace). Returns ``{client: [(kind, result_blocks)]}``."""
    from benchmarks.taskbench_scaling import (taskbench_blocks,
                                              taskbench_bodies,
                                              taskbench_graph)
    from repro.linalg.cholesky import (cholesky_bodies, cholesky_graph,
                                       make_spd_blocks)

    patterns = ("stencil", "fft", "tree", "random")
    n = svc.n_shards
    tb_blocks = taskbench_blocks(width, depth, seed=seed)
    tb_bodies = taskbench_bodies()
    ch_blocks, _ = make_spd_blocks(nb, 4, seed=seed)
    ch_bodies = cholesky_bodies()
    results: dict = {}

    def client_thread(name: str, weight: float) -> None:
        c = svc.client(name, weight=weight)
        futs = []
        for j in range(n_submissions):
            ns = f"{name}/{j}"
            if j % len(patterns) == len(patterns) - 1 and j:
                futs.append(("cholesky", c.submit(
                    cholesky_graph(nb, n, 1, 4), ch_blocks, ch_bodies,
                    namespace=ns)))
            else:
                p = patterns[j % len(patterns)]
                g, _ = taskbench_graph(p, width, depth, n, seed=seed)
                futs.append((p, c.submit(g, tb_blocks, tb_bodies,
                                         namespace=ns)))
        results[name] = [(kind, f.result(svc.timeout)) for kind, f in futs]

    threads = [threading.Thread(target=client_thread,
                                args=(f"client{i}", float(i + 1)),
                                daemon=True)
               for i in range(n_clients)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return results


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--submissions", type=int, default=8,
                    help="PTGs per client")
    ap.add_argument("--width", type=int, default=4)
    ap.add_argument("--depth", type=int, default=3)
    ap.add_argument("--nb", type=int, default=4,
                    help="Cholesky blocks per dimension")
    ap.add_argument("--threads", type=int, default=2,
                    help="worker threads per rank")
    ap.add_argument("--verify", action="store_true",
                    help="check bit-identity against one-shot executions")
    args = ap.parse_args()

    # benchmarks/ lives at the repo root, beside src/
    sys.path.insert(0, str(Path(__file__).resolve().parents[3]))

    import numpy as np

    from repro.sched import SchedulerService

    t0 = time.monotonic()
    with SchedulerService(args.shards, n_threads=args.threads,
                          timeout=300.0) as svc:
        results = run_stream(svc, args.clients, args.submissions,
                             width=args.width, depth=args.depth, nb=args.nb)
    wall = time.monotonic() - t0
    stats = svc.stats()

    total_subs = sum(len(v) for v in results.values())
    print(f"{args.clients} clients x {args.submissions} submissions on "
          f"{args.shards} resident shards: {total_subs} PTGs in {wall:.2f}s")
    for name in sorted(results):
        cs = stats["clients"][name]
        print(f"  {name}: {cs['completed']} completed, {cs['tasks']} tasks, "
              f"{cs['bytes']} bytes, {cs['wall_seconds']:.2f}s wall")
    print(f"retirement: blocks_hwm={stats['blocks_hwm']} / "
          f"blocks_total={stats['blocks_total']} "
          f"(live_frac={stats['live_frac']:.3f})")

    if args.verify:
        from benchmarks.taskbench_scaling import (taskbench_blocks,
                                                  taskbench_bodies,
                                                  taskbench_graph)
        from repro.linalg.cholesky import (cholesky_bodies, cholesky_graph,
                                           make_spd_blocks)

        tb_blocks = taskbench_blocks(args.width, args.depth, seed=7)
        ch_blocks, _ = make_spd_blocks(args.nb, 4, seed=7)
        refs = {}
        for kind in {k for rows in results.values() for k, _ in rows}:
            if kind == "cholesky":
                refs[kind] = cholesky_graph(args.nb, args.shards, 1, 4) \
                    .run_host(ch_blocks, cholesky_bodies(),
                              n_threads=args.threads)
            else:
                g, _ = taskbench_graph(kind, args.width, args.depth,
                                       args.shards, seed=7)
                refs[kind] = g.run_host(tb_blocks, taskbench_bodies(),
                                        n_threads=args.threads)
        for name, rows in results.items():
            for kind, out in rows:
                for blk, v in out.items():
                    assert np.array_equal(np.asarray(v),
                                          np.asarray(refs[kind][blk])), \
                        (name, kind, blk)
        print(f"verify: all {total_subs} submissions bit-identical to "
              f"one-shot executions")


if __name__ == "__main__":
    main()
