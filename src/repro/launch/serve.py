"""Production serving launcher: sharded weights + batched decode loop.

    python -m repro.launch.serve --arch yi-6b --reduced --host-devices 4 \
        --batch 8 --tokens 64
"""

import argparse
import os
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--host-devices", type=int, default=0)
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import numpy as np
    import jax
    import jax.numpy as jnp

    from repro.configs.base import reduced as reduce_cfg
    from repro.configs.registry import get_config
    from repro.dist.ctx import set_batch_axes, set_seq_shard, use_mesh
    from repro.dist.sharding import (batch_axis, cache_specs, kv_head_pad,
                                     named_shardings, param_specs,
                                     sanitize_specs)
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as tfm
    from repro.serve.decode import make_serve_step

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)

    n_dev = len(jax.devices())
    if n_dev >= 256:
        mesh = make_production_mesh()
    else:
        model = max(1, min(4, n_dev))
        mesh = jax.make_mesh((n_dev // model, model), ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"arch={cfg.name}")

    set_batch_axes(batch_axis(mesh, args.batch))
    set_seq_shard(False)

    with use_mesh(mesh):
        params_abs = tfm.abstract_params(cfg)
        p_specs = sanitize_specs(
            param_specs(cfg, model_axis=mesh.shape["model"]), params_abs,
            mesh)
        p_sh = named_shardings(mesh, p_specs)
        params = jax.jit(lambda k: tfm.init_params(cfg, k),
                         out_shardings=p_sh)(jax.random.key(0))

        enc_out = None
        if cfg.family == "encdec":
            hd, hkv = cfg.head_dim, cfg.n_kv_heads
            enc_out = tuple(
                jnp.zeros((cfg.n_layers, args.batch, hkv, args.max_seq, hd),
                          jnp.bfloat16) for _ in range(2))
        cache = tfm.init_cache(cfg, args.batch, args.max_seq, enc_out=enc_out,
                               kv_head_pad=kv_head_pad(
                                   cfg, mesh.shape["model"]))
        c_specs = sanitize_specs(
            cache_specs(cfg, jax.eval_shape(lambda: cache),
                        batch_axis(mesh, args.batch),
                        model_axis=mesh.shape["model"]),
            jax.eval_shape(lambda: cache), mesh)
        cache = jax.tree.map(
            lambda x, s: jax.device_put(x, jax.NamedSharding(mesh, s)),
            cache, c_specs, is_leaf=lambda x: hasattr(x, "shape"))

        step = jax.jit(lambda p, t, c: make_serve_step(cfg)(p, t, c),
                       donate_argnums=(2,))
        tok = jnp.ones((args.batch,), jnp.int32)
        tok, _, cache = step(params, tok, cache)  # warmup/compile
        t0 = time.time()
        out = []
        for _ in range(args.tokens):
            tok, _, cache = step(params, tok, cache)
            out.append(np.asarray(tok))
        dt = time.time() - t0
        print(f"decoded {args.tokens} x batch {args.batch}: "
              f"{args.batch * args.tokens / dt:.1f} tok/s; "
              f"sample {np.stack(out, 1)[0][:12].tolist()}")


if __name__ == "__main__":
    main()
