"""Production training launcher: mesh + shardings + elastic step loop.

    python -m repro.launch.train --arch qwen3-14b --steps 1000 \
        [--multi-pod] [--microbatch 4] [--ckpt-dir ...] [--host-devices N]

On hardware this runs under one controller per host (jax.distributed);
here `--host-devices N` forces N host devices so the full code path —
production mesh, sharded state, donated step, async checkpointing,
straggler monitor, elastic restart — executes identically at toy scale.
"""

import argparse
import os
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--global-batch", type=int, default=None)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--pipeline", type=int, default=0, metavar="STAGES",
                    help="stage-parallel training on a ('pipe', 'data', "
                         "'model') mesh: the layer stack splits into STAGES "
                         "pipeline stages (repro.dist.pipeline; stage graph "
                         "from the repro.ptg builder). Microbatch count = "
                         "--microbatch if > 1 else 2*STAGES (GPipe rule).")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host devices (dev runs)")
    ap.add_argument("--data", default=None)
    ap.add_argument("--ckpt-dir", default="ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--elastic", action="store_true",
                    help="run the heartbeat/straggler/re-mesh decision loop "
                         "around the step loop: on a declared host failure "
                         "the survivors re-mesh (model axis fixed, data "
                         "axis shrunk) and restore the latest checkpoint")
    ap.add_argument("--fake-hosts", type=int, default=0,
                    help="with --elastic at dev scale: pretend the host "
                         "devices are split across N hosts")
    ap.add_argument("--kill-host", default=None, metavar="HOST@STEP",
                    help="dev fault injection: fake host HOST stops "
                         "heartbeating at STEP")
    ap.add_argument("--lease", type=float, default=2.0,
                    help="steps without a heartbeat before a host is "
                         "declared dead (--elastic)")
    ap.add_argument("--transport", default=None,
                    choices=("inproc", "multiproc"),
                    help="with --elastic: comm backend for the cross-host "
                         "control-plane preflight (every host exchanges "
                         "active messages over it before the step loop — "
                         "multiproc proves the path out of the process, "
                         "the jax.distributed-style bootstrap)")
    args = ap.parse_args()

    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices}")

    import jax

    from repro.configs.base import reduced as reduce_cfg
    from repro.configs.registry import get_config
    from repro.train.elastic import ElasticController

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    seq = args.seq or (128 if args.reduced else 4096)
    global_batch = args.global_batch or (8 if args.reduced else 256)

    all_devices = list(jax.devices())
    controller = None
    kill_host = kill_at = None
    chips_per_host = len(all_devices)
    if args.elastic:
        if args.pipeline > 1:
            sys.exit("--elastic does not compose with --pipeline yet")
        fake_hosts = args.fake_hosts or 1
        if len(all_devices) % fake_hosts:
            sys.exit(f"--fake-hosts {fake_hosts} does not divide "
                     f"{len(all_devices)} devices")
        chips_per_host = len(all_devices) // fake_hosts
        controller = ElasticController(
            n_hosts=fake_hosts, chips_per_host=chips_per_host,
            model_axis=max(1, min(4, chips_per_host)),
            dead_after=args.lease)
        if args.kill_host:
            kh, ka = args.kill_host.split("@")
            kill_host, kill_at = int(kh), int(ka)
        if args.transport:
            _transport_preflight(args.transport, fake_hosts)

    devices = list(all_devices)
    shape_override = None  # set by a re-mesh plan after a host failure
    end = None  # absolute final step, fixed across re-meshes

    while True:
        plan, end = _run_epoch(args, cfg, seq, global_batch, devices,
                               shape_override, controller, kill_host,
                               kill_at, end)
        if plan is None:
            break
        devices = [all_devices[h * chips_per_host + c]
                   for h in plan.survivors for c in range(chips_per_host)]
        shape_override = plan.mesh_shape


def _preflight_main(ctx):
    got = []
    am = ctx.comm.make_active_msg(lambda src: got.append(src))
    for d in range(ctx.n_ranks):
        if d != ctx.rank:
            am.send(d, ctx.rank)
    ctx.barrier_free_join()
    return len(got)


def _transport_preflight(transport: str, n_hosts: int) -> None:
    """Cross-host control-plane bootstrap over the pluggable comm backend
    (``repro.core.comm``): every host sends an active message to every
    other and distributed completion drains the full set — the
    jax.distributed-style rendezvous, run over real OS processes under
    ``--transport multiproc``. Fails loudly before the step loop if any
    host pair cannot exchange messages."""
    from repro.core import run_ranks

    t0 = time.time()
    counts = run_ranks(n_hosts, _preflight_main, transport=transport)
    dt = time.time() - t0
    if counts != [n_hosts - 1] * n_hosts:
        sys.exit(f"transport preflight failed: per-host AM counts {counts}")
    print(f"transport preflight [{transport}]: {n_hosts} hosts all-to-all "
          f"({n_hosts * (n_hosts - 1)} AMs) in {dt * 1e3:.1f}ms", flush=True)


def _run_epoch(args, cfg, seq, global_batch, devices, shape_override,
               controller, kill_host, kill_at, end):
    """One mesh-lifetime of the step loop. Returns ``(plan, end)``:
    ``plan`` is None on normal completion, else the ElasticPlan that
    triggered a re-mesh (the caller rebuilds the survivor mesh and calls
    again; restore-from-checkpoint happens on the way back in)."""
    import sys
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.dist.ctx import set_batch_axes, set_seq_shard, use_mesh
    from repro.dist.sharding import (batch_axis, named_shardings,
                                     param_specs, sanitize_specs)
    from repro.launch.mesh import make_production_mesh
    from repro.train import checkpoint as ckpt
    from repro.train.data import PackedBinaryDataset, SyntheticLM
    from repro.train.elastic import StragglerDetector
    from repro.train.optimizer import make_optimizer, opt_state_specs
    from repro.train.train_step import (init_train_state,
                                        make_pipeline_train_step,
                                        make_train_step)

    n_dev = len(devices)
    if shape_override is not None:
        mesh = jax.sharding.Mesh(
            np.array(devices).reshape(shape_override), ("data", "model"))
    elif args.pipeline > 1:
        # stage parallelism: ("pipe", "data", "model") — the ROADMAP's
        # pipeline_apply wiring; stage graph from the unified PTG builder
        from repro.models.transformer import layer_kinds

        if set(layer_kinds(cfg)) != {"dense"}:
            sys.exit(f"--pipeline supports the dense family for now; "
                     f"{cfg.name} is {cfg.family!r}")
        if n_dev % args.pipeline:
            sys.exit(f"--pipeline {args.pipeline} does not divide "
                     f"{n_dev} devices")
        mesh = jax.make_mesh((args.pipeline, n_dev // args.pipeline, 1),
                             ("pipe", "data", "model"))
    elif n_dev >= 512 and args.multi_pod:
        mesh = make_production_mesh(multi_pod=True)
    elif n_dev >= 256:
        mesh = make_production_mesh()
    else:  # dev-scale mesh of the same shape family
        model = (controller.model_axis if controller is not None
                 else max(1, min(4, n_dev)))
        mesh = jax.sharding.Mesh(
            np.array(devices).reshape(n_dev // model, model),
            ("data", "model"))
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"arch={cfg.name} ({cfg.n_params() / 1e9:.2f}B params), "
          f"seq={seq} batch={global_batch}")

    set_batch_axes(batch_axis(mesh, global_batch))
    set_seq_shard(seq % mesh.shape["model"] == 0)

    with use_mesh(mesh):
        p_abs = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.key(0)))
        p_specs = sanitize_specs(
            param_specs(cfg, model_axis=mesh.shape["model"]), p_abs[0], mesh)
        if args.pipeline > 1:
            # per-stage parameter stacking: each stage holds its slice of
            # the layer stack (dim 0 of every "dense" leaf over "pipe")
            from jax.sharding import PartitionSpec as P

            if cfg.n_layers % args.pipeline:
                sys.exit(f"{cfg.n_layers} layers do not split into "
                         f"{args.pipeline} equal pipeline stages")
            p_specs["dense"] = jax.tree.map(lambda _: P("pipe"),
                                            p_abs[0]["dense"])
        o_specs = sanitize_specs(
            opt_state_specs(p_specs, cfg.optimizer, p_abs[0]), p_abs[1], mesh)
        p_sh = named_shardings(mesh, p_specs)
        o_sh = named_shardings(mesh, o_specs)

        # init sharded (jit'd init writes each shard on its device)
        params, opt_state = jax.jit(
            lambda k: init_train_state(cfg, k),
            out_shardings=(p_sh, o_sh))(jax.random.key(0))

        start = 0
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            print(f"elastic restore from step {latest} "
                  f"(mesh-shape independent)")
            state = ckpt.restore(args.ckpt_dir, latest,
                                 {"params": params, "opt": opt_state},
                                 shardings={"params": p_sh, "opt": o_sh})
            params, opt_state = state["params"], state["opt"]
            start = latest

        if args.data:
            ds = PackedBinaryDataset(args.data, seq, global_batch)
        else:
            ds = SyntheticLM(cfg.vocab_size, seq, global_batch,
                             embed_dim=cfg.d_model if cfg.embed_inputs
                             else None, encdec=cfg.family == "encdec",
                             learnable=args.reduced)

        if args.pipeline > 1:
            n_micro = (args.microbatch if args.microbatch > 1
                       else 2 * args.pipeline)
            if global_batch % n_micro:
                sys.exit(f"batch {global_batch} does not split into "
                         f"{n_micro} microbatches")
            step_fn = jax.jit(
                make_pipeline_train_step(cfg, mesh, lr=args.lr,
                                         n_micro=n_micro),
                donate_argnums=(0, 1))
        else:
            step_fn = jax.jit(
                make_train_step(cfg, lr=args.lr,
                                microbatches=args.microbatch),
                donate_argnums=(0, 1))
        saver = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=3)
        monitor = StragglerDetector()
        if end is None:
            end = start + args.steps

        for step in range(start, end):
            t0 = time.time()
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            dt = time.time() - t0
            monitor.record(0, dt)  # per-host on a real cluster
            if step % 10 == 0 or step == end - 1:
                print(f"step {step:6d}  loss {float(metrics['loss']):8.4f}  "
                      f"|g| {float(metrics['grad_norm']):8.3f}  "
                      f"{global_batch * seq / dt:10.0f} tok/s", flush=True)
            if step and step % args.ckpt_every == 0:
                saver.save(step, {"params": params, "opt": opt_state})
            if controller is not None:
                # fake-host heartbeats: one controller step == one train
                # step (`now` is the step index, lease in steps). A real
                # cluster beats with wall time from every host.
                for h in controller.alive():
                    if not (h == kill_host and step >= kill_at):
                        controller.beat(h, dt, now=float(step))
                plan = controller.poll(ckpt.latest_step(args.ckpt_dir),
                                       now=float(step))
                if plan is not None:
                    print(f"host failure: survivors {plan.survivors}, "
                          f"re-mesh {plan.mesh_shape}, restore step "
                          f"{plan.restore_step}", flush=True)
                    saver.wait()  # quiesce before tearing the mesh down
                    return plan, end
        saver.save(end - 1, {"params": params, "opt": opt_state})
        saver.wait()  # quiesce (completion rule) before exit
        print("done")
    return None, end


if __name__ == "__main__":
    main()
