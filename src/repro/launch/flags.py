"""Launch-time flags threaded to model internals via env vars.

REPRO_UNROLL_SCANS=1 — unroll every lax.scan (layers + attention chunks).
XLA's cost analysis counts a while-loop body ONCE regardless of trip count,
so the dry-run compiles each cell twice: scan-form (production HLO: memory
analysis, compile proof) and unrolled (exact FLOPs/bytes/collective counts
for §Roofline). Verified empirically: scan(10 steps) and a single step
report identical `flops`.
"""

import os


def unroll_scans() -> bool:
    return os.environ.get("REPRO_UNROLL_SCANS", "0") == "1"


def scan_unroll_arg():
    return True if unroll_scans() else 1


# ---- §Perf hill-climbing knobs (env-set so dryrun cells A/B/C can sweep
# them without config surgery; defaults = paper-faithful baseline) ----

def remat_policy() -> str:
    """none | full | dots — activation-checkpoint policy for layer scans."""
    return os.environ.get("REPRO_REMAT", "full")


def moe_capacity_factor():
    v = os.environ.get("REPRO_MOE_CF")
    return float(v) if v else None


def ssd_chunk():
    v = os.environ.get("REPRO_SSD_CHUNK")
    return int(v) if v else None


def attn_chunk():
    v = os.environ.get("REPRO_ATTN_CHUNK")
    return int(v) if v else None
