"""deepseek-v3-671b [moe]: 61L d_model=7168 128H (MLA) vocab=129280,
MoE 1 shared + 256 routed top-8 (expert d_ff=2048), first 3 layers dense
(d_ff=18432), aux-loss-free sigmoid router [arXiv:2412.19437; hf].

MTP head omitted (DESIGN.md §Arch-applicability). Trains with Adafactor —
full-Adam mixed precision at 14 B/param does not fit 256 x 16 GB.
"""

from .base import MLAConfig, ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-671b", family="moe", n_layers=61, d_model=7168,
        n_heads=128, n_kv_heads=128, d_ff=18432, vocab_size=129280,
        ffn="swiglu", attention="mla",
        mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_dim=128,
                      qk_rope_dim=64, v_head_dim=128),
        moe=MoEConfig(n_experts=256, experts_per_token=8, n_shared_experts=1,
                      d_ff=2048, first_dense_layers=3, router="sigmoid"),
        optimizer="adafactor", param_dtype="bfloat16")
