"""seamless-m4t-large-v2 [audio]: 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206 — enc-dec; the audio frontend is a STUB (input_specs()
provides precomputed frame embeddings) [arXiv:2308.11596; hf]."""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="encdec", n_layers=24,
        d_model=1024, n_heads=16, n_kv_heads=16, d_head=64, d_ff=8192,
        vocab_size=256206, ffn="swiglu", encoder_layers=24,
        embed_inputs=True)
