"""Config system: one frozen dataclass per architecture + the shape cells.

Every assigned architecture gets a ``configs/<id>.py`` exposing ``config()``
with the exact published dimensions; ``reduced()`` returns the same family
shrunk for CPU smoke tests. Shape cells (train_4k / prefill_32k / decode_32k
/ long_500k) are global and filtered per-arch by the skip rules recorded in
DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    experts_per_token: int
    n_shared_experts: int = 0
    d_ff: int = 0                     # per-expert hidden dim
    first_dense_layers: int = 0       # leading layers that stay dense
    router: str = "softmax"           # softmax | sigmoid (aux-free bias)
    capacity_factor: float = 1.25


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                   # 0 -> d_model // n_heads
    ffn: str = "swiglu"               # swiglu | gelu
    qk_norm: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    attention: str = "gqa"            # gqa | mla | none
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba-style): one *shared* attention block applied every
    # `shared_attn_every` backbone layers
    shared_attn_every: int = 0
    # enc-dec
    encoder_layers: int = 0
    # frontends ([vlm]/[audio]): inputs arrive as precomputed embeddings
    embed_inputs: bool = False
    # long-context policy: True iff attention cost per decoded token is O(1)
    # (SSM state) or windowed — full-attention archs skip long_500k
    subquadratic: bool = False
    sliding_window: int = 0           # used by hybrid shared-attn at 500k
    # training knobs
    optimizer: str = "adamw"          # adamw | adafactor (giant archs)
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    def n_params(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.n_layers
        p = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.attention == "gqa":
            hd = self.head_dim
            per_layer += d * hd * (self.n_heads + 2 * self.n_kv_heads)
            per_layer += self.n_heads * hd * d
        elif self.attention == "mla":
            m = self.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            per_layer += d * m.q_lora_rank + m.q_lora_rank * self.n_heads * qk
            per_layer += d * (m.kv_lora_rank + m.qk_rope_dim)
            per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim
                                                          + m.v_head_dim)
            per_layer += self.n_heads * m.v_head_dim * d
        if self.ssm is not None:
            di = self.ssm.d_inner(d)
            nh = self.ssm.n_heads(d)
            g = self.ssm.n_groups
            per_layer_ssm = d * (2 * di + 2 * g * self.ssm.d_state + nh) + di * d
            per_layer = per_layer + per_layer_ssm if self.family == "hybrid" \
                else per_layer_ssm
        ff_mult = 3 if self.ffn == "swiglu" else 2
        if self.moe is not None:
            moe_layers = L - self.moe.first_dense_layers
            dense_layers = self.moe.first_dense_layers
            per_moe = (self.moe.n_experts + self.moe.n_shared_experts) \
                * ff_mult * d * self.moe.d_ff + d * self.moe.n_experts
            p += moe_layers * (per_layer + per_moe)
            p += dense_layers * (per_layer + ff_mult * d * self.d_ff)
        elif self.family in ("ssm",):
            p += L * per_layer
        elif self.family == "hybrid":
            p += L * per_layer_ssm
            hd = self.head_dim
            shared = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                + self.n_heads * hd * d + ff_mult * d * self.d_ff
            p += shared  # one shared block
        else:
            layers = L + self.encoder_layers
            p += layers * (per_layer + ff_mult * d * self.d_ff)
        return p

    def n_active_params(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        full = self.n_params()
        ff_mult = 3 if self.ffn == "swiglu" else 2
        moe_layers = L - self.moe.first_dense_layers
        all_experts = moe_layers * self.moe.n_experts * ff_mult * d * self.moe.d_ff
        active = moe_layers * self.moe.experts_per_token * ff_mult * d \
            * self.moe.d_ff
        return full - all_experts + active


@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train | prefill | decode


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4_096, 256, "train"),
    ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    ShapeCell("decode_32k", 32_768, 128, "decode"),
    ShapeCell("long_500k", 524_288, 1, "decode"),
)


def shapes_for(cfg: ModelConfig) -> List[ShapeCell]:
    """Skip rules (DESIGN.md §Arch-applicability): long_500k only for
    subquadratic archs; decode for every arch here (all have decoders)."""
    cells = []
    for cell in SHAPES:
        if cell.name == "long_500k" and not cfg.subquadratic:
            continue
        cells.append(cell)
    return cells


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Shrink a config for CPU smoke tests, keeping the family intact."""
    small = dict(
        n_layers=min(cfg.n_layers, 2),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        d_head=32,
        d_ff=256,
        vocab_size=512,
    )
    if cfg.moe is not None:
        small["moe"] = dataclasses.replace(
            cfg.moe, n_experts=min(cfg.moe.n_experts, 8), d_ff=128,
            first_dense_layers=min(cfg.moe.first_dense_layers, 1))
    if cfg.mla is not None:
        small["mla"] = MLAConfig(q_lora_rank=64, kv_lora_rank=32,
                                 qk_nope_dim=16, qk_rope_dim=16, v_head_dim=32)
    if cfg.ssm is not None:
        small["ssm"] = dataclasses.replace(cfg.ssm, d_state=16, head_dim=32)
    if cfg.encoder_layers:
        small["encoder_layers"] = 2
    if cfg.shared_attn_every:
        small["shared_attn_every"] = 2
        small["n_layers"] = 4
    small.update(overrides)
    return dataclasses.replace(cfg, **small)
