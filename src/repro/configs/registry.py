"""Architecture registry: --arch <id> -> ModelConfig."""

from importlib import import_module

ARCHS = {
    "llava-next-34b": "llava_next_34b",
    "qwen3-14b": "qwen3_14b",
    "yi-34b": "yi_34b",
    "starcoder2-3b": "starcoder2_3b",
    "yi-6b": "yi_6b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "mamba2-1.3b": "mamba2_1_3b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "grok-1-314b": "grok_1_314b",
    "zamba2-1.2b": "zamba2_1_2b",
}


def get_config(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return import_module(f"repro.configs.{ARCHS[arch]}").config()


def all_archs():
    return list(ARCHS)
