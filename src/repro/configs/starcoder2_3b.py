"""starcoder2-3b [dense]: 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152 — GQA, RoPE, non-GLU MLP (d_ff = 4d) [arXiv:2402.19173; hf]."""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b", family="dense", n_layers=30, d_model=3072,
        n_heads=24, n_kv_heads=2, d_head=128, d_ff=12288, vocab_size=49152,
        ffn="gelu", rope_theta=1e5)
