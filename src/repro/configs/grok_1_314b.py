"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2 [hf:xai-org/grok-1; unverified].

Trains with Adafactor (giant-arch memory policy, DESIGN.md)."""

from .base import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
        n_heads=48, n_kv_heads=8, d_head=128, d_ff=32768, vocab_size=131072,
        ffn="gelu",
        moe=MoEConfig(n_experts=8, experts_per_token=2, d_ff=32768),
        optimizer="adafactor", param_dtype="bfloat16")
