"""mamba2-1.3b [ssm]: 48L d_model=2048 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified]."""

from .base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-1.3b", family="ssm", n_layers=48, d_model=2048,
        n_heads=0, n_kv_heads=0, d_head=64, d_ff=0, vocab_size=50280,
        attention="none", tie_embeddings=True, subquadratic=True,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1))
