"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 — anyres tiling; the modality frontend is a STUB
(input_specs() provides precomputed anyres patch embeddings)
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]."""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
        n_heads=56, n_kv_heads=8, d_head=128, d_ff=20480, vocab_size=64000,
        ffn="swiglu", rope_theta=5e6, embed_inputs=True)
