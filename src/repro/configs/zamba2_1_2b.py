"""zamba2-1.2b [hybrid]: 38L d_model=2048 32H (kv=32) d_ff=8192
vocab=32000, ssm_state=64 — Mamba2 backbone + one *shared* attention block
applied every 6 layers (zamba-style) [arXiv:2411.15242; hf].

Long-context: the shared attention block uses a sliding window at 500k, so
long_500k runs (subquadratic)."""

from .base import ModelConfig, SSMConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
        n_heads=32, n_kv_heads=32, d_head=64, d_ff=8192, vocab_size=32000,
        ffn="swiglu", tie_embeddings=True, subquadratic=True,
        sliding_window=4096, shared_attn_every=6,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1))
