"""Cross-process facade of the scheduler frontdoor for resident ranks.

On the ``inproc`` transport a :class:`~repro.sched.service.ShardRuntime`
calls its :class:`~repro.sched.service.SchedulerService` directly — same
address space. On ``multiproc`` the service (and its bus) live in the
parent process; each rank process gets these proxies instead, which relay
the exact method surface the rank side uses over the child's RPC channel
(``world.svc_rpc``, a lock-serialized request/response socket — see
:class:`repro.core.comm.multiproc._RpcClient`).

The surface is deliberately explicit — no ``__getattr__`` magic — so a new
service dependency on the rank side fails loudly here instead of silently
pickling half a service across.
"""

from __future__ import annotations

import time
from typing import List, Optional


class BusProxy:
    """The rank-side slice of :class:`~repro.sched.service._Bus`.

    ``read_from`` is the serve loop's hot poll (every ~10µs in-proc);
    over RPC an empty read is rate-limited to ~2ms so an idle resident
    rank doesn't thrash the service process.
    """

    def __init__(self, rpc):
        self._rpc = rpc
        self._last_empty = 0.0

    def read_from(self, cursor: int, reader: int) -> List[tuple]:
        now = time.monotonic()
        if now - self._last_empty < 0.002:
            return []
        out = self._rpc.call("bus", "read_from", cursor, reader)
        if not out:
            self._last_empty = now
        return out

    def read_range(self, lo: int, hi: int) -> List[tuple]:
        return self._rpc.call("bus", "read_range", lo, hi)

    def frozen_cursor(self, reader: int) -> int:
        return self._rpc.call("bus", "frozen_cursor", reader)

    def floor(self) -> Optional[int]:
        return self._rpc.call("bus", "floor")

    def retire_reader(self, reader: int, votes_needed: int = 1) -> None:
        self._rpc.call("bus", "retire_reader", reader,
                       votes_needed=votes_needed)


class ServiceProxy:
    """The rank-side slice of :class:`~repro.sched.service.SchedulerService`.

    ``rank_stats`` / ``_runtimes`` are local placeholders: the in-proc
    service reads them for live stats and shared-memory forensics, but a
    cross-process parent gets stats from rank summaries and forensics
    over the SNAPSHOT control message instead, so the child-side writes
    just land here.
    """

    def __init__(self, rpc, n_shards: int):
        self._rpc = rpc
        self.n_shards = n_shards
        self.bus = BusProxy(rpc)
        self.rank_stats: list = [None] * n_shards
        self._runtimes: list = [None] * n_shards
        self._weights: dict = {}

    def client_weight(self, name: str) -> float:
        # weights are fixed at client creation: cache per name so the
        # assimilation path doesn't pay an RPC per submission
        if name not in self._weights:
            self._weights[name] = self._rpc.call("svc", "client_weight",
                                                 name)
        return self._weights[name]

    def _beat(self, rank: int) -> None:
        self._rpc.call("svc", "_beat", rank)

    def _rank_done(self, sub_id: int, shard: int, published: dict,
                   n_bytes: int, seeded=None) -> None:
        self._rpc.call("svc", "_rank_done", sub_id, shard, published,
                       n_bytes, seeded=seeded)

    def _fail_submission(self, sub_id: int, exc: BaseException) -> None:
        self._rpc.call("svc", "_fail_submission", sub_id, exc)

    def _note_poisoned(self, sub_id: int, keys) -> None:
        self._rpc.call("svc", "_note_poisoned", sub_id, keys)

    def _published_so_far(self, sub_id: int) -> dict:
        return self._rpc.call("svc", "_published_so_far", sub_id)

    def _sub_state(self, sub_id: int) -> str:
        return self._rpc.call("svc", "_sub_state", sub_id)

    def _checkpoint_rows(self) -> list:
        return self._rpc.call("svc", "_checkpoint_rows")

    def _owner_of(self, ns: str):
        return self._rpc.call("svc", "_owner_of", ns)

    def _on_ranks_dead(self, newly, lost_shards) -> None:
        self._rpc.call("svc", "_on_ranks_dead", newly, lost_shards)
