"""Named block namespaces: how submissions in a stream depend on each other.

A one-shot run reads its inputs from an initial store and returns its
writes. In a stream, a later PTG must be able to read blocks a prior PTG
wrote — *without* any global graph tying the two together. The scheduler
expresses this with named namespaces: each submission targets a namespace,
its external reads (operand blocks with no producer inside its own graph,
``LocalView.external_reads``) bind to namespace versions, and its final
writes (``LocalView.final_writes``) publish new versions.

Versions are keyed ``(sub_id, kind)`` with kind 0 = initial-value seed and
kind 1 = final write, so the binding rule is a pure function of submission
ids: *reader submission r binds block B to the latest version with key
< (r, 1)* — its own initial seed (r, 0) included, any earlier submission's
write preferred over it. Every rank processes the submission bus in the
same total order, so all ranks resolve identical bindings with no
negotiation — the stream-level analogue of the PTG's "dependencies are a
pure function of the task id".

Lifecycle mirrors the task state machine: a version is PENDING from
assimilation (the owner rank learns a final write is coming) until the
writer publishes (AVAILABLE) or its submission fails (POISONED — readers
that bound to it fail too, instead of deadlocking). Both resolutions are
final: a straggler publish from a failed submission's surviving task
never flips POISONED back, and one whose version retirement already
dropped is discarded — what readers observe is a pure function of bus
order, never of message timing. Retirement is driven
by the frontdoor's watermark (the resolved-submission prefix): a version
superseded by a later one at or below the watermark can never be a
binding target again and is dropped — namespace memory holds the latest
resolved version per block plus in-flight ones, not the stream's history.

Ownership: a namespace's blocks are sharded by the graph owner mapping,
which must therefore be consistent across the submissions of a namespace
(the service checks nothing here — a block whose owner moves between
submissions would silently split its timeline across ranks).
"""

from __future__ import annotations

import threading
from typing import Callable, Dict, Hashable, List, Tuple

from .state import LiveStats

B = Hashable

PENDING, AVAILABLE, POISONED = "pending", "available", "poisoned"


class _Version:
    __slots__ = ("key", "state", "value", "waiters")

    def __init__(self, key: Tuple[int, int], state: str, value=None):
        self.key = key          # (sub_id, kind): 0 seed, 1 final write
        self.state = state
        self.value = value
        self.waiters: List[Callable] = []  # cb(value, poisoned)


class NamespaceShard:
    """One rank's slice of every namespace: per owned block, a short
    timeline of versions in key order. All methods are thread-safe;
    waiter callbacks fire outside the lock."""

    def __init__(self, stats: LiveStats) -> None:
        self._lock = threading.Lock()
        self._vers: Dict[Tuple[str, B], List[_Version]] = {}
        self._stats = stats
        # resolved-prefix watermark seen by retire_through: versions of
        # submissions <= this may already have been dropped as superseded,
        # so straggler publishes for them must not re-insert stale state
        self._retired = 0

    # -------------------------------------------------------------- writes

    def seed_initial(self, ns: str, blk: B, sub_id: int, value) -> bool:
        """Submission-provided initial value for an owned block — only
        honored on a virgin timeline: once any submission wrote (or is
        writing) the block, the namespace value is the truth and a later
        submission's initial value is ignored. A timeline holding *only*
        POISONED versions counts as virgin again: every writer so far
        failed, so a retry resubmitting the same inputs gets its seeds
        honored instead of deterministically binding to the poison (the
        FAIL command precedes the retry's SUBMIT in bus order, so the
        decision is a pure function of the bus prefix on every rank).
        Only versions *visible to this submission* (key < ``(sub_id, 0)``)
        count: a later submission's publish racing ahead of this
        assimilation — or a checkpoint restore inserting future-submission
        versions before adoption replay — must not flip the decision, or
        it would stop being a pure function of the bus prefix. (Safe
        against retirement: a dropped earlier version implies a surviving
        later version that is still < ``(sub_id, 0)``, since unresolved
        submissions sit above the watermark.)
        Returns True iff the seed was inserted (the owner reports honored
        seeds to the frontdoor checkpoint for adoption replay)."""
        with self._lock:
            timeline = self._vers.setdefault((ns, blk), [])
            if any(v.key == (sub_id, 0) for v in timeline):
                return True   # adoption replay re-seeding: already honored
            if any(v.state != POISONED for v in timeline
                   if v.key < (sub_id, 0)):
                return False
            self._insert(timeline, _Version((sub_id, 0), AVAILABLE, value))
        self._stats.block_up()
        return True

    def ensure_pending(self, ns: str, blk: B, sub_id: int) -> None:
        """Owner-side assimilation of a final write: reserve the version so
        readers of later submissions can bind (and wait) before the writer
        has run. No-op if publish already raced ahead."""
        with self._lock:
            timeline = self._vers.setdefault((ns, blk), [])
            if any(v.key == (sub_id, 1) for v in timeline):
                return
            self._insert(timeline, _Version((sub_id, 1), PENDING))

    def publish(self, ns: str, blk: B, sub_id: int, value) -> None:
        """Fill (or create) version ``(sub_id, 1)`` and serve its waiters.
        May arrive before the owner assimilated ``sub_id`` — the writer's
        rank runs ahead — in which case the publish creates the version;
        no reader of a later submission can have bound yet, because the
        owner binds readers only after assimilating them, in bus order.

        Two straggler cases are ignored so resolution stays final and
        timing-independent: a POISONED version stays poisoned (a task of a
        failed submission finishing on another rank after the fail command
        must not resurrect the value), and a publish whose version
        ``retire_through`` already dropped as superseded must not
        re-insert it (it could never be a binding target again)."""
        with self._lock:
            timeline = self._vers.setdefault((ns, blk), [])
            for v in timeline:
                if v.key == (sub_id, 1):
                    break
            else:
                if sub_id <= self._retired:
                    if not timeline:
                        del self._vers[(ns, blk)]
                    return
                v = _Version((sub_id, 1), PENDING)
                self._insert(timeline, v)
            if v.state == POISONED:
                return
            first = v.state != AVAILABLE
            v.state = AVAILABLE
            v.value = value
            waiters, v.waiters = v.waiters, []
        if first:
            self._stats.block_up()
        for cb in waiters:
            cb(value, False)

    def restore(self, ns: str, blk: B, key: Tuple[int, int], state: str,
                value=None) -> None:
        """Insert an already-*resolved* version (AVAILABLE or POISONED)
        verbatim — the frontdoor checkpoint recording a resolved
        submission's effect, and an adopter reseeding its shard from that
        checkpoint after a rank death. Idempotent; never downgrades: an
        existing POISONED version stays poisoned, an existing AVAILABLE one
        keeps its value, and a PENDING one is resolved in place (serving
        its waiters). AVAILABLE restores for retired submissions are
        discarded like straggler publishes; POISONED restores bypass that
        guard — a poison that is the *latest* version of a retired timeline
        is still the live binding target, and a superseded one is inert
        residue the next ``retire_through`` drops."""
        fresh = False
        with self._lock:
            timeline = self._vers.setdefault((ns, blk), [])
            for v in timeline:
                if v.key == key:
                    break
            else:
                if state == AVAILABLE and key[0] <= self._retired:
                    if not timeline:
                        del self._vers[(ns, blk)]
                    return
                v = _Version(key, PENDING)
                self._insert(timeline, v)
            if v.state != PENDING:
                return
            fresh = state == AVAILABLE
            v.state = state
            v.value = value
            waiters, v.waiters = v.waiters, []
        if fresh:
            self._stats.block_up()
        for cb in waiters:
            cb(value, state == POISONED)

    def export(self) -> List[tuple]:
        """Every resolved version, as ``(ns, blk, key, state, value)`` rows
        feedable to :meth:`restore`. PENDING versions are excluded: they
        belong to in-flight submissions, which adoption reconstructs by
        replaying the bus, not by copying state."""
        with self._lock:
            return [(ns, blk, v.key, v.state, v.value)
                    for (ns, blk), timeline in self._vers.items()
                    for v in timeline if v.state != PENDING]

    @staticmethod
    def _insert(timeline: List[_Version], v: _Version) -> None:
        i = len(timeline)
        while i > 0 and timeline[i - 1].key > v.key:
            i -= 1
        timeline.insert(i, v)

    # --------------------------------------------------------------- reads

    def bind(self, ns: str, blk: B, reader_sub: int, cb: Callable) -> None:
        """Bind one external read of ``reader_sub`` to its version (latest
        key < ``(reader_sub, 1)``). Requires every submission up to
        ``reader_sub`` assimilated on this rank — the callers guarantee it
        (local binds run during assimilation; remote fetches are held until
        the owner catches up). ``cb(value, poisoned)`` fires immediately if
        the version is resolved, else when it resolves."""
        with self._lock:
            timeline = self._vers.get((ns, blk), [])
            target = None
            for v in timeline:
                if v.key < (reader_sub, 1):
                    target = v
                else:
                    break
            if target is None:
                raise KeyError(
                    f"namespace {ns!r}: block {blk!r} has no version visible "
                    f"to submission {reader_sub} (not written by any earlier "
                    "submission and no initial value supplied)")
            if target.state == PENDING:
                target.waiters.append(cb)
                return
            value, poisoned = target.value, target.state == POISONED
        cb(value, poisoned)

    # ---------------------------------------------------------- lifecycle

    def poison_sub(self, sub_id: int) -> List[Tuple[str, B]]:
        """A submission failed: its unproduced (still PENDING) versions
        will never publish — poison them so readers fail loudly instead of
        waiting forever. Versions it already published keep their value.
        Returns the ``(ns, blk)`` keys poisoned, so the owner rank can
        report them to the frontdoor checkpoint (a poison can be the live
        binding target of a timeline; an adopter reconstructing the
        namespace without it would silently bind readers to stale earlier
        data instead of failing them)."""
        fire: List[Callable] = []
        keys: List[Tuple[str, B]] = []
        with self._lock:
            for (ns, blk), timeline in self._vers.items():
                for v in timeline:
                    if v.key == (sub_id, 1) and v.state == PENDING:
                        v.state = POISONED
                        keys.append((ns, blk))
                        fire.extend(v.waiters)
                        v.waiters = []
        for cb in fire:
            cb(None, True)
        return keys

    def retire_through(self, watermark: int) -> None:
        """Drop versions superseded within the resolved prefix: any version
        strictly before the last one with key <= ``(watermark, 1)`` cannot
        bind a future reader (all readers <= watermark are resolved; any
        later reader binds at or after that survivor). Waiters only exist
        on PENDING versions of unresolved submissions, which survive."""
        freed = 0
        with self._lock:
            self._retired = max(self._retired, watermark)
            for key, timeline in list(self._vers.items()):
                cut = 0
                for i, v in enumerate(timeline):
                    if v.key <= (watermark, 1):
                        cut = i
                if cut:
                    freed += sum(1 for v in timeline[:cut]
                                 if v.state == AVAILABLE)
                    del timeline[:cut]
        if freed:
            self._stats.block_down(freed)

    def drop_namespace(self, ns: str) -> None:
        """Drop every timeline of an *ephemeral* namespace (one no later
        submission will ever target — ``Client.map``'s throwaway
        namespaces). The frontdoor posts the drop after the watermark has
        passed the namespace's one submission, so any straggler publish
        that follows is caught by the ``_retired`` guard instead of
        resurrecting state. Surviving waiters (there should be none on a
        resolved submission) fail loudly rather than hang."""
        freed = 0
        fire: List[Callable] = []
        with self._lock:
            for key in [k for k in self._vers if k[0] == ns]:
                for v in self._vers.pop(key):
                    if v.state == AVAILABLE:
                        freed += 1
                    fire.extend(v.waiters)
                    v.waiters = []
        if freed:
            self._stats.block_down(freed)
        for cb in fire:
            cb(None, True)

    def live_versions(self) -> int:
        with self._lock:
            return sum(len(t) for t in self._vers.values())
