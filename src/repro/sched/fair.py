"""Weighted fair scheduling across clients, on Taskflow's priority hook.

The host runtime already has everything needed for a scheduling *policy*:
worker threads pop a max-priority heap, and ``Taskflow.set_priority`` is
evaluated exactly once per task — at spawn time, when its last dependency
lands and it enters the ready queue. Start-time fair queuing (SFQ) drops
straight into that hook:

- each client owns a *lane* with a virtual time; admitting a task charges
  the lane ``1/weight`` virtual seconds and the task's priority is the
  negated start tag, so the heap drains lanes in virtual-time order —
  weighted round-robin over whatever is concurrently ready;
- an idle lane resuming is clamped to the global virtual "now"
  (``max(lane, vnow)``): a client that sat out earns no unbounded credit
  and cannot starve the others when it returns;
- a submission-level ``priority`` is added as a bias on top of the start
  tag, so higher-priority work from the *same* client overtakes its
  lower-priority backlog (order across clients stays governed by the
  lanes — fairness first, priorities within).

The policy is per rank (each rank schedules its own ready queue), pure
arithmetic, and deterministic for a deterministic admission order — what
``tests/test_scheduler.py`` exploits to assert the WRR interleaving
exactly.
"""

from __future__ import annotations

import threading
from typing import Dict


class FairPolicy:
    """Start-time fair queuing: ``priority_for`` returns the max-heap
    priority for one task of ``client`` entering the ready queue."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._vnow = 0.0
        self._lanes: Dict[str, float] = {}

    def priority_for(self, client: str, weight: float = 1.0,
                     bias: float = 0.0) -> float:
        with self._lock:
            start = max(self._lanes.get(client, 0.0), self._vnow)
            self._lanes[client] = start + 1.0 / max(weight, 1e-9)
            self._vnow = start
            return bias - start

    def snapshot(self) -> dict:
        """Lane state for timeout forensics: which client's virtual time
        is ahead says who the rank has been serving."""
        with self._lock:
            return {"vnow": self._vnow, "lanes": dict(self._lanes)}
