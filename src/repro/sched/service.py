"""The persistent, multi-tenant scheduler service.

One-shot execution (``Graph.run_host``) spins up ranks, runs one graph,
and tears the world down. The service keeps the ranks *resident*: a
stream of PTGs from many concurrent clients is assimilated into one live
dependency state and tasks run as predecessors complete — TaskTorrent's
"the DAG is discovered piece by piece, as messages arrive" lifted from
one graph to an open-ended stream of them.

Architecture (all in-process, mirroring the paper's rank model):

- the **frontdoor** (:class:`SchedulerService` + :class:`Client`) accepts
  submissions, applies admission control (max in-flight tasks per client
  — ``submit`` blocks, which is the backpressure), assigns monotone
  submission ids, and appends SUBMIT / FAIL / WATERMARK / STOP commands
  to a **submission bus** — an append-only log every rank consumes at its
  own cursor. The bus's total order is the determinism anchor: all ranks
  resolve identical cross-submission bindings because they all see the
  same prefix in the same order;
- each rank runs a :class:`ShardRuntime`: a resident loop that pumps the
  communicator, assimilates new submissions **via the lazy path only**
  (``Graph.derive_local`` — owned tasks + halo; no rank ever materializes
  a global edge dict), and lets the work-stealing threadpool execute
  ready tasks. The loop never drives the completion detector's quiescence
  rounds (which would tear the world down at the first idle moment), only
  its failure-detection half;
- per-submission wiring reuses the host-runtime shape (indegree from the
  view's in-edges plus its external reads, cross-rank fulfillments as
  active messages carrying the block iff the consumer reads it), but all
  ranks share **one dispatcher-AM set registered at rank start** —
  registration order is the global AM identity, so submissions arriving
  later must not register new ones;
- cross-submission data flows through named block namespaces
  (:mod:`repro.sched.namespace`); retirement
  (:mod:`repro.sched.state`) keeps memory on the live frontier; the ready
  queue is ordered by the weighted-fair policy (:mod:`repro.sched.fair`).

Failure is per-submission, not per-service: a task body that raises fails
its submission's future and poisons the namespace versions it will never
produce (readers fail loudly instead of hanging) — other clients and
unrelated submissions are untouched.

**Rank death** (active when the world carries a
:class:`~repro.core.faults.FaultPlan`) is survived, not fatal: the serve
loop drives the membership half of the completion protocol
(``poll_failure_detector``), so a resident rank that dies mid-stream is
declared dead by rank 0's lease monitor and a DEATH broadcast reaches the
survivors. Each survivor's ``on_reconfigure`` hook then

- **adopts** the dead rank's shards (deterministic next-live-rank
  assignment, same as the one-shot runtime): the adopter reseeds its
  namespace shard from the frontdoor's *resolved-prefix checkpoint*
  (honored seeds + published versions + poisons of resolved submissions,
  retired in lockstep with the watermark) and **replays the submission
  bus** from the dead rank's frozen cursor — re-deriving each unresolved
  submission's LocalView for the adopted shard and re-executing only the
  lost tasks. Replay is idempotent: already-published versions are final
  (``publish``/``restore`` never downgrade), already-retired blocks are
  discarded by the ``_retired`` guard, and re-produced cross-shard
  fulfillments are deduped per (consumer, producer) at the receiver;
- **replays its send log** (cross-rank fulfillments and publishes whose
  destination shard moved) and re-issues outstanding fetches along the new
  route, so in-flight state lost with the dead rank is reconstructed;
- keeps the frontdoor futures alive: the dead rank's shards are re-added
  to every unresolved record's pending set and the adopter re-reports, so
  clients observe an epoch change only as latency.

The bus-trim invariant that makes replay sound: a dead rank's cursor is
**frozen** at the DEATH declaration and keeps pinning the trim until every
adopter of its shards has finished replaying (``retire_reader`` votes), and
the **floor** — the oldest unresolved submission's SUBMIT position — pins
the trim unconditionally, so replay never reads a trimmed prefix
(``read_range`` asserts it loudly).

Client-facing robustness layers on top: per-submission **deadlines**
(over-deadline submissions are shed through the same FAIL/poisoning path —
a clean :class:`DeadlineExceeded`, never a hang), bounded **retry** with
exponential backoff (``Client.submit(..., retries=)``), and **graceful
degradation** — admission backpressure tightens to the surviving ranks'
capacity when the service shrinks (the elastic controller from
:mod:`repro.train.elastic` tracks membership and can admit a replacement
rank into the live stream).
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, List, Optional

import numpy as np

from repro.core import runtime as core_runtime
from repro.core.faults import FaultPlan
from repro.core.messages import RankKilled, WorldPoisoned

from .fair import FairPolicy
from .namespace import AVAILABLE, POISONED, NamespaceShard
from .state import LiveStats, SubmissionShard

K = Hashable
B = Hashable


class SubmissionError(RuntimeError):
    """A submission failed (its own body raised, or an upstream submission
    it reads from failed before producing the block)."""


class DeadlineExceeded(SubmissionError):
    """A submission's deadline passed before it resolved: the service shed
    it (FAIL + namespace poisoning, so downstream readers fail loudly) and
    its future raises this instead of hanging on a degraded service."""


# ---------------------------------------------------------------- frontdoor


@dataclass
class Submission:
    sub_id: int
    client: str
    namespace: str
    graph: object
    blocks: dict
    bodies: dict
    owner_map: Optional[Callable]
    priority: float
    n_tasks: int
    # ephemeral: no later submission will ever target this namespace, so
    # its state is dropped wholesale once the watermark passes (Client.map)
    ephemeral: bool = False

    def owner(self) -> Callable[[B], int]:
        return self.owner_map if self.owner_map is not None \
            else self.graph.owner


class SubmissionFuture:
    """Handle for one submission: ``result()`` returns the blocks the
    submission wrote (block id -> value), the same contract as the
    one-shot ``run_host`` — which is what makes bit-identity checkable.

    A ``result`` timeout raises with the service's forensic snapshot
    (per-rank protocol state, bus cursors, unresolved submissions) instead
    of a bare TimeoutError — the stuck side is named, not guessed."""

    def __init__(self, sub_id: int, client: str, n_tasks: int, svc=None):
        self.sub_id = sub_id
        self.client = client
        self.n_tasks = n_tasks
        self._svc = svc
        self._ev = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None
        self._transform: Optional[Callable] = None

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            detail = ""
            if self._svc is not None:
                try:  # forensics must never mask the timeout itself
                    detail = "\n" + self._svc.debug_snapshot()
                except Exception as e:
                    detail = f"\n<debug snapshot failed: {e!r}>"
            raise TimeoutError(
                f"submission {self.sub_id} not done after {timeout}s{detail}")
        if self._exc is not None:
            raise self._exc
        return (self._transform(self._result) if self._transform
                else self._result)

    def _complete(self, blocks) -> None:
        self._result = blocks
        self._ev.set()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._ev.set()


class RetryingFuture:
    """Future facade from ``Client.submit(..., retries=N)``: on a shed
    (:class:`DeadlineExceeded`), resubmits after an exponential backoff,
    up to ``retries`` times. Only the deadline-shed path retries — a
    submission whose own body raised would deterministically raise again.

    Retries re-run the whole submission, so they are sound for
    self-contained work (ephemeral namespaces get a fresh one per attempt;
    a retry into a durable namespace re-seeds only all-POISONED timelines
    — its reads of healthy earlier writes bind unchanged, but a poisoned
    *upstream* stays poisoned and the retry budget just burns down)."""

    def __init__(self, attempt: Callable[[int], SubmissionFuture],
                 first: SubmissionFuture, retries: int, backoff: float):
        self._attempt = attempt
        self._fut = first
        self._retries = retries
        self._backoff = backoff
        self.attempts = 1

    @property
    def sub_id(self) -> int:
        return self._fut.sub_id

    @property
    def client(self) -> str:
        return self._fut.client

    @property
    def _transform(self):
        return self._fut._transform

    @_transform.setter
    def _transform(self, fn) -> None:
        self._fut._transform = fn

    def done(self) -> bool:
        return self._fut.done()

    def result(self, timeout: Optional[float] = None):
        deadline = None if timeout is None else time.monotonic() + timeout
        n = 0
        while True:
            left = None if deadline is None \
                else max(0.0, deadline - time.monotonic())
            try:
                return self._fut.result(left)
            except DeadlineExceeded:
                if n >= self._retries:
                    raise
                time.sleep(min(self._backoff * (2.0 ** n), 5.0))
                n += 1
                fresh = self._attempt(n)
                fresh._transform = self._fut._transform
                self._fut = fresh
                self.attempts += 1


class _Bus:
    """Append-only command log; ranks read at their own cursor. The total
    order of appends IS the stream's sequential semantics. Cursors are
    absolute (they keep counting up forever), but storage is not: the
    prefix every reader has consumed can never be read again and is
    trimmed away, so a resident service holds O(unconsumed commands), not
    the whole stream history.

    Two pins keep adoption replay sound against that trim:

    - a **frozen** reader (a rank declared dead) stops reading — its
      recorded cursor (always <= the commands it actually applied, since
      the cursor is recorded at batch start) keeps pinning the trim until
      every adopter of its shards has replayed past it and voted
      ``retire_reader``;
    - the **floor** — the oldest unresolved submission's SUBMIT position,
      maintained by the frontdoor — pins the trim unconditionally, so an
      unresolved submission the dead rank had already consumed can still
      be re-read for re-derivation.
    """

    def __init__(self, n_readers: int) -> None:
        self._items: List[tuple] = []
        self._base = 0                      # absolute index of _items[0]
        self._cursors = [0] * n_readers
        self._frozen: set = set()           # dead readers, pre-adoption
        self._retired_readers: set = set()  # dead readers fully replayed
        self._retire_votes: Dict[int, int] = {}
        self._floor: Optional[int] = None
        self._lock = threading.Lock()
        self.posted = 0

    def post(self, item: tuple, pin: bool = False) -> int:
        """Append; returns the absolute position. ``pin=True`` (SUBMITs)
        atomically lowers the floor to this position if none is set, so
        there is no window where a fast reader's trim could eat a SUBMIT
        before the frontdoor records it as unresolved."""
        with self._lock:
            pos = self._base + len(self._items)
            self._items.append(item)
            self.posted += 1
            if pin and self._floor is None:
                self._floor = pos
            return pos

    def set_floor(self, pos: Optional[int]) -> None:
        with self._lock:
            self._floor = pos

    def floor(self) -> Optional[int]:
        with self._lock:
            return self._floor

    def read_from(self, cursor: int, reader: int) -> List[tuple]:
        with self._lock:
            if reader in self._frozen or reader in self._retired_readers:
                # a killed rank's serve thread may spin briefly before it
                # notices the fence: its cursor stays frozen for replay
                return []
            self._cursors[reader] = cursor
            self._trim()
            return self._items[cursor - self._base:]

    def read_range(self, lo: int, hi: int) -> List[tuple]:
        """Adoption replay: absolute ``[lo, hi)``. The freeze/floor
        invariants make a trimmed ``lo`` impossible — raising here means
        the invariant broke, and a loud error beats a silent partial
        replay."""
        with self._lock:
            if lo < self._base:
                raise RuntimeError(
                    f"bus replay would read below the trimmed prefix: "
                    f"lo={lo} < base={self._base} (a dead rank's frozen "
                    "cursor was outrun by the trim)")
            return self._items[max(0, lo - self._base):
                               max(0, hi - self._base)]

    def freeze(self, reader: int) -> None:
        with self._lock:
            self._frozen.add(reader)

    def frozen_cursor(self, reader: int) -> int:
        with self._lock:
            return self._cursors[reader]

    def retire_reader(self, reader: int, votes_needed: int = 1) -> None:
        """One adopter finished replaying ``reader``'s prefix. The cursor
        pin lifts only at the last vote — a dead rank's shards can land on
        several adopters, and the first finisher must not unpin the prefix
        the others still need."""
        with self._lock:
            if reader in self._retired_readers:
                return
            self._retire_votes[reader] = self._retire_votes.get(reader, 0) + 1
            if self._retire_votes[reader] >= votes_needed:
                self._frozen.discard(reader)
                self._retired_readers.add(reader)
                self._trim()

    def _trim(self) -> None:
        # caller holds the lock
        lows = [c for r, c in enumerate(self._cursors)
                if r not in self._retired_readers]
        if self._floor is not None:
            lows.append(self._floor)
        low = min(lows) if lows else self._base + len(self._items)
        if low > self._base:
            del self._items[:low - self._base]
            self._base = low

    def snapshot(self) -> dict:
        with self._lock:
            return {"base": self._base, "posted": self.posted,
                    "backlog": len(self._items), "floor": self._floor,
                    "cursors": list(self._cursors),
                    "frozen": sorted(self._frozen),
                    "retired_readers": sorted(self._retired_readers)}


@dataclass
class _SubRecord:
    sub: Submission
    future: SubmissionFuture
    pending_ranks: set                    # shard ids still to report
    published: dict = field(default_factory=dict)
    t0: float = 0.0
    resolved: bool = False
    failed: bool = False
    bus_pos: int = 0
    deadline: Optional[float] = None      # absolute monotonic shed time
    seeded: dict = field(default_factory=dict)   # honored seeds (rank truth)
    bytes_by_shard: dict = field(default_factory=dict)


class Client:
    """Per-tenant frontdoor handle: submissions, accounting, admission.

    ``max_inflight_tasks`` is the admission-control knob: ``submit``
    blocks while the client's in-flight task count would exceed it (a
    single oversized submission is admitted alone rather than deadlocking).
    When ranks have died, the effective cap shrinks proportionally to the
    surviving capacity — graceful degradation instead of a queue growing
    at full-speed admission into a half-speed service. ``weight`` feeds
    the ranks' fair policy. ``stats`` accumulates tasks, bytes (result
    blocks produced), and wall seconds per submission.
    """

    def __init__(self, service: "SchedulerService", name: str, *,
                 weight: float = 1.0,
                 max_inflight_tasks: Optional[int] = None,
                 namespace: Optional[str] = None):
        self._svc = service
        self.name = name
        self.weight = weight
        self.max_inflight_tasks = max_inflight_tasks
        self.namespace = namespace if namespace is not None else name
        self._map_seq = itertools.count()
        self.inflight_tasks = 0
        self.stats = {"submitted": 0, "completed": 0, "failed": 0,
                      "tasks": 0, "bytes": 0, "wall_seconds": 0.0}

    def submit(self, graph, blocks=None, bodies=None, *,
               owner_map: Optional[Callable] = None,
               priority: float = 0.0,
               namespace: Optional[str] = None,
               ephemeral: bool = False,
               timeout: Optional[float] = None,
               deadline: Optional[float] = None,
               retries: int = 0,
               retry_backoff: float = 0.25):
        """Submit one PTG against a namespace; returns a future for its
        written blocks. External reads (blocks no task of this graph
        writes first) bind to the namespace — earlier submissions' final
        writes win over ``blocks``' initial values. Blocks of the graph
        must keep one owner across the namespace's submissions.
        ``ephemeral=True`` declares that no later submission will target
        the namespace: its block state is dropped wholesale once this
        submission resolves, instead of its last versions living on as
        the namespace's durable values.

        ``timeout`` bounds the admission wait (backpressure). ``deadline``
        bounds the submission's *life*: seconds from admission after which
        the service sheds it and the future raises
        :class:`DeadlineExceeded`. ``retries`` > 0 wraps the future so a
        shed attempt is resubmitted after an exponential backoff
        (``retry_backoff`` seconds, doubling, capped at 5s); ephemeral
        namespaces get a fresh ``~rN`` namespace per attempt."""
        n_tasks = sum(1 for _ in graph._program_iter())
        ns0 = namespace if namespace is not None else self.namespace

        def attempt(n: int) -> SubmissionFuture:
            ns = ns0 if (n == 0 or not ephemeral) else f"{ns0}~r{n}"
            return self._svc._admit(
                self, graph, dict(blocks or {}), dict(bodies or {}),
                owner_map=owner_map, priority=priority, namespace=ns,
                ephemeral=ephemeral, n_tasks=n_tasks, timeout=timeout,
                deadline=deadline)

        fut = attempt(0)
        if retries <= 0:
            return fut
        return RetryingFuture(attempt, fut, retries, retry_backoff)

    def map(self, fn: Callable, values, *, priority: float = 0.0,
            deadline: Optional[float] = None, retries: int = 0):
        """Embarrassingly parallel convenience: one task per element of
        ``values``, sharded round-robin; ``result()`` returns the mapped
        list in order. Each call runs in its own private throwaway
        namespace (unique per call — reusing one would bind this call's
        ``("x", i)`` reads to a previous call's seeds, since a namespace
        honors initial values only on virgin timelines) that is dropped
        wholesale once the call resolves."""
        from repro.ptg import Graph, IndexSpace

        vals = list(values)
        n = self._svc.n_shards
        g = Graph(f"map-{self.name}", n_shards=n,
                  owner=lambda blk: blk[1] % n)
        g.task_type("map",
                    writes=lambda i: ("y", i),
                    reads=lambda i: [("x", i)],
                    space=IndexSpace(
                        lambda: range(len(vals)),
                        lambda s: [i for i in range(len(vals))
                                   if i % n == s],
                        size=len(vals)))
        blocks = {("x", i): np.asarray(v) for i, v in enumerate(vals)}
        fut = self.submit(g, blocks, {"map": fn}, priority=priority,
                          namespace=f"{self.name}/map{next(self._map_seq)}",
                          ephemeral=True, deadline=deadline, retries=retries)
        fut._transform = lambda out: [out[("y", i)]
                                      for i in range(len(vals))]
        return fut


# ------------------------------------------------------------------ service


class SchedulerService:
    """The resident scheduler. Typical use::

        with SchedulerService(n_shards=2) as svc:
            alice = svc.client("alice", weight=2.0)
            fut = alice.submit(graph, blocks, bodies)
            out = fut.result()

    ``start()`` launches a driver thread running ``run_ranks(...,
    serve_scheduler=self)``; ranks stay resident between submissions.
    ``close()`` (or leaving the ``with``) waits for in-flight work, posts
    STOP, and runs the distributed completion protocol to tear down.
    ``faults`` (a :class:`~repro.core.faults.FaultPlan`) makes the world
    adversarial — and arms the recovery machinery described in the module
    docstring.
    """

    def __init__(self, n_shards: int, *, n_threads: int = 2,
                 timeout: float = 120.0,
                 faults: Optional[FaultPlan] = None,
                 transport: Optional[str] = None):
        self.n_shards = n_shards
        self.n_threads = n_threads
        self.timeout = timeout
        self.faults = faults
        self.transport = transport
        self.bus = _Bus(n_shards)
        self.draining = threading.Event()  # run_ranks arms its deadline here
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._clients: Dict[str, Client] = {}
        self._subs: Dict[int, _SubRecord] = {}
        self._next_sub = 1
        self._resolved_through = 0
        self._accepting = False
        self._closed = False
        self._driver: Optional[threading.Thread] = None
        self._driver_err: Optional[BaseException] = None
        self._reaper: Optional[threading.Thread] = None
        self.rank_stats: List[Optional[LiveStats]] = [None] * n_shards
        self.rank_summaries: Optional[list] = None
        self.recovery_report = None
        # --- recovery state (armed by attach_world iff faults are active)
        self._world = None
        self._recoverable = faults is not None
        self._runtimes: List[Optional["ShardRuntime"]] = [None] * n_shards
        # resolved-prefix checkpoint: the adopter's namespace seed corpus.
        # Private LiveStats — checkpoint bookkeeping must not pollute the
        # ranks' live_frac measurement.
        self._ns_ckpt = NamespaceShard(LiveStats())
        self._ns_owner: Dict[str, Callable] = {}
        self._dead_ranks: set = set()
        self._dead_shards: set = set()
        self._death_t0: Optional[float] = None
        self._inflight_at_death: Optional[set] = None
        self.sched_recover_ms: Optional[float] = None
        self._elastic = None
        self.elastic_plan = None

    # ------------------------------------------------------------ lifecycle

    def start(self) -> "SchedulerService":
        if self._driver is not None:
            raise RuntimeError("scheduler already started")
        self._accepting = True
        self._driver = threading.Thread(target=self._drive, daemon=True,
                                        name="sched-driver")
        self._driver.start()
        self._reaper = threading.Thread(target=self._reap, daemon=True,
                                        name="sched-reaper")
        self._reaper.start()
        return self

    def __enter__(self) -> "SchedulerService":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(wait=exc_type is None)

    def attach_world(self, world) -> None:
        """Called by ``run_ranks`` in resident mode. The recovery machinery
        (cursor freezing, checkpointing, adoption re-reports, elastic
        membership) arms only when the world injects faults — the
        fault-free service pays nothing for survivability it cannot
        need. This also catches faults injected *around* us (the chaos
        wrapper hands ``run_ranks`` a plan the service never saw)."""
        self._world = world
        if world.faults is not None and not self._recoverable:
            self._recoverable = True
        if self._recoverable and self._elastic is None:
            from repro.train.elastic import ElasticController
            lease = world.faults.lease if world.faults is not None else 60.0
            self._elastic = ElasticController(
                self.n_shards, chips_per_host=1, model_axis=1,
                dead_after=lease)

    def _drive(self) -> None:
        try:
            # attribute lookup at call time so the chaos-injection wrapper
            # (conftest REPRO_CHAOS) sees this run_ranks call too
            kwargs = {"faults": self.faults} if self.faults is not None else {}
            if self.transport is not None:
                kwargs["transport"] = self.transport
            res = core_runtime.run_ranks(
                self.n_shards, self._rank_main, n_threads=self.n_threads,
                timeout=self.timeout, serve_scheduler=self, **kwargs)
            if isinstance(res, tuple):
                self.rank_summaries, self.recovery_report = res
            else:
                self.rank_summaries = res
        except BaseException as e:
            self._driver_err = e
            with self._cond:
                for rec in self._subs.values():
                    if not rec.resolved:
                        rec.resolved = rec.failed = True
                        rec.future._fail(SubmissionError(
                            f"scheduler service died: {e!r}"))
                self._accepting = False
                self._cond.notify_all()

    def _reap(self) -> None:
        """Deadline enforcement: shed over-deadline submissions through the
        normal FAIL path — a degraded (or dying) service fails them
        cleanly instead of letting clients hang."""
        while not self.draining.wait(timeout=0.05):
            now = time.monotonic()
            with self._cond:
                over = [s for s, r in self._subs.items()
                        if not r.resolved and r.deadline is not None
                        and now >= r.deadline]
            for s in over:
                self._fail_submission(s, DeadlineExceeded(
                    f"submission {s} shed: deadline passed before "
                    "completion"))

    def close(self, *, wait: bool = True) -> None:
        """Stop accepting, optionally drain in-flight submissions, then
        shut the ranks down through the completion protocol."""
        if self._closed:
            return
        deadline = time.monotonic() + self.timeout
        with self._cond:
            self._accepting = False
            if wait:
                while (any(not r.resolved for r in self._subs.values())
                       and self._driver_err is None):
                    left = deadline - time.monotonic()
                    if left <= 0 or not self._cond.wait(timeout=min(left, 0.5)):
                        if time.monotonic() >= deadline:
                            break
        self.draining.set()
        self.bus.post(("stop",))
        self._closed = True
        if self._reaper is not None:
            self._reaper.join(timeout=2.0)
        if self._driver is not None:
            self._driver.join(self.timeout)
        if self._driver_err is not None:
            raise RuntimeError("scheduler service failed") \
                from self._driver_err

    # ------------------------------------------------------------- clients

    def client(self, name: str, **kwargs) -> Client:
        with self._lock:
            if name in self._clients:
                raise ValueError(f"client {name!r} already registered")
            c = Client(self, name, **kwargs)
            self._clients[name] = c
            return c

    def client_weight(self, name: str) -> float:
        c = self._clients.get(name)
        return c.weight if c is not None else 1.0

    # ----------------------------------------------------------- admission

    def _effective_cap(self, cap: Optional[int]) -> Optional[int]:
        # caller holds the lock. Shrink admission to surviving capacity:
        # n-1 of n ranks => the client's window shrinks by the same ratio
        # (floor 1 task so progress is always possible).
        if cap is None or not self._dead_ranks:
            return cap
        live = self.n_shards - len(self._dead_ranks)
        return max(1, int(cap * live / self.n_shards))

    def _admit(self, client: Client, graph, blocks, bodies, *,
               owner_map, priority, namespace, ephemeral, n_tasks,
               timeout, deadline=None) -> SubmissionFuture:
        adm_deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while True:
                cap = self._effective_cap(client.max_inflight_tasks)
                if not (cap is not None and client.inflight_tasks > 0
                        and client.inflight_tasks + n_tasks > cap):
                    break
                if self._driver_err is not None or self._closed:
                    break
                left = None if adm_deadline is None \
                    else adm_deadline - time.monotonic()
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"client {client.name!r}: admission blocked "
                        f"({client.inflight_tasks} tasks in flight, "
                        f"effective cap {cap})")
                self._cond.wait(timeout=0.5 if left is None
                                else min(left, 0.5))
            if not self._accepting:
                raise RuntimeError("scheduler service is not accepting "
                                   "submissions (closed or not started)")
            sub_id = self._next_sub
            self._next_sub += 1
            sub = Submission(sub_id, client.name, namespace, graph, blocks,
                             bodies, owner_map, priority, n_tasks,
                             ephemeral=ephemeral)
            fut = SubmissionFuture(sub_id, client.name, n_tasks, svc=self)
            rec = _SubRecord(sub, fut, set(range(self.n_shards)),
                             t0=time.monotonic())
            if deadline is not None:
                rec.deadline = rec.t0 + deadline
            self._subs[sub_id] = rec
            self._ns_owner[namespace] = sub.owner()
            client.inflight_tasks += n_tasks
            client.stats["submitted"] += 1
            # post inside the lock: bus order == sub_id order, always.
            # pin=True lowers the trim floor to this SUBMIT atomically —
            # an unresolved submission's SUBMIT is always re-readable.
            rec.bus_pos = self.bus.post(("submit", sub), pin=True)
        return fut

    # -------------------------------------------------- rank-side callbacks

    def _rank_done(self, sub_id: int, shard: int, published: dict,
                   n_bytes: int, seeded: Optional[dict] = None) -> None:
        with self._cond:
            rec = self._subs.get(sub_id)
            if rec is None or rec.resolved:
                return
            if shard not in rec.pending_ranks:
                return   # duplicate report: account each shard exactly once
            rec.pending_ranks.discard(shard)
            rec.published.update(published)
            if seeded:
                rec.seeded.update(seeded)
            client = self._clients[rec.sub.client]
            # bytes accumulate per shard, replacing a previous report for
            # the same shard — an adopter re-reporting an adopted shard
            # must not double-count
            client.stats["bytes"] += n_bytes - rec.bytes_by_shard.get(shard, 0)
            rec.bytes_by_shard[shard] = n_bytes
            if rec.pending_ranks:
                return
            rec.resolved = True
            client.inflight_tasks -= rec.sub.n_tasks
            client.stats["completed"] += 1
            client.stats["tasks"] += rec.sub.n_tasks
            client.stats["wall_seconds"] += time.monotonic() - rec.t0
            if self._recoverable:
                self._checkpoint_resolved(rec)
            rec.future._complete(rec.published)
            # the future owns the result now; every shard has assimilated
            # (it reported done), so the record's payloads are dead weight
            rec.published = {}
            rec.sub.blocks = {}
            self._update_floor()
            self._note_drained(sub_id)
            self._advance_watermark()
            self._cond.notify_all()

    def _fail_submission(self, sub_id: int, exc: BaseException) -> None:
        with self._cond:
            rec = self._subs.get(sub_id)
            if rec is None or rec.resolved:
                return
            rec.resolved = rec.failed = True
            client = self._clients[rec.sub.client]
            client.inflight_tasks -= rec.sub.n_tasks
            client.stats["failed"] += 1
            if self._recoverable:
                self._checkpoint_failed(rec)
            rec.future._fail(exc if isinstance(exc, SubmissionError)
                             else SubmissionError(
                                 f"submission {sub_id} failed: {exc!r}"))
            # partial rank results are dead (sub.blocks stays: ranks that
            # have not assimilated yet still read it off the bus)
            rec.published = {}
            # every rank must learn: skip the sub's queued tasks, poison
            # the namespace versions it will never produce
            self.bus.post(("fail", sub_id))
            self._update_floor()
            self._note_drained(sub_id)
            self._advance_watermark()
            self._cond.notify_all()

    def _advance_watermark(self) -> None:
        # caller holds the lock
        w = self._resolved_through
        while (w + 1) in self._subs and self._subs[w + 1].resolved:
            w += 1
        if w != self._resolved_through:
            # records at or below the watermark are finished everywhere —
            # evict them so frontdoor memory tracks in-flight work, not
            # the stream's history
            evicted = [self._subs.pop(s)
                       for s in range(self._resolved_through + 1, w + 1)]
            self._resolved_through = w
            self.bus.post(("watermark", w))
            if self._recoverable:
                self._ns_ckpt.retire_through(w)
            for rec in evicted:
                # after the watermark: ranks process the drop only once
                # their retired-through covers the sub, so any straggler
                # publish into the dead namespace is discarded, not kept
                if rec.sub.ephemeral:
                    self.bus.post(("drop_ns", rec.sub.namespace))
                    if self._recoverable:
                        self._ns_ckpt.drop_namespace(rec.sub.namespace)
                    self._ns_owner.pop(rec.sub.namespace, None)

    def _update_floor(self) -> None:
        # caller holds the lock; pin the bus trim at the oldest unresolved
        # SUBMIT so adoption replay can always re-read it
        unresolved = [r.bus_pos for r in self._subs.values()
                      if not r.resolved]
        self.bus.set_floor(min(unresolved) if unresolved else None)

    # ----------------------------------------------------- recovery (death)

    def _checkpoint_resolved(self, rec: _SubRecord) -> None:
        # caller holds the lock. Record the resolved submission's durable
        # effect so an adopter can reseed its namespace shard without
        # replaying resolved work: honored seeds and published versions.
        sub = rec.sub
        for blk, val in rec.seeded.items():
            self._ns_ckpt.restore(sub.namespace, blk, (sub.sub_id, 0),
                                  AVAILABLE, val)
        for blk, val in rec.published.items():
            self._ns_ckpt.restore(sub.namespace, blk, (sub.sub_id, 1),
                                  AVAILABLE, val)

    def _checkpoint_failed(self, rec: _SubRecord) -> None:
        # caller holds the lock. A failed submission's poisons must reach
        # the checkpoint even if the owning rank died before reporting
        # them (a reader binding to a lost poison would silently read
        # stale data instead of failing) — so the frontdoor derives the
        # final-write set itself. Failure path only; never on the hot path.
        sub = rec.sub
        try:
            for s in range(self.n_shards):
                view = sub.graph.derive_local(s, sub.owner_map)
                for blk in view.final_writes:
                    self._ns_ckpt.restore(sub.namespace, blk,
                                          (sub.sub_id, 1), POISONED)
        except Exception:
            pass  # checkpointing must never mask the submission failure

    def _note_poisoned(self, sub_id: int, keys) -> None:
        """Rank-side poison report: precise (only versions that were
        actually PENDING on that rank), complementing the frontdoor's
        conservative derivation in ``_checkpoint_failed``."""
        if not self._recoverable or not keys:
            return
        with self._lock:
            for ns, blk in keys:
                self._ns_ckpt.restore(ns, blk, (sub_id, 1), POISONED)

    def _checkpoint_rows(self) -> List[tuple]:
        return self._ns_ckpt.export()

    def _owner_of(self, ns: str) -> Optional[Callable]:
        with self._lock:
            return self._ns_owner.get(ns)

    def _published_so_far(self, sub_id: int) -> dict:
        """Values an *unresolved* submission already published via shards
        that since completed locally and dropped their state — the
        frontdoor record still holds them, and an adopter restores the
        ones it now owns so later binds see them."""
        with self._lock:
            rec = self._subs.get(sub_id)
            return dict(rec.published) if rec is not None else {}

    def _sub_state(self, sub_id: int) -> str:
        with self._lock:
            rec = self._subs.get(sub_id)
            if rec is None:
                return "gone"       # evicted below the watermark
            if not rec.resolved:
                return "unresolved"
            return "failed" if rec.failed else "done"

    def _on_ranks_dead(self, newly, lost_shards) -> None:
        """First survivor to apply a DEATH declaration lands here (the
        others dedup): freeze the dead cursors, re-arm every unresolved
        record's pending set with the lost shards (the adopters will
        re-report them — client futures stay alive across the epoch),
        start the recovery clock, and shrink the elastic membership."""
        with self._cond:
            fresh = [d for d in newly if d not in self._dead_ranks]
            if not fresh:
                return
            self._dead_ranks.update(fresh)
            self._dead_shards.update(lost_shards)
            for d in fresh:
                self.bus.freeze(d)
                if self._elastic is not None:
                    self._elastic.declare_failed(d)
            if self._elastic is not None:
                try:
                    self.elastic_plan = self._elastic.poll(None)
                except Exception:
                    self.elastic_plan = None
            if self._death_t0 is None:
                self._death_t0 = time.monotonic()
                self._inflight_at_death = {
                    s for s, r in self._subs.items() if not r.resolved}
                if not self._inflight_at_death:
                    self.sched_recover_ms = 0.0
            for r in self._subs.values():
                if not r.resolved:
                    r.pending_ranks.update(lost_shards)
            self._cond.notify_all()

    def _note_drained(self, sub_id: int) -> None:
        # caller holds the lock: stamp sched_recover_ms once — DEATH
        # declaration -> every submission in flight at that moment resolved
        if self._inflight_at_death is None \
                or self.sched_recover_ms is not None:
            return
        self._inflight_at_death.discard(sub_id)
        if not self._inflight_at_death:
            self.sched_recover_ms = (time.monotonic()
                                     - self._death_t0) * 1e3
    def _beat(self, rank: int) -> None:
        if self._elastic is not None:
            self._elastic.beat(rank)

    def admit_replacement(self, rank: int) -> None:
        """Announce a replacement host for a dead rank. The in-proc world
        cannot spawn a new rank thread mid-run, so admission is
        control-plane today: the elastic controller re-arms the rank's
        lease, and its first heartbeat emits the grow plan (remesh over
        the proven-alive set). The data plane keeps routing the dead
        rank's shards to their adopters until a remesh migrates them."""
        with self._lock:
            if self._elastic is None:
                from repro.train.elastic import ElasticController
                self._elastic = ElasticController(
                    self.n_shards, chips_per_host=1, model_axis=1)
            self._elastic.admit(rank)

    # --------------------------------------------------------------- stats

    def stats(self) -> dict:
        ranks = [s.to_dict() for s in self.rank_stats if s is not None]
        if not ranks and self.rank_summaries:
            # cross-process ranks: no shared-memory LiveStats — the final
            # summaries (which embed the same counters) stand in once the
            # stream has drained
            ranks = [s for s in self.rank_summaries if isinstance(s, dict)]
        total = sum(r["blocks_total"] for r in ranks)
        hwm = sum(r["blocks_hwm"] for r in ranks)
        with self._lock:
            clients = {n: dict(c.stats) for n, c in self._clients.items()}
        return {
            "ranks": ranks,
            "clients": clients,
            "blocks_total": total,
            "blocks_hwm": hwm,
            "live_frac": (hwm / total) if total else 0.0,
            "resolved_through": self._resolved_through,
            "capacity": self.capacity(),
        }

    def capacity(self) -> dict:
        with self._lock:
            live = self.n_shards - len(self._dead_ranks)
            return {"n_shards": self.n_shards, "live_ranks": live,
                    "dead_ranks": sorted(self._dead_ranks),
                    "dead_shards": sorted(self._dead_shards),
                    "degraded": bool(self._dead_ranks),
                    "sched_recover_ms": self.sched_recover_ms}

    def debug_snapshot(self) -> str:
        """Forensic dump for future timeouts: the bus-cursor picture,
        unresolved submissions and their pending shards, and each live
        rank's serve-loop + protocol state."""
        lines = ["scheduler snapshot:"]
        try:
            lines.append(f"  bus: {self.bus.snapshot()}")
        except Exception as e:
            lines.append(f"  bus: <snapshot failed: {e!r}>")
        with self._lock:
            unresolved = {s: sorted(r.pending_ranks)
                          for s, r in self._subs.items() if not r.resolved}
        lines.append(f"  unresolved (sub -> pending shards): {unresolved}")
        lines.append(f"  capacity: {self.capacity()}")
        # per-rank state travels through the world's snapshot providers (a
        # SNAPSHOT request over the control channel on multiproc — ranks
        # may live in other processes); fall back to the shared-memory
        # runtime handles when no world is attached
        for r in range(self.n_shards):
            snap = None
            if self._world is not None:
                try:
                    snap = self._world.snapshot_rank(r)
                except Exception as e:
                    snap = f"<snapshot failed: {e!r}>"
            if snap is None:
                rt = self._runtimes[r]
                if rt is None:
                    continue
                try:
                    snap = rt.snapshot()
                except Exception as e:
                    snap = f"<snapshot failed: {e!r}>"
            lines.append(f"  rank {r}: {snap}")
        return "\n".join(lines)

    # ------------------------------------------------------------ rank side

    def _rank_main(self, ctx):
        # on a cross-process transport the rank talks to the parent-hosted
        # service/bus through RPC proxies; `self` here is a forked copy
        # whose locks and threads must never be touched
        svc = self
        rpc = getattr(ctx.comm.world, "svc_rpc", None)
        if rpc is not None:
            from .proxy import ServiceProxy
            svc = ServiceProxy(rpc, self.n_shards)
        rt = ShardRuntime(ctx, svc)
        svc.rank_stats[ctx.rank] = rt.stats
        svc._runtimes[ctx.rank] = rt
        rt.serve()
        ctx.tp.join()   # distributed completion protocol, after STOP
        return rt.summary()


# ------------------------------------------------------------ rank runtime


class ShardRuntime:
    """One resident rank: bus consumption, lazy assimilation, execution —
    for its own shard and any shard it adopts after a death declaration.

    The serve loop pumps ``comm.progress()`` (delivery, acks, retransmits
    — plus the failure-detection half of the completion protocol when
    faults are active, never its quiescence rounds) and applies new bus
    commands; task bodies run on the rank's worker threads as
    fulfillments land. ``route``/``hosted`` mirror ``linalg.host_exec``'s
    fault-tolerant host: shard->rank routing is identical on every rank
    (driven by the DEATH assignment broadcast), misrouted traffic is
    forwarded, and cross-rank sends are logged for replay when their
    destination shard moves.
    """

    def __init__(self, ctx, svc: SchedulerService):
        self.ctx = ctx
        self.rank = ctx.rank
        self.n = svc.n_shards
        self.svc = svc
        self.stats = LiveStats()
        self.fair = FairPolicy()
        self.ns = NamespaceShard(self.stats)
        # shard -> hosting rank; task->shard (view.mapping) is immutable,
        # only shard->host moves. Guarded by _rlock together with hosted
        # and the send log (workers read the route; reconfigure writes it).
        self.route: List[int] = list(range(self.n))
        self.hosted: set = {self.rank}
        self._rlock = threading.RLock()
        self.subs: Dict[tuple, SubmissionShard] = {}  # (sub_id, shard)
        self.open: set = set()                        # (sub_id, shard)
        self.finished: set = set()
        # guards the finished/open transition: a worker thread (last task
        # completing) and the serve thread (assimilation-time remaining==0
        # after held fulfillments) can race into _local_complete
        self._fin_lock = threading.Lock()
        self.assimilated = 0    # highest sub_id ingested (bus order == id)
        self.cursor = 0
        self.tasks_run = 0
        self._stop = False
        # (sub_id, shard) -> fulfillments that raced ahead of assimilation;
        # the lock closes the lookup-or-hold vs insert-and-drain race that
        # multi-shard hosting introduces (workers deliver locally now)
        self._held_lock = threading.Lock()
        self._held_fulfills: Dict[tuple, list] = {}
        # fetches for readers this rank has not assimilated yet
        self._held_fetches: List[tuple] = []
        # sub_id -> cross-rank sends ("ful"/"pub" entries) to replay if
        # the destination shard moves; fault runs only, pruned at the
        # watermark (a resolved submission's sends can never be needed)
        self._sendlog: Dict[int, List[tuple]] = {}
        self._recover = ctx.comm.world.faults is not None
        self._last_beat = 0.0
        # the dispatcher-AM set: registered once, at rank start, in the
        # same order on every rank (registration order is the AM identity)
        self.am_fulfill = ctx.comm.make_active_msg(self._on_fulfill)
        self.am_fetch = ctx.comm.make_active_msg(self._on_fetch)
        self.am_value = ctx.comm.make_active_msg(self._on_value)
        self.am_publish = ctx.comm.make_active_msg(self._on_publish)
        if self._recover:
            ctx.comm.on_reconfigure = self._reconfigure
        # forensics: serve-loop state overrides the bare comm snapshot the
        # rank session registered (works cross-process: the world routes a
        # SNAPSHOT request here)
        ctx.comm.world.attach_snapshot_provider(ctx.rank, self.snapshot)

    # ------------------------------------------------------------ the loop

    def serve(self) -> None:
        world = self.ctx.comm.world
        while True:
            if self.rank in world.dead:
                # killed mid-stream: fall silent like a crashed process.
                # The frontdoor froze this rank's bus cursor at the DEATH
                # declaration; the adopter replays from there.
                raise RankKilled(f"rank {self.rank} killed while serving")
            if world.poison.is_set():
                raise WorldPoisoned("world poisoned while serving")
            if self._recover:
                self._maybe_beat()
                self.ctx.comm.poll_failure_detector()
            for cmd in self.svc.bus.read_from(self.cursor, self.rank):
                if self.rank in world.dead:
                    raise RankKilled(
                        f"rank {self.rank} killed mid-batch")
                self.cursor += 1
                self._apply(cmd)
            self.ctx.comm.progress()
            if self._stop:
                with self._fin_lock:
                    if not self.open:
                        return
            time.sleep(10e-6)

    def _maybe_beat(self) -> None:
        now = time.monotonic()
        if now - self._last_beat >= 0.05:
            self._last_beat = now
            self.svc._beat(self.rank)

    def _apply(self, cmd: tuple) -> None:
        kind = cmd[0]
        if kind == "submit":
            sub = cmd[1]
            with self._rlock:
                shards = sorted(self.hosted)
            for s in shards:
                self._assimilate(sub, s)
            self.assimilated = sub.sub_id
            self._drain_held_fetches()
        elif kind == "fail":
            self._fail_cmd(cmd[1])
        elif kind == "watermark":
            w = cmd[1]
            self.ns.retire_through(w)
            with self._fin_lock:
                self.finished = {f for f in self.finished if f[0] > w}
            if self._recover:
                with self._rlock:
                    for s in [s for s in self._sendlog if s <= w]:
                        del self._sendlog[s]
        elif kind == "drop_ns":
            self.ns.drop_namespace(cmd[1])
        elif kind == "stop":
            self._stop = True

    def summary(self) -> dict:
        with self._rlock:
            hosted = sorted(self.hosted)
        return {"rank": self.rank, "tasks_run": self.tasks_run,
                "assimilated": self.assimilated, "hosted": hosted,
                "ns_live_versions": self.ns.live_versions(),
                **self.stats.to_dict()}

    def snapshot(self) -> dict:
        """Serve-loop + protocol forensics for ``debug_snapshot``."""
        with self._rlock:
            hosted, route = sorted(self.hosted), list(self.route)
        with self._fin_lock:
            open_ = sorted(self.open)
        try:
            comm = self.ctx.comm.snapshot()
        except Exception as e:
            comm = f"<comm snapshot failed: {e!r}>"
        return {"cursor": self.cursor, "assimilated": self.assimilated,
                "hosted": hosted, "route": route, "open": open_,
                "tasks_run": self.tasks_run,
                "fair": self.fair.snapshot(), "comm": comm}

    # -------------------------------------------------------- assimilation

    def _assimilate(self, sub: Submission, s: int, *,
                    replay: bool = False) -> None:
        owner = sub.owner()
        # the one and only discovery step: owned + halo, never global
        view = sub.graph.derive_local(s, sub.owner_map)
        if replay:
            # per (submission, shard) re-derivation: count the edges here;
            # _adopt records the shard itself once per adoption
            self.ctx.comm.world.report.bump(
                "rederived_edges", view.stats.get("derived_edges", 0))
        tf = self.ctx.taskflow(f"sub{sub.sub_id}@s{s}")
        shard = SubmissionShard(sub, view, tf, self.stats, shard=s)
        shard.adopted = replay

        # wire the per-submission Taskflow before exposing the shard:
        # a concurrent local fulfillment must never find half-set hooks
        weight = self.svc.client_weight(sub.client)

        def indegree(k):
            return (len(view.in_deps(k)) + len(view.external_reads(k))) or 1

        def priority(k):
            shard.mark_ready(k)   # spawn time == entering the ready queue
            return self.fair.priority_for(sub.client, weight, sub.priority)

        tf.set_indegree(indegree)
        tf.set_mapping(lambda k: hash(k) % self.ctx.tp.n_threads)
        tf.set_priority(priority)
        tf.set_task(lambda k: self._run_task(shard, k))

        with self._held_lock:
            self.subs[(sub.sub_id, s)] = shard
        with self._fin_lock:
            self.open.add((sub.sub_id, s))

        # 1. seed initial values for owned blocks (virgin timelines only:
        #    an earlier submission's write is the truth)
        for blk, val in sub.blocks.items():
            if owner(blk) % self.n == s:
                arr = np.asarray(val)
                if self.ns.seed_initial(sub.namespace, blk, sub.sub_id, arr):
                    shard.seeded[blk] = arr
        # 2. reserve the versions this submission will write here
        for blk in view.final_writes:
            if owner(blk) % self.n == s:
                self.ns.ensure_pending(sub.namespace, blk, sub.sub_id)
        if replay:
            # values this submission already published through shards that
            # completed-and-dropped before the death: the frontdoor record
            # still holds them — restore the ones this shard now owns
            for blk, val in self.svc._published_so_far(sub.sub_id).items():
                if owner(blk) % self.n == s:
                    self.ns.restore(sub.namespace, blk, (sub.sub_id, 1),
                                    AVAILABLE, np.asarray(val))

        # 3. bind external reads + release seeds (a bad binding fails the
        #    submission, but assimilation always finalizes: the cursor and
        #    held-fetch draining must advance regardless)
        if self._bind_external(shard, owner):
            # seeds: tasks with no dependencies at all (synthetic indegree
            # 1, fulfilled here — execution may start immediately)
            for k in view.tasks:
                if not view.in_deps(k) and not view.external_reads(k):
                    tf.fulfill_promise(k)
            # fulfillments that arrived before this shard existed here
            with self._held_lock:
                held = self._held_fulfills.pop((sub.sub_id, s), [])
            for (d, k, blk, payload) in held:
                self._apply_fulfill(shard, d, k, blk, payload)
        else:
            with self._held_lock:
                self._held_fulfills.pop((sub.sub_id, s), None)
        if not shard.failed and shard.remaining == 0:
            self._local_complete(shard)

    def _bind_external(self, shard: SubmissionShard, owner) -> bool:
        """Bind the view's external reads: blocks whose owner shard is
        hosted here straight from this rank's namespace shard, remote ones
        via one FETCH per block along the current route."""
        sub, view = shard.sub, shard.view
        remote: Dict[B, List[K]] = {}
        with self._rlock:
            hosted = set(self.hosted)
        for k in view.tasks:
            for blk in view.external_reads(k):
                ob = owner(blk) % self.n
                if ob in hosted:
                    try:
                        self.ns.bind(sub.namespace, blk, sub.sub_id,
                                     self._bind_cb(shard, blk, [k]))
                    except KeyError as e:
                        self._fail_local(shard, SubmissionError(str(e)))
                        return False
                else:
                    remote.setdefault(blk, []).append(k)
        with shard.lock:
            shard.fetch_waiters.update(remote)
        for blk in remote:
            self._send_fetch(sub.namespace, blk, owner(blk) % self.n,
                             sub.sub_id, shard.shard)
        return True

    def _bind_cb(self, shard: SubmissionShard, blk: B, ks: List[K]):
        def cb(value, poisoned):
            if poisoned:
                self._fail_local(shard, SubmissionError(
                    f"submission {shard.sub.sub_id}: upstream submission "
                    f"failed before producing block {blk!r}"))
                return
            shard.put(blk, value)
            for k in ks:
                shard.tf.fulfill_promise(k)
        return cb

    # ----------------------------------------------------------- execution

    def _run_task(self, shard: SubmissionShard, k: K) -> None:
        if shard.failed:
            return   # sub already failed: don't run, don't propagate
        view = shard.view
        try:
            shard.mark_running(k)
            with shard.lock:
                ops = [shard.store[b] for b in view.operands(k)]
            out = np.asarray(shard.sub.bodies[view.type_of(k)](*ops))
        except BaseException as e:
            self._fail_local(shard, e)
            return
        if shard.adopted:
            self.ctx.comm.world.report.bump("reexecuted_tasks")
        blk = view.block_of(k)
        shard.put(blk, out)
        payload_to = view.payload_consumers(k)
        n_remote = 0
        sub_id = shard.sub.sub_id
        for d in view.out_deps(k):
            ds = view.mapping(d) % self.n
            if ds == shard.shard:
                shard.tf.fulfill_promise(d)
            else:
                n_remote += 1
                self._deliver_fulfill(sub_id, ds, d, k, blk,
                                      out if d in payload_to else None)
        if view.final_writes.get(blk) == k:
            self._publish(shard, blk, out)
        self.tasks_run += 1
        if shard.complete(k, n_remote):
            self._local_complete(shard)

    def _deliver_fulfill(self, sub_id: int, ds: int, d: K, k: K, blk: B,
                         payload) -> None:
        """Route one cross-shard fulfillment (and log it for replay)."""
        with self._rlock:
            if self._recover:
                self._sendlog.setdefault(sub_id, []).append(
                    ("ful", ds, d, k, blk, payload))
            tgt = self.route[ds]
        if tgt == self.rank:
            self._local_fulfill(sub_id, ds, d, k, blk, payload)
        else:
            self.am_fulfill.send(tgt, sub_id, ds, d, k, blk, payload)

    def _local_fulfill(self, sub_id: int, ds: int, d: K, k: K, blk: B,
                       payload) -> None:
        with self._held_lock:
            shard = self.subs.get((sub_id, ds))
            if shard is None:
                if sub_id > self.assimilated:
                    self._held_fulfills.setdefault((sub_id, ds), []).append(
                        (d, k, blk, payload))
                return   # finished or failed: late traffic is inert
        self._apply_fulfill(shard, d, k, blk, payload)

    def _apply_fulfill(self, shard: SubmissionShard, d: K, k: K, blk: B,
                       payload) -> None:
        # exactly once per (consumer, producer) edge: transport dedup
        # stops retransmits, but adoption re-execution and send-log replay
        # legitimately re-produce the same fulfillment
        with shard.lock:
            if (d, k) in shard.applied:
                return
            shard.applied.add((d, k))
        if payload is not None:
            shard.put(blk, np.asarray(payload))
        shard.tf.fulfill_promise(d)

    def _publish(self, shard: SubmissionShard, blk: B, out) -> None:
        sub = shard.sub
        with shard.lock:
            shard.published[blk] = out
        ob = sub.owner()(blk) % self.n
        with self._rlock:
            hosted = ob in self.hosted
            if hosted:
                tgt = self.rank
            else:
                if self._recover:
                    self._sendlog.setdefault(sub.sub_id, []).append(
                        ("pub", ob, sub.namespace, blk, sub.sub_id, out))
                tgt = self.route[ob]
        if hosted:
            self.ns.publish(sub.namespace, blk, sub.sub_id, out)
        else:
            self.am_publish.send(tgt, sub.namespace, blk, sub.sub_id, ob,
                                 out)

    def _local_complete(self, shard: SubmissionShard) -> None:
        key = (shard.sub.sub_id, shard.shard)
        with self._fin_lock:
            if key in self.finished:
                return
            self.open.discard(key)
            self.finished.add(key)
        with shard.lock:
            published = dict(shard.published)
            seeded = dict(shard.seeded)
        n_bytes = sum(getattr(v, "nbytes", 0) for v in published.values())
        self.svc._rank_done(shard.sub.sub_id, shard.shard, published,
                            n_bytes, seeded=seeded)
        shard.drop()
        self.subs.pop(key, None)   # forget the submission: O(frontier)

    # ------------------------------------------------------------- failure

    def _fail_local(self, shard: SubmissionShard,
                    exc: BaseException) -> None:
        sub_id = shard.sub.sub_id
        with shard.lock:
            if shard.failed:
                return
            shard.failed = True
        key = (sub_id, shard.shard)
        with self._fin_lock:
            self.open.discard(key)
            self.finished.add(key)
        self.svc._fail_submission(sub_id, exc)
        self.svc._note_poisoned(sub_id, self.ns.poison_sub(sub_id))
        shard.drop()
        self.subs.pop(key, None)

    def _fail_cmd(self, sub_id: int) -> None:
        with self._rlock:
            shards = sorted(self.hosted)
        for s in shards:
            shard = self.subs.get((sub_id, s))
            if shard is not None:
                with shard.lock:
                    shard.failed = True
                with self._fin_lock:
                    self.open.discard((sub_id, s))
                    self.finished.add((sub_id, s))
                shard.drop()
                self.subs.pop((sub_id, s), None)
        self.svc._note_poisoned(sub_id, self.ns.poison_sub(sub_id))
        if self._recover:
            with self._rlock:
                self._sendlog.pop(sub_id, None)

    # ------------------------------------------------------- active messages

    def _on_fulfill(self, sub_id: int, ds: int, d: K, k: K, blk: B,
                    payload) -> None:
        with self._rlock:
            hosted = ds in self.hosted
        if not hosted:
            # stale route: a survivor's replay raced ahead of our own
            # DEATH processing. Forward along our route — _deliver_fulfill
            # logs the forward, so if our route is itself stale (the
            # fenced dead rank), our reconfigure replays it.
            self.ctx.comm.world.report.bump("forwarded_ams")
            self._deliver_fulfill(sub_id, ds, d, k, blk, payload)
            return
        self._local_fulfill(sub_id, ds, d, k, blk, payload)

    def _send_fetch(self, ns: str, blk: B, ob: int, reader_sub: int,
                    ds: int) -> None:
        with self._rlock:
            hosted = ob in self.hosted
            tgt = self.route[ob]
        if hosted:
            self._on_fetch(ns, blk, ob, reader_sub, ds, self.rank)
        else:
            self.am_fetch.send(tgt, ns, blk, ob, reader_sub, ds, self.rank)

    def _on_fetch(self, ns: str, blk: B, ob: int, reader_sub: int,
                  ds: int, src: int) -> None:
        with self._rlock:
            hosted = ob in self.hosted
            tgt = self.route[ob]
            if not hosted and self._recover:
                # a fetch forwarded into a stale route (the fenced dead
                # rank) would strand its reader: log it like a fulfill so
                # our own reconfigure replays it once the shard is re-homed
                self._sendlog.setdefault(reader_sub, []).append(
                    ("fet", ob, ns, blk, reader_sub, ds, src))
        if not hosted:
            self.ctx.comm.world.report.bump("forwarded_ams")
            self.am_fetch.send(tgt, ns, blk, ob, reader_sub, ds, src)
            return
        if reader_sub > self.assimilated:
            # binding needs every version with key < (reader_sub, 1) in
            # the timeline — hold until this rank's cursor catches up
            self._held_fetches.append((ns, blk, ob, reader_sub, ds, src))
            return

        def cb(value, poisoned):
            if src == self.rank:   # post-adoption self-fetch
                self._on_value(reader_sub, ds, blk, value, poisoned)
            else:
                self.am_value.send(src, reader_sub, ds, blk, value,
                                   poisoned)
        try:
            self.ns.bind(ns, blk, reader_sub, cb)
        except KeyError:
            cb(None, True)

    def _drain_held_fetches(self) -> None:
        held, self._held_fetches = self._held_fetches, []
        for args in held:
            self._on_fetch(*args)

    def _on_value(self, reader_sub: int, ds: int, blk: B, value,
                  poisoned) -> None:
        with self._held_lock:
            shard = self.subs.get((reader_sub, ds))
        if shard is None:
            return
        if poisoned:
            self._fail_local(shard, SubmissionError(
                f"submission {reader_sub}: upstream submission failed "
                f"before producing block {blk!r}"))
            return
        with shard.lock:
            ks = shard.fetch_waiters.pop(blk, [])
        if not ks:
            return   # duplicate value: a re-issued fetch raced the original
        shard.put(blk, np.asarray(value))
        for k in ks:
            shard.tf.fulfill_promise(k)

    def _on_publish(self, ns: str, blk: B, sub_id: int, ob: int,
                    value) -> None:
        with self._rlock:
            hosted = ob in self.hosted
            if not hosted:
                if self._recover:
                    self._sendlog.setdefault(sub_id, []).append(
                        ("pub", ob, ns, blk, sub_id, value))
                tgt = self.route[ob]
        if not hosted:
            self.ctx.comm.world.report.bump("forwarded_ams")
            self.am_publish.send(tgt, ns, blk, sub_id, ob, value)
            return
        self.ns.publish(ns, blk, sub_id, np.asarray(value))

    # ------------------------------------------------------------ recovery

    def _reconfigure(self, newly_dead, assignment, epoch) -> None:
        """DEATH declaration applied (runs on this rank's serve thread,
        inside ``progress()``): freeze the dead cursors and re-arm the
        frontdoor, adopt what is ours (checkpoint restore + bus replay),
        flip the routes, replay logged sends to every moved shard, and
        re-issue outstanding fetches whose owner moved."""
        report = self.ctx.comm.world.report
        dead = set(newly_dead)
        with self._rlock:
            old_route = list(self.route)
        # the DEATH assignment keys dead ranks — which ARE shard ids (shard
        # s starts on rank s, and the cumulative map re-states every dead
        # rank's shard each epoch), same reading as linalg's _FaultHost
        changed = {s: h for s, h in assignment.items()
                   if old_route[s] != h}
        # shards lost with the newly dead ranks (their pre-flip host just
        # died): the frontdoor re-arms exactly these in pending sets
        lost = [s for s in range(self.n) if old_route[s] in dead]
        mine: Dict[int, List[int]] = {}
        for s, h in changed.items():
            if h == self.rank:
                mine.setdefault(old_route[s], []).append(s)
        self.svc._on_ranks_dead(newly_dead, lost)
        for dead_host, shards in sorted(mine.items()):
            self._adopt(dead_host, sorted(shards), report)
        # adoption wired the shards into `hosted` BEFORE this flip: a route
        # that says "me" must always find its state
        with self._rlock:
            for s, h in changed.items():
                self.route[s] = h
            entries = [(sid, e) for sid, log in self._sendlog.items()
                       for e in log if e[1] in changed]
        for sid, e in entries:
            self._replay_send(sid, e, report)
        self._refetch(set(changed))
        # lift the dead cursors' trim pins. Each adopter votes once per
        # dead host it adopted from; the pin holds until the LAST adopter
        # has replayed (one dead rank's shards can land on several
        # survivors). Vote counts agree on every rank: they derive from
        # the broadcast assignment and the deterministic pre-flip route.
        adopters: Dict[int, set] = {}
        for s, h in changed.items():
            if old_route[s] in dead:
                adopters.setdefault(old_route[s], set()).add(h)
        for dead_host, who in adopters.items():
            if self.rank in who:
                self.svc.bus.retire_reader(dead_host,
                                           votes_needed=len(who))

    def _adopt(self, dead_host: int, shards: List[int], report) -> None:
        """Adopt ``shards`` lost with ``dead_host``: reseed the namespace
        from the frontdoor's resolved-prefix checkpoint, then replay the
        bus from the dead rank's frozen cursor (floored at the oldest
        unresolved SUBMIT), re-deriving unresolved submissions for the
        adopted shards. Every effect is idempotent, so over-covering the
        dead rank's actually-applied prefix is safe."""
        shard_set = set(shards)
        for ns, blk, key, state, value in self.svc._checkpoint_rows():
            owner = self.svc._owner_of(ns)
            if owner is None or owner(blk) % self.n not in shard_set:
                continue
            self.ns.restore(ns, blk, key, state, value)
        lo = self.svc.bus.frozen_cursor(dead_host)
        floor = self.svc.bus.floor()
        if floor is not None:
            lo = min(lo, floor)
        # host the shards before replaying: replay-time assimilation must
        # bind the adopted shard's own blocks locally, not fetch them from
        # the pre-flip route (the fenced dead rank)
        with self._rlock:
            self.hosted.update(shards)
        for s in shards:
            report.note_rederived(s, 0)
        for cmd in self.svc.bus.read_range(lo, self.cursor):
            report.bump("bus_replayed")
            self._replay_cmd(cmd, shards)

    def _replay_cmd(self, cmd: tuple, shards: List[int]) -> None:
        kind = cmd[0]
        if kind == "submit":
            sub = cmd[1]
            if self.svc._sub_state(sub.sub_id) == "unresolved":
                for s in shards:
                    self._assimilate(sub, s, replay=True)
            # resolved (done or failed) or evicted: its durable effect —
            # publishes, honored seeds, poisons — was restored from the
            # frontdoor checkpoint before replay began
        elif kind == "fail":
            self.ns.poison_sub(cmd[1])
        elif kind == "watermark":
            self.ns.retire_through(cmd[1])
        elif kind == "drop_ns":
            self.ns.drop_namespace(cmd[1])
        # stop: this rank's own cursor already tracked it

    def _replay_send(self, sub_id: int, e: tuple, report) -> None:
        report.bump("replayed_sends")
        if e[0] == "ful":
            _, ds, d, k, blk, payload = e
            self._deliver_fulfill(sub_id, ds, d, k, blk, payload)
        elif e[0] == "fet":
            _, ob, ns, blk, reader_sub, ds, src = e
            self._on_fetch(ns, blk, ob, reader_sub, ds, src)
        else:
            _, ob, ns, blk, sid, value = e
            with self._rlock:
                hosted = ob in self.hosted
                tgt = self.route[ob]
            if hosted:
                self.ns.publish(ns, blk, sid, value)
            else:
                self.am_publish.send(tgt, ns, blk, sid, ob, value)

    def _refetch(self, changed: set) -> None:
        """Outstanding fetches whose owner shard just moved: the fetch (or
        its value) may have died with the old host — re-issue along the
        new route. Duplicate values are absorbed by the empty-waiters
        guard in ``_on_value``; bindings are deterministic, so a
        duplicate carries the identical value anyway."""
        with self._held_lock:
            live = list(self.subs.items())
        for (sub_id, s), shard in live:
            owner = shard.sub.owner()
            with shard.lock:
                waiting = list(shard.fetch_waiters.keys())
            for blk in waiting:
                ob = owner(blk) % self.n
                if ob in changed:
                    self._send_fetch(shard.sub.namespace, blk, ob,
                                     sub_id, s)
