"""The persistent, multi-tenant scheduler service.

One-shot execution (``Graph.run_host``) spins up ranks, runs one graph,
and tears the world down. The service keeps the ranks *resident*: a
stream of PTGs from many concurrent clients is assimilated into one live
dependency state and tasks run as predecessors complete — TaskTorrent's
"the DAG is discovered piece by piece, as messages arrive" lifted from
one graph to an open-ended stream of them.

Architecture (all in-process, mirroring the paper's rank model):

- the **frontdoor** (:class:`SchedulerService` + :class:`Client`) accepts
  submissions, applies admission control (max in-flight tasks per client
  — ``submit`` blocks, which is the backpressure), assigns monotone
  submission ids, and appends SUBMIT / FAIL / WATERMARK / STOP commands
  to a **submission bus** — an append-only log every rank consumes at its
  own cursor. The bus's total order is the determinism anchor: all ranks
  resolve identical cross-submission bindings because they all see the
  same prefix in the same order;
- each rank runs a :class:`ShardRuntime`: a resident loop that pumps the
  communicator, assimilates new submissions **via the lazy path only**
  (``Graph.derive_local`` — owned tasks + halo; no rank ever materializes
  a global edge dict), and lets the work-stealing threadpool execute
  ready tasks. The loop never drives the completion detector, so the
  distributed-shutdown protocol (which would tear the world down at the
  first quiescent moment) only runs inside the final ``tp.join()`` after
  STOP;
- per-submission wiring reuses the host-runtime shape (indegree from the
  view's in-edges plus its external reads, cross-rank fulfillments as
  active messages carrying the block iff the consumer reads it), but all
  ranks share **one dispatcher-AM set registered at rank start** —
  registration order is the global AM identity, so submissions arriving
  later must not register new ones;
- cross-submission data flows through named block namespaces
  (:mod:`repro.sched.namespace`); retirement
  (:mod:`repro.sched.state`) keeps memory on the live frontier; the ready
  queue is ordered by the weighted-fair policy (:mod:`repro.sched.fair`).

Failure is per-submission, not per-service: a task body that raises fails
its submission's future and poisons the namespace versions it will never
produce (readers fail loudly instead of hanging) — other clients and
unrelated submissions are untouched.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, List, Optional

import numpy as np

from repro.core import runtime as core_runtime
from repro.core.messages import WorldPoisoned

from .fair import FairPolicy
from .namespace import NamespaceShard
from .state import LiveStats, SubmissionShard

K = Hashable
B = Hashable


class SubmissionError(RuntimeError):
    """A submission failed (its own body raised, or an upstream submission
    it reads from failed before producing the block)."""


# ---------------------------------------------------------------- frontdoor


@dataclass
class Submission:
    sub_id: int
    client: str
    namespace: str
    graph: object
    blocks: dict
    bodies: dict
    owner_map: Optional[Callable]
    priority: float
    n_tasks: int
    # ephemeral: no later submission will ever target this namespace, so
    # its state is dropped wholesale once the watermark passes (Client.map)
    ephemeral: bool = False

    def owner(self) -> Callable[[B], int]:
        return self.owner_map if self.owner_map is not None \
            else self.graph.owner


class SubmissionFuture:
    """Handle for one submission: ``result()`` returns the blocks the
    submission wrote (block id -> value), the same contract as the
    one-shot ``run_host`` — which is what makes bit-identity checkable."""

    def __init__(self, sub_id: int, client: str, n_tasks: int):
        self.sub_id = sub_id
        self.client = client
        self.n_tasks = n_tasks
        self._ev = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None
        self._transform: Optional[Callable] = None

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError(
                f"submission {self.sub_id} not done after {timeout}s")
        if self._exc is not None:
            raise self._exc
        return (self._transform(self._result) if self._transform
                else self._result)

    def _complete(self, blocks) -> None:
        self._result = blocks
        self._ev.set()

    def _fail(self, exc: BaseException) -> None:
        self._exc = exc
        self._ev.set()


class _Bus:
    """Append-only command log; ranks read at their own cursor. The total
    order of appends IS the stream's sequential semantics. Cursors are
    absolute (they keep counting up forever), but storage is not: the
    prefix every reader has consumed can never be read again and is
    trimmed away, so a resident service holds O(unconsumed commands), not
    the whole stream history."""

    def __init__(self, n_readers: int) -> None:
        self._items: List[tuple] = []
        self._base = 0                      # absolute index of _items[0]
        self._cursors = [0] * n_readers
        self._lock = threading.Lock()

    def post(self, item: tuple) -> None:
        with self._lock:
            self._items.append(item)

    def read_from(self, cursor: int, reader: int) -> List[tuple]:
        with self._lock:
            self._cursors[reader] = cursor
            low = min(self._cursors)
            if low > self._base:
                del self._items[:low - self._base]
                self._base = low
            return self._items[cursor - self._base:]


@dataclass
class _SubRecord:
    sub: Submission
    future: SubmissionFuture
    pending_ranks: set
    published: dict = field(default_factory=dict)
    t0: float = 0.0
    resolved: bool = False
    failed: bool = False


class Client:
    """Per-tenant frontdoor handle: submissions, accounting, admission.

    ``max_inflight_tasks`` is the admission-control knob: ``submit``
    blocks while the client's in-flight task count would exceed it (a
    single oversized submission is admitted alone rather than deadlocking).
    ``weight`` feeds the ranks' fair policy. ``stats`` accumulates tasks,
    bytes (result blocks produced), and wall seconds per submission.
    """

    def __init__(self, service: "SchedulerService", name: str, *,
                 weight: float = 1.0,
                 max_inflight_tasks: Optional[int] = None,
                 namespace: Optional[str] = None):
        self._svc = service
        self.name = name
        self.weight = weight
        self.max_inflight_tasks = max_inflight_tasks
        self.namespace = namespace if namespace is not None else name
        self._map_seq = itertools.count()
        self.inflight_tasks = 0
        self.stats = {"submitted": 0, "completed": 0, "failed": 0,
                      "tasks": 0, "bytes": 0, "wall_seconds": 0.0}

    def submit(self, graph, blocks=None, bodies=None, *,
               owner_map: Optional[Callable] = None,
               priority: float = 0.0,
               namespace: Optional[str] = None,
               ephemeral: bool = False,
               timeout: Optional[float] = None) -> SubmissionFuture:
        """Submit one PTG against a namespace; returns a future for its
        written blocks. External reads (blocks no task of this graph
        writes first) bind to the namespace — earlier submissions' final
        writes win over ``blocks``' initial values. Blocks of the graph
        must keep one owner across the namespace's submissions.
        ``ephemeral=True`` declares that no later submission will target
        the namespace: its block state is dropped wholesale once this
        submission resolves, instead of its last versions living on as
        the namespace's durable values."""
        n_tasks = sum(1 for _ in graph._program_iter())
        return self._svc._admit(
            self, graph, dict(blocks or {}), dict(bodies or {}),
            owner_map=owner_map, priority=priority,
            namespace=namespace if namespace is not None else self.namespace,
            ephemeral=ephemeral, n_tasks=n_tasks, timeout=timeout)

    def map(self, fn: Callable, values, *,
            priority: float = 0.0) -> SubmissionFuture:
        """Embarrassingly parallel convenience: one task per element of
        ``values``, sharded round-robin; ``result()`` returns the mapped
        list in order. Each call runs in its own private throwaway
        namespace (unique per call — reusing one would bind this call's
        ``("x", i)`` reads to a previous call's seeds, since a namespace
        honors initial values only on virgin timelines) that is dropped
        wholesale once the call resolves."""
        from repro.ptg import Graph, IndexSpace

        vals = list(values)
        n = self._svc.n_shards
        g = Graph(f"map-{self.name}", n_shards=n,
                  owner=lambda blk: blk[1] % n)
        g.task_type("map",
                    writes=lambda i: ("y", i),
                    reads=lambda i: [("x", i)],
                    space=IndexSpace(
                        lambda: range(len(vals)),
                        lambda s: [i for i in range(len(vals))
                                   if i % n == s],
                        size=len(vals)))
        blocks = {("x", i): np.asarray(v) for i, v in enumerate(vals)}
        fut = self.submit(g, blocks, {"map": fn}, priority=priority,
                          namespace=f"{self.name}/map{next(self._map_seq)}",
                          ephemeral=True)
        fut._transform = lambda out: [out[("y", i)]
                                      for i in range(len(vals))]
        return fut


# ------------------------------------------------------------------ service


class SchedulerService:
    """The resident scheduler. Typical use::

        with SchedulerService(n_shards=2) as svc:
            alice = svc.client("alice", weight=2.0)
            fut = alice.submit(graph, blocks, bodies)
            out = fut.result()

    ``start()`` launches a driver thread running ``run_ranks(...,
    serve_scheduler=self)``; ranks stay resident between submissions.
    ``close()`` (or leaving the ``with``) waits for in-flight work, posts
    STOP, and runs the distributed completion protocol to tear down.
    """

    def __init__(self, n_shards: int, *, n_threads: int = 2,
                 timeout: float = 120.0):
        self.n_shards = n_shards
        self.n_threads = n_threads
        self.timeout = timeout
        self.bus = _Bus(n_shards)
        self.draining = threading.Event()  # run_ranks arms its deadline here
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._clients: Dict[str, Client] = {}
        self._subs: Dict[int, _SubRecord] = {}
        self._next_sub = 1
        self._resolved_through = 0
        self._accepting = False
        self._closed = False
        self._driver: Optional[threading.Thread] = None
        self._driver_err: Optional[BaseException] = None
        self.rank_stats: List[Optional[LiveStats]] = [None] * n_shards
        self.rank_summaries: Optional[list] = None

    # ------------------------------------------------------------ lifecycle

    def start(self) -> "SchedulerService":
        if self._driver is not None:
            raise RuntimeError("scheduler already started")
        self._accepting = True
        self._driver = threading.Thread(target=self._drive, daemon=True,
                                        name="sched-driver")
        self._driver.start()
        return self

    def __enter__(self) -> "SchedulerService":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close(wait=exc_type is None)

    def _drive(self) -> None:
        try:
            # attribute lookup at call time so the chaos-injection wrapper
            # (conftest REPRO_CHAOS) sees this run_ranks call too
            res = core_runtime.run_ranks(
                self.n_shards, self._rank_main, n_threads=self.n_threads,
                timeout=self.timeout, serve_scheduler=self)
            self.rank_summaries = res[0] if isinstance(res, tuple) else res
        except BaseException as e:
            self._driver_err = e
            with self._cond:
                for rec in self._subs.values():
                    if not rec.resolved:
                        rec.resolved = rec.failed = True
                        rec.future._fail(SubmissionError(
                            f"scheduler service died: {e!r}"))
                self._accepting = False
                self._cond.notify_all()

    def close(self, *, wait: bool = True) -> None:
        """Stop accepting, optionally drain in-flight submissions, then
        shut the ranks down through the completion protocol."""
        if self._closed:
            return
        deadline = time.monotonic() + self.timeout
        with self._cond:
            self._accepting = False
            if wait:
                while (any(not r.resolved for r in self._subs.values())
                       and self._driver_err is None):
                    left = deadline - time.monotonic()
                    if left <= 0 or not self._cond.wait(timeout=min(left, 0.5)):
                        if time.monotonic() >= deadline:
                            break
        self.draining.set()
        self.bus.post(("stop",))
        self._closed = True
        if self._driver is not None:
            self._driver.join(self.timeout)
        if self._driver_err is not None:
            raise RuntimeError("scheduler service failed") \
                from self._driver_err

    # ------------------------------------------------------------- clients

    def client(self, name: str, **kwargs) -> Client:
        with self._lock:
            if name in self._clients:
                raise ValueError(f"client {name!r} already registered")
            c = Client(self, name, **kwargs)
            self._clients[name] = c
            return c

    def client_weight(self, name: str) -> float:
        c = self._clients.get(name)
        return c.weight if c is not None else 1.0

    # ----------------------------------------------------------- admission

    def _admit(self, client: Client, graph, blocks, bodies, *,
               owner_map, priority, namespace, ephemeral, n_tasks,
               timeout) -> SubmissionFuture:
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            cap = client.max_inflight_tasks
            while (cap is not None and client.inflight_tasks > 0
                   and client.inflight_tasks + n_tasks > cap):
                if self._driver_err is not None or self._closed:
                    break
                left = None if deadline is None \
                    else deadline - time.monotonic()
                if left is not None and left <= 0:
                    raise TimeoutError(
                        f"client {client.name!r}: admission blocked "
                        f"({client.inflight_tasks} tasks in flight, "
                        f"cap {cap})")
                self._cond.wait(timeout=0.5 if left is None
                                else min(left, 0.5))
            if not self._accepting:
                raise RuntimeError("scheduler service is not accepting "
                                   "submissions (closed or not started)")
            sub_id = self._next_sub
            self._next_sub += 1
            sub = Submission(sub_id, client.name, namespace, graph, blocks,
                             bodies, owner_map, priority, n_tasks,
                             ephemeral=ephemeral)
            fut = SubmissionFuture(sub_id, client.name, n_tasks)
            self._subs[sub_id] = _SubRecord(
                sub, fut, set(range(self.n_shards)), t0=time.monotonic())
            client.inflight_tasks += n_tasks
            client.stats["submitted"] += 1
            # post inside the lock: bus order == sub_id order, always
            self.bus.post(("submit", sub))
        return fut

    # -------------------------------------------------- rank-side callbacks

    def _rank_done(self, sub_id: int, rank: int, published: dict,
                   n_bytes: int) -> None:
        with self._cond:
            rec = self._subs.get(sub_id)
            if rec is None or rec.resolved:
                return
            if rank not in rec.pending_ranks:
                return   # duplicate report: account each rank exactly once
            rec.pending_ranks.discard(rank)
            rec.published.update(published)
            client = self._clients[rec.sub.client]
            client.stats["bytes"] += n_bytes
            if rec.pending_ranks:
                return
            rec.resolved = True
            client.inflight_tasks -= rec.sub.n_tasks
            client.stats["completed"] += 1
            client.stats["tasks"] += rec.sub.n_tasks
            client.stats["wall_seconds"] += time.monotonic() - rec.t0
            rec.future._complete(rec.published)
            # the future owns the result now; every rank has assimilated
            # (it reported done), so the record's payloads are dead weight
            rec.published = {}
            rec.sub.blocks = {}
            self._advance_watermark()
            self._cond.notify_all()

    def _fail_submission(self, sub_id: int, exc: BaseException) -> None:
        with self._cond:
            rec = self._subs.get(sub_id)
            if rec is None or rec.resolved:
                return
            rec.resolved = rec.failed = True
            client = self._clients[rec.sub.client]
            client.inflight_tasks -= rec.sub.n_tasks
            client.stats["failed"] += 1
            rec.future._fail(exc if isinstance(exc, SubmissionError)
                             else SubmissionError(
                                 f"submission {sub_id} failed: {exc!r}"))
            # partial rank results are dead (sub.blocks stays: ranks that
            # have not assimilated yet still read it off the bus)
            rec.published = {}
            # every rank must learn: skip the sub's queued tasks, poison
            # the namespace versions it will never produce
            self.bus.post(("fail", sub_id))
            self._advance_watermark()
            self._cond.notify_all()

    def _advance_watermark(self) -> None:
        # caller holds the lock
        w = self._resolved_through
        while (w + 1) in self._subs and self._subs[w + 1].resolved:
            w += 1
        if w != self._resolved_through:
            # records at or below the watermark are finished everywhere —
            # evict them so frontdoor memory tracks in-flight work, not
            # the stream's history
            evicted = [self._subs.pop(s)
                       for s in range(self._resolved_through + 1, w + 1)]
            self._resolved_through = w
            self.bus.post(("watermark", w))
            for rec in evicted:
                # after the watermark: ranks process the drop only once
                # their retired-through covers the sub, so any straggler
                # publish into the dead namespace is discarded, not kept
                if rec.sub.ephemeral:
                    self.bus.post(("drop_ns", rec.sub.namespace))

    # --------------------------------------------------------------- stats

    def stats(self) -> dict:
        ranks = [s.to_dict() for s in self.rank_stats if s is not None]
        total = sum(r["blocks_total"] for r in ranks)
        hwm = sum(r["blocks_hwm"] for r in ranks)
        with self._lock:
            clients = {n: dict(c.stats) for n, c in self._clients.items()}
        return {
            "ranks": ranks,
            "clients": clients,
            "blocks_total": total,
            "blocks_hwm": hwm,
            "live_frac": (hwm / total) if total else 0.0,
            "resolved_through": self._resolved_through,
        }

    # ------------------------------------------------------------ rank side

    def _rank_main(self, ctx):
        rt = ShardRuntime(ctx, self)
        self.rank_stats[ctx.rank] = rt.stats
        rt.serve()
        ctx.tp.join()   # distributed completion protocol, after STOP
        return rt.summary()


# ------------------------------------------------------------ rank runtime


class ShardRuntime:
    """One resident rank: bus consumption, lazy assimilation, execution.

    The serve loop pumps ``comm.progress()`` (delivery, acks, retransmits
    — but *not* the completion detector, whose rounds would shut the
    world down between submissions) and applies new bus commands; task
    bodies run on the rank's worker threads as fulfillments land.
    """

    def __init__(self, ctx, svc: SchedulerService):
        self.ctx = ctx
        self.rank = ctx.rank
        self.n = svc.n_shards
        self.svc = svc
        self.stats = LiveStats()
        self.fair = FairPolicy()
        self.ns = NamespaceShard(self.stats)
        self.subs: Dict[int, SubmissionShard] = {}
        self.open: set = set()
        self.finished: set = set()
        # guards the finished/open transition: a worker thread (last task
        # completing) and the serve thread (assimilation-time remaining==0
        # after held fulfillments) can race into _local_complete
        self._fin_lock = threading.Lock()
        self.assimilated = 0    # highest sub_id ingested (bus order == id)
        self.cursor = 0
        self.tasks_run = 0
        self._stop = False
        # sub_id -> fulfillments that raced ahead of assimilation
        self._held_fulfills: Dict[int, list] = {}
        # fetches for readers this rank has not assimilated yet
        self._held_fetches: List[tuple] = []
        # the dispatcher-AM set: registered once, at rank start, in the
        # same order on every rank (registration order is the AM identity)
        self.am_fulfill = ctx.comm.make_active_msg(self._on_fulfill)
        self.am_fetch = ctx.comm.make_active_msg(self._on_fetch)
        self.am_value = ctx.comm.make_active_msg(self._on_value)
        self.am_publish = ctx.comm.make_active_msg(self._on_publish)

    # ------------------------------------------------------------ the loop

    def serve(self) -> None:
        while True:
            if self.ctx.comm.world.poison.is_set():
                raise WorldPoisoned("world poisoned while serving")
            for cmd in self.svc.bus.read_from(self.cursor, self.rank):
                self.cursor += 1
                self._apply(cmd)
            self.ctx.comm.progress()
            if self._stop:
                with self._fin_lock:
                    if not self.open:
                        return
            time.sleep(10e-6)

    def _apply(self, cmd: tuple) -> None:
        kind = cmd[0]
        if kind == "submit":
            self._assimilate(cmd[1])
        elif kind == "fail":
            self._fail_cmd(cmd[1])
        elif kind == "watermark":
            self.ns.retire_through(cmd[1])
        elif kind == "drop_ns":
            self.ns.drop_namespace(cmd[1])
        elif kind == "stop":
            self._stop = True

    def summary(self) -> dict:
        return {"rank": self.rank, "tasks_run": self.tasks_run,
                "assimilated": self.assimilated,
                "ns_live_versions": self.ns.live_versions(),
                **self.stats.to_dict()}

    # -------------------------------------------------------- assimilation

    def _assimilate(self, sub: Submission) -> None:
        owner = sub.owner()
        # the one and only discovery step: owned + halo, never global
        view = sub.graph.derive_local(self.rank, sub.owner_map)
        tf = self.ctx.taskflow(f"sub{sub.sub_id}")
        shard = SubmissionShard(sub, view, tf, self.stats)
        self.subs[sub.sub_id] = shard
        self.open.add(sub.sub_id)

        # 1. seed initial values for owned blocks (virgin timelines only:
        #    an earlier submission's write is the truth)
        for blk, val in sub.blocks.items():
            if owner(blk) % self.n == self.rank:
                self.ns.seed_initial(sub.namespace, blk, sub.sub_id,
                                     np.asarray(val))
        # 2. reserve the versions this submission will write here
        for blk in view.final_writes:
            if owner(blk) % self.n == self.rank:
                self.ns.ensure_pending(sub.namespace, blk, sub.sub_id)

        # 3. wire the per-submission Taskflow
        weight = self.svc.client_weight(sub.client)

        def indegree(k):
            return (len(view.in_deps(k)) + len(view.external_reads(k))) or 1

        def priority(k):
            shard.mark_ready(k)   # spawn time == entering the ready queue
            return self.fair.priority_for(sub.client, weight, sub.priority)

        tf.set_indegree(indegree)
        tf.set_mapping(lambda k: hash(k) % self.ctx.tp.n_threads)
        tf.set_priority(priority)
        tf.set_task(lambda k: self._run_task(shard, k))

        # 4. bind external reads + release seeds (a bad binding fails the
        #    submission, but assimilation always finalizes: the cursor and
        #    held-fetch draining must advance regardless)
        if self._bind_external(shard, owner):
            # seeds: tasks with no dependencies at all (synthetic indegree
            # 1, fulfilled here — execution may start immediately)
            for k in view.tasks:
                if not view.in_deps(k) and not view.external_reads(k):
                    tf.fulfill_promise(k)
            # fulfillments that arrived before this submission existed here
            for (d, blk, payload) in self._held_fulfills.pop(
                    sub.sub_id, []):
                self._apply_fulfill(shard, d, blk, payload)
        else:
            self._held_fulfills.pop(sub.sub_id, None)
        self.assimilated = sub.sub_id
        self._drain_held_fetches()
        if not shard.failed and shard.remaining == 0:
            self._local_complete(shard)

    def _bind_external(self, shard: SubmissionShard, owner) -> bool:
        """Bind the view's external reads: owned blocks straight from this
        rank's namespace shard, remote ones via one FETCH per block."""
        sub, view = shard.sub, shard.view
        remote: Dict[B, List[K]] = {}
        for k in view.tasks:
            for blk in view.external_reads(k):
                ob = owner(blk) % self.n
                if ob == self.rank:
                    try:
                        self.ns.bind(sub.namespace, blk, sub.sub_id,
                                     self._bind_cb(shard, blk, [k]))
                    except KeyError as e:
                        self._fail_local(shard, SubmissionError(str(e)))
                        return False
                else:
                    remote.setdefault(blk, []).append(k)
        with shard.lock:
            shard.fetch_waiters.update(remote)
        for blk in remote:
            self.am_fetch.send(owner(blk) % self.n, sub.namespace, blk,
                               sub.sub_id, self.rank)
        return True

    def _bind_cb(self, shard: SubmissionShard, blk: B, ks: List[K]):
        def cb(value, poisoned):
            if poisoned:
                self._fail_local(shard, SubmissionError(
                    f"submission {shard.sub.sub_id}: upstream submission "
                    f"failed before producing block {blk!r}"))
                return
            shard.put(blk, value)
            for k in ks:
                shard.tf.fulfill_promise(k)
        return cb

    # ----------------------------------------------------------- execution

    def _run_task(self, shard: SubmissionShard, k: K) -> None:
        if shard.failed:
            return   # sub already failed: don't run, don't propagate
        view = shard.view
        try:
            shard.mark_running(k)
            with shard.lock:
                ops = [shard.store[b] for b in view.operands(k)]
            out = np.asarray(shard.sub.bodies[view.type_of(k)](*ops))
        except BaseException as e:
            self._fail_local(shard, e)
            return
        blk = view.block_of(k)
        shard.put(blk, out)
        payload_to = view.payload_consumers(k)
        n_remote = 0
        for d in view.out_deps(k):
            ds = view.mapping(d) % self.n
            if ds == self.rank:
                shard.tf.fulfill_promise(d)
            else:
                n_remote += 1
                self.am_fulfill.send(ds, shard.sub.sub_id, d, blk,
                                     out if d in payload_to else None)
        if view.final_writes.get(blk) == k:
            self._publish(shard, blk, out)
        self.tasks_run += 1
        if shard.complete(k, n_remote):
            self._local_complete(shard)

    def _publish(self, shard: SubmissionShard, blk: B, out) -> None:
        sub = shard.sub
        with shard.lock:
            shard.published[blk] = out
        ob = sub.owner()(blk) % self.n
        if ob == self.rank:
            self.ns.publish(sub.namespace, blk, sub.sub_id, out)
        else:
            self.am_publish.send(ob, sub.namespace, blk, sub.sub_id, out)

    def _local_complete(self, shard: SubmissionShard) -> None:
        sub_id = shard.sub.sub_id
        with self._fin_lock:
            if sub_id in self.finished:
                return
            self.open.discard(sub_id)
            self.finished.add(sub_id)
        with shard.lock:
            published = dict(shard.published)
        n_bytes = sum(getattr(v, "nbytes", 0) for v in published.values())
        self.svc._rank_done(sub_id, self.rank, published, n_bytes)
        shard.drop()
        self.subs.pop(sub_id, None)   # forget the submission: O(frontier)

    # ------------------------------------------------------------- failure

    def _fail_local(self, shard: SubmissionShard, exc: BaseException) -> None:
        sub_id = shard.sub.sub_id
        with shard.lock:
            if shard.failed:
                return
            shard.failed = True
        with self._fin_lock:
            self.open.discard(sub_id)
            self.finished.add(sub_id)
        self.svc._fail_submission(sub_id, exc)
        self.ns.poison_sub(sub_id)
        shard.drop()
        self.subs.pop(sub_id, None)

    def _fail_cmd(self, sub_id: int) -> None:
        shard = self.subs.get(sub_id)
        if shard is not None:
            with shard.lock:
                shard.failed = True
            with self._fin_lock:
                self.open.discard(sub_id)
                self.finished.add(sub_id)
            shard.drop()
            self.subs.pop(sub_id, None)
        self.ns.poison_sub(sub_id)

    # ------------------------------------------------------- active messages

    def _on_fulfill(self, sub_id: int, d: K, blk: B, payload) -> None:
        shard = self.subs.get(sub_id)
        if shard is None:
            if sub_id > self.assimilated:
                self._held_fulfills.setdefault(sub_id, []).append(
                    (d, blk, payload))
            return   # finished or failed: late traffic is inert
        self._apply_fulfill(shard, d, blk, payload)

    def _apply_fulfill(self, shard: SubmissionShard, d: K, blk: B,
                       payload) -> None:
        if payload is not None:
            shard.put(blk, np.asarray(payload))
        shard.tf.fulfill_promise(d)

    def _on_fetch(self, ns: str, blk: B, reader_sub: int,
                  src: int) -> None:
        if reader_sub > self.assimilated:
            # binding needs every version with key < (reader_sub, 1) in
            # the timeline — hold until this rank's cursor catches up
            self._held_fetches.append((ns, blk, reader_sub, src))
            return

        def cb(value, poisoned):
            self.am_value.send(src, reader_sub, blk, value, poisoned)
        try:
            self.ns.bind(ns, blk, reader_sub, cb)
        except KeyError:
            self.am_value.send(src, reader_sub, blk, None, True)

    def _drain_held_fetches(self) -> None:
        held, self._held_fetches = self._held_fetches, []
        for args in held:
            self._on_fetch(*args)

    def _on_value(self, reader_sub: int, blk: B, value, poisoned) -> None:
        shard = self.subs.get(reader_sub)
        if shard is None:
            return
        if poisoned:
            self._fail_local(shard, SubmissionError(
                f"submission {reader_sub}: upstream submission failed "
                f"before producing block {blk!r}"))
            return
        shard.put(blk, np.asarray(value))
        with shard.lock:
            ks = shard.fetch_waiters.pop(blk, [])
        for k in ks:
            shard.tf.fulfill_promise(k)

    def _on_publish(self, ns: str, blk: B, sub_id: int, value) -> None:
        self.ns.publish(ns, blk, sub_id, np.asarray(value))
