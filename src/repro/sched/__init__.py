"""Persistent multi-tenant scheduler: a stream of PTGs, one live DAG.

Entry point: :class:`SchedulerService` (see :mod:`repro.sched.service`).
"""

from .fair import FairPolicy
from .namespace import NamespaceShard
from .service import (Client, SchedulerService, Submission, SubmissionError,
                      SubmissionFuture)
from .state import LiveStats, SubmissionShard, TaskState

__all__ = [
    "Client",
    "FairPolicy",
    "LiveStats",
    "NamespaceShard",
    "SchedulerService",
    "Submission",
    "SubmissionError",
    "SubmissionFuture",
    "SubmissionShard",
    "TaskState",
]
