"""Persistent multi-tenant scheduler: a stream of PTGs, one live DAG.

Entry point: :class:`SchedulerService` (see :mod:`repro.sched.service`).
"""

from .fair import FairPolicy
from .namespace import NamespaceShard
from .service import (Client, DeadlineExceeded, RetryingFuture,
                      SchedulerService, Submission, SubmissionError,
                      SubmissionFuture)
from .state import LiveStats, SubmissionShard, TaskState

__all__ = [
    "Client",
    "DeadlineExceeded",
    "FairPolicy",
    "LiveStats",
    "NamespaceShard",
    "RetryingFuture",
    "SchedulerService",
    "Submission",
    "SubmissionError",
    "SubmissionFuture",
    "SubmissionShard",
    "TaskState",
]
