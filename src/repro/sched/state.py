"""Per-task state machine + live-frontier accounting for the scheduler.

TaskTorrent's memory claim is O(live tasks), never O(DAG): the runtime
learns of a task at its first fulfilled dependency and forgets it when it
spawns. The stream scheduler extends the same discipline to *block state*
across many submissions: every block value (operand overlay, halo copy,
namespace version) is reference-counted and dropped the moment its last
consumer is done, so a service that has executed a million tasks holds
only the live frontier — what :class:`LiveStats` measures as the
high-water mark the ``live_frac`` benchmark guard tracks.

The task lifecycle is ``waiting -> ready -> running -> done -> retired``:

- *waiting* is implicit (the Taskflow only materializes a counter at the
  first fulfillment — tasks never touched have no state at all);
- *ready* is recorded at spawn time (the Taskflow's priority hook, which
  is evaluated exactly once per task, when its last dependency lands);
- *done* when the body has run and every out-edge is discharged;
- *retired* when all consumers of the task's write are themselves done —
  the task's record and its block refcounts are dropped.
"""

from __future__ import annotations

import enum
import threading
from typing import Dict, Hashable, List, Optional

K = Hashable
B = Hashable


class TaskState(enum.Enum):
    WAITING = "waiting"
    READY = "ready"
    RUNNING = "running"
    DONE = "done"
    RETIRED = "retired"


class LiveStats:
    """Lock-guarded live/total/high-water counters for one rank.

    ``blocks_*`` counts materialized block values (submission overlays,
    halo copies, namespace versions); ``tasks_*`` counts tasks between
    READY and RETIRED. ``live_frac`` — the benchmark guard — is
    ``blocks_hwm / blocks_total``: near 1.0 means retirement is broken and
    memory tracks total submitted work; small means it tracks the frontier.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.tasks_live = 0
        self.tasks_total = 0
        self.tasks_hwm = 0
        self.blocks_live = 0
        self.blocks_total = 0
        self.blocks_hwm = 0

    def task_up(self, n: int = 1) -> None:
        with self._lock:
            self.tasks_live += n
            self.tasks_total += n
            self.tasks_hwm = max(self.tasks_hwm, self.tasks_live)

    def task_down(self, n: int = 1) -> None:
        with self._lock:
            self.tasks_live -= n

    def block_up(self, n: int = 1) -> None:
        with self._lock:
            self.blocks_live += n
            self.blocks_total += n
            self.blocks_hwm = max(self.blocks_hwm, self.blocks_live)

    def block_down(self, n: int = 1) -> None:
        with self._lock:
            self.blocks_live -= n

    def to_dict(self) -> dict:
        with self._lock:
            return {
                "tasks_live": self.tasks_live,
                "tasks_total": self.tasks_total,
                "tasks_hwm": self.tasks_hwm,
                "blocks_live": self.blocks_live,
                "blocks_total": self.blocks_total,
                "blocks_hwm": self.blocks_hwm,
            }


class SubmissionShard:
    """One rank's slice of one in-flight submission.

    Holds the lazily derived :class:`~repro.ptg.graph.LocalView`, the
    per-submission Taskflow, the block overlay (owned writes + halo copies
    + namespace-bound external inputs), and the reference counts that
    drive retirement:

    - ``consumers_left[k]``: out-edges of owned task ``k`` not yet
      discharged (a local consumer discharges at completion; a remote one
      the moment its fulfillment is handed to the reliable transport) —
      at zero a DONE task retires and its record is dropped;
    - ``readers_left[blk]``: owned tasks that will still read ``blk`` —
      at zero the overlay value is freed.

    All mutation is under ``lock``; the scan that builds the counts is
    O(owned edges) — exactly the state the view already materialized.
    """

    def __init__(self, sub, view, tf, stats: LiveStats,
                 shard: Optional[int] = None) -> None:
        self.sub = sub
        self.view = view
        self.tf = tf
        self.stats = stats
        # the logical shard this slice represents — equal to the hosting
        # rank until a death moves it to an adopter (service routes by it)
        self.shard = view.shard if shard is None else shard
        self.lock = threading.Lock()
        # cross-shard fulfillments applied, keyed (consumer, producer):
        # transport retransmits are deduped by seq, but recovery re-execution
        # and send-log replay legitimately re-produce the same fulfillment —
        # each promise must still be decremented exactly once
        self.applied: set = set()
        # initial-value seeds this shard's owner actually honored (reported
        # to the frontdoor checkpoint at completion, for adoption replay)
        self.seeded: Dict[B, object] = {}
        self.store: Dict[B, object] = {}
        self.state: Dict[K, TaskState] = {}   # absent == WAITING or RETIRED
        self.retired = 0
        self.remaining = len(view.tasks)
        self.failed = False
        self.published: Dict[B, object] = {}  # this rank's final writes
        self.fetch_waiters: Dict[B, List[K]] = {}
        self.consumers_left: Dict[K, int] = {
            k: len(view.out_deps(k)) for k in view.tasks}
        readers: Dict[B, int] = {}
        for k in view.tasks:
            for blk in set(view.operands(k)):
                readers[blk] = readers.get(blk, 0) + 1
        self.readers_left = readers

    # ------------------------------------------------------- state machine

    def mark_ready(self, k: K) -> None:
        with self.lock:
            self.state[k] = TaskState.READY
        self.stats.task_up()

    def mark_running(self, k: K) -> None:
        with self.lock:
            self.state[k] = TaskState.RUNNING

    def put(self, blk: B, value) -> None:
        """Store a block value, counting only first materialization."""
        with self.lock:
            fresh = blk not in self.store
            self.store[blk] = value
        if fresh:
            self.stats.block_up()

    def complete(self, k: K, n_remote_consumers: int) -> bool:
        """Record owned task ``k`` DONE, discharge its remote out-edges,
        retire whatever became retirable, and free dead block values.
        Returns True when this was the shard's last owned task."""
        view = self.view
        freed = 0
        retired = 0
        with self.lock:
            self.state[k] = TaskState.DONE
            self.consumers_left[k] -= n_remote_consumers
            retired += self._maybe_retire(k)
            for p in view.in_deps(k):
                if p in self.consumers_left:       # local producer
                    self.consumers_left[p] -= 1
                    retired += self._maybe_retire(p)
            blk_w = view.block_of(k)
            for blk in set(view.operands(k)):
                self.readers_left[blk] -= 1
                if self.readers_left[blk] == 0 and blk in self.store:
                    del self.store[blk]
                    freed += 1
            # a write nobody here reads (payloads/publication already
            # captured the value) is dead the moment it lands
            if self.readers_left.get(blk_w, 0) == 0 and blk_w in self.store:
                del self.store[blk_w]
                freed += 1
            self.remaining -= 1
            last = self.remaining == 0
        if freed:
            self.stats.block_down(freed)
        if retired:
            self.stats.task_down(retired)
        return last

    def _maybe_retire(self, k: K) -> int:
        """(Caller holds ``lock``.) Retire ``k`` if DONE with no undischarged
        consumers: drop its record — the O(live) forgetting step."""
        if (self.consumers_left.get(k) == 0
                and self.state.get(k) is TaskState.DONE):
            del self.consumers_left[k]
            del self.state[k]
            self.retired += 1
            return 1
        return 0

    def drop(self) -> None:
        """Release whatever overlay state is left (submission finished
        locally, or failed — partial state must not outlive it)."""
        with self.lock:
            n = len(self.store)
            self.store.clear()
            live = len(self.state)
            self.state.clear()
        if n:
            self.stats.block_down(n)
        if live:
            self.stats.task_down(live)
