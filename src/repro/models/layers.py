"""Shared model layers: RMSNorm, RoPE, FFNs, initializers (pure JAX)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dtype)


def rope_freqs(positions: jnp.ndarray, dim: int, theta: float) -> tuple:
    """positions [*] -> (cos, sin) each [*, dim/2] (f32)."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x [..., S, D]; cos/sin broadcastable to [..., S, D/2]."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    while cos.ndim < x1.ndim:
        cos, sin = cos[..., None, :, :], sin[..., None, :, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_in: jnp.ndarray,
           w_out: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ w_gate) * (x @ w_in)
    return h @ w_out


def gelu_mlp(x: jnp.ndarray, w_in: jnp.ndarray, w_out: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.gelu(x @ w_in, approximate=True) @ w_out


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    """LeCun-normal in the input dimension(s)."""
    fan_in = 1
    for ax in range(len(shape) - 1) if in_axis is None else [in_axis]:
        fan_in *= shape[ax]
    return (jax.random.normal(key, shape) * (fan_in ** -0.5)).astype(dtype)


def stacked_dense_init(key, n: int, shape, in_axis: int = 0,
                       dtype=jnp.float32):
    """[n, *shape] — one init per layer."""
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: dense_init(k, shape, in_axis, dtype))(keys)
