"""Mixture-of-Experts layer: token-choice top-k with capacity, SPMD-friendly.

Dispatch is the TPU-standard sort-free scatter/gather form, decomposed into
**data-parallel rows**: tokens reshape to [R, T_local] where R = pod x data
(`ctx.data_rows()`), and every dispatch structure (one-hot cumsum positions,
capacity, the [R, E, C, D] expert buffers) is per-row. This keeps buffers
O(local tokens) — dispatching over global tokens would materialize a
capacity buffer proportional to the *global* batch (150 TB at deepseek's
train_4k scale; measured in EXPERIMENTS §Perf A2).

The expert dimension shards over the "model" mesh axis when E divides it
(deepseek: 256/16 = 16 experts per group — expert parallelism; the row
boundary then makes the a2a pattern explicit); otherwise the expert hidden
dim shards (grok: 8 experts, d_ff 32768/16 = 2048).

Routers: "softmax" (classic top-k) or "sigmoid" (deepseek-v3 aux-loss-free:
sigmoid affinities + learned per-expert bias; the bias is a non-gradient
buffer updated by the training loop).
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.dist.ctx import annotate, batch_axes, data_rows, get_mesh


def moe_params_shapes(cfg_moe: MoEConfig, d_model: int, ffn: str) -> dict:
    e = cfg_moe.n_experts
    f = cfg_moe.d_ff
    shapes = {
        "router": (d_model, e),
        "router_bias": (e,),
        "w_in": (e, d_model, f),
        "w_out": (e, f, d_model),
    }
    if ffn == "swiglu":
        shapes["w_gate"] = (e, d_model, f)
    if cfg_moe.n_shared_experts:
        fs = f * cfg_moe.n_shared_experts
        shapes["shared_w_in"] = (d_model, fs)
        shapes["shared_w_out"] = (fs, d_model)
        if ffn == "swiglu":
            shapes["shared_w_gate"] = (d_model, fs)
    return shapes


def _expert_spec(e: int) -> P:
    mesh = get_mesh()
    if mesh is not None and e % mesh.shape.get("model", 1) == 0:
        return P(batch_axes(), "model", None, None)
    return P(batch_axes(), None, None, None)


def moe_ffn(x: jnp.ndarray, p: dict, cfg_moe: MoEConfig, ffn: str,
            compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """x: [B, S, D] -> [B, S, D]."""
    from repro.launch.flags import moe_capacity_factor

    cf = moe_capacity_factor()
    if cf is not None:
        cfg_moe = dataclasses.replace(cfg_moe, capacity_factor=cf)

    b, s, d = x.shape
    e, k = cfg_moe.n_experts, cfg_moe.experts_per_token
    rows = data_rows()
    if b % rows != 0:
        rows = 1
    t = (b * s) // rows                                       # per-row tokens
    xt = x.reshape(rows, t, d)
    xt = annotate(xt, P(batch_axes(), None, None))

    logits = jnp.einsum("rtd,de->rte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    if cfg_moe.router == "sigmoid":           # deepseek-v3 aux-free
        affinity = jax.nn.sigmoid(logits)
        select = affinity + p["router_bias"].astype(jnp.float32)
        weights_src = affinity
    else:
        select = jax.nn.softmax(logits, axis=-1)
        weights_src = select
    _, topk_idx = jax.lax.top_k(select, k)                    # [R, T, k]
    topk_w = jnp.take_along_axis(weights_src, topk_idx, axis=-1)
    topk_w = topk_w / (topk_w.sum(-1, keepdims=True) + 1e-9)  # renormalize

    cap = int(t * k / e * cfg_moe.capacity_factor) + 1
    onehot = jax.nn.one_hot(topk_idx, e, dtype=jnp.int32)     # [R, T, k, E]
    flat = onehot.reshape(rows, t * k, e)
    pos = jnp.cumsum(flat, axis=1) * flat - 1                 # [R, T*k, E]
    pos_in_e = (pos * flat).sum(-1).reshape(rows, t, k)       # [R, T, k]
    expert = topk_idx
    keep = pos_in_e < cap

    # scatter tokens into [R, E, C, D] (vmapped over rows — row-local).
    # Loop over the k slots: a fused [T, k, D] gather materializes
    # tokens x k activation copies (14 GiB/device at deepseek scale —
    # EXPERIMENTS §Perf A3); per-slot passes peak at [T, D].
    def scatter_row(xr, er, pr, kr):
        xin = jnp.zeros((e, cap, d), compute_dtype)
        xr_c = xr.astype(compute_dtype)
        for j in range(k):
            xin = xin.at[
                jnp.where(kr[:, j], er[:, j], e - 1),
                jnp.where(kr[:, j], pr[:, j], cap - 1)
            ].add(jnp.where(kr[:, j, None], xr_c, 0))
        return xin

    xin = jax.vmap(scatter_row)(xt, expert, pos_in_e, keep)   # [R, E, C, D]
    xin = annotate(xin, _expert_spec(e))

    # batched expert FFN (expert dim sharded by the mesh rules)
    if "w_gate" in p:
        h = jax.nn.silu(jnp.einsum("recd,edf->recf", xin, p["w_gate"])) \
            * jnp.einsum("recd,edf->recf", xin, p["w_in"])
    else:
        h = jax.nn.gelu(jnp.einsum("recd,edf->recf", xin, p["w_in"]),
                        approximate=True)
    yout = jnp.einsum("recf,efd->recd", h, p["w_out"])        # [R, E, C, D]
    yout = annotate(yout, _expert_spec(e))

    # combine: gather each token's k expert outputs, weight, sum (row-local;
    # same per-slot looping — no [T, k, D] f32 intermediate)
    def combine_row(yr, er, pr, kr, wr):
        acc = jnp.zeros((t, d), jnp.float32)
        for j in range(k):
            g = yr[jnp.where(kr[:, j], er[:, j], 0),
                   jnp.where(kr[:, j], pr[:, j], 0)]          # [T, D]
            g = jnp.where(kr[:, j, None], g, 0).astype(jnp.float32)
            acc = acc + g * wr[:, j, None]
        return acc

    y = jax.vmap(combine_row)(yout, expert, pos_in_e, keep,
                              topk_w).astype(x.dtype)         # [R, T, D]

    if cfg_moe.n_shared_experts:
        xs = xt.astype(compute_dtype)
        if "shared_w_gate" in p:
            hs = jax.nn.silu(xs @ p["shared_w_gate"]) * (xs @ p["shared_w_in"])
        else:
            hs = jax.nn.gelu(xs @ p["shared_w_in"], approximate=True)
        y = y + (hs @ p["shared_w_out"]).astype(x.dtype)

    return y.reshape(b, s, d)
