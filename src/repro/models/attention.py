"""Attention variants: GQA (with qk-norm/RoPE/windows) and MLA.

Three execution paths:
- train/prefill: `chunked_attention` — differentiable jnp online-softmax over
  KV chunks (flash-style memory behavior, O(S·chunk) live scores), which XLA
  fuses well; on TPU the Pallas `flash_attention` kernel takes over for the
  non-differentiated serve path.
- decode: single-token attention against a cache (Pallas `decode_attention`
  on TPU, oracle elsewhere).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_ref
from repro.kernels.flash_attention.ref import mha_ref

NEG_INF = -1e30


def chunked_attention(q, k, v, *, causal: bool = True, chunk: int = 1024,
                      window: int = 0):
    """q [B,Hq,Lq,D], k/v [B,Hkv,Lk,D] -> [B,Hq,Lq,D]; differentiable,
    never materializes more than [*, Lq, chunk] scores."""
    from repro.launch.flags import attn_chunk

    chunk = attn_chunk() or chunk
    b, hq, lq, dh = q.shape
    _, hkv, lk, dk = k.shape          # dk may differ from dv (MLA: 192/128)
    dv = v.shape[-1]
    group = hq // hkv
    scale = dh ** -0.5
    if lk <= chunk:
        return _attn_block(q, k, v, 0, causal, window, scale, group)

    n_chunks = lk // chunk
    assert lk % chunk == 0, (lk, chunk)
    ks = k.reshape(b, hkv, n_chunks, chunk, dk).transpose(2, 0, 1, 3, 4)
    vs = v.reshape(b, hkv, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4)

    def step(carry, inp):
        m, l, acc = carry
        ci, kc, vc = inp
        kx = jnp.repeat(kc, group, axis=1).astype(jnp.float32)
        vx = jnp.repeat(vc, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx) * scale
        qpos = jnp.arange(lq)[:, None] + (lk - lq)
        kpos = ci * chunk + jnp.arange(chunk)[None, :]
        mask = jnp.ones((lq, chunk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = alpha * l + p.sum(-1, keepdims=True)
        acc = alpha * acc + jnp.einsum("bhqk,bhkd->bhqd", p, vx)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hq, lq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, lq, 1), jnp.float32)
    a0 = jnp.zeros((b, hq, lq, dv), jnp.float32)
    from repro.launch.flags import scan_unroll_arg

    # nested remat: without it every chunk's [.., lq, chunk] score matrix is
    # saved as a scan residual for backward — O(S²) live memory, the exact
    # thing flash attention exists to avoid. With it only carries survive.
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0), (jnp.arange(n_chunks), ks, vs),
        unroll=scan_unroll_arg())
    return (acc / l).astype(q.dtype)


def _attn_block(q, k, v, k_offset, causal, window, scale, group):
    b, hq, lq, dh = q.shape
    lk = k.shape[2]
    kx = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vx = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kx) * scale
    qpos = jnp.arange(lq)[:, None] + (k_offset + lk - lq)
    kpos = k_offset + jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vx).astype(q.dtype)


def decode_attention_host(q, k, v, kv_len=None):
    """Single-token decode (oracle path; Pallas kernel on TPU via ops)."""
    return decode_ref(q, k, v, kv_len)


__all__ = ["chunked_attention", "decode_attention_host", "mha_ref"]
