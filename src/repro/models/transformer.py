"""The model zoo: one init/forward/prefill/decode covering all families.

Families: dense (llama-style GQA), vlm (dense + embed inputs), moe
(GQA or MLA attention + top-k experts), ssm (Mamba-2), hybrid (Mamba-2
backbone + one shared attention block, zamba-style), encdec (bidirectional
encoder + causal decoder with cross-attention).

Layers execute under ``lax.scan`` over stacked parameters (small HLO at 61
layers — essential for the 80-cell dry-run) with optional remat. Params are
plain pytrees; sharding rules attach by tree path in repro.dist.sharding.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist.ctx import act_spec, annotate
from repro.models.attention import chunked_attention, decode_attention_host
from repro.models.layers import (apply_rope, dense_init, gelu_mlp, rms_norm,
                                 rope_freqs, stacked_dense_init, swiglu)
from repro.models.mamba2 import (Mamba2State, mamba2_forward, mamba2_init_state,
                                 mamba2_params_shapes, mamba2_step)
from repro.models.moe import moe_ffn, moe_params_shapes


# =============================================================== parameters

def _attn_shapes(cfg: ModelConfig) -> Dict[str, tuple]:
    d, hd = cfg.d_model, cfg.head_dim
    if cfg.attention == "mla":
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        s = {
            "wq_a": (d, m.q_lora_rank),
            "q_ln": (m.q_lora_rank,),
            "wq_b": (m.q_lora_rank, cfg.n_heads * qk),
            "wkv_a": (d, m.kv_lora_rank + m.qk_rope_dim),
            "kv_ln": (m.kv_lora_rank,),
            "wkv_b": (m.kv_lora_rank,
                      cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)),
            "wo": (cfg.n_heads * m.v_head_dim, d),
        }
        return s
    s = {
        "wq": (d, cfg.n_heads * hd),
        "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd),
        "wo": (cfg.n_heads * hd, d),
    }
    if cfg.qk_norm:
        s["q_norm"] = (hd,)
        s["k_norm"] = (hd,)
    return s


def _ffn_shapes(cfg: ModelConfig, d_ff: Optional[int] = None) -> Dict[str, tuple]:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.ffn == "swiglu":
        return {"w_gate": (d, f), "w_in": (d, f), "w_out": (f, d)}
    return {"w_in": (d, f), "w_out": (f, d)}


def _block_shapes(cfg: ModelConfig, kind: str) -> Dict[str, Any]:
    d = cfg.d_model
    if kind == "ssm":
        return {"ln": (d,), "mamba": mamba2_params_shapes(cfg.ssm, d)}
    s: Dict[str, Any] = {"ln1": (d,), "ln2": (d,),
                         "attn": _attn_shapes(cfg)}
    if kind == "moe":
        s["moe"] = moe_params_shapes(cfg.moe, d, cfg.ffn)
    elif kind == "cross":  # encdec decoder block
        s["ln_cross"] = (d,)
        s["cross"] = _attn_shapes(cfg)
        s["ffn"] = _ffn_shapes(cfg)
    else:
        s["ffn"] = _ffn_shapes(cfg)
    return s


def _init_tree(key, shapes, n_stack: int, dtype) -> Any:
    flat, treedef = jax.tree.flatten(shapes, is_leaf=lambda x: isinstance(x, tuple))
    keys = jax.random.split(key, len(flat))
    leaves = []
    for k, shp in zip(keys, flat):
        if len(shp) == 1:  # norm weights / biases -> ones (biases re-zeroed)
            leaves.append(jnp.ones((n_stack, *shp) if n_stack else shp, dtype))
        else:
            leaves.append(stacked_dense_init(k, n_stack, shp, 0, dtype)
                          if n_stack else dense_init(k, shp, 0, dtype))
    return jax.tree.unflatten(treedef, leaves)


def _zero_biases(tree, names=("router_bias", "conv_b", "dt_bias")):
    def fix(path, leaf):
        last = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if last in names:
            return jnp.zeros_like(leaf)
        if last == "a_log":
            return jnp.zeros_like(leaf)  # A = -1 -> stable decay
        return leaf
    return jax.tree_util.tree_map_with_path(fix, tree)


def layer_kinds(cfg: ModelConfig) -> Dict[str, int]:
    """Named layer segments -> stack depth (scan runs per segment)."""
    if cfg.family in ("dense", "vlm"):
        return {"dense": cfg.n_layers}
    if cfg.family == "moe":
        fd = cfg.moe.first_dense_layers
        out = {}
        if fd:
            out["dense"] = fd
        out["moe"] = cfg.n_layers - fd
        return out
    if cfg.family == "ssm":
        return {"ssm": cfg.n_layers}
    if cfg.family == "hybrid":
        return {"ssm": cfg.n_layers}  # + one shared attn block (unstacked)
    if cfg.family == "encdec":
        return {"enc": cfg.encoder_layers, "cross": cfg.n_layers}
    raise ValueError(cfg.family)


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: Dict[str, Any] = {
        "embed": dense_init(keys[0], (cfg.vocab_size, cfg.d_model), 1, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[1], (cfg.d_model, cfg.vocab_size), 0, dtype)
    ki = iter(jax.random.split(keys[2], 8))
    for seg, depth in layer_kinds(cfg).items():
        kind = {"dense": "dense", "moe": "moe", "ssm": "ssm", "enc": "dense",
                "cross": "cross"}[seg]
        params[seg] = _init_tree(next(ki), _block_shapes(cfg, kind), depth,
                                 dtype)
    if cfg.family == "hybrid":
        params["shared"] = _init_tree(next(ki), _block_shapes(cfg, "dense"),
                                      0, dtype)
    if cfg.family == "encdec":
        params["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
    return _zero_biases(params)


def abstract_params(cfg: ModelConfig) -> Any:
    """Shapes-only params (dry-run: no allocation)."""
    return jax.eval_shape(lambda: init_params(cfg, jax.random.key(0)))


# ============================================================== attention

def _gqa_full(cfg: ModelConfig, p, x, *, causal=True, window=0,
              kv_x=None, positions=None):
    """Full-sequence GQA (train/prefill); returns (out, (k, v) cache)."""
    b, s, d = x.shape
    hd = cfg.head_dim
    kv_src = x if kv_x is None else kv_x
    sk = kv_src.shape[1]
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, hd)
    k = (kv_src @ p["wk"]).reshape(b, sk, cfg.n_kv_heads, hd)
    v = (kv_src @ p["wv"]).reshape(b, sk, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if kv_x is None:  # self-attention: rope
        pos = positions if positions is not None else jnp.arange(s)
        cos, sin = rope_freqs(pos, hd, cfg.rope_theta)
        q = apply_rope(q.transpose(0, 2, 1, 3), cos, sin)
        k = apply_rope(k.transpose(0, 2, 1, 3), cos, sin)
    else:
        q = q.transpose(0, 2, 1, 3)
        k = k.transpose(0, 2, 1, 3)
    v = v.transpose(0, 2, 1, 3)
    o = chunked_attention(q, k, v, causal=causal, window=window)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)
    return o @ p["wo"], (k, v)


def _gqa_decode(cfg: ModelConfig, p, x, cache_kv, pos, *, window=0):
    """x [B, D], cache_kv (k, v) [B, Hkv, S, hd]; writes at `pos`."""
    b, d = x.shape
    hd = cfg.head_dim
    k_cache, v_cache = cache_kv
    s_max = k_cache.shape[2]
    q = (x @ p["wq"]).reshape(b, cfg.n_heads, hd)
    k = (x @ p["wk"]).reshape(b, cfg.n_kv_heads, hd)
    v = (x @ p["wv"]).reshape(b, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_freqs(pos[None], hd, cfg.rope_theta)  # [1, hd/2]
    q = apply_rope(q[:, :, None], cos, sin)[:, :, 0]
    k = apply_rope(k[:, :, None], cos, sin)[:, :, 0]
    pad = k_cache.shape[1] // cfg.n_kv_heads  # cache with replicated heads
    if pad > 1:
        k = jnp.repeat(k, pad, axis=1)
        v = jnp.repeat(v, pad, axis=1)
    slot = pos % s_max if window else pos  # ring buffer when windowed
    k_cache = jax.lax.dynamic_update_index_in_dim(k_cache, k, slot, 2)
    v_cache = jax.lax.dynamic_update_index_in_dim(v_cache, v, slot, 2)
    kv_len = jnp.minimum(pos + 1, s_max)
    o = decode_attention_host(q, k_cache, v_cache,
                              jnp.full((b,), kv_len, jnp.int32))
    o = o.reshape(b, cfg.n_heads * hd)
    return o @ p["wo"], (k_cache, v_cache)


def _mla_full(cfg: ModelConfig, p, x, positions=None):
    """Full-sequence MLA (train/prefill); cache = (ckv, k_rope)."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads
    q_lat = rms_norm(x @ p["wq_a"], p["q_ln"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    kv_a = x @ p["wkv_a"]
    ckv, k_rope = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_ln"], cfg.norm_eps)          # [B, S, r]
    kvb = (ckv @ p["wkv_b"]).reshape(b, s, h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = jnp.split(kvb, [m.qk_nope_dim], axis=-1)

    pos = positions if positions is not None else jnp.arange(s)
    cos, sin = rope_freqs(pos, m.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope.transpose(0, 2, 1, 3), cos, sin)
    k_rope_r = apply_rope(k_rope[:, None], cos, sin)       # [B, 1, S, rope]
    q_full = jnp.concatenate([q_nope.transpose(0, 2, 1, 3), q_rope], -1)
    k_full = jnp.concatenate(
        [k_nope.transpose(0, 2, 1, 3),
         jnp.broadcast_to(k_rope_r, (b, h, s, m.qk_rope_dim))], -1)
    o = chunked_attention(q_full, k_full, v.transpose(0, 2, 1, 3),
                          causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * m.v_head_dim)
    return o @ p["wo"], (ckv, k_rope_r[:, 0])


def _mla_decode(cfg: ModelConfig, p, x, cache, pos):
    """Absorbed-matmul MLA decode: attention runs in the latent space, so
    per-token cost is O(S·(r + rope)) instead of O(S·H·dh)."""
    m = cfg.mla
    b, d = x.shape
    h = cfg.n_heads
    ckv_cache, krope_cache = cache                          # [B,S,r],[B,S,rope]
    s_max = ckv_cache.shape[1]
    q_lat = rms_norm(x @ p["wq_a"], p["q_ln"], cfg.norm_eps)
    q = (q_lat @ p["wq_b"]).reshape(b, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    kv_a = x @ p["wkv_a"]
    ckv_t, krope_t = jnp.split(kv_a, [m.kv_lora_rank], axis=-1)
    ckv_t = rms_norm(ckv_t, p["kv_ln"], cfg.norm_eps)
    cos, sin = rope_freqs(pos[None], m.qk_rope_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope[:, :, None], cos, sin)[:, :, 0]
    krope_t = apply_rope(krope_t[:, None, None], cos, sin)[:, 0, 0]
    ckv_cache = jax.lax.dynamic_update_index_in_dim(ckv_cache, ckv_t, pos, 1)
    krope_cache = jax.lax.dynamic_update_index_in_dim(
        krope_cache, krope_t, pos, 1)

    wkv_b = p["wkv_b"].reshape(m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    w_k = wkv_b[..., : m.qk_nope_dim]                       # [r, H, nope]
    w_v = wkv_b[..., m.qk_nope_dim:]                        # [r, H, vdim]
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32),
                       w_k.astype(jnp.float32))             # [B, H, r]
    scores = (jnp.einsum("bhr,bsr->bhs", q_abs,
                         ckv_cache.astype(jnp.float32))
              + jnp.einsum("bhp,bsp->bhs", q_rope.astype(jnp.float32),
                           krope_cache.astype(jnp.float32)))
    scores *= (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    mask = jnp.arange(s_max)[None, None, :] <= pos
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", w, ckv_cache.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", ctx, w_v.astype(jnp.float32))
    o = o.reshape(b, h * m.v_head_dim).astype(x.dtype)
    return o @ p["wo"], (ckv_cache, krope_cache)


# ================================================================= blocks

def _cast_params(cfg: ModelConfig, p):
    """Cast float params to the compute dtype at the point of use (norm
    weights are re-upcast inside rms_norm; biases stay f32-safe there too)."""
    ct = jnp.dtype(cfg.compute_dtype)
    return jax.tree.map(
        lambda a: a.astype(ct) if jnp.issubdtype(a.dtype, jnp.floating) else a,
        p)


def _ffn_apply(cfg: ModelConfig, p, x):
    if cfg.ffn == "swiglu":
        return swiglu(x, p["w_gate"], p["w_in"], p["w_out"])
    return gelu_mlp(x, p["w_in"], p["w_out"])


def _block_full(cfg: ModelConfig, kind: str, p, x, *, enc_out=None,
                positions=None, window=0):
    """Full-sequence block; returns (x, cache_for_layer)."""
    p = _cast_params(cfg, p)
    if kind == "ssm":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        return x + mamba2_forward(h, p["mamba"], cfg.ssm, cfg.d_model), None
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attention == "mla" and kind in ("dense", "moe"):
        att, cache = _mla_full(cfg, p["attn"], h, positions)
    else:
        causal = kind != "enc"
        att, cache = _gqa_full(cfg, p["attn"], h, causal=causal,
                               window=window, positions=positions)
    x = x + att
    if kind == "cross":
        hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        catt, ccache = _gqa_full(cfg, p["cross"], hc, causal=False,
                                 kv_x=enc_out)
        x = x + catt
        cache = (cache, ccache)
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if kind == "moe":
        y = moe_ffn(h2, p["moe"], cfg.moe, cfg.ffn,
                    jnp.dtype(cfg.compute_dtype))
    else:
        y = _ffn_apply(cfg, p["ffn"], h2)
    return x + y, cache


# ============================================================ full forward

def forward(cfg: ModelConfig, params, tokens=None, embeds=None,
            enc_tokens=None, enc_embeds=None, *, collect_cache=False):
    """Training/prefill forward -> (logits [B,S,V], caches or None)."""
    if embeds is None:
        x = params["embed"][tokens]
    else:
        x = embeds
    x = annotate(x.astype(jnp.dtype(cfg.compute_dtype)), act_spec())
    caches: Dict[str, Any] = {}

    enc_out = None
    if cfg.family == "encdec":
        e = params["embed"][enc_tokens] if enc_embeds is None else enc_embeds
        e = e.astype(x.dtype)
        e = _scan_segment(cfg, "dense", params["enc"], e, causal_kind="enc")[0]
        enc_out = rms_norm(e, params["enc_norm"], cfg.norm_eps)

    if cfg.family == "hybrid":
        x, caches = _hybrid_forward(cfg, params, x, collect_cache)
    else:
        for seg, depth in layer_kinds(cfg).items():
            if seg == "enc":
                continue
            kind = {"dense": "dense", "moe": "moe", "ssm": "ssm",
                    "cross": "cross"}[seg]
            x, cache = _scan_segment(cfg, kind, params[seg], x,
                                     enc_out=enc_out,
                                     collect_cache=collect_cache)
            if collect_cache:
                caches[seg] = cache

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    logits = annotate(logits, P(("pod", "data"), None, "model"))
    return logits, (caches if collect_cache else None)


def _scan_segment(cfg, kind, seg_params, x, *, enc_out=None,
                  collect_cache=False, causal_kind=None):
    kind_eff = causal_kind or kind

    def body(carry, layer_p):
        # sequence-parallel layout between layers: remat saves the carry, so
        # constraining it here divides residual-stack memory by the TP width
        carry = annotate(carry, act_spec())
        y, cache = _block_full(cfg, kind_eff, layer_p, carry,
                               enc_out=enc_out)
        y = annotate(y, act_spec())
        return y, (cache if collect_cache else None)

    from repro.launch.flags import remat_policy, scan_unroll_arg

    policy = remat_policy()
    if cfg.remat and policy != "none":
        if policy == "dots":
            # save matmul outputs (no recompute of the big GEMMs in bwd) at
            # the cost of more live activation memory — §Perf lever
            body = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        else:
            body = jax.checkpoint(body)
    x, caches = jax.lax.scan(body, x, seg_params, unroll=scan_unroll_arg())
    return x, caches


def _hybrid_forward(cfg, params, x, collect_cache):
    """Mamba backbone with the shared attention block every k layers."""
    segs = _hybrid_segments(cfg)
    caches = {"ssm": [], "shared_kv": []}
    offset = 0
    for si, depth in enumerate(segs):
        seg_p = jax.tree.map(lambda a: a[offset:offset + depth],
                             params["ssm"])
        x, c = _scan_segment(cfg, "ssm", seg_p, x,
                             collect_cache=collect_cache)
        offset += depth
        if si < len(segs) - 1:  # shared attention between segments
            x, kv = _block_full(cfg, "dense", params["shared"], x,
                                window=cfg.sliding_window)
            if collect_cache:
                caches["shared_kv"].append(kv)
    return x, caches


def _hybrid_segments(cfg) -> Tuple[int, ...]:
    every = cfg.shared_attn_every
    n = cfg.n_layers
    segs = []
    done = 0
    while done < n:
        d = min(every, n - done)
        segs.append(d)
        done += d
    return tuple(segs)


def lm_loss(cfg: ModelConfig, params, batch) -> jnp.ndarray:
    logits, _ = forward(cfg, params,
                        tokens=batch.get("tokens"),
                        embeds=batch.get("embeds"),
                        enc_tokens=batch.get("enc_tokens"),
                        enc_embeds=batch.get("enc_embeds"))
    labels = batch["labels"]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    return -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ================================================================ serving

class DecodeCache(NamedTuple):
    pos: jnp.ndarray            # scalar int32
    layers: Any                 # per-family cache pytree


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, enc_out=None, *,
               kv_head_pad: int = 1) -> DecodeCache:
    """``kv_head_pad`` replicates each KV head that many times in the cache
    layout (``dist.sharding.kv_head_pad`` picks the factor lifting Hkv to
    the mesh's model axis); the GQA decode path detects the factor from the
    cache shape and repeats its per-token k/v writes to match — attention
    output is unchanged, head sharding survives small-Hkv archs."""
    hd, hkv = cfg.head_dim, max(cfg.n_kv_heads, 1) * max(kv_head_pad, 1)
    window = cfg.sliding_window or 0

    def kv(n, s):
        return (jnp.zeros((n, batch, hkv, s, hd), dtype),
                jnp.zeros((n, batch, hkv, s, hd), dtype))

    if cfg.family in ("dense", "vlm"):
        layers = {"dense": kv(cfg.n_layers, max_seq)}
    elif cfg.family == "moe":
        fd = cfg.moe.first_dense_layers
        layers = {}

        def mla_cache(n):
            m = cfg.mla
            return (jnp.zeros((n, batch, max_seq, m.kv_lora_rank), dtype),
                    jnp.zeros((n, batch, max_seq, m.qk_rope_dim), dtype))

        if fd:
            layers["dense"] = mla_cache(fd) if cfg.attention == "mla" \
                else kv(fd, max_seq)
        layers["moe"] = mla_cache(cfg.n_layers - fd) \
            if cfg.attention == "mla" else kv(cfg.n_layers - fd, max_seq)
    elif cfg.family == "ssm":
        layers = {"ssm": _stacked_ssm_state(cfg, cfg.n_layers, batch, dtype)}
    elif cfg.family == "hybrid":
        n_sites = len(_hybrid_segments(cfg)) - 1
        s_att = min(max_seq, window) if window else max_seq
        layers = {
            "ssm": _stacked_ssm_state(cfg, cfg.n_layers, batch, dtype),
            "shared_kv": kv(max(n_sites, 1), s_att),
        }
    elif cfg.family == "encdec":
        layers = {"cross_self": kv(cfg.n_layers, max_seq), "enc_out": enc_out}
    else:
        raise ValueError(cfg.family)
    return DecodeCache(pos=jnp.zeros((), jnp.int32), layers=layers)


def _stacked_ssm_state(cfg, n, batch, dtype):
    st = mamba2_init_state(cfg.ssm, cfg.d_model, batch, dtype)
    return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (n, *a.shape)), st)


def decode_step(cfg: ModelConfig, params, token_or_embed,
                cache: DecodeCache):
    """One decode step: token [B] (or embed [B, D]) -> (logits [B,V], cache).

    Layer caches are scanned alongside the stacked layer params, so the HLO
    stays O(1) in depth.
    """
    if token_or_embed.ndim == 1:
        x = params["embed"][token_or_embed]
    else:
        x = token_or_embed
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    pos = cache.pos
    new_layers = dict(cache.layers)

    if cfg.family in ("dense", "vlm"):
        x, new_layers["dense"] = _decode_scan_gqa(
            cfg, params["dense"], x, cache.layers["dense"], pos)
    elif cfg.family == "moe":
        fd = cfg.moe.first_dense_layers
        if fd:
            x, new_layers["dense"] = _decode_scan_dense_seg(
                cfg, params["dense"], x, cache.layers["dense"], pos)
        x, new_layers["moe"] = _decode_scan_moe(
            cfg, params["moe"], x, cache.layers["moe"], pos)
    elif cfg.family == "ssm":
        x, new_layers["ssm"] = _decode_scan_ssm(
            cfg, params["ssm"], x, cache.layers["ssm"], pos)
    elif cfg.family == "hybrid":
        x, new_layers = _decode_hybrid(cfg, params, x, cache.layers, pos)
    elif cfg.family == "encdec":
        x, new_layers = _decode_encdec(cfg, params, x, cache.layers, pos)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head.astype(x.dtype)
    logits = annotate(logits, P(("pod", "data"), "model"))
    return logits, DecodeCache(pos=pos + 1, layers=new_layers)


def _unroll():
    from repro.launch.flags import scan_unroll_arg
    return scan_unroll_arg()


def _decode_block_gqa(cfg, p, x, kv, pos, *, window=0, enc_out_kv=None):
    p = _cast_params(cfg, p)
    h = rms_norm(x, p["ln1"], cfg.norm_eps)
    att, kv = _gqa_decode(cfg, p["attn"], h, kv, pos, window=window)
    x = x + att
    if enc_out_kv is not None:
        hc = rms_norm(x, p["ln_cross"], cfg.norm_eps)
        q = (hc @ p["cross"]["wq"]).reshape(
            x.shape[0], cfg.n_heads, cfg.head_dim)
        o = decode_attention_host(q, enc_out_kv[0], enc_out_kv[1])
        x = x + o.reshape(x.shape[0], -1) @ p["cross"]["wo"]
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
    if "moe" in p:
        y = moe_ffn(h2[:, None], p["moe"], cfg.moe, cfg.ffn,
                    jnp.dtype(cfg.compute_dtype))[:, 0]
    else:
        y = _ffn_apply(cfg, p["ffn"], h2)
    return x + y, kv


def _decode_scan_gqa(cfg, seg_params, x, kv_cache, pos, window=0):
    def body(carry, inp):
        layer_p, kv = inp
        y, kv = _decode_block_gqa(cfg, layer_p, carry, kv, pos,
                                  window=window)
        return y, kv

    x, kv_out = jax.lax.scan(body, x, (seg_params, kv_cache),
                             unroll=_unroll())
    return x, kv_out


def _decode_scan_dense_seg(cfg, seg_params, x, cache, pos):
    """Dense-FFN segment; attention variant follows cfg.attention (MLA for
    deepseek's leading dense layers)."""
    if cfg.attention != "mla":
        return _decode_scan_gqa(cfg, seg_params, x, cache, pos)

    def body(carry, inp):
        layer_p, c = inp
        layer_p = _cast_params(cfg, layer_p)
        h = rms_norm(carry, layer_p["ln1"], cfg.norm_eps)
        att, c = _mla_decode(cfg, layer_p["attn"], h, c, pos)
        y = carry + att
        h2 = rms_norm(y, layer_p["ln2"], cfg.norm_eps)
        y = y + _ffn_apply(cfg, layer_p["ffn"], h2)
        return y, c

    return jax.lax.scan(body, x, (seg_params, cache), unroll=_unroll())


def _decode_scan_moe(cfg, seg_params, x, cache, pos):
    if cfg.attention != "mla":
        return _decode_scan_gqa(cfg, seg_params, x, cache, pos)

    def body(carry, inp):
        layer_p, c = inp
        layer_p = _cast_params(cfg, layer_p)
        h = rms_norm(carry, layer_p["ln1"], cfg.norm_eps)
        att, c = _mla_decode(cfg, layer_p["attn"], h, c, pos)
        y = carry + att
        h2 = rms_norm(y, layer_p["ln2"], cfg.norm_eps)
        y = y + moe_ffn(h2[:, None], layer_p["moe"], cfg.moe, cfg.ffn,
                        jnp.dtype(cfg.compute_dtype))[:, 0]
        return y, c

    return jax.lax.scan(body, x, (seg_params, cache), unroll=_unroll())


def _decode_scan_ssm(cfg, seg_params, x, states, pos):
    def body(carry, inp):
        layer_p, st = inp
        layer_p = _cast_params(cfg, layer_p)
        h = rms_norm(carry, layer_p["ln"], cfg.norm_eps)
        y, st = mamba2_step(h, Mamba2State(*st), layer_p["mamba"],
                            cfg.ssm, cfg.d_model)
        return carry + y, tuple(st)

    x, states = jax.lax.scan(body, x, (seg_params, tuple(states)),
                             unroll=_unroll())
    return x, states


def _decode_hybrid(cfg, params, x, layers, pos):
    segs = _hybrid_segments(cfg)
    states = layers["ssm"]
    kv = layers["shared_kv"]
    new_states, new_kv = [], []
    offset = 0
    for si, depth in enumerate(segs):
        seg_p = jax.tree.map(lambda a: a[offset:offset + depth],
                             params["ssm"])
        st = jax.tree.map(lambda a: a[offset:offset + depth], states)
        x, st = _decode_scan_ssm(cfg, seg_p, x, st, pos)
        new_states.append(st)
        offset += depth
        if si < len(segs) - 1:
            kv_i = jax.tree.map(lambda a: a[si], kv)
            x, kv_i = _decode_block_gqa(cfg, params["shared"], x, kv_i, pos,
                                        window=cfg.sliding_window)
            new_kv.append(kv_i)
    states = jax.tree.map(lambda *xs: jnp.concatenate(xs, 0), *new_states)
    kv_out = jax.tree.map(lambda *xs: jnp.stack(xs, 0), *new_kv) if new_kv \
        else kv
    return x, {"ssm": states, "shared_kv": kv_out}


def _decode_encdec(cfg, params, x, layers, pos):
    enc_out = layers["enc_out"]  # precomputed [L, B, Hkv, S_enc, hd] pairs

    def body(carry, inp):
        layer_p, kv, cross_kv = inp
        y, kv = _decode_block_gqa(cfg, layer_p, carry, kv, pos,
                                  enc_out_kv=cross_kv)
        return y, kv

    x, kv_out = jax.lax.scan(
        body, x, (params["cross"], layers["cross_self"], enc_out),
        unroll=_unroll())
    return x, {"cross_self": kv_out, "enc_out": enc_out}


def prefill(cfg: ModelConfig, params, tokens=None, embeds=None,
            enc_tokens=None, enc_embeds=None):
    """Forward over the prompt; returns last-position logits (cache wiring
    for incremental decode is exercised via decode_step)."""
    logits, _ = forward(cfg, params, tokens=tokens, embeds=embeds,
                        enc_tokens=enc_tokens, enc_embeds=enc_embeds)
    return logits[:, -1]
