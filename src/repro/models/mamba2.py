"""Mamba-2 block (SSD) — train path via the differentiable reference scan,
serve path via the Pallas chunked kernel on TPU; O(1)-state decode step.

Projection layout follows the Mamba-2 paper: one in-projection produces
[z | x | B | C | dt]; a depthwise causal conv runs over [x | B | C]; the SSD
scan mixes over time; gated RMSNorm and out-projection close the block.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.kernels.ssd_scan.ops import ssd
from repro.kernels.ssd_scan.ref import ssd_ref

from .layers import rms_norm


def mamba2_params_shapes(ssm: SSMConfig, d_model: int) -> dict:
    di = ssm.d_inner(d_model)
    nh = ssm.n_heads(d_model)
    g, n = ssm.n_groups, ssm.d_state
    conv_dim = di + 2 * g * n
    return {
        "w_in": (d_model, 2 * di + 2 * g * n + nh),  # z,x,B,C,dt
        "conv_w": (ssm.d_conv, conv_dim),            # depthwise causal conv
        "conv_b": (conv_dim,),
        "a_log": (nh,),
        "d_skip": (nh,),
        "dt_bias": (nh,),
        "norm_w": (di,),
        "w_out": (di, d_model),
    }


def _split(proj: jnp.ndarray, ssm: SSMConfig, d_model: int):
    di = ssm.d_inner(d_model)
    g, n = ssm.n_groups, ssm.d_state
    nh = ssm.n_heads(d_model)
    z, xbc, dt = jnp.split(proj, [di, di + di + 2 * g * n], axis=-1)
    return z, xbc, dt, di, g, n, nh


def mamba2_forward(x: jnp.ndarray, p: dict, ssm: SSMConfig,
                   d_model: int) -> jnp.ndarray:
    """x: [B, S, D] -> [B, S, D] (full-sequence; differentiable)."""
    bsz, s, _ = x.shape
    proj = x @ p["w_in"]
    z, xbc, dt, di, g, n, nh = _split(proj, ssm, d_model)

    # depthwise causal conv over the sequence
    pad = jnp.pad(xbc, ((0, 0), (ssm.d_conv - 1, 0), (0, 0)))
    xbc = sum(pad[:, i:i + s] * p["conv_w"][i][None, None]
              for i in range(ssm.d_conv))
    xbc = jax.nn.silu(xbc + p["conv_b"][None, None])

    xs, b_mat, c_mat = jnp.split(xbc, [di, di + g * n], axis=-1)
    xs = xs.reshape(bsz, s, nh, ssm.head_dim)
    b_mat = b_mat.reshape(bsz, s, g, n)
    c_mat = c_mat.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None, None].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    from repro.launch.flags import ssd_chunk

    y = ssd(xs, dt.astype(xs.dtype), a, b_mat, c_mat,
            p["d_skip"].astype(jnp.float32),
            q_chunk=ssd_chunk() or 128)
    y = y.reshape(bsz, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["w_out"]


class Mamba2State(NamedTuple):
    conv: jnp.ndarray   # [B, d_conv-1, conv_dim]
    ssm: jnp.ndarray    # [B, nh, N, P] (f32)


def mamba2_init_state(ssm: SSMConfig, d_model: int, batch: int,
                      dtype=jnp.bfloat16) -> Mamba2State:
    di = ssm.d_inner(d_model)
    g, n = ssm.n_groups, ssm.d_state
    nh = ssm.n_heads(d_model)
    conv_dim = di + 2 * g * n
    return Mamba2State(
        conv=jnp.zeros((batch, ssm.d_conv - 1, conv_dim), dtype),
        ssm=jnp.zeros((batch, nh, n, ssm.head_dim), jnp.float32))


def mamba2_step(x: jnp.ndarray, state: Mamba2State, p: dict, ssm: SSMConfig,
                d_model: int) -> Tuple[jnp.ndarray, Mamba2State]:
    """Single-token decode: x [B, D] -> (y [B, D], new state). O(1) per token
    — this is what makes long_500k tractable for SSM/hybrid archs."""
    bsz = x.shape[0]
    proj = x @ p["w_in"]
    z, xbc, dt, di, g, n, nh = _split(proj, ssm, d_model)

    window = jnp.concatenate([state.conv, xbc[:, None]], axis=1)
    conv_out = (window * p["conv_w"][None]).sum(axis=1) + p["conv_b"][None]
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:]

    xs, b_mat, c_mat = jnp.split(xbc, [di, di + g * n], axis=-1)
    xs = xs.reshape(bsz, nh, ssm.head_dim).astype(jnp.float32)
    b_mat = b_mat.reshape(bsz, g, n).astype(jnp.float32)
    c_mat = c_mat.reshape(bsz, g, n).astype(jnp.float32)
    rep = nh // g
    b_h = jnp.repeat(b_mat, rep, axis=1)   # [B, nh, N]
    c_h = jnp.repeat(c_mat, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"][None].astype(jnp.float32))  # [B, nh]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                    # [nh]

    decay = jnp.exp(dt * a[None])                                   # [B, nh]
    xdt = xs * dt[..., None]
    h_new = (decay[..., None, None] * state.ssm
             + b_h[..., :, None] * xdt[..., None, :])               # [B,nh,N,P]
    y = jnp.einsum("bhn,bhnp->bhp", c_h, h_new)
    y = y + xs * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(bsz, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["w_out"], Mamba2State(conv=new_conv, ssm=h_new)
