"""Sequential Task Flow (STF) baseline — the StarPU-style comparison point.

The paper's central comparison (§I-B, §III) is PTG vs STF: an STF runtime
discovers the DAG by *sequentially* enumerating tasks with data-access modes
(READ / WRITE / READWRITE) and inferring dependencies from last-writer /
reader sets. This file implements that model on top of the same
work-stealing threadpool, so benchmark deltas isolate the *DAG-discovery
strategy*, not the executor:

- task submission is single-threaded and builds the explicit DAG up front
  (the O(global DAG) cost the PTG avoids);
- every rank in a distributed STF run enumerates the *full* DAG (as StarPU's
  MPI mode does), while the PTG discovers only its local slice.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, List, Sequence

from .threadpool import Task, Threadpool

READ, WRITE, READWRITE = "R", "W", "RW"


@dataclass
class _Node:
    fn: Callable[[], None]
    indegree: int = 0
    indegree0: int = 0  # as submitted — execution consumes `indegree`
    out: List["_Node"] = field(default_factory=list)
    priority: float = 0.0
    mapping: int = 0


class STFGraph:
    """Sequential-semantics task submission with inferred dependencies."""

    def __init__(self, tp: Threadpool):
        self.tp = tp
        self._nodes: List[_Node] = []
        self._last_writer: Dict[Hashable, _Node] = {}
        self._readers_since_write: Dict[Hashable, List[_Node]] = {}
        self._lock = threading.Lock()
        self._remaining = 0
        self._executed = False

    def submit(
        self,
        fn: Callable[[], None],
        accesses: Sequence[tuple],  # (data_key, mode)
        *,
        priority: float = 0.0,
        mapping: int = 0,
    ) -> None:
        """Sequentially declare one task; dependencies are inferred (RAW,
        WAR, WAW hazards) from the access modes — StarPU's data model."""
        node = _Node(fn, priority=priority, mapping=mapping)
        deps: set = set()
        for key, mode in accesses:
            if mode in (READ, READWRITE):
                w = self._last_writer.get(key)
                if w is not None:
                    deps.add(id(w)); w.out.append(node)           # RAW
            if mode in (WRITE, READWRITE):
                for r in self._readers_since_write.get(key, []):
                    if r is not node:
                        deps.add(id(r)); r.out.append(node)       # WAR
                w = self._last_writer.get(key)
                if w is not None and id(w) not in deps:
                    deps.add(id(w)); w.out.append(node)           # WAW
                self._last_writer[key] = node
                self._readers_since_write[key] = []
            if mode in (READ, READWRITE):
                self._readers_since_write.setdefault(key, []).append(node)
        node.indegree = node.indegree0 = len(deps)
        self._nodes.append(node)

    def reset(self) -> None:
        """Restore every dependency counter to its submitted value so the
        same DAG can execute again. The edge structure is immutable —
        execution only consumes the counters — so resetting them is the
        whole job; this closes the one-shot dead end where the only answer
        to re-running a graph was rebuilding it from scratch."""
        if self._remaining:
            raise RuntimeError(
                "STFGraph.reset() while tasks are still in flight")
        for n in self._nodes:
            n.indegree = n.indegree0
        self._executed = False

    def execute(self) -> None:
        """Release roots, run the whole DAG, block until done.

        Execution consumes the per-node ``indegree`` counters, so calling
        this twice without a :meth:`reset` in between would see every node
        at zero and release the whole DAG at once, silently ignoring all
        dependencies — hence the guard.
        """
        if self._executed:
            raise RuntimeError(
                "STFGraph.execute() already ran; dependency counters are "
                "consumed and a re-run would ignore every edge. Call "
                "reset() (or build a fresh STFGraph) to run again.")
        self._executed = True
        self._remaining = len(self._nodes)
        done = threading.Event()
        lock = threading.Lock()

        def run_node(node: _Node) -> None:
            node.fn()
            for succ in node.out:
                with lock:
                    succ.indegree -= 1
                    ready = succ.indegree == 0
                if ready:
                    self.tp.insert(Task(run=lambda s=succ: run_node(s),
                                        priority=succ.priority), succ.mapping)
            with lock:
                self._remaining -= 1
                if self._remaining == 0:
                    done.set()

        roots = [n for n in self._nodes if n.indegree == 0]
        if not self._nodes:
            return
        for n in roots:
            self.tp.insert(Task(run=lambda s=n: run_node(s), priority=n.priority),
                           n.mapping)
        done.wait()
