"""Fault injection plans and recovery accounting for the host runtime.

The in-proc world (:mod:`repro.core.messages`) emulates the transport; a
:class:`FaultPlan` makes it *adversarial in the failure dimension* the way
``delay_fn`` already makes it adversarial in the ordering dimension:

- per-edge message **drop** and **duplication** probabilities, driven by a
  seeded per-``(src, dst)`` RNG so every schedule is reproducible;
- **rank kills** — ``kill={rank: at_msg}`` silences ``rank`` the moment it
  tries to queue its ``at_msg``-th user AM: the send is dropped, every
  undelivered message from that rank is purged, and the rank never sends or
  receives again (a crashed process, not a slow one);
- the failure-detector knobs (heartbeat period, lease) and the reliable
  layer's retry schedule.

:class:`RecoveryReport` is the measurement half — what the ISSUE calls
"robustness features must be measured, not just asserted": every injected
fault, transport retry, suppressed duplicate, declared death, re-derived
shard, replayed send, and re-executed task is counted, and
``recovery_seconds`` / ``rederived_frac`` feed ``benchmarks/recovery.py``.
All mutators are lock-guarded: workers, progress threads, and the world all
write into one report.
"""

from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass(frozen=True)
class FaultPlan:
    """Deterministic, seeded description of the faults to inject.

    ``drop`` / ``duplicate`` apply independently to every wire message
    (user AMs, protocol traffic, and transport acks alike — the reliable
    layer must survive all of it). ``kill`` maps rank -> the 1-based user-AM
    send count at which the rank dies mid-send. Rank 0 is the completion /
    failure arbiter and cannot be killed (the paper's rank-0 asymmetry;
    arbiter election is out of scope).
    """

    seed: int = 0
    drop: float = 0.0
    duplicate: float = 0.0
    kill: Dict[int, int] = field(default_factory=dict)
    # failure detector: heartbeat period and lease (silence -> declared dead)
    heartbeat_every: float = 0.02
    lease: float = 0.5
    # reliable layer: retransmit after retry_base * 2**attempt (capped),
    # SUSPECT the destination after retry_budget unacked attempts
    retry_base: float = 0.03
    retry_budget: int = 8

    def __post_init__(self):
        if 0 in self.kill:
            raise ValueError("rank 0 is the arbiter and cannot be killed")
        if not (0.0 <= self.drop < 1.0 and 0.0 <= self.duplicate < 1.0):
            raise ValueError("drop/duplicate must be probabilities in [0, 1)")

    def edge_rng(self, src: int, dst: int) -> random.Random:
        """Independent deterministic stream per directed edge."""
        return random.Random(f"{self.seed}:{src}->{dst}")


class RecoveryReport:
    """Thread-safe tally of injected faults and the runtime's response."""

    _COUNTERS = (
        "injected_drops", "injected_dups", "retries", "dup_suppressed",
        "replayed_sends", "reexecuted_tasks", "rederived_edges",
        "forwarded_ams", "bus_replayed",
    )

    def __init__(self, total_edges: Optional[int] = None):
        self._lock = threading.Lock()
        for c in self._COUNTERS:
            setattr(self, c, 0)
        self.suspects: List[int] = []
        self.deaths: List[int] = []
        self.rederived_shards: List[int] = []
        self.total_edges = total_edges
        self.recovery_seconds: Optional[float] = None
        self._death_declared_at: Optional[float] = None

    def __getstate__(self) -> dict:
        # the report crosses process boundaries on the multiproc transport
        # (each rank ships its tally home for merging): drop the lock
        state = self.__dict__.copy()
        del state["_lock"]
        return state

    def __setstate__(self, state: dict) -> None:
        self.__dict__.update(state)
        self._lock = threading.Lock()

    def bump(self, counter: str, n: int = 1) -> None:
        with self._lock:
            setattr(self, counter, getattr(self, counter) + n)

    def note_suspect(self, rank: int) -> None:
        with self._lock:
            if rank not in self.suspects:
                self.suspects.append(rank)

    def note_death(self, rank: int, now: float) -> None:
        with self._lock:
            if rank not in self.deaths:
                self.deaths.append(rank)
                if self._death_declared_at is None:
                    self._death_declared_at = now

    def note_rederived(self, shard: int, edges: int) -> None:
        with self._lock:
            self.rederived_shards.append(shard)
            self.rederived_edges += edges

    def note_recovered(self, now: float) -> None:
        """Stamp recovery_seconds once: first death -> back to quiescence."""
        with self._lock:
            if self._death_declared_at is not None and \
                    self.recovery_seconds is None:
                self.recovery_seconds = now - self._death_declared_at

    @property
    def rederived_frac(self) -> Optional[float]:
        """Re-derived edge entries / full eager edge entries (the lazy-
        discovery payoff: should track halo-sized, not O(global))."""
        if not self.total_edges:
            return None
        return self.rederived_edges / self.total_edges

    def to_dict(self) -> dict:
        d = {c: getattr(self, c) for c in self._COUNTERS}
        d.update(
            suspects=list(self.suspects),
            deaths=list(self.deaths),
            rederived_shards=list(self.rederived_shards),
            total_edges=self.total_edges,
            recovery_seconds=self.recovery_seconds,
            rederived_frac=self.rederived_frac,
        )
        return d

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"RecoveryReport({self.to_dict()!r})"
