"""The in-process backend: today's threaded multi-rank world, re-homed.

Behavior-identical to the pre-registry transport (and still the default):
one heap inbox per rank with injectable delivery delay/reorder, and — via
:class:`~repro.core.faults.FaultPlan` — seeded message loss, duplication,
and rank kills, so the completion protocol is stress-tested adversarially
without leaving the process.

Also provides the loopback :class:`InProcListener` / :class:`InProcComm`
channel pair (Dask's ``inproc://`` analogue) so the transport conformance
suite exercises the channel contract itself, not only the world built on
top of it.
"""

from __future__ import annotations

import heapq
import itertools
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional

from ..faults import FaultPlan, RecoveryReport
from .core import (Backend, Comm, CommClosedError, Connector, Listener,
                   Wire)


class InProcWorld:
    """Per-rank inboxes + adversarial delivery (delay / reorder / loss /
    duplication / rank death)."""

    def __init__(self, n_ranks: int,
                 delay_fn: Optional[Callable[..., float]] = None,
                 faults: Optional[FaultPlan] = None):
        self.n_ranks = n_ranks
        self.delay_fn = delay_fn
        self.faults = faults
        self.report = RecoveryReport()
        # Set when any rank *fails* (exception): every other rank aborts
        # instead of waiting forever inside the completion protocol.
        self.poison = threading.Event()
        self._locks = [threading.Lock() for _ in range(n_ranks)]
        # Each inbox is a heap of (deliver_at, seq, wire).
        self._inboxes: List[list] = [[] for _ in range(n_ranks)]
        self._seq = itertools.count()
        self._fingerprints: List[list] = [[] for _ in range(n_ranks)]
        # Fault machinery: killed ranks, per-rank user-AM send counts (kill
        # triggers), per-edge RNG streams, per-rank shutdown flags (the
        # post-SHUTDOWN ack linger; see Communicator.run_until_shutdown).
        self.dead: set = set()
        self._fault_lock = threading.Lock()
        self._user_sent = [0] * n_ranks
        self._edge_rng: Dict[tuple, Any] = {}
        self._shutdown_flags = [False] * n_ranks
        # rank -> zero-arg callable returning that rank's forensic state
        self._snapshots: List[Optional[Callable]] = [None] * n_ranks

    # ----------------------------------------------------------- fault hooks

    def check_dead_or_kill(self, src: int) -> bool:
        """Called once per *user AM first-send* from ``src``; counts it
        against the kill plan. True => the rank is (now) dead and the send
        must be abandoned."""
        if src in self.dead:
            return True
        f = self.faults
        if f is None or src not in f.kill:
            return False
        with self._fault_lock:
            self._user_sent[src] += 1
            fire = self._user_sent[src] >= f.kill[src] and src not in self.dead
        if fire:
            self.kill(src)
        return src in self.dead

    def kill(self, rank: int) -> None:
        """Physically silence ``rank``: no message from it is ever delivered
        again, its inbox is discarded, undelivered messages it already sent
        are purged. Idempotent; safe from any thread."""
        with self._fault_lock:
            if rank in self.dead:
                return
            self.dead.add(rank)
        for r in range(self.n_ranks):
            with self._locks[r]:
                if r == rank:
                    self._inboxes[r].clear()
                else:
                    kept = [item for item in self._inboxes[r]
                            if item[2].src != rank]
                    if len(kept) != len(self._inboxes[r]):
                        heapq.heapify(kept)
                        self._inboxes[r] = kept
        # a dead rank cannot object to shutdown
        self._shutdown_flags[rank] = True

    def flag_shutdown(self, rank: int) -> None:
        self._shutdown_flags[rank] = True

    def all_shutdown(self) -> bool:
        return all(self._shutdown_flags)

    # ------------------------------------------------------------- transport

    def send(self, dst: int, wire: Wire) -> None:
        if wire.src in self.dead or dst in self.dead:
            return  # crashed endpoints: silently fenced
        duplicate = False
        f = self.faults
        if f is not None and (f.drop or f.duplicate):
            with self._fault_lock:
                rng = self._edge_rng.get((wire.src, dst))
                if rng is None:
                    rng = self._edge_rng[(wire.src, dst)] = f.edge_rng(
                        wire.src, dst)
                # always draw both so the stream stays aligned per edge
                dropped = rng.random() < f.drop
                duplicate = rng.random() < f.duplicate
            if dropped:
                self.report.bump("injected_drops")
                return
            if duplicate:
                self.report.bump("injected_dups")
        self._deliver(dst, wire)
        if duplicate:
            self._deliver(dst, wire)

    def _deliver(self, dst: int, wire: Wire) -> None:
        delay = self.delay_fn(wire.src, dst, wire.kind) if self.delay_fn \
            else 0.0
        deliver_at = time.monotonic() + delay
        with self._locks[dst]:
            heapq.heappush(self._inboxes[dst],
                           (deliver_at, next(self._seq), wire))

    def poll(self, rank: int) -> List[Wire]:
        """Pop every message whose delivery time has arrived."""
        now = time.monotonic()
        out: List[Wire] = []
        with self._locks[rank]:
            inbox = self._inboxes[rank]
            while inbox and inbox[0][0] <= now:
                out.append(heapq.heappop(inbox)[2])
        return out

    def has_traffic(self, rank: int) -> bool:
        with self._locks[rank]:
            return bool(self._inboxes[rank])

    def register_fingerprint(self, rank: int, fp: str) -> int:
        """Record AM registration order; verify global consistency (§II-B2)."""
        fps = self._fingerprints[rank]
        am_id = len(fps)
        fps.append(fp)
        for other in range(self.n_ranks):
            others = self._fingerprints[other]
            if len(others) > am_id and others[am_id] != fp:
                raise RuntimeError(
                    f"active messages registered in different orders: rank {rank} "
                    f"registered {fp!r} as id {am_id}, rank {other} has {others[am_id]!r}"
                )
        return am_id

    # ------------------------------------------------------------- forensics

    def attach_snapshot_provider(self, rank: int, fn: Callable) -> None:
        """Register the callable serving ``rank``'s forensic snapshot
        (later registrations win: the scheduler's ShardRuntime overrides
        the bare communicator snapshot with its richer serve-loop state)."""
        self._snapshots[rank] = fn

    def snapshot_rank(self, rank: int):
        fn = self._snapshots[rank]
        if fn is None:
            return None
        try:
            return fn()
        except Exception as e:  # forensics must never mask the real error
            return f"<snapshot failed: {e!r}>"


# ------------------------------------------------------- loopback channels


class InProcComm(Comm):
    """One end of an in-process duplex channel (a queue pair)."""

    def __init__(self, rx: "queue.Queue", tx: "queue.Queue",
                 peer_closed: threading.Event, self_closed: threading.Event):
        self._rx = rx
        self._tx = tx
        self._peer_closed = peer_closed
        self._self_closed = self_closed

    def write(self, msg) -> None:
        if self._self_closed.is_set() or self._peer_closed.is_set():
            raise CommClosedError("inproc comm is closed")
        self._tx.put(msg)

    def read(self, timeout: Optional[float] = None):
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            try:
                return self._rx.get(timeout=0.05)
            except queue.Empty:
                if self._peer_closed.is_set() and self._rx.empty():
                    raise CommClosedError("peer closed") from None
                if self._self_closed.is_set():
                    raise CommClosedError("comm closed") from None
                if deadline is not None and time.monotonic() >= deadline:
                    raise TimeoutError("inproc read timed out") from None

    def close(self) -> None:
        self._self_closed.set()

    @property
    def closed(self) -> bool:
        return self._self_closed.is_set()


_LISTENERS: Dict[str, "InProcListener"] = {}
_LISTENER_LOCK = threading.Lock()
_ADDR = itertools.count()


class InProcListener(Listener):
    """Loopback listener: connects land as queue pairs, the handler runs
    on a dedicated thread per accepted channel."""

    def __init__(self, handler):
        super().__init__(handler)
        self.address = f"inproc://{next(_ADDR)}"
        self._stopped = threading.Event()

    def start(self) -> None:
        with _LISTENER_LOCK:
            _LISTENERS[self.address] = self

    def stop(self) -> None:
        self._stopped.set()
        with _LISTENER_LOCK:
            _LISTENERS.pop(self.address, None)

    def _accept(self) -> Comm:
        if self._stopped.is_set():
            raise CommClosedError(f"listener {self.address} is stopped")
        a2b: queue.Queue = queue.Queue()
        b2a: queue.Queue = queue.Queue()
        ca, cb = threading.Event(), threading.Event()
        server = InProcComm(a2b, b2a, peer_closed=cb, self_closed=ca)
        client = InProcComm(b2a, a2b, peer_closed=ca, self_closed=cb)
        threading.Thread(target=self.handler, args=(server,),
                         daemon=True).start()
        return client


class InProcConnector(Connector):
    def connect(self, address: str, timeout: float = 5.0) -> Comm:
        with _LISTENER_LOCK:
            listener = _LISTENERS.get(address)
        if listener is None:
            raise CommClosedError(f"no inproc listener at {address}")
        return listener._accept()


# ------------------------------------------------------------- the backend


class InProcBackend(Backend):
    """Threaded rank emulation: the pre-registry ``run_ranks`` semantics,
    verbatim (poison propagation, root-cause surfacing, resident
    scheduler mode, timeout forensics)."""

    def listener(self, handler) -> Listener:
        return InProcListener(handler)

    def connector(self) -> Connector:
        return InProcConnector()

    def run_ranks(self, n_ranks: int, main, *, n_threads: int = 2,
                  delay_fn=None, faults=None, timeout: float = 120.0,
                  serve_scheduler=None):
        from .. import runtime as rt

        world = InProcWorld(n_ranks, delay_fn=delay_fn, faults=faults)
        if serve_scheduler is not None:
            # the resident service needs the world for recovery gating (is
            # a fault plan active?), the dead set, and future-timeout
            # forensics
            serve_scheduler.attach_world(world)
        results = [None] * n_ranks
        errors: list = []

        def runner(rank: int) -> None:
            status, payload = rt.rank_session(world, rank, main, n_threads)
            if status == "ok":
                results[rank] = payload
            elif status == "error":
                errors.append((rank, payload))

        threads = [
            threading.Thread(target=runner, args=(r,), daemon=True,
                             name=f"rank{r}")
            for r in range(n_ranks)
        ]
        for t in threads:
            t.start()
        if serve_scheduler is not None:
            while not serve_scheduler.draining.wait(timeout=0.25):
                if world.poison.is_set() or errors:
                    break   # a rank died while serving: fall through, join
        deadline = time.monotonic() + timeout
        stuck = []
        for t in threads:
            t.join(timeout=max(0.0, deadline - time.monotonic()))
            if t.is_alive():
                stuck.append(int(t.name.replace("rank", "")))
        if stuck:
            world.poison.set()  # let salvageable ranks unwind first
            raise TimeoutError(rt.timeout_forensics(stuck, world, timeout))
        if errors:
            rank, err = errors[0]
            raise RuntimeError(
                f"rank {rank} failed:\n{rt.format_rank_error(err)}") from err
        if faults is not None:
            return results, world.report
        return results
