"""Pluggable active-message transport: interfaces + backend registry.

The host runtime above this package is transport-agnostic by construction
(reliable delivery, completion detection, and DEATH/epoch recovery all
speak the :class:`World` contract below) — this module makes the transport
itself pluggable, shaped after Dask Distributed's ``distributed/comm``:

- :class:`Comm` — one established duplex point-to-point channel;
- :class:`Listener` — accepts inbound channels at an address;
- :class:`Connector` — opens an outbound channel to an address;
- :class:`Backend` — a named bundle of the three plus the rank launcher
  (``run_ranks``) that runs SPMD mains over that transport.

Backends register under a name (``register_backend``) and are selected by
``run_ranks(..., transport=...)`` / ``SchedulerService(transport=...)``:

========== ============================================================
backend    world
========== ============================================================
inproc     one process, one thread-group per rank, heap inboxes — the
           default for tests; supports delay/reorder/loss/dup/kill
           injection (:mod:`repro.core.comm.inproc`)
multiproc  one OS process per rank, length-prefixed cloudpickle frames
           over loopback TCP sockets, parent-process rendezvous — the
           same runtime messages (reliable delivery, fault injection,
           DEATH/epoch recovery) over a real remote transport
           (:mod:`repro.core.comm.multiproc`)
========== ============================================================

The **world contract** every backend's world satisfies (the transport
surface :class:`~repro.core.messages.Communicator`,
:class:`~repro.core.completion.CompletionDetector`, and the scheduler's
:class:`~repro.sched.service.ShardRuntime` program against):

- attributes: ``n_ranks``, ``faults``, ``report`` (a
  :class:`~repro.core.faults.RecoveryReport`), ``poison`` (Event-like:
  ``is_set``/``set``), ``dead`` (set of fenced ranks);
- transport: ``send(dst, wire)`` (thread-safe, lossy under a FaultPlan),
  ``poll(rank)`` (drain due messages), ``has_traffic(rank)``;
- membership: ``kill(rank)`` (idempotent physical fence),
  ``check_dead_or_kill(src)`` (user-AM send counting against the kill
  plan), ``flag_shutdown(rank)`` / ``all_shutdown()`` (the post-SHUTDOWN
  ack linger), ``register_fingerprint(rank, fp)`` (global AM identity);
- forensics: ``attach_snapshot_provider(rank, fn)`` /
  ``snapshot_rank(rank)`` — how timeout diagnostics reach a rank's
  protocol state without assuming shared memory (a multiproc snapshot is
  served by the rank's process over its control channel).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import numpy as np


@dataclass
class Wire:
    """One message on the wire — the unit every backend carries.

    ``kind`` is ``"am"`` / ``"large_am"`` for user traffic, a completion-
    protocol kind (COUNT/REQUEST/CONFIRMATION/SHUTDOWN/DEATH), or a
    transport kind (ACK/HB). ``seq`` is the reliable-stream sequence per
    ``(src, dst)``; ``-1`` rides the raw (unsequenced) wire.
    """

    kind: str          # "am" | "large_am" | protocol kinds | ACK | HB
    src: int
    am_id: int = -1
    blob: bytes = b""          # pickled regular args
    raw: Optional[np.ndarray] = None  # large-AM view payload (no copy)
    meta: Any = None           # protocol payload
    seq: int = -1              # reliable-stream seq per (src, dst); -1 = raw


class CommClosedError(RuntimeError):
    """The channel (or its listener) was closed under the operation."""


class Comm:
    """One established duplex channel between two endpoints.

    ``write`` enqueues one message (any picklable object; backends may
    pass it by reference in-process); ``read`` blocks up to ``timeout``
    for the next message and raises :class:`CommClosedError` once the
    peer closed and the buffer drained. Both ends see FIFO order.
    """

    def write(self, msg) -> None:
        raise NotImplementedError

    def read(self, timeout: Optional[float] = None):
        raise NotImplementedError

    def close(self) -> None:
        raise NotImplementedError

    @property
    def closed(self) -> bool:
        raise NotImplementedError


class Listener:
    """Accepts inbound channels at ``address``; each accepted
    :class:`Comm` is handed to ``handler`` (on an internal thread).
    ``stop()`` is idempotent and releases the address — a clean shutdown
    must leave later ``connect`` attempts failing fast, not hanging."""

    address: str

    def __init__(self, handler: Callable[[Comm], None]):
        self.handler = handler

    def start(self) -> None:
        raise NotImplementedError

    def stop(self) -> None:
        raise NotImplementedError


class Connector:
    """Opens an outbound :class:`Comm` to a listener's address."""

    def connect(self, address: str, timeout: float = 5.0) -> Comm:
        raise NotImplementedError


class Backend:
    """One registered transport backend."""

    name: str = "?"

    def listener(self, handler: Callable[[Comm], None]) -> Listener:
        raise NotImplementedError

    def connector(self) -> Connector:
        raise NotImplementedError

    def run_ranks(self, n_ranks: int, main, *, n_threads: int = 2,
                  delay_fn=None, faults=None, timeout: float = 120.0,
                  serve_scheduler=None):
        """SPMD-launch ``main`` over this transport; the contract of
        :func:`repro.core.runtime.run_ranks`."""
        raise NotImplementedError


_REGISTRY: Dict[str, Backend] = {}


def register_backend(name: str, backend: Backend) -> None:
    backend.name = name
    _REGISTRY[name] = backend


def get_backend(name: Optional[str] = None) -> Backend:
    """Resolve a backend by name (default: ``$REPRO_TRANSPORT`` or
    ``inproc``). Unknown names fail loudly with the registered set."""
    import os

    if name is None:
        name = os.environ.get("REPRO_TRANSPORT", "inproc")
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown transport backend {name!r}; registered: "
            f"{sorted(_REGISTRY)}") from None


def backend_names():
    return sorted(_REGISTRY)
