"""The multi-process backend: one real OS process per rank.

The first transport that leaves the process. Each rank is a forked child
carrying the host runtime unchanged — reliable delivery, fault injection,
DEATH/epoch recovery — over length-prefixed cloudpickle frames on loopback
TCP sockets:

- **data plane**: every child runs a :class:`TcpListener`; peers connect
  lazily and stream :class:`~repro.core.comm.core.Wire` frames. A send to
  a crashed peer simply fails and is dropped — exactly the lossy-channel
  model the seq/ack/retry layer (PR 7) was built for.
- **control plane**: one channel per child back to the parent, used for
  rendezvous (``hello``/``addr`` -> ``peers`` broadcast), membership relays
  (a self-kill becomes a ``peerdead`` broadcast so survivors fence the
  rank physically, like the in-proc world's global ``kill``), poison and
  shutdown-flag propagation, AM-fingerprint validation, forensic snapshot
  requests, and the final per-rank result.
- **service plane** (resident scheduler only): an RPC channel per child to
  the parent-hosted :class:`~repro.sched.service.SchedulerService` and its
  bus; the child's ShardRuntime talks to them through
  :mod:`repro.sched.proxy` instead of shared memory.

Bootstrap is **fork-only** by design: ``main`` and the scheduler's bound
``_rank_main`` pass to the child by address-space inheritance, never
pickled. Children must not touch fork-hostile state the parent initialized
(XLA/jax in particular) — use numpy task bodies for cross-process runs.
Children exit with ``os._exit`` after reporting, so no atexit/teardown of
inherited state runs twice.
"""

from __future__ import annotations

import heapq
import itertools
import os
import socket
import struct
import threading
import time
from typing import Any, Dict, List, Optional

import cloudpickle

from ..faults import RecoveryReport
from .core import (Backend, Comm, CommClosedError, Connector, Listener,
                   Wire)

_HDR = struct.Struct("!I")


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise CommClosedError("peer closed the connection")
        buf += chunk
    return buf


class TcpComm(Comm):
    """One TCP channel carrying length-prefixed cloudpickle frames."""

    def __init__(self, sock: socket.socket):
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._sock = sock
        self._wlock = threading.Lock()
        self._rlock = threading.Lock()
        self._closed = False

    def write(self, msg) -> None:
        payload = cloudpickle.dumps(msg)
        frame = _HDR.pack(len(payload)) + payload
        try:
            with self._wlock:
                if self._closed:
                    raise CommClosedError("comm closed")
                self._sock.sendall(frame)
        except OSError as e:
            self.close()
            raise CommClosedError(f"write failed: {e}") from None

    def read(self, timeout: Optional[float] = None):
        try:
            with self._rlock:
                self._sock.settimeout(timeout)
                hdr = _recv_exact(self._sock, _HDR.size)
                # the frame header arrived: finish the body on a generous
                # clock even if the caller's poll timeout was tiny
                self._sock.settimeout(60.0)
                payload = _recv_exact(self._sock, _HDR.unpack(hdr)[0])
        except socket.timeout:
            raise TimeoutError("tcp read timed out") from None
        except CommClosedError:
            self.close()
            raise
        except OSError as e:
            self.close()
            raise CommClosedError(f"read failed: {e}") from None
        return cloudpickle.loads(payload)

    def close(self) -> None:
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass

    @property
    def closed(self) -> bool:
        return self._closed


class TcpListener(Listener):
    """Accepts loopback TCP channels; one handler thread per accept."""

    def __init__(self, handler):
        super().__init__(handler)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind(("127.0.0.1", 0))
        self._sock.listen(64)
        self.port = self._sock.getsockname()[1]
        self.address = f"tcp://127.0.0.1:{self.port}"
        self._stopped = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def start(self) -> None:
        self._thread = threading.Thread(target=self._accept_loop,
                                        daemon=True, name="tcp-accept")
        self._thread.start()

    def _accept_loop(self) -> None:
        while not self._stopped.is_set():
            try:
                conn, _ = self._sock.accept()
            except OSError:
                return  # listener socket closed under us: clean stop
            if self._stopped.is_set():
                # stop() raced our in-flight accept: never service a
                # channel after shutdown
                conn.close()
                return
            threading.Thread(target=self.handler, args=(TcpComm(conn),),
                             daemon=True).start()

    def stop(self) -> None:
        self._stopped.set()
        # close() alone does not abort a blocked accept() on Linux (the
        # in-flight syscall pins the socket, so the port keeps accepting);
        # shutdown() wakes it with an error immediately
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self._sock.close()
        except OSError:
            pass
        if self._thread is not None:
            self._thread.join(timeout=1.0)


class TcpConnector(Connector):
    def connect(self, address: str, timeout: float = 5.0) -> Comm:
        host, port = address.rsplit("://", 1)[-1].rsplit(":", 1)
        try:
            sock = socket.create_connection((host, int(port)),
                                            timeout=timeout)
        except OSError as e:
            raise CommClosedError(
                f"connect to {address} failed: {e}") from None
        sock.settimeout(None)
        return TcpComm(sock)


# ------------------------------------------------------------- child side


class _RelayEvent(threading.Event):
    """A poison event whose first local ``set()`` also tells the parent,
    which re-broadcasts it to every rank — the cross-process analogue of
    the in-proc world's single shared Event."""

    def __init__(self, notify):
        super().__init__()
        self._notify = notify

    def set(self) -> None:
        first = not self.is_set()
        super().set()
        if first:
            try:
                self._notify()
            except Exception:
                pass  # parent gone: local poison still unwinds this rank

    def set_local(self) -> None:
        super().set()


class _RpcClient:
    """Lock-serialized request/response channel to the parent-hosted
    scheduler service (see :mod:`repro.sched.proxy`)."""

    def __init__(self, port: int):
        self._comm = TcpConnector().connect(f"tcp://127.0.0.1:{port}",
                                            timeout=10.0)
        self._lock = threading.Lock()

    def call(self, target: str, method: str, *args, **kwargs):
        with self._lock:
            self._comm.write(("call", target, method, args, kwargs))
            status, payload = self._comm.read(timeout=60.0)
        if status == "ok":
            return payload
        raise RuntimeError(
            f"rpc {target}.{method} failed in the service process:\n"
            f"{payload}")


class MultiProcWorld:
    """The world contract, implemented by one child process for its own
    rank: local delay heap for inbound wires, lazy outbound channels,
    sender-side fault injection with the same per-edge RNG streams as the
    in-proc world (deterministic parity), and membership relayed through
    the parent control channel."""

    def __init__(self, rank: int, n_ranks: int, peers: Dict[int, str],
                 ctrl: TcpComm, delay_fn, faults, rpc_port: Optional[int]):
        self.rank = rank
        self.n_ranks = n_ranks
        self.delay_fn = delay_fn
        self.faults = faults
        self.report = RecoveryReport()
        self.dead: set = set()
        self.poison = _RelayEvent(self._relay_poison)
        self._peers = peers
        self._ctrl = ctrl
        self._listener: Optional[TcpListener] = None
        self._lock = threading.Lock()
        self._inbox: list = []
        self._order = itertools.count()
        self._conns: Dict[int, TcpComm] = {}
        self._conn_lock = threading.Lock()
        self._fault_lock = threading.Lock()
        self._user_sent = 0
        self._edge_rng: Dict[tuple, Any] = {}
        self._shutdown_flags = [False] * n_ranks
        self._fps: List[str] = []
        self._snapshot_fn = None
        self.svc_rpc = _RpcClient(rpc_port) if rpc_port is not None else None

    # --------------------------------------------------------- control plane

    def _ctrl_send(self, msg: tuple) -> None:
        try:
            self._ctrl.write(msg)
        except CommClosedError:
            # parent died: nothing to relay to; poison locally so this
            # rank unwinds instead of spinning in the protocol forever
            self.poison.set_local()

    def _relay_poison(self) -> None:
        self._ctrl_send(("poison",))

    def _handle_ctrl(self, msg: tuple) -> None:
        kind = msg[0]
        if kind == "peerdead":
            self.kill(msg[1])
        elif kind == "poison":
            self.poison.set_local()   # relay, not origin: don't echo back
        elif kind == "sdflag":
            self._shutdown_flags[msg[1]] = True
        elif kind == "snap?":
            self._ctrl_send(("snap", self.rank,
                             self.snapshot_rank(self.rank)))

    def _ctrl_loop(self) -> None:
        while True:
            try:
                msg = self._ctrl.read()
            except (CommClosedError, TimeoutError, Exception):
                self.poison.set_local()
                return
            self._handle_ctrl(msg)

    # ----------------------------------------------------------- fault hooks

    def check_dead_or_kill(self, src: int) -> bool:
        if src in self.dead:
            return True
        f = self.faults
        if f is None or src != self.rank or src not in f.kill:
            return False
        with self._fault_lock:
            self._user_sent += 1
            fire = self._user_sent >= f.kill[src] and src not in self.dead
        if fire:
            self.kill(src)
        return src in self.dead

    def kill(self, rank: int) -> None:
        """Local fence for ``rank`` (purge its inbound frames, flag its
        shutdown). Killing *this* rank additionally tells the parent,
        which broadcasts ``peerdead`` so every survivor fences it too —
        the cross-process version of the in-proc global kill."""
        with self._fault_lock:
            if rank in self.dead:
                return
            self.dead.add(rank)
        self._shutdown_flags[rank] = True
        with self._lock:
            if rank == self.rank:
                self._inbox.clear()
            else:
                kept = [item for item in self._inbox
                        if item[2].src != rank]
                if len(kept) != len(self._inbox):
                    heapq.heapify(kept)
                    self._inbox = kept
        if rank == self.rank:
            self._ctrl_send(("ikilled", rank))
            if self._listener is not None:
                self._listener.stop()
            with self._conn_lock:
                conns, self._conns = dict(self._conns), {}
            for c in conns.values():
                c.close()

    def flag_shutdown(self, rank: int) -> None:
        self._shutdown_flags[rank] = True
        if rank == self.rank:
            self._ctrl_send(("sdflag", rank))

    def all_shutdown(self) -> bool:
        return all(self._shutdown_flags)

    # ------------------------------------------------------------- transport

    def send(self, dst: int, wire: Wire) -> None:
        if wire.src in self.dead or dst in self.dead:
            return
        duplicate = False
        f = self.faults
        if f is not None and (f.drop or f.duplicate):
            with self._fault_lock:
                rng = self._edge_rng.get((wire.src, dst))
                if rng is None:
                    rng = self._edge_rng[(wire.src, dst)] = f.edge_rng(
                        wire.src, dst)
                dropped = rng.random() < f.drop
                duplicate = rng.random() < f.duplicate
            if dropped:
                self.report.bump("injected_drops")
                return
            if duplicate:
                self.report.bump("injected_dups")
        self._post(dst, wire)
        if duplicate:
            self._post(dst, wire)

    def _post(self, dst: int, wire: Wire) -> None:
        if dst == self.rank:
            self._ingest(wire)
            return
        try:
            self._conn(dst).write(wire)
        except CommClosedError:
            # crashed/closed peer: a dropped frame, the reliable layer's
            # retransmit owns recovery. Forget the conn so the next send
            # redials (the peer may just not be accepting *yet*).
            with self._conn_lock:
                self._conns.pop(dst, None)

    def _conn(self, dst: int) -> TcpComm:
        with self._conn_lock:
            c = self._conns.get(dst)
            if c is None or c.closed:
                c = self._conns[dst] = TcpConnector().connect(
                    self._peers[dst], timeout=5.0)
            return c

    def _ingest(self, wire: Wire) -> None:
        if wire.src in self.dead:
            return  # fenced: frames from a declared-dead rank never land
        delay = self.delay_fn(wire.src, self.rank, wire.kind) \
            if self.delay_fn else 0.0
        with self._lock:
            heapq.heappush(self._inbox, (time.monotonic() + delay,
                                         next(self._order), wire))

    def poll(self, rank: int) -> List[Wire]:
        now = time.monotonic()
        out: List[Wire] = []
        with self._lock:
            while self._inbox and self._inbox[0][0] <= now:
                wire = heapq.heappop(self._inbox)[2]
                if wire.src not in self.dead:
                    out.append(wire)
        return out

    def has_traffic(self, rank: int) -> bool:
        with self._lock:
            return bool(self._inbox)

    def register_fingerprint(self, rank: int, fp: str) -> int:
        """Registration order is per-rank deterministic, so the id is
        assigned locally; the parent cross-validates all ranks' orders
        and poisons the world on divergence (§II-B2, like in-proc)."""
        am_id = len(self._fps)
        self._fps.append(fp)
        self._ctrl_send(("reg", rank, am_id, fp))
        return am_id

    # ------------------------------------------------------------- forensics

    def attach_snapshot_provider(self, rank: int, fn) -> None:
        self._snapshot_fn = fn

    def snapshot_rank(self, rank: int):
        fn = self._snapshot_fn
        if fn is None:
            return None
        try:
            return fn()
        except Exception as e:
            return f"<snapshot failed: {e!r}>"


def _scrub_inherited_import_state() -> None:
    """Make the forked child's import machinery usable again.

    The parent may fork from a background thread (the scheduler service
    forks resident ranks from its drive thread) while *another* parent
    thread is mid-way through a lazy import — e.g. ``scipy.linalg`` inside
    ``cholesky_bodies_numpy``.  CPython resets the global import lock at
    fork but keeps the per-module ``_ModuleLock`` instances, so the child
    inherits locks owned by threads that do not exist here: the first
    unpickle that re-imports such a module (cloudpickle ``subimport``)
    blocks forever.  Drop half-initialized modules and every per-module
    lock; the child re-imports them cleanly on demand.
    """
    import importlib._bootstrap as _boot
    import sys
    initializing = [
        name for name, mod in sys.modules.items()
        if getattr(getattr(mod, "__spec__", None), "_initializing", False)
    ]
    popped = set(initializing)
    # an aborted package import leaves *completed* submodules behind
    # (e.g. ``jax.version`` inside a half-imported ``jax``); a re-import
    # of the parent then finds them cached and never rebinds them as
    # attributes on the fresh parent module — drop the whole subtree so
    # the re-import is fully fresh
    prefixes = tuple(n + "." for n in initializing)
    if prefixes:
        popped.update(n for n in sys.modules if n.startswith(prefixes))
    for name in popped:
        sys.modules.pop(name, None)
    if popped and os.environ.get("REPRO_MP_DEBUG"):
        print(f"[multiproc child] scrubbed {sorted(popped)}",
              file=sys.stderr, flush=True)
    _boot._module_locks.clear()


def _child_entry(rank: int, n_ranks: int, main, n_threads: int,
                 delay_fn, faults, ctrl_port: int,
                 rpc_port: Optional[int]) -> None:
    """Whole life of one rank process. Always exits via ``os._exit`` so no
    parent-inherited teardown (atexit hooks, XLA state) runs here."""
    ctrl = None
    try:
        _scrub_inherited_import_state()
        # debug aid: SIGUSR1 dumps every thread's stack to stderr, so a
        # wedged rank can be diagnosed from outside without a debugger
        import faulthandler
        import signal
        faulthandler.register(signal.SIGUSR1, all_threads=True)
        ctrl = TcpConnector().connect(f"tcp://127.0.0.1:{ctrl_port}",
                                      timeout=10.0)
        ctrl.write(("hello", rank))
        ready = threading.Event()
        cell: dict = {}

        def on_data(comm: Comm) -> None:
            ready.wait()
            world = cell["world"]
            while True:
                try:
                    wire = comm.read()
                except (CommClosedError, TimeoutError):
                    return
                world._ingest(wire)

        listener = TcpListener(on_data)
        listener.start()
        ctrl.write(("addr", rank, listener.address))
        # rendezvous: async relays (a sibling may already be failing) can
        # arrive before the peer map — buffer them for the world
        peers, early = None, []
        while peers is None:
            msg = ctrl.read(timeout=30.0)
            if msg[0] == "peers":
                peers = msg[1]
            else:
                early.append(msg)
        world = MultiProcWorld(rank, n_ranks, peers, ctrl, delay_fn,
                               faults, rpc_port)
        world._listener = listener
        cell["world"] = world
        ready.set()
        for msg in early:
            world._handle_ctrl(msg)
        threading.Thread(target=world._ctrl_loop, daemon=True,
                         name="ctrl").start()

        from .. import runtime as rt  # cached import: parent loaded it

        status, payload = rt.rank_session(world, rank, main, n_threads)
        if status == "error":
            payload = rt.format_rank_error(payload)
        try:
            ctrl.write(("result", rank, status, payload, world.report))
        except Exception as e:
            try:
                ctrl.write(("result", rank, "error",
                            f"rank {rank} result not picklable "
                            f"({type(payload).__name__}: {e!r})", None))
            except Exception:
                pass
    except BaseException:
        import sys
        import traceback
        tb = traceback.format_exc()
        print(f"[multiproc rank {rank}] {tb}", file=sys.stderr, flush=True)
        if ctrl is not None:
            try:
                ctrl.write(("result", rank, "error", tb, None))
            except Exception:
                pass
    finally:
        os._exit(0)


# ------------------------------------------------------------ parent side


class _RpcServer:
    """Parent-hosted dispatch onto the resident scheduler: children call
    ``svc``/``bus`` methods by name; exceptions travel back formatted."""

    def __init__(self, objs: Dict[str, object]):
        self._objs = objs
        self._listener = TcpListener(self._serve)
        self._listener.start()
        self.port = self._listener.port

    def _serve(self, comm: Comm) -> None:
        import traceback
        while True:
            try:
                _, target, method, args, kwargs = comm.read()
            except (CommClosedError, TimeoutError):
                return
            try:
                out = ("ok", getattr(self._objs[target], method)(
                    *args, **kwargs))
            except BaseException:
                out = ("err", traceback.format_exc())
            try:
                comm.write(out)
            except CommClosedError:
                return

    def stop(self) -> None:
        self._listener.stop()


class _ParentWorld:
    """What the resident scheduler sees as "the world" in the parent
    process: fault plan, membership mirror, poison mirror, and forensic
    snapshots served by the rank processes over their control channels."""

    def __init__(self, n_ranks: int, faults, state: "_ParentState"):
        self.n_ranks = n_ranks
        self.faults = faults
        self.report = RecoveryReport()
        self.poison = threading.Event()
        self.dead: set = set()
        self._state = state

    def attach_snapshot_provider(self, rank: int, fn) -> None:
        pass  # ranks live elsewhere; their processes serve snapshots

    def snapshot_rank(self, rank: int):
        return self._state.request_snapshot(rank)


class _ParentState:
    """Rendezvous + relay hub: one handler thread per child control
    channel (spawned by the listener), shared collection state here."""

    def __init__(self, n_ranks: int, faults):
        self.n_ranks = n_ranks
        self.lock = threading.Lock()
        self.comms: Dict[int, TcpComm] = {}
        self.addrs: Dict[int, str] = {}
        self.results: Dict[int, tuple] = {}   # rank -> (status, payload)
        self.reports: Dict[int, Optional[RecoveryReport]] = {}
        self.errors: List[tuple] = []         # (rank, formatted traceback)
        self.snaps: Dict[int, object] = {}
        self.all_addrs = threading.Event()
        self.all_results = threading.Event()
        self.snap_ev = threading.Event()
        self._fps: Dict[int, List[str]] = {}
        self.world = _ParentWorld(n_ranks, faults, self)

    # ---- broadcast & per-child serving

    def broadcast(self, msg: tuple) -> None:
        with self.lock:
            comms = list(self.comms.values())
        for c in comms:
            try:
                c.write(msg)
            except CommClosedError:
                pass  # that child is gone; its EOF path reports it

    def serve_child(self, comm: Comm) -> None:
        rank = None
        try:
            while True:
                msg = comm.read()
                kind = msg[0]
                if kind == "hello":
                    rank = msg[1]
                    with self.lock:
                        self.comms[rank] = comm
                elif kind == "addr":
                    with self.lock:
                        self.addrs[msg[1]] = msg[2]
                        if len(self.addrs) == self.n_ranks:
                            self.all_addrs.set()
                elif kind == "ikilled":
                    with self.lock:
                        self.world.dead.add(msg[1])
                    self.broadcast(("peerdead", msg[1]))
                elif kind == "poison":
                    self.world.poison.set()
                    self.broadcast(("poison",))
                elif kind == "sdflag":
                    self.broadcast(("sdflag", msg[1]))
                elif kind == "reg":
                    self._validate_fp(*msg[1:])
                elif kind == "snap":
                    with self.lock:
                        self.snaps[msg[1]] = msg[2]
                    self.snap_ev.set()
                elif kind == "result":
                    _, r, status, payload, report = msg
                    with self.lock:
                        self.results[r] = (status, payload)
                        self.reports[r] = report
                        if status == "error":
                            self.errors.append((r, payload))
                            self.world.poison.set()
                        if len(self.results) == self.n_ranks:
                            self.all_results.set()
                    return
        except (CommClosedError, TimeoutError):
            with self.lock:
                if rank is not None and rank not in self.results:
                    # died without reporting: a hard crash, not a planned
                    # kill (killed ranks still report "killed")
                    self.results[rank] = ("error", None)
                    self.errors.append((rank, (
                        f"rank {rank} process died without reporting "
                        "(control channel EOF)")))
                    self.world.poison.set()
                    if len(self.results) == self.n_ranks:
                        self.all_results.set()
            if rank is not None:
                self.broadcast(("poison",))

    def _validate_fp(self, rank: int, am_id: int, fp: str) -> None:
        with self.lock:
            self._fps.setdefault(rank, []).append(fp)
            for other, fps in self._fps.items():
                if other != rank and len(fps) > am_id \
                        and fps[am_id] != fp:
                    self.errors.append((rank, (
                        f"active messages registered in different orders: "
                        f"rank {rank} registered {fp!r} as id {am_id}, "
                        f"rank {other} has {fps[am_id]!r}")))
                    self.world.poison.set()
                    break
            else:
                return
        self.broadcast(("poison",))

    def request_snapshot(self, rank: int, timeout: float = 2.0):
        with self.lock:
            self.snaps.pop(rank, None)
            comm = self.comms.get(rank)
        if comm is None:
            return None
        self.snap_ev.clear()
        try:
            comm.write(("snap?",))
        except CommClosedError:
            return None
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            self.snap_ev.wait(timeout=0.05)
            with self.lock:
                if rank in self.snaps:
                    return self.snaps[rank]
        return None


def _merge_report(base: RecoveryReport,
                  parts: List[Optional[RecoveryReport]]) -> RecoveryReport:
    for rep in parts:
        if rep is None:
            continue
        for c in RecoveryReport._COUNTERS:
            setattr(base, c, getattr(base, c) + getattr(rep, c))
        for s in rep.suspects:
            if s not in base.suspects:
                base.suspects.append(s)
        for d in rep.deaths:
            if d not in base.deaths:
                base.deaths.append(d)
        for sh in rep.rederived_shards:
            if sh not in base.rederived_shards:
                base.rederived_shards.append(sh)
        if rep.total_edges is not None and base.total_edges is None:
            base.total_edges = rep.total_edges
        if rep.recovery_seconds is not None:
            base.recovery_seconds = max(base.recovery_seconds or 0.0,
                                        rep.recovery_seconds)
    return base


class MultiProcBackend(Backend):
    """Fork one process per rank; rendezvous, relay, and collect."""

    def listener(self, handler) -> Listener:
        return TcpListener(handler)

    def connector(self) -> Connector:
        return TcpConnector()

    def run_ranks(self, n_ranks: int, main, *, n_threads: int = 2,
                  delay_fn=None, faults=None, timeout: float = 120.0,
                  serve_scheduler=None):
        import multiprocessing

        if "fork" not in multiprocessing.get_all_start_methods():
            raise RuntimeError(
                "the multiproc transport needs the fork start method "
                "(main/_rank_main pass to children by inheritance); "
                "this platform has none")
        mp = multiprocessing.get_context("fork")
        state = _ParentState(n_ranks, faults)
        ctrl = TcpListener(state.serve_child)
        ctrl.start()
        rpc = None
        if serve_scheduler is not None:
            rpc = _RpcServer({"svc": serve_scheduler,
                              "bus": serve_scheduler.bus})
            serve_scheduler.attach_world(state.world)
        procs = []
        try:
            procs = [
                mp.Process(
                    target=_child_entry,
                    args=(r, n_ranks, main, n_threads, delay_fn, faults,
                          ctrl.port, rpc.port if rpc else None),
                    daemon=True, name=f"rank{r}")
                for r in range(n_ranks)
            ]
            for p in procs:
                p.start()
            if not state.all_addrs.wait(timeout=30.0):
                missing = [r for r in range(n_ranks)
                           if r not in state.addrs]
                raise RuntimeError(
                    f"multiproc rendezvous failed: no address from ranks "
                    f"{missing} within 30s")
            state.broadcast(("peers", dict(state.addrs)))
            if serve_scheduler is not None:
                while not serve_scheduler.draining.wait(timeout=0.25):
                    if state.world.poison.is_set() or state.errors:
                        break
            if not state.all_results.wait(timeout=timeout):
                with state.lock:
                    stuck = [r for r in range(n_ranks)
                             if r not in state.results]
                from .. import runtime as rt
                forensics = rt.timeout_forensics(stuck, state.world,
                                                 timeout)
                state.world.poison.set()
                state.broadcast(("poison",))
                raise TimeoutError(forensics)
        finally:
            for p in procs:
                p.join(timeout=5.0)
                if p.is_alive():
                    p.terminate()
            ctrl.stop()
            if rpc is not None:
                rpc.stop()
        with state.lock:
            errors = list(state.errors)
            results = [state.results.get(r, ("error", None))[1]
                       if state.results.get(r, ("", None))[0] == "ok"
                       else None for r in range(n_ranks)]
            reports = [state.reports.get(r) for r in range(n_ranks)]
        if errors:
            rank, tb = errors[0]
            raise RuntimeError(f"rank {rank} failed:\n{tb}")
        _merge_report(state.world.report, reports)
        if faults is not None:
            return results, state.world.report
        return results
