"""Pluggable active-message transports (see :mod:`repro.core.comm.core`).

Importing this package registers the built-in backends:

- ``inproc``    — threaded ranks in this process (the default);
- ``multiproc`` — one forked OS process per rank over loopback TCP.
"""

from .core import (Backend, Comm, CommClosedError, Connector, Listener,
                   Wire, backend_names, get_backend, register_backend)
from .inproc import InProcBackend, InProcWorld
from .multiproc import MultiProcBackend, MultiProcWorld

register_backend("inproc", InProcBackend())
register_backend("multiproc", MultiProcBackend())

__all__ = [
    "Backend", "Comm", "CommClosedError", "Connector", "Listener", "Wire",
    "backend_names", "get_backend", "register_backend",
    "InProcBackend", "InProcWorld", "MultiProcBackend", "MultiProcWorld",
]
