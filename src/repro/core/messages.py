"""One-sided active messages (§II-A2) over an in-process multi-rank world.

An **active message** (AM) is a pair ``(function, payload)``: sent from rank
*a* to rank *b*, the payload travels the network and on arrival the function
runs on *b* with the payload as arguments — the receiver never waits.

Semantics kept faithful to the paper:

- ``make_active_msg`` must be called in the *same order on every rank*; the
  registration index is the globally-consistent AM id used to look the
  function up on the receiver (§II-B2).
- ``send`` serializes the payload into a temporary buffer immediately, so
  caller arguments are reusable the moment ``send`` returns; it is
  thread-safe (any worker may send).
- **Large AMs** skip the temporary copy: the payload contains one
  :class:`view` sent "directly" plus regular args, with the three-callback
  contract — receiver-side buffer allocation, receiver-side processing, and
  a sender-side completion hook that fires when the sender buffer is
  reusable (here: when the transport ack arrives, since the buffer must stay
  live across retransmits).
- The communicator counts *queued* and *processed* user AMs (``q_r``,
  ``p_r``); protocol traffic (completion detection, acks, heartbeats,
  retransmits) is excluded, exactly as required by §II-B3 step 1.

The "network" is any registered comm backend's world (see
:mod:`repro.core.comm`): the default :class:`InProcWorld` keeps one inbox
per rank in-process with injectable per-message delivery delay and
reordering, and — via :class:`~repro.core.faults.FaultPlan` — message
loss, duplication, and rank kills, so the completion protocol can be
stress-tested adversarially; the ``multiproc`` world carries the same
wires between real OS processes over loopback TCP.

On top of the lossy wire the communicator runs a **reliable delivery
layer**: every non-ack message carries a per-``(src, dst)`` sequence number;
the receiver acks each seq (acks themselves are unreliable) and
deduplicates by ``(src, seq)`` with cumulative compaction; the sender keeps
an unacked window per destination and retransmits on an exponential
backoff, marking a destination SUSPECT after the retry budget (retransmits
then continue at the capped interval — only the failure detector may
*declare* a rank dead). Exactly-once accounting survives because ``q_r``
counts a user AM once at first queue and ``p_r`` once at first (post-dedup)
delivery; retransmits and duplicates touch neither counter.

Semantically each rank is one MPI rank; the mapping to a real cluster is
one process per node with the world's queues replaced by
MPI_Isend/Iprobe/Irecv (the paper's transport) — the reliability protocol
is transport-agnostic by construction: everything in this module programs
against the world contract documented in :mod:`repro.core.comm.core`.
"""

from __future__ import annotations

import itertools
import pickle
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Set

import numpy as np

from .comm import InProcWorld  # noqa: F401  (compat re-export)
from .comm import Wire as _Wire
from .faults import FaultPlan

# Transport-level kinds that are themselves the reliability mechanism and so
# ride the raw (lossy) wire without sequence numbers.
ACK, HEARTBEAT = "ACK", "HB"
_UNRELIABLE_KINDS = (ACK, HEARTBEAT)


class WorldPoisoned(RuntimeError):
    """Another rank failed; this rank aborts its join loop as a *victim*
    (its own work is not the root cause and is not reported as such)."""


class RankKilled(RuntimeError):
    """Raised inside a rank that a :class:`FaultPlan` killed mid-run."""


class view:
    """A (pointer, length) view over a contiguous buffer (paper's view<T>)."""

    def __init__(self, array):
        self.array = np.asarray(array)

    def __len__(self) -> int:
        return self.array.size


class ActiveMsg:
    """Handle returned by ``Communicator.make_active_msg`` (paper's am->send)."""

    def __init__(self, comm: "Communicator", am_id: int, large: bool):
        self._comm = comm
        self.am_id = am_id
        self.large = large

    def send(self, dest: int, *args) -> None:
        self._comm._send_am(self, dest, args)

    # paper examples use `am->send(...)`; both spellings provided
    __call__ = send


class _SeqSeen:
    """Receiver-side dedup state for one source: every seq <= ``cum`` has
    been delivered, plus the out-of-order set ``extra`` (compacted)."""

    __slots__ = ("cum", "extra")

    def __init__(self):
        self.cum = -1
        self.extra: Set[int] = set()

    def first_delivery(self, seq: int) -> bool:
        if seq <= self.cum or seq in self.extra:
            return False
        self.extra.add(seq)
        while self.cum + 1 in self.extra:
            self.cum += 1
            self.extra.discard(self.cum)
        return True


@dataclass
class _Pending:
    """One unacked reliable message at the sender."""

    wire: _Wire
    attempts: int = 0
    due: float = 0.0
    on_ack: Optional[Callable[[], None]] = None


class Communicator:
    """AM factory + transport endpoint for one rank (paper's Communicator).

    Maintains the three queues of §II-B2 (ready-to-send / in-flight sends /
    received-to-run); with the in-process transport the in-flight-send queue
    is the per-destination unacked window of the reliable layer, and a large
    AM's sender-completion callback fires when its ack arrives.
    """

    # retry schedule used when no FaultPlan overrides it
    _RETRY_BASE = 0.05
    _RETRY_BUDGET = 10
    _RETRY_CAP = 0.5

    def __init__(self, world: InProcWorld, rank: int):
        self.world = world
        self.rank = rank
        self.n_ranks = world.n_ranks
        self._registry: List[dict] = []
        self._send_lock = threading.Lock()
        # Monotone counters over *user* AMs only (q_r / p_r of §II-B3),
        # plus per-peer splits so counts attributable to a dead rank can be
        # excluded after a death declaration (epoch-fenced; see completion).
        self.queued_count = 0
        self.processed_count = 0
        self.queued_to = [0] * self.n_ranks
        self.processed_from = [0] * self.n_ranks
        self._adjust_q = 0
        self._adjust_p = 0
        self._counted_dead: Set[int] = set()
        # reliable layer state
        self._next_seq: Dict[int, Any] = {
            d: itertools.count() for d in range(self.n_ranks)}
        self._pending: Dict[int, Dict[int, _Pending]] = {
            d: {} for d in range(self.n_ranks)}
        self._seen: Dict[int, _SeqSeen] = {
            s: _SeqSeen() for s in range(self.n_ranks)}
        self.suspected: Set[int] = set()
        f = world.faults
        self._retry_base = f.retry_base if f else self._RETRY_BASE
        self._retry_budget = f.retry_budget if f else self._RETRY_BUDGET
        self._last_hb = 0.0
        self._tp = None
        self._detector = None  # attached by runtime for distributed join
        # recovery hook: called as on_reconfigure(newly_dead, assignment,
        # epoch) from the progress thread when a death is applied
        self.on_reconfigure: Optional[Callable] = None
        self.shutdown = threading.Event()

    # ----------------------------------------------------------- factories

    def make_active_msg(self, fn: Callable[..., None]) -> ActiveMsg:
        am_id = self.world.register_fingerprint(self.rank, f"am:{fn.__name__}")
        self._registry.append({"fn": fn, "large": False})
        return ActiveMsg(self, am_id, large=False)

    def make_large_active_msg(
        self,
        fn: Callable[..., None],
        alloc: Callable[..., np.ndarray],
        complete: Callable[[], None],
    ) -> ActiveMsg:
        """Large AM (§II-A2a): ``alloc(*args)`` returns the receiver buffer the
        view is stored into (zero extra copy); ``fn(*args)`` processes it after
        arrival; ``complete()`` runs on the *sender* once its buffer is
        reusable — i.e. when the transport ack arrives, since the buffer may
        be retransmitted until then."""
        am_id = self.world.register_fingerprint(self.rank, f"lam:{fn.__name__}")
        self._registry.append({"fn": fn, "large": True, "alloc": alloc,
                               "complete": complete})
        return ActiveMsg(self, am_id, large=True)

    # -------------------------------------------------------------- sending

    def _send_am(self, am: ActiveMsg, dest: int, args: Sequence[Any]) -> None:
        views = [a for a in args if isinstance(a, view)]
        plain = tuple(a for a in args if not isinstance(a, view))
        if am.large:
            if len(views) != 1:
                raise ValueError("a large AM payload must contain exactly one view")
            raw = views[0].array  # sent directly — no temporary copy
        else:
            if views:
                # Regular AMs serialize everything (copy) — views included.
                plain = tuple(a.array.copy() if isinstance(a, view) else a
                              for a in args)
            raw = None
        blob = pickle.dumps(plain)  # the paper's temporary serialization buffer
        if self.world.check_dead_or_kill(self.rank):
            raise RankKilled(f"rank {self.rank} killed by fault plan")
        with self._send_lock:
            if dest in self.world.dead:
                return  # fenced: never counted, never delivered
            self.queued_count += 1
            self.queued_to[dest] += 1
            wire = _Wire("large_am" if am.large else "am",
                         self.rank, am.am_id, blob, raw)
            on_ack = self._registry[am.am_id]["complete"] if am.large else None
            self._post_reliable(dest, wire, on_ack)

    def protocol_send(self, dest: int, kind: str, meta: Any) -> None:
        """Completion-protocol traffic — excluded from q/p counts, but
        riding the reliable layer (COUNT/REQUEST/... must survive loss)."""
        with self._send_lock:
            if self.rank in self.world.dead or dest in self.world.dead:
                return
            self._post_reliable(dest, _Wire(kind, self.rank, meta=meta), None)

    def _post_reliable(self, dest: int, wire: _Wire,
                       on_ack: Optional[Callable]) -> None:
        """Assign a seq, record the unacked entry, first transmission.
        Caller holds ``_send_lock``."""
        wire.seq = next(self._next_seq[dest])
        self._pending[dest][wire.seq] = _Pending(
            wire, attempts=0, due=time.monotonic() + self._retry_base,
            on_ack=on_ack)
        self.world.send(dest, wire)

    def _post_raw(self, dest: int, kind: str, meta: Any) -> None:
        """Unsequenced transport traffic (acks, heartbeats)."""
        self.world.send(dest, _Wire(kind, self.rank, meta=meta))

    # ------------------------------------------------------------- recovery

    def drop_rank_counts(self, newly_dead: Sequence[int]) -> None:
        """A death was declared: stop attributing traffic to the dead ranks.
        Counter splits are frozen (the world fence stops post-death sends
        before they are counted), so the one-shot adjustment here keeps the
        *effective* counts consistent over the survivor set. Unacked sends
        to the dead are abandoned (their large-AM buffers are reusable —
        nothing will retransmit them)."""
        callbacks: List[Callable] = []
        with self._send_lock:
            for d in newly_dead:
                if d in self._counted_dead:
                    continue
                self._counted_dead.add(d)
                self._adjust_q += self.queued_to[d]
                self._adjust_p += self.processed_from[d]
                abandoned = self._pending.get(d, {})
                self._pending[d] = {}
                self.suspected.discard(d)
                callbacks.extend(p.on_ack for p in abandoned.values()
                                 if p.on_ack)
        for cb in callbacks:
            cb()

    def effective_counts(self):
        """(q, p) over the *current survivor set* — raw monotone counters
        minus everything queued-to / processed-from declared-dead ranks."""
        with self._send_lock:
            return (self.queued_count - self._adjust_q,
                    self.processed_count - self._adjust_p)

    # ------------------------------------------------------------- progress

    def attach_threadpool(self, tp) -> None:
        self._tp = tp

    def attach_detector(self, detector) -> None:
        self._detector = detector

    def _maybe_heartbeat(self) -> None:
        f = self.world.faults
        if f is None or self._detector is None or self.rank == 0:
            return
        now = time.monotonic()
        if now - self._last_hb >= f.heartbeat_every:
            self._last_hb = now
            self._post_raw(0, HEARTBEAT, None)

    def _retransmit_due(self) -> None:
        now = time.monotonic()
        resend: List[_Wire] = []
        dests: List[int] = []
        with self._send_lock:
            for dst, pend in self._pending.items():
                if not pend or dst in self.world.dead:
                    continue
                for p in pend.values():
                    if p.due > now:
                        continue
                    p.attempts += 1
                    if p.attempts >= self._retry_budget and \
                            dst not in self.suspected:
                        # budget exhausted: report, keep retrying at the cap
                        # (only the failure detector declares death)
                        self.suspected.add(dst)
                        self.world.report.note_suspect(dst)
                    p.due = now + min(self._retry_base * (2 ** p.attempts),
                                      self._RETRY_CAP)
                    resend.append(p.wire)
                    dests.append(dst)
        for dst, wire in zip(dests, resend):
            self.world.report.bump("retries")
            self.world.send(dst, wire)

    def _on_ack(self, src: int, seq: int) -> None:
        with self._send_lock:
            p = self._pending.get(src, {}).pop(seq, None)
            self.suspected.discard(src)
        if p is not None and p.on_ack is not None:
            p.on_ack()  # large-AM sender buffer is reusable now

    def progress(self, *, transport_only: bool = False) -> None:
        """One progress step of the main/MPI thread (§II-B2)."""
        self._maybe_heartbeat()
        self._retransmit_due()
        for wire in self.world.poll(self.rank):
            if self._detector is not None:
                # any traffic from a rank is proof of life, not just HBs
                self._detector.on_heartbeat(wire.src)
            if wire.kind == ACK:
                self._on_ack(wire.src, wire.meta)
                continue
            if wire.kind == HEARTBEAT:
                if self._detector is not None:
                    self._detector.on_heartbeat(wire.src)
                continue
            if wire.seq >= 0:
                # reliable delivery: always ack (acks are idempotent), then
                # drop anything already delivered — retransmits and injected
                # duplicates alike never reach the counters twice
                self._post_raw(wire.src, ACK, wire.seq)
                if not self._seen[wire.src].first_delivery(wire.seq):
                    self.world.report.bump("dup_suppressed")
                    continue
            if wire.kind == "am":
                if transport_only:
                    raise RuntimeError(
                        "user AM arrived after local shutdown linger began")
                entry = self._registry[wire.am_id]
                entry["fn"](*pickle.loads(wire.blob))
                self.processed_count += 1
                self.processed_from[wire.src] += 1
            elif wire.kind == "large_am":
                if transport_only:
                    raise RuntimeError(
                        "user AM arrived after local shutdown linger began")
                entry = self._registry[wire.am_id]
                args = pickle.loads(wire.blob)
                buf = entry["alloc"](*args)
                np.copyto(np.asarray(buf).reshape(-1), wire.raw.reshape(-1))
                entry["fn"](*args)
                self.processed_count += 1
                self.processed_from[wire.src] += 1
            else:
                self._detector.on_message(wire)

    def poll_failure_detector(self) -> None:
        """Drive the attached detector's failure half only (lease checks and
        DEATH declaration) — the resident scheduler's between-submissions
        heartbeat of the membership protocol, with the quiescence rounds
        deliberately left to the final ``tp.join()``."""
        if self._detector is not None:
            self._detector.poll_failures()

    def worker_idle(self) -> bool:
        return self._tp is None or self._tp.quiescent()

    def run_until_shutdown(self) -> None:
        """Main-thread loop: progress + completion detection until SHUTDOWN,
        then an ack linger so no peer is left retransmitting into the void."""
        if self._detector is None:
            # Single-rank shared-memory mode: local quiescence == completion.
            while not (self.worker_idle() and not self._has_traffic()):
                self.progress()
                time.sleep(20e-6)
            self.shutdown.set()
            return
        while not self.shutdown.is_set():
            if self.world.poison.is_set():
                raise WorldPoisoned("world poisoned: another rank failed")
            if self.rank in self.world.dead:
                raise RankKilled(f"rank {self.rank} killed by fault plan")
            self.progress()
            self._detector.step()
            time.sleep(10e-6)
        self._drain_shutdown()

    def _drain_shutdown(self) -> None:
        """Post-SHUTDOWN linger: quiescence is proven, but transport-level
        traffic (acks for our last sends, retransmits from peers whose acks
        were lost) may still be in flight. Keep acking/retransmitting until
        every rank has flagged that its unacked window is empty; a rank that
        stopped cold here would leave peers retrying into the void until
        their budgets exhausted."""
        flagged = False
        while True:
            if self.world.poison.is_set():
                return
            self.progress(transport_only=True)
            if not flagged and not self._has_unacked():
                self.world.flag_shutdown(self.rank)
                flagged = True
            if flagged and self.world.all_shutdown():
                return
            time.sleep(20e-6)

    def _has_unacked(self) -> bool:
        with self._send_lock:
            return any(pend and dst not in self.world.dead
                       for dst, pend in self._pending.items())

    def _has_traffic(self) -> bool:
        return self.world.has_traffic(self.rank)

    # ---------------------------------------------------------- diagnostics

    def snapshot(self) -> dict:
        """Last-known protocol state, for timeout forensics."""
        with self._send_lock:
            unacked = {d: len(p) for d, p in self._pending.items() if p}
        q, p = self.effective_counts()
        snap = {
            "rank": self.rank,
            "queued": self.queued_count,
            "processed": self.processed_count,
            "effective_q": q,
            "effective_p": p,
            "unacked": unacked,
            "suspected": sorted(self.suspected),
            "worker_quiescent": self.worker_idle(),
            "shutdown": self.shutdown.is_set(),
        }
        if self._detector is not None:
            snap["detector"] = self._detector.snapshot()
        return snap
