"""One-sided active messages (§II-A2) over an in-process multi-rank world.

An **active message** (AM) is a pair ``(function, payload)``: sent from rank
*a* to rank *b*, the payload travels the network and on arrival the function
runs on *b* with the payload as arguments — the receiver never waits.

Semantics kept faithful to the paper:

- ``make_active_msg`` must be called in the *same order on every rank*; the
  registration index is the globally-consistent AM id used to look the
  function up on the receiver (§II-B2).
- ``send`` serializes the payload into a temporary buffer immediately, so
  caller arguments are reusable the moment ``send`` returns; it is
  thread-safe (any worker may send).
- **Large AMs** skip the temporary copy: the payload contains one
  :class:`view` sent "directly" plus regular args, with the three-callback
  contract — receiver-side buffer allocation, receiver-side processing, and
  a sender-side completion hook that fires when the sender buffer is
  reusable.
- The communicator counts *queued* and *processed* user AMs (``q_r``,
  ``p_r``); protocol traffic (completion detection) is excluded, exactly as
  required by §II-B3 step 1.

The "network" here is :class:`InProcWorld`: one inbox per rank, with
injectable per-message delivery delay and reordering so the completion
protocol can be stress-tested adversarially. Semantically each rank is one
MPI rank; the mapping to a real cluster is one process per node with this
module's queues replaced by MPI_Isend/Iprobe/Irecv (the paper's transport).
"""

from __future__ import annotations

import heapq
import itertools
import pickle
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, List, Optional, Sequence

import numpy as np


class view:
    """A (pointer, length) view over a contiguous buffer (paper's view<T>)."""

    def __init__(self, array):
        self.array = np.asarray(array)

    def __len__(self) -> int:
        return self.array.size


@dataclass
class _Wire:
    """One message on the wire."""

    kind: str          # "am" | "large_am" | protocol kinds
    src: int
    am_id: int = -1
    blob: bytes = b""          # pickled regular args
    raw: Optional[np.ndarray] = None  # large-AM view payload (no copy)
    meta: Any = None           # protocol payload


class InProcWorld:
    """Per-rank inboxes + optional adversarial delivery (delay / reorder)."""

    def __init__(self, n_ranks: int, delay_fn: Optional[Callable[..., float]] = None):
        self.n_ranks = n_ranks
        self.delay_fn = delay_fn
        # Set when any rank dies: every other rank aborts instead of waiting
        # forever inside the completion protocol.
        self.poison = threading.Event()
        self._locks = [threading.Lock() for _ in range(n_ranks)]
        # Each inbox is a heap of (deliver_at, seq, wire).
        self._inboxes: List[list] = [[] for _ in range(n_ranks)]
        self._seq = itertools.count()
        self._fingerprints: List[list] = [[] for _ in range(n_ranks)]

    def send(self, dst: int, wire: _Wire) -> None:
        delay = self.delay_fn(wire.src, dst, wire.kind) if self.delay_fn else 0.0
        deliver_at = time.monotonic() + delay
        with self._locks[dst]:
            heapq.heappush(self._inboxes[dst], (deliver_at, next(self._seq), wire))

    def poll(self, rank: int) -> List[_Wire]:
        """Pop every message whose delivery time has arrived."""
        now = time.monotonic()
        out: List[_Wire] = []
        with self._locks[rank]:
            inbox = self._inboxes[rank]
            while inbox and inbox[0][0] <= now:
                out.append(heapq.heappop(inbox)[2])
        return out

    def register_fingerprint(self, rank: int, fp: str) -> int:
        """Record AM registration order; verify global consistency (§II-B2)."""
        fps = self._fingerprints[rank]
        am_id = len(fps)
        fps.append(fp)
        for other in range(self.n_ranks):
            others = self._fingerprints[other]
            if len(others) > am_id and others[am_id] != fp:
                raise RuntimeError(
                    f"active messages registered in different orders: rank {rank} "
                    f"registered {fp!r} as id {am_id}, rank {other} has {others[am_id]!r}"
                )
        return am_id


class ActiveMsg:
    """Handle returned by ``Communicator.make_active_msg`` (paper's am->send)."""

    def __init__(self, comm: "Communicator", am_id: int, large: bool):
        self._comm = comm
        self.am_id = am_id
        self.large = large

    def send(self, dest: int, *args) -> None:
        self._comm._send_am(self, dest, args)

    # paper examples use `am->send(...)`; both spellings provided
    __call__ = send


class Communicator:
    """AM factory + transport endpoint for one rank (paper's Communicator).

    Maintains the three queues of §II-B2 (ready-to-send / in-flight sends /
    received-to-run); with the in-process transport the in-flight-send queue
    collapses to the sender-completion callback list for large AMs.
    """

    def __init__(self, world: InProcWorld, rank: int):
        self.world = world
        self.rank = rank
        self.n_ranks = world.n_ranks
        self._registry: List[dict] = []
        self._send_lock = threading.Lock()
        # Monotone counters over *user* AMs only (q_r / p_r of §II-B3).
        self.queued_count = 0
        self.processed_count = 0
        self._pending_sender_callbacks: List[Callable[[], None]] = []
        self._tp = None
        self._detector = None  # attached by runtime for distributed join
        self.shutdown = threading.Event()

    # ----------------------------------------------------------- factories

    def make_active_msg(self, fn: Callable[..., None]) -> ActiveMsg:
        am_id = self.world.register_fingerprint(self.rank, f"am:{fn.__name__}")
        self._registry.append({"fn": fn, "large": False})
        return ActiveMsg(self, am_id, large=False)

    def make_large_active_msg(
        self,
        fn: Callable[..., None],
        alloc: Callable[..., np.ndarray],
        complete: Callable[[], None],
    ) -> ActiveMsg:
        """Large AM (§II-A2a): ``alloc(*args)`` returns the receiver buffer the
        view is stored into (zero extra copy); ``fn(*args)`` processes it after
        arrival; ``complete()`` runs on the *sender* once its buffer is
        reusable."""
        am_id = self.world.register_fingerprint(self.rank, f"lam:{fn.__name__}")
        self._registry.append({"fn": fn, "large": True, "alloc": alloc,
                               "complete": complete})
        return ActiveMsg(self, am_id, large=True)

    # -------------------------------------------------------------- sending

    def _send_am(self, am: ActiveMsg, dest: int, args: Sequence[Any]) -> None:
        views = [a for a in args if isinstance(a, view)]
        plain = tuple(a for a in args if not isinstance(a, view))
        if am.large:
            if len(views) != 1:
                raise ValueError("a large AM payload must contain exactly one view")
            raw = views[0].array  # sent directly — no temporary copy
        else:
            if views:
                # Regular AMs serialize everything (copy) — views included.
                plain = tuple(a.array.copy() if isinstance(a, view) else a
                              for a in args)
            raw = None
        blob = pickle.dumps(plain)  # the paper's temporary serialization buffer
        with self._send_lock:
            self.queued_count += 1
            self.world.send(dest, _Wire("large_am" if am.large else "am",
                                        self.rank, am.am_id, blob, raw))
            if am.large:
                entry = self._registry[am.am_id]
                self._pending_sender_callbacks.append(entry["complete"])

    def protocol_send(self, dest: int, kind: str, meta: Any) -> None:
        """Completion-protocol traffic — excluded from q/p counts."""
        self.world.send(dest, _Wire(kind, self.rank, meta=meta))

    # ------------------------------------------------------------- progress

    def attach_threadpool(self, tp) -> None:
        self._tp = tp

    def attach_detector(self, detector) -> None:
        self._detector = detector

    def progress(self) -> None:
        """One progress step of the main/MPI thread (§II-B2)."""
        # Sender-side completions ("MPI_Test succeeded").
        callbacks, self._pending_sender_callbacks = (
            self._pending_sender_callbacks, [])
        for cb in callbacks:
            cb()
        for wire in self.world.poll(self.rank):
            if wire.kind == "am":
                entry = self._registry[wire.am_id]
                entry["fn"](*pickle.loads(wire.blob))
                self.processed_count += 1
            elif wire.kind == "large_am":
                entry = self._registry[wire.am_id]
                args = pickle.loads(wire.blob)
                buf = entry["alloc"](*args)
                np.copyto(np.asarray(buf).reshape(-1), wire.raw.reshape(-1))
                entry["fn"](*args)
                self.processed_count += 1
            else:
                self._detector.on_message(wire)

    def worker_idle(self) -> bool:
        return self._tp is None or self._tp.quiescent()

    def run_until_shutdown(self) -> None:
        """Main-thread loop: progress + completion detection until SHUTDOWN."""
        if self._detector is None:
            # Single-rank shared-memory mode: local quiescence == completion.
            while not (self.worker_idle() and not self._has_traffic()):
                self.progress()
                time.sleep(20e-6)
            self.shutdown.set()
            return
        while not self.shutdown.is_set():
            if self.world.poison.is_set():
                raise RuntimeError("world poisoned: another rank failed")
            self.progress()
            self._detector.step()
            time.sleep(10e-6)

    def _has_traffic(self) -> bool:
        with self.world._locks[self.rank]:
            return bool(self.world._inboxes[self.rank])
