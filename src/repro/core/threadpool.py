"""Work-stealing threadpool — the shared-memory half of TaskTorrent.

Faithful to §II-B1 of the paper:

- each worker thread owns *two* priority queues of ready tasks — one for
  tasks *bound* to the thread and one for *stealable* tasks;
- the queues are lock-protected so any thread may insert into any queue;
- an idle worker first drains its own queues, then attempts to steal the
  highest-priority stealable task from another worker;
- ``join()`` returns once every worker is idle and (when a
  :class:`~repro.core.messages.Communicator` is attached) the distributed
  completion protocol has established global quiescence.
"""

from __future__ import annotations

import heapq
import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

# Worker-thread identity, set once per worker; consumed by Taskflow to decide
# whether a dependency decrement may run in-place (owner thread) or must be
# routed. Correct under work stealing (identity is the *executing* thread).
_tls = threading.local()


def current_thread_id() -> Optional[int]:
    return getattr(_tls, "thread_id", None)


@dataclass(order=True)
class Task:
    """A ready-to-run task. Ordered by (-priority, seq): max-priority first."""

    sort_key: tuple = field(init=False, repr=False)
    run: Callable[[], Any] = field(compare=False)
    priority: float = field(default=0.0, compare=False)
    name: str = field(default="", compare=False)

    _seq = itertools.count()

    def __post_init__(self) -> None:
        # Negate priority: heapq is a min-heap, the paper uses max-priority.
        self.sort_key = (-self.priority, next(Task._seq))


class _WorkerQueues:
    """The two per-thread priority queues (bound + stealable) of §II-B1."""

    def __init__(self) -> None:
        self.lock = threading.Lock()
        self.bound: list[Task] = []
        self.stealable: list[Task] = []

    def push(self, task: Task, bound: bool) -> None:
        with self.lock:
            heapq.heappush(self.bound if bound else self.stealable, task)

    def pop_local(self) -> Optional[Task]:
        """Pop the highest-priority task across both queues (owner thread)."""
        with self.lock:
            pick_bound = bool(self.bound) and (
                not self.stealable or self.bound[0] < self.stealable[0]
            )
            if pick_bound:
                return heapq.heappop(self.bound)
            if self.stealable:
                return heapq.heappop(self.stealable)
            return None

    def steal(self) -> Optional[Task]:
        """Pop the highest-priority *stealable* task (foreign thread)."""
        with self.lock:
            if self.stealable:
                return heapq.heappop(self.stealable)
            return None


class Threadpool:
    """A fixed set of worker threads receiving and processing :class:`Task`s.

    Mirrors the paper's ``Threadpool tp(n_threads, &comm)``.  When ``comm`` is
    given, ``join()`` uses the distributed completion protocol (§II-B3) to
    decide termination; otherwise local quiescence (zero in-flight tasks)
    suffices.

    ``start=False`` reproduces the paper's micro-benchmark setup where task
    insertion happens before ``tp.start()`` so insertion time can be excluded
    from the measurement.
    """

    def __init__(self, n_threads: int, comm=None, *, start: bool = True):
        if n_threads < 1:
            raise ValueError("need at least one worker thread")
        self.n_threads = n_threads
        self.comm = comm
        self._queues = [_WorkerQueues() for _ in range(n_threads)]
        self._started = threading.Event()
        self._shutdown = threading.Event()
        # in-flight = queued-but-not-finished tasks; quiescent <=> 0.
        self._inflight = 0
        self._inflight_lock = threading.Lock()
        self._tasks_run = 0
        self._steals = 0
        self._errors: list[BaseException] = []
        self._threads = [
            threading.Thread(target=self._worker, args=(i,), daemon=True)
            for i in range(n_threads)
        ]
        if comm is not None:
            comm.attach_threadpool(self)
        for t in self._threads:
            t.start()
        if start:
            self.start()

    # ------------------------------------------------------------------ API

    def start(self) -> None:
        self._started.set()

    def insert(self, task: Task, thread: int, *, bound: bool = False) -> None:
        """Insert a ready task into ``thread``'s queue (any thread may call)."""
        with self._inflight_lock:
            self._inflight += 1
        self._queues[thread % self.n_threads].push(task, bound)

    def join(self) -> None:
        """Block until quiescent (and, distributed, globally complete)."""
        self._started.set()
        if self.comm is not None:
            # Distributed: the communicator's progress loop runs the
            # completion protocol; it flips `_shutdown` on SHUTDOWN.
            self.comm.run_until_shutdown()
        else:
            while not self.quiescent():
                time.sleep(50e-6)
        self._shutdown.set()
        for t in self._threads:
            t.join()
        if self._errors:
            raise self._errors[0]

    def abort(self) -> None:
        """Hard-stop for a crashing rank (fault-plan kill or poisoned
        world): discard every queued task and release the workers. The
        in-flight accounting is deliberately left inconsistent — nobody
        joins an aborted pool."""
        self._shutdown.set()
        self._started.set()
        for q in self._queues:
            with q.lock:
                q.bound.clear()
                q.stealable.clear()

    def quiescent(self) -> bool:
        """True iff no task is queued or running on this rank."""
        with self._inflight_lock:
            return self._inflight == 0

    @property
    def stats(self) -> dict:
        return {"tasks_run": self._tasks_run, "steals": self._steals}

    # --------------------------------------------------------------- worker

    def _next_task(self, me: int) -> Optional[Task]:
        task = self._queues[me].pop_local()
        if task is not None:
            return task
        # Work stealing: scan other workers' stealable queues.
        for off in range(1, self.n_threads):
            task = self._queues[(me + off) % self.n_threads].steal()
            if task is not None:
                self._steals += 1
                return task
        return None

    def _worker(self, me: int) -> None:
        _tls.thread_id = me
        self._started.wait()
        idle_spins = 0
        while True:
            task = self._next_task(me)
            if task is None:
                if self._shutdown.is_set():
                    return
                idle_spins += 1
                # Exponential-ish backoff; keeps the GIL available.
                time.sleep(20e-6 if idle_spins < 100 else 200e-6)
                continue
            idle_spins = 0
            try:
                task.run()
            except BaseException as e:  # surfaced at join()
                self._errors.append(e)
            finally:
                self._tasks_run += 1
                with self._inflight_lock:
                    self._inflight -= 1
