"""Parallel distributed DAG discovery from a PTG — the compiled-layer analogue
of TaskTorrent's "the DAG is discovered piece by piece, in parallel" (§I-C).

On the host runtime, a task materializes when its first dependency is
fulfilled and discovery flows along edges via active messages. Here we run
the *same* message-driven discovery symbolically, shard by shard:

- each shard expands only the frontier of tasks *mapped to it*;
- a cross-shard out-dependency emits a **discovery message** (the trace-time
  stand-in for the AM that would carry the payload at runtime);
- remote tasks enter a shard's frontier only when such a message arrives.

No shard ever enumerates the global index space: the per-shard work is
O(local tasks + halo edges) — the paper's scalability property, checked by
`test_discovery_locality`. The output is a :class:`WavefrontSchedule`:
per-shard task lists leveled into wavefronts plus a batched communication
plan (cross-shard edges fused per (wavefront, src, dst) — the compiled
analogue of the paper's large-AM copy-avoidance).

Two edge oracles drive the same loop:

- :func:`discover` — a global :class:`PTG` (eagerly derived edge dicts or
  hand-written edge rules);
- :func:`discover_local` — per-shard *lazy views*
  (:meth:`repro.ptg.Graph.derive_local`), each holding edges only for its
  owned tasks + halo, so the full derivation also never materializes the
  global graph (see docs/architecture.md). :func:`union_ptg` is the
  PTG-protocol facade over such views for consumers that must follow an
  edge to its remote endpoint (consistency checks, lowering tables).
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

K = Hashable


@dataclass(frozen=True)
class PTG:
    """A parametrized task graph with statically queryable edges.

    ``in_deps(k)``  — tasks k depends on (the static counterpart of
                      ``indegree``: ``indegree(k) == len(in_deps(k))``);
    ``out_deps(k)`` — tasks whose promises k fulfills;
    ``mapping(k)``  — shard owning k (the distributed mapping; the paper's
                      per-thread mapping becomes per-chip);
    ``type_of(k)``  — task-type tag (selects the compute body at lowering).
    """

    in_deps: Callable[[K], Sequence[K]]
    out_deps: Callable[[K], Sequence[K]]
    mapping: Callable[[K], int]
    type_of: Callable[[K], str] = lambda k: "task"

    def check_consistency(self, sample_keys: Sequence[K]) -> int:
        """Check the PTG contract on ``sample_keys``: ``in_deps``/``out_deps``
        are mutual inverses and ``mapping`` is stable (pure).

        A hand-written spec whose ``out_deps`` forgets an edge that
        ``in_deps`` declares silently drops the message that would carry the
        payload — the consumer just never runs (or reads garbage). Graphs
        built with :mod:`repro.ptg` satisfy this by construction; this check
        gives hand-written specs the same guarantee. Returns the number of
        edges verified; raises ``ValueError`` naming the first broken edge.
        """
        checked = 0
        for k in sample_keys:
            if self.mapping(k) != self.mapping(k):
                raise ValueError(
                    f"mapping({k!r}) is unstable across calls; the runtime "
                    "would route fulfillments to different shards")
            ins = list(self.in_deps(k))
            if [repr(d) for d in ins] != [repr(d) for d in self.in_deps(k)]:
                raise ValueError(f"in_deps({k!r}) is unstable across calls")
            for d in ins:
                if not any(o == k for o in self.out_deps(d)):
                    raise ValueError(
                        f"in_deps({k!r}) contains {d!r} but out_deps({d!r}) "
                        f"does not contain {k!r}: the producer would never "
                        "fulfill (or send to) this task — its promise, and "
                        "any payload riding it, is silently dropped")
                checked += 1
            for d in self.out_deps(k):
                if not any(i == k for i in self.in_deps(d)):
                    raise ValueError(
                        f"out_deps({k!r}) contains {d!r} but in_deps({d!r}) "
                        f"does not contain {k!r}: the fulfillment would "
                        "over-decrement the consumer's dependency counter")
                checked += 1
        return checked


@dataclass
class Message:
    """A discovery/communication edge crossing shards: produced by ``src_task``
    on shard ``src`` at its wavefront, consumed by ``dst_task`` on ``dst``."""

    src: int
    dst: int
    src_task: K
    dst_task: K
    level: int = -1  # producer wavefront


@dataclass
class ShardSchedule:
    """One shard's discovered schedule: ``wavefronts[level]`` lists the
    tasks this shard runs at that level, in discovery order. ``expanded``
    counts the fulfill events the shard processed — the locality metric:
    it is O(owned tasks + halo edges), never O(global DAG)."""

    shard: int
    wavefronts: List[List[K]] = field(default_factory=list)  # level -> tasks
    expanded: int = 0  # tasks this shard touched during discovery (locality)


@dataclass(frozen=True)
class CommPattern:
    """Shape of one wavefront's exchange, classified from the message plan.

    The lowering picks its collective from this: a handful of active pairs
    (low ``density``) wants point-to-point ``ppermute`` rounds; a
    near-complete pair set amortizes better as one ``all_to_all``. The host
    runtime needs no such choice — its AMs are naturally sparse — so this
    classification is exactly what the compiled path must recover to match
    the paper's wire behavior.
    """

    level: int
    n_shards: int
    pair_counts: Dict[Tuple[int, int], int]  # (src, dst) -> messages

    @property
    def n_pairs(self) -> int:
        return len(self.pair_counts)

    @property
    def density(self) -> float:
        """Active fraction of the n·(n-1) possible off-diagonal pairs."""
        possible = self.n_shards * (self.n_shards - 1)
        return self.n_pairs / possible if possible else 0.0

    @property
    def max_pair(self) -> int:
        """Widest per-pair batch — the dense lowering pads every pair to it."""
        return max(self.pair_counts.values(), default=0)

    @property
    def total(self) -> int:
        return sum(self.pair_counts.values())

    def rounds(self) -> List[List[Tuple[int, int]]]:
        """Decompose the pair set into partial permutations (each shard sends
        to <= 1 dst and receives from <= 1 src per round) — the schedule of
        ``ppermute`` rounds for the sparse lowering. Greedy maximal matchings
        over the widest-first pair list: <= 2*max_degree - 1 rounds."""
        remaining = sorted(self.pair_counts,
                           key=lambda p: (-self.pair_counts[p], p))
        out: List[List[Tuple[int, int]]] = []
        while remaining:
            srcs: set = set()
            dsts: set = set()
            round_, rest = [], []
            for pair in remaining:
                s, d = pair
                if s in srcs or d in dsts:
                    rest.append(pair)
                else:
                    srcs.add(s)
                    dsts.add(d)
                    round_.append(pair)
            out.append(sorted(round_))
            remaining = rest
        return out

    def round_perms(self) -> Tuple[Tuple[Tuple[int, int], ...], ...]:
        """The *static* structure of :meth:`rounds` — the per-round partial
        permutations as a hashable tuple. A ``jax.lax.scan`` body can only
        carry a fixed ``ppermute`` permutation, so this is exactly the part
        of the pattern a scanned lowering must hold constant across the
        wavefronts it folds together (per-pair widths may differ — they pad)."""
        return tuple(tuple(r) for r in self.rounds())

    def signature(self, choice: str) -> Tuple:
        """Hashable *comm signature* of this wavefront's exchange under the
        lowering ``choice`` ("none" | "all_to_all" | "ppermute") — the
        segmentation key for the segmented-scan executor. Two wavefronts
        with equal signatures can share one scan body: same collective, and
        for ppermute the identical static round permutations (table widths
        are made compatible by per-segment padding)."""
        if choice == "none":
            return ("none",)
        if choice == "all_to_all":
            return ("all_to_all",)
        if choice == "ppermute":
            return ("ppermute", self.round_perms())
        raise ValueError(f"unknown lowering choice {choice!r}")


def union_pattern(patterns: Sequence["CommPattern"]) -> "CommPattern":
    """The *union permutation cover* of a run of comm patterns: per-pair
    counts are the pairwise max, so the union's :meth:`CommPattern.rounds`
    give a single static round structure every wavefront in the run can ride
    — a pair inactive at some wavefront simply ships trash padding there.

    This is what lets a fragmented run (every wavefront a different partial
    permutation, e.g. deep FFT's stride cycling) still lower to one
    ``jax.lax.scan``: the scan body carries the union rounds, and each
    wavefront realizes its own slots on them. The padding cost is the
    inactive (pair, wavefront) slots — accounted honestly by
    ``BlockProgram.comm_stats(cover="union")``, and accepted by
    ``plan_lowering`` only when it still beats the dense-scan wire."""
    if not patterns:
        return CommPattern(level=0, n_shards=0, pair_counts={})
    pair_counts: Dict[Tuple[int, int], int] = {}
    for p in patterns:
        for pair, cnt in p.pair_counts.items():
            pair_counts[pair] = max(pair_counts.get(pair, 0), cnt)
    return CommPattern(level=patterns[0].level,
                       n_shards=patterns[0].n_shards,
                       pair_counts=dict(sorted(pair_counts.items())))


def segment_runs(items: Sequence[Hashable]) -> List[Tuple[int, int]]:
    """Partition ``[0, len(items))`` into maximal ``[start, stop)`` runs of
    equal items. The segmentation primitive shared by the segmented-scan
    executor (runs of equal comm signature -> one ``jax.lax.scan`` each) and
    the pipeline lowering (runs of equal stage hand-off permutation)."""
    runs: List[Tuple[int, int]] = []
    for i, item in enumerate(items):
        if runs and items[runs[-1][0]] == item:
            runs[-1] = (runs[-1][0], i + 1)
        else:
            runs.append((i, i + 1))
    return runs


@dataclass
class WavefrontSchedule:
    """The complete output of parallel discovery: per-shard wavefront task
    lists (normalized to equal depth for the lockstep lowerings), the fused
    cross-shard message plan grouped by producer wavefront, and the global
    leveling ``level_of``. Invariant: every dependency is scheduled at a
    strictly earlier level than its dependents, and every cross-shard edge
    has exactly one message at the producer's level (:meth:`validate`)."""

    n_shards: int
    shards: List[ShardSchedule]
    # messages grouped by producer wavefront, then (src, dst) — one fused
    # "large AM" per group.
    messages: Dict[int, Dict[Tuple[int, int], List[Message]]]
    level_of: Dict[K, int]

    @property
    def n_wavefronts(self) -> int:
        return max((len(s.wavefronts) for s in self.shards), default=0)

    def comm_plan(self, level: int) -> Dict[Tuple[int, int], List[Message]]:
        """The batched exchange at one wavefront: ``{(src, dst): [Message]}``,
        deterministically ordered. All edges of a (src, dst) pair ride one
        fused buffer — the compiled analogue of the paper's *large AM*
        batching — so every lowering (the block executor's all_to_all tables,
        ``repro.dist.pipeline``'s stage transfers) derives its communication
        from this single plan rather than re-walking the PTG."""
        groups = self.messages.get(level, {})
        return {pair: list(groups[pair]) for pair in sorted(groups)}

    def comm_pairs(self, level: int) -> List[Tuple[int, int]]:
        """Just the (src, dst) pairs exchanging data at ``level`` — the
        collective-permute pattern for lockstep lowerings."""
        return sorted(self.messages.get(level, {}))

    def comm_pattern(self, level: int) -> CommPattern:
        """Classify the exchange at ``level``: per-pair message counts and
        pair density, from which a lowering picks sparse (ppermute rounds)
        vs dense (all_to_all) collectives."""
        groups = self.messages.get(level, {})
        return CommPattern(
            level=level, n_shards=self.n_shards,
            pair_counts={pair: len(groups[pair]) for pair in sorted(groups)})

    def halo_split(self, level: int) -> List[Tuple[List[K], List[K]]]:
        """Split each shard's tasks at wavefront ``level`` into
        (halo-independent, halo-dependent) sets wrt the arrivals of the
        *previous* wavefront's exchange.

        Halo-independent tasks consume no block landing at ``level - 1``'s
        exchange, so a double-buffered lowering may run them concurrently
        with that exchange — the compiled analogue of the paper's AM/compute
        overlap. Task order within each set preserves wavefront order."""
        arriving: set = set()
        for msgs in self.messages.get(level - 1, {}).values():
            for m in msgs:
                if self.level_of.get(m.dst_task) == level:
                    arriving.add(m.dst_task)
        out: List[Tuple[List[K], List[K]]] = []
        for s in self.shards:
            tasks = s.wavefronts[level] if level < len(s.wavefronts) else []
            indep = [k for k in tasks if k not in arriving]
            dep = [k for k in tasks if k in arriving]
            out.append((indep, dep))
        return out

    def validate(self, ptg: PTG) -> None:
        """Every dependency is scheduled strictly before its dependents, and
        every cross-shard edge has a message at the producer's level."""
        for k, lvl in self.level_of.items():
            for d in ptg.in_deps(k):
                assert self.level_of[d] < lvl, (d, k)
                if ptg.mapping(d) != ptg.mapping(k):
                    group = self.messages[self.level_of[d]][
                        (ptg.mapping(d), ptg.mapping(k))]
                    assert any(m.src_task == d and m.dst_task == k
                               for m in group), (d, k)


def _run_discovery(view_of: Callable[[int], object],
                   seed_pairs: Sequence[Tuple[K, int]],
                   n_shards: int) -> WavefrontSchedule:
    """The bulk-synchronous discovery loop shared by :func:`discover`
    (global PTG) and :func:`discover_local` (per-shard lazy views).

    ``view_of(s)`` returns the edge oracle shard ``s`` expands through —
    anything exposing ``in_deps`` / ``out_deps`` / ``mapping``. Shard ``s``
    only ever queries its own view, and only for tasks mapped to it plus
    the out-edge targets those tasks fulfill (the halo) — so a per-shard
    view never needs the global edge dicts. ``seed_pairs`` is the
    ``(task, shard)`` list of zero-indegree roots, per-shard in program
    order. Invariant: the schedule depends only on the edge *values* the
    views return, so any two view sets agreeing edge-for-edge produce
    identical wavefronts and message plans.
    """
    shards = [ShardSchedule(s) for s in range(n_shards)]
    # per-shard discovery state — *disjoint by construction*; a shard only
    # ever touches keys it owns (asserted in tests for locality).
    remaining: List[Dict[K, int]] = [dict() for _ in range(n_shards)]
    level_of: Dict[K, int] = {}
    messages: Dict[int, Dict[Tuple[int, int], List[Message]]] = defaultdict(
        lambda: defaultdict(list))

    # "fulfill" events pending per shard: (task, from_level)
    inbox: List[List[Tuple[K, int]]] = [[] for _ in range(n_shards)]
    for k, s in seed_pairs:
        inbox[s % n_shards].append((k, -1))

    round_ = 0
    while any(inbox):
        next_inbox: List[List[Tuple[K, int]]] = [[] for _ in range(n_shards)]
        for s in range(n_shards):
            view = view_of(s)
            sched = shards[s]
            ready: List[Tuple[K, int]] = []
            for k, from_level in inbox[s]:
                sched.expanded += 1
                cnt = remaining[s].get(k)
                if cnt is None:
                    cnt = len(view.in_deps(k))
                    cnt = max(cnt, 1)  # seeds carry one synthetic dep
                cnt -= 1
                lvl = level_of.get(k, -1)
                level_of[k] = max(lvl, from_level + 1)
                if cnt == 0:
                    remaining[s].pop(k, None)
                    ready.append((k, level_of[k]))
                else:
                    remaining[s][k] = cnt
            for k, lvl in ready:
                sched_lvl = lvl
                while len(sched.wavefronts) <= sched_lvl:
                    sched.wavefronts.append([])
                sched.wavefronts[sched_lvl].append(k)
                for d in view.out_deps(k):
                    ds = view.mapping(d) % n_shards
                    if ds != s:
                        messages[sched_lvl][(s, ds)].append(
                            Message(s, ds, k, d, level=sched_lvl))
                    next_inbox[ds].append((d, sched_lvl))
        inbox = next_inbox
        round_ += 1
        if round_ > 10_000_000:  # pragma: no cover
            raise RuntimeError("discovery did not converge (cyclic PTG?)")

    leftover = [k for s in range(n_shards) for k in remaining[s]]
    if leftover:
        raise ValueError(
            f"{len(leftover)} task(s) never became ready (unreachable deps or "
            f"wrong indegree), e.g. {leftover[:3]}")
    sched = WavefrontSchedule(n_shards, shards, dict(messages), level_of)
    # normalize: same number of wavefronts everywhere (lockstep lowering)
    depth = sched.n_wavefronts
    for s in shards:
        while len(s.wavefronts) < depth:
            s.wavefronts.append([])
    return sched


def discover(ptg: PTG, seeds: Sequence[K], n_shards: int, *,
             validate: bool = False) -> WavefrontSchedule:
    """Message-driven parallel discovery (run symbolically, shard-local)
    from a *global* PTG — every shard expands through the same edge oracle.

    Implemented as a bulk-synchronous emulation of the asynchronous runtime:
    at each round every shard independently expands the ready tasks it owns,
    posting discovery messages for remote out-edges; messages are delivered
    between rounds. Wavefront level(k) = 1 + max(level of deps) — the ALAP/
    ASAP leveling the lockstep lowering needs. Returns the
    :class:`WavefrontSchedule`; raises ``ValueError`` when tasks never
    become ready (wrong indegree / unreachable deps).

    ``validate=True`` additionally runs :meth:`PTG.check_consistency` over
    every discovered task, so hand-written in/out-edge pairs get the same
    mutual-inverse guarantee the :mod:`repro.ptg` builder provides by
    construction.
    """
    sched = _run_discovery(lambda s: ptg,
                           [(k, ptg.mapping(k)) for k in seeds], n_shards)
    if validate:
        ptg.check_consistency(list(sched.level_of))
    return sched


def discover_local(views: Sequence, n_shards: int, *,
                   validate: bool = False) -> WavefrontSchedule:
    """The ``local=True`` discovery mode: the same message-driven loop as
    :func:`discover`, but shard ``s`` expands through ``views[s]`` — a
    lazily derived per-shard slice of the PTG
    (:meth:`repro.ptg.Graph.derive_local`) that holds edges only for the
    tasks the shard owns plus their halo. No global edge dicts exist at any
    point; the union of what the views store is O(sum of owned + halo), and
    each shard's expansion cost is O(its tasks + halo edges).

    ``views[s]`` must expose ``in_deps`` / ``out_deps`` (complete for the
    shard's owned tasks), ``mapping`` (owned *and* halo tasks — out-edge
    targets are routed by the producer's view), ``seeds`` (owned
    zero-indegree tasks in program order), and ``shard``.

    Invariant (asserted by ``tests/test_lazy_discovery.py``): the returned
    schedule — per-shard wavefronts, levels, and fused message plans — is
    identical to ``discover`` over the eagerly derived global PTG.

    ``validate=True`` runs :meth:`PTG.check_consistency` over every
    discovered task through the :func:`union_ptg` of the views (the
    cross-shard dispatch needed to follow an edge to its other endpoint).
    """
    seed_pairs = [(k, view.shard) for view in views for k in view.seeds]
    sched = _run_discovery(lambda s: views[s], seed_pairs, n_shards)
    if validate:
        union_ptg(views).check_consistency(list(sched.level_of))
    return sched


def union_ptg(views: Sequence, home: Optional[Dict[K, object]] = None
              ) -> PTG:
    """A PTG-protocol facade over per-shard lazy views: each query is
    dispatched to the view *owning* the task, so the union behaves exactly
    like the eagerly derived global PTG without any shard's edge dicts
    being merged. The dispatch table is O(n_tasks) keys (comparable to the
    slot maps every lowering builds anyway) — the avoided global state is
    the O(n_edges) in/out dicts; pass a prebuilt ``home`` (task -> owning
    view) to share one table between callers. Raises ``KeyError`` for
    unknown tasks."""
    if home is None:
        home = {k: v for v in views for k in v.tasks}

    def _view(k: K):
        try:
            return home[k]
        except KeyError:
            raise KeyError(f"task {k!r} is owned by no shard view")

    return PTG(in_deps=lambda k: _view(k).in_deps(k),
               out_deps=lambda k: _view(k).out_deps(k),
               mapping=lambda k: _view(k).mapping(k),
               type_of=lambda k: _view(k).type_of(k))
