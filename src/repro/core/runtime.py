"""Multi-rank execution context — one emulated MPI rank per thread-group.

``run_ranks(n_ranks, main, n_threads=...)`` runs ``main(ctx)`` once per rank,
SPMD-style, exactly like the paper's example program::

    Communicator comm(MPI_COMM_WORLD);
    Threadpool   tp(n_threads, &comm);
    Taskflow<int> tf(&tp);
    ... seed ... ; tp.join();

Each rank owns a main (comm) thread — which runs the user's ``main`` and
then, inside ``tp.join()``, the progress + completion-detection loop — and
``n_threads`` worker threads. Delivery delay/reorder can be injected via
``delay_fn`` to stress the completion protocol.

On a real cluster this module is replaced 1:1 by MPI (the transport is
isolated behind ``InProcWorld``); everything above it is transport-agnostic.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Optional

from .completion import CompletionDetector
from .messages import Communicator, InProcWorld
from .taskflow import Taskflow
from .threadpool import Threadpool


@dataclass
class RankContext:
    rank: int
    n_ranks: int
    comm: Communicator
    tp: Threadpool
    _results: dict = field(default_factory=dict)

    def taskflow(self, name: str = "tf") -> Taskflow:
        return Taskflow(self.tp, name=name)

    def barrier_free_join(self) -> None:
        """The paper's ``tp.join()`` — distributed completion, no barrier."""
        self.tp.join()


def run_ranks(
    n_ranks: int,
    main: Callable[[RankContext], object],
    *,
    n_threads: int = 2,
    delay_fn: Optional[Callable[[int, int, str], float]] = None,
    timeout: float = 120.0,
) -> list:
    """SPMD-launch ``main`` on ``n_ranks`` emulated ranks; returns per-rank
    results. Raises on per-rank exception or timeout (deadlock guard)."""
    world = InProcWorld(n_ranks, delay_fn=delay_fn)
    results = [None] * n_ranks
    errors: list = []

    def rank_main(rank: int) -> None:
        comm = Communicator(world, rank)
        tp = Threadpool(n_threads, comm)
        CompletionDetector(comm)
        ctx = RankContext(rank, n_ranks, comm, tp)
        try:
            results[rank] = main(ctx)
        except BaseException as e:  # surfaced to the caller
            errors.append((rank, e))
            comm.shutdown.set()
            world.poison.set()  # unblock every other rank's join()

    threads = [
        threading.Thread(target=rank_main, args=(r,), daemon=True, name=f"rank{r}")
        for r in range(n_ranks)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=timeout)
        if t.is_alive():
            raise TimeoutError(
                f"rank thread {t.name} did not finish within {timeout}s "
                "(possible completion-protocol deadlock)"
            )
    if errors:
        rank, err = errors[0]
        raise RuntimeError(f"rank {rank} failed: {err!r}") from err
    return results
