"""Multi-rank execution context — one emulated MPI rank per thread-group.

``run_ranks(n_ranks, main, n_threads=...)`` runs ``main(ctx)`` once per rank,
SPMD-style, exactly like the paper's example program::

    Communicator comm(MPI_COMM_WORLD);
    Threadpool   tp(n_threads, &comm);
    Taskflow<int> tf(&tp);
    ... seed ... ; tp.join();

Each rank owns a main (comm) thread — which runs the user's ``main`` and
then, inside ``tp.join()``, the progress + completion-detection loop — and
``n_threads`` worker threads. Delivery delay/reorder can be injected via
``delay_fn``, and loss/duplication/rank-death via ``faults`` (a
:class:`~repro.core.faults.FaultPlan`), to stress the completion protocol;
with ``faults`` set, ``run_ranks`` returns ``(results, RecoveryReport)``.

Failure semantics:

- a rank killed by the fault plan simply stops (its result is ``None``;
  survivors recover via the membership protocol in ``core.completion``);
- a rank that *raises* poisons the world; the other ranks abort as victims
  and the **root cause** is re-raised with its full formatted traceback —
  not the victims' "world poisoned" echoes;
- a timeout raises with a per-rank forensic dump: which ranks are stuck and
  each stuck rank's last protocol state (counters, unacked sends, detector
  epoch/confirmations) instead of a bare TimeoutError.

On a real cluster this module is replaced 1:1 by MPI (the transport is
isolated behind ``InProcWorld``); everything above it is transport-agnostic.
"""

from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass, field
from typing import Callable, Optional

from .completion import CompletionDetector
from .faults import FaultPlan, RecoveryReport
from .messages import Communicator, InProcWorld, RankKilled, WorldPoisoned
from .taskflow import Taskflow
from .threadpool import Threadpool


@dataclass
class RankContext:
    rank: int
    n_ranks: int
    comm: Communicator
    tp: Threadpool
    _results: dict = field(default_factory=dict)

    def taskflow(self, name: str = "tf") -> Taskflow:
        return Taskflow(self.tp, name=name)

    def barrier_free_join(self) -> None:
        """The paper's ``tp.join()`` — distributed completion, no barrier."""
        self.tp.join()


def run_ranks(
    n_ranks: int,
    main: Callable[[RankContext], object],
    *,
    n_threads: int = 2,
    delay_fn: Optional[Callable[[int, int, str], float]] = None,
    faults: Optional[FaultPlan] = None,
    timeout: float = 120.0,
    serve_scheduler=None,
):
    """SPMD-launch ``main`` on ``n_ranks`` emulated ranks; returns per-rank
    results (or ``(results, report)`` when ``faults`` is given). Raises on
    per-rank exception or timeout (deadlock guard).

    ``serve_scheduler`` (a :class:`repro.sched.SchedulerService`) switches
    to resident mode: ranks stay alive between submissions for as long as
    the service is open, so the deadlock deadline only arms once the
    service's ``draining`` event is set (``close()`` sets it before
    posting STOP) — an idle resident rank is not a hang. Everything else
    (poison propagation, timeout forensics, error surfacing) is
    unchanged."""
    world = InProcWorld(n_ranks, delay_fn=delay_fn, faults=faults)
    if serve_scheduler is not None:
        # the resident service needs the world for recovery gating (is a
        # fault plan active?), the dead set, and future-timeout forensics
        serve_scheduler.attach_world(world)
    results = [None] * n_ranks
    errors: list = []
    ctxs: list = [None] * n_ranks

    def rank_main(rank: int) -> None:
        comm = Communicator(world, rank)
        tp = Threadpool(n_threads, comm)
        CompletionDetector(comm)
        ctx = RankContext(rank, n_ranks, comm, tp)
        ctxs[rank] = ctx
        try:
            results[rank] = main(ctx)
        except RankKilled:
            # this rank was crashed by the fault plan: its silence is the
            # point — survivors recover; nothing to report, nothing to keep
            results[rank] = None
            tp.abort()
        except WorldPoisoned:
            # victim of another rank's failure: abort quietly so the root
            # cause below is the only error surfaced
            tp.abort()
        except BaseException as e:  # surfaced to the caller
            errors.append((rank, e))
            comm.shutdown.set()
            world.poison.set()  # unblock every other rank's join()
            tp.abort()

    threads = [
        threading.Thread(target=rank_main, args=(r,), daemon=True, name=f"rank{r}")
        for r in range(n_ranks)
    ]
    for t in threads:
        t.start()
    if serve_scheduler is not None:
        while not serve_scheduler.draining.wait(timeout=0.25):
            if world.poison.is_set() or errors:
                break   # a rank died while serving: fall through and join
    deadline = time.monotonic() + timeout
    stuck = []
    for t in threads:
        t.join(timeout=max(0.0, deadline - time.monotonic()))
        if t.is_alive():
            stuck.append(t)
    if stuck:
        world.poison.set()  # let salvageable ranks unwind before reporting
        raise TimeoutError(_timeout_forensics(stuck, ctxs, timeout))
    if errors:
        rank, err = errors[0]
        tb = "".join(traceback.format_exception(type(err), err,
                                                err.__traceback__))
        raise RuntimeError(f"rank {rank} failed:\n{tb}") from err
    if faults is not None:
        return results, world.report
    return results


def _timeout_forensics(stuck, ctxs, timeout: float) -> str:
    """Per-rank protocol state for the deadlock report: which ranks hung,
    and what their communicator/detector last looked like."""
    lines = [
        f"{len(stuck)} rank thread(s) did not finish within {timeout}s "
        "(possible completion-protocol deadlock):"
    ]
    for t in stuck:
        rank = int(t.name.replace("rank", ""))
        ctx = ctxs[rank]
        if ctx is None:
            lines.append(f"  rank {rank}: stuck before context creation")
            continue
        try:
            snap = ctx.comm.snapshot()
        except Exception as e:  # forensics must never mask the timeout
            snap = f"<snapshot failed: {e!r}>"
        lines.append(f"  rank {rank}: {snap}")
    return "\n".join(lines)
