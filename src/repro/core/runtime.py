"""Multi-rank execution context — SPMD launch over a pluggable transport.

``run_ranks(n_ranks, main, n_threads=...)`` runs ``main(ctx)`` once per rank,
SPMD-style, exactly like the paper's example program::

    Communicator comm(MPI_COMM_WORLD);
    Threadpool   tp(n_threads, &comm);
    Taskflow<int> tf(&tp);
    ... seed ... ; tp.join();

Each rank owns a main (comm) thread — which runs the user's ``main`` and
then, inside ``tp.join()``, the progress + completion-detection loop — and
``n_threads`` worker threads. Delivery delay/reorder can be injected via
``delay_fn``, and loss/duplication/rank-death via ``faults`` (a
:class:`~repro.core.faults.FaultPlan`), to stress the completion protocol;
with ``faults`` set, ``run_ranks`` returns ``(results, RecoveryReport)``.

Where the ranks *live* is decided by ``transport=`` (or the
``REPRO_TRANSPORT`` env var): the default ``inproc`` backend emulates each
rank as a thread-group in this process; the ``multiproc`` backend forks one
real OS process per rank and carries the same wire messages over loopback
TCP sockets. Everything above the world contract — reliable delivery,
completion detection, DEATH/epoch recovery, the scheduler — is identical
on both. See :mod:`repro.core.comm`.

Failure semantics:

- a rank killed by the fault plan simply stops (its result is ``None``;
  survivors recover via the membership protocol in ``core.completion``);
- a rank that *raises* poisons the world; the other ranks abort as victims
  and the **root cause** is re-raised with its full formatted traceback —
  not the victims' "world poisoned" echoes;
- a timeout raises with a per-rank forensic dump: which ranks are stuck and
  each stuck rank's last protocol state (counters, unacked sends, detector
  epoch/confirmations) instead of a bare TimeoutError.
"""

from __future__ import annotations

import traceback
from dataclasses import dataclass, field
from typing import Callable, Optional

from .comm import get_backend
from .completion import CompletionDetector
from .faults import FaultPlan
from .messages import Communicator, RankKilled, WorldPoisoned
from .taskflow import Taskflow
from .threadpool import Threadpool


@dataclass
class RankContext:
    rank: int
    n_ranks: int
    comm: Communicator
    tp: Threadpool
    _results: dict = field(default_factory=dict)

    def taskflow(self, name: str = "tf") -> Taskflow:
        return Taskflow(self.tp, name=name)

    def barrier_free_join(self) -> None:
        """The paper's ``tp.join()`` — distributed completion, no barrier."""
        self.tp.join()


def rank_session(world, rank: int, main, n_threads: int):
    """One rank's whole life, shared by every backend: build the
    communicator / threadpool / detector stack on ``world``, run ``main``,
    classify the outcome.

    Returns ``(status, payload)`` with status one of ``"ok"`` (payload =
    main's return value), ``"killed"`` (crashed by the fault plan — its
    silence is the point, survivors recover), ``"poisoned"`` (victim of
    another rank's failure; aborts quietly so the root cause is the only
    error surfaced), or ``"error"`` (payload = the exception; the session
    has already poisoned the world).
    """
    comm = Communicator(world, rank)
    tp = Threadpool(n_threads, comm)
    CompletionDetector(comm)
    ctx = RankContext(rank, world.n_ranks, comm, tp)
    world.attach_snapshot_provider(rank, comm.snapshot)
    try:
        return "ok", main(ctx)
    except RankKilled:
        tp.abort()
        return "killed", None
    except WorldPoisoned:
        tp.abort()
        return "poisoned", None
    except BaseException as e:  # surfaced to the caller
        comm.shutdown.set()
        world.poison.set()  # unblock every other rank's join()
        tp.abort()
        return "error", e


def format_rank_error(err: BaseException) -> str:
    return "".join(traceback.format_exception(type(err), err,
                                              err.__traceback__))


def timeout_forensics(stuck, world, timeout: float) -> str:
    """Per-rank protocol state for the deadlock report: which ranks hung,
    and what their communicator/scheduler last looked like. ``stuck`` is a
    list of rank numbers; each snapshot is pulled through the world's
    snapshot providers (cross-process safe)."""
    lines = [
        f"{len(stuck)} rank thread(s) did not finish within {timeout}s "
        "(possible completion-protocol deadlock):"
    ]
    for rank in stuck:
        snap = world.snapshot_rank(rank)
        if snap is None:
            lines.append(f"  rank {rank}: stuck before context creation")
        else:
            lines.append(f"  rank {rank}: {snap}")
    return "\n".join(lines)


def run_ranks(
    n_ranks: int,
    main: Callable[[RankContext], object],
    *,
    n_threads: int = 2,
    delay_fn: Optional[Callable[[int, int, str], float]] = None,
    faults: Optional[FaultPlan] = None,
    timeout: float = 120.0,
    serve_scheduler=None,
    transport: Optional[str] = None,
):
    """SPMD-launch ``main`` on ``n_ranks`` ranks; returns per-rank results
    (or ``(results, report)`` when ``faults`` is given). Raises on
    per-rank exception or timeout (deadlock guard).

    ``transport`` selects the registered comm backend (default: the
    ``REPRO_TRANSPORT`` env var, else ``inproc``).

    ``serve_scheduler`` (a :class:`repro.sched.SchedulerService`) switches
    to resident mode: ranks stay alive between submissions for as long as
    the service is open, so the deadlock deadline only arms once the
    service's ``draining`` event is set (``close()`` sets it before
    posting STOP) — an idle resident rank is not a hang. Everything else
    (poison propagation, timeout forensics, error surfacing) is
    unchanged."""
    backend = get_backend(transport)
    return backend.run_ranks(
        n_ranks, main, n_threads=n_threads, delay_fn=delay_fn,
        faults=faults, timeout=timeout, serve_scheduler=serve_scheduler)
