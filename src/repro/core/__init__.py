"""repro.core — TaskTorrent: PTG task runtime + one-sided active messages.

Host-dynamic layer (faithful to the paper):
  Threadpool, Taskflow, Communicator/ActiveMsg/view, CompletionDetector,
  run_ranks (SPMD rank emulation), STFGraph (StarPU-style baseline).

Compiled layer (TPU-native adaptation):
  PTG -> per-shard parallel DAG discovery -> wavefront schedule -> shard_map
  lowering with batched collective "active messages" (see discovery.py /
  schedule.py).
"""

from .comm import backend_names, get_backend, register_backend
from .completion import CompletionDetector
from .faults import FaultPlan, RecoveryReport
from .messages import (ActiveMsg, Communicator, InProcWorld, RankKilled,
                       WorldPoisoned, view)
from .runtime import RankContext, run_ranks
from .stf import READ, READWRITE, STFGraph, WRITE
from .taskflow import Taskflow
from .threadpool import Task, Threadpool

__all__ = [
    "ActiveMsg", "Communicator", "CompletionDetector", "FaultPlan",
    "InProcWorld", "RankContext", "RankKilled", "READ", "READWRITE",
    "RecoveryReport", "STFGraph", "Task", "Taskflow", "Threadpool",
    "WorldPoisoned", "WRITE", "backend_names", "get_backend",
    "register_backend", "run_ranks", "view",
]
