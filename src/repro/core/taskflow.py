"""Taskflow<K> — the Parametrized Task Graph of TaskTorrent (§II-A1b).

The DAG is *never stored*: the user provides pure functions over an index
space K —

- ``indegree(k)``  number of in-dependencies of task ``k``;
- ``task(k)``      the body; typically computes then ``fulfill_promise`` of
                   downstream tasks (locally) or sends an active message
                   (remotely);
- ``mapping(k)``   the worker thread ``k`` is initially mapped to;
- ``priority(k)``  optional max-heap priority (default 0);
- ``binding(k)``   optional: bind ``k`` to its thread (not stealable).

Dependency counters live in per-thread hash maps (sharded by ``mapping(k)``,
§II-B1): a counter for ``k`` is only ever touched by thread ``mapping(k)``.
``fulfill_promise(k)`` called from any other thread routes a bound
micro-task to the owner thread; called *on* the owner thread it decrements
in-place. The runtime therefore becomes aware of a task only when its first
dependency is fulfilled, and forgets it as soon as it is spawned — O(live
tasks) state, never O(DAG).
"""

from __future__ import annotations

from typing import Callable, Dict, Generic, Hashable, Optional, TypeVar

from .threadpool import Task, Threadpool, current_thread_id

K = TypeVar("K", bound=Hashable)


class Taskflow(Generic[K]):
    def __init__(self, threadpool: Threadpool, name: str = "tf"):
        self.tp = threadpool
        self.name = name
        self._indegree: Optional[Callable[[K], int]] = None
        self._task: Optional[Callable[[K], None]] = None
        self._mapping: Optional[Callable[[K], int]] = None
        self._priority: Callable[[K], float] = lambda k: 0.0
        self._binding: Callable[[K], bool] = lambda k: False
        # One dependency-counter map per worker thread (sharded, §II-B1).
        self._deps: list[Dict[K, int]] = [dict() for _ in range(threadpool.n_threads)]

    # ----------------------------------------------------------- PTG spec

    def set_indegree(self, fn: Callable[[K], int]) -> "Taskflow[K]":
        self._indegree = fn
        return self

    def set_task(self, fn: Callable[[K], None]) -> "Taskflow[K]":
        self._task = fn
        return self

    set_run = set_task  # paper uses set_run in the example listing

    def set_mapping(self, fn: Callable[[K], int]) -> "Taskflow[K]":
        self._mapping = fn
        return self

    def set_priority(self, fn: Callable[[K], float]) -> "Taskflow[K]":
        self._priority = fn
        return self

    def set_binding(self, fn: Callable[[K], bool]) -> "Taskflow[K]":
        self._binding = fn
        return self

    # ----------------------------------------------------------- execution

    def fulfill_promise(self, k: K) -> None:
        """Fulfill one in-dependency of task ``k`` (thread-safe)."""
        owner = self._mapping(k) % self.tp.n_threads
        if current_thread_id() == owner:
            self._decrement(owner, k)
        else:
            # Route a *bound* micro-task to the owner thread so the sharded
            # map is only ever touched by its owner (no data races).
            self.tp.insert(
                Task(run=lambda: self._decrement(owner, k), priority=float("inf"),
                     name=f"{self.name}:dec"),
                owner,
                bound=True,
            )

    def _decrement(self, owner: int, k: K) -> None:
        deps = self._deps[owner]
        count = deps.get(k)
        if count is None:
            count = self._indegree(k)
            if count < 1:
                raise ValueError(f"indegree({k!r}) = {count}; must be >= 1")
        count -= 1
        if count == 0:
            deps.pop(k, None)  # forget the task: O(live tasks) state
            self._spawn(owner, k)
        else:
            deps[k] = count

    def _spawn(self, owner: int, k: K) -> None:
        self.tp.insert(
            Task(run=lambda: self._task(k), priority=self._priority(k),
                 name=f"{self.name}:{k!r}"),
            owner,
            bound=self._binding(k),
        )

    # ------------------------------------------------------------- helpers

    def pending(self) -> int:
        """Number of partially-fulfilled (live) tasks — O(1) metadata check."""
        return sum(len(d) for d in self._deps)

    def snapshot(self) -> dict:
        """Live-task state for deadlock/timeout forensics: per-thread counts
        of partially-fulfilled tasks (the only state the runtime holds)."""
        per_thread = [len(d) for d in self._deps]
        return {"name": self.name, "live": sum(per_thread),
                "per_thread": per_thread}
