"""Distributed completion detection — §II-B3 of the paper, verbatim.

The difficulty: all taskflows being idle does *not* imply termination — AMs
may still be in flight, and a naive all-ranks-idle signal terminates early.
The paper's protocol (with correctness proof, Lemma 1 + Theorems 1-2):

every rank r tracks monotone counters ``q_r`` (user AMs queued) and ``p_r``
(user AMs processed); protocol messages are excluded from both.

1. COUNT        — when rank r's worker pool is idle and (q_r, p_r) differ
                  from the last values it sent, r sends (r, q_r, p_r) to 0.
2. REQUEST      — rank 0 keeps the *latest* counts per rank (they are
                  monotone, so greatest wins; stale ones are discarded).
                  When Σq == Σp and that sum differs from the last sum it
                  requested on, it sends (q_r, p_r, t̃) back to every rank,
                  echoing each rank's own counts, with a strictly increasing
                  integer tag t̃ (the synchronization time).
3. CONFIRMATION — rank r processes the REQUEST with the largest t̃ only; if
                  its counts are *unchanged* from the echoed ones (and its
                  workers are still idle), it replies (t̃).
4. SHUTDOWN     — once every rank confirmed the latest t̃ (rank 0 checking
                  itself directly), completion is certain: rank 0 broadcasts
                  SHUTDOWN.
5. ranks terminate on SHUTDOWN.

The two-phase check (COUNT then CONFIRMATION around the same t̃) is exactly
what Lemma 1 needs: counts stable across a synchronization time with equal
global sums ⇒ every queued message was processed ⇒ quiescence is permanent.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from .messages import Communicator

COUNT, REQUEST, CONFIRMATION, SHUTDOWN = "COUNT", "REQUEST", "CONFIRMATION", "SHUTDOWN"


@dataclass
class _Rank0State:
    latest: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    tilde_t: int = 0
    last_requested_sum: Optional[int] = None
    requested: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    confirmations: set = field(default_factory=set)
    sent_shutdown: bool = False


class CompletionDetector:
    """Drives the §II-B3 protocol for one rank; ``step()`` runs inside the
    main thread's progress loop ("continuously")."""

    def __init__(self, comm: Communicator):
        self.comm = comm
        self.rank = comm.rank
        self.n_ranks = comm.n_ranks
        self._last_sent: Optional[Tuple[int, int]] = None
        # REQUEST handling (all ranks, incl. 0 via direct path)
        self._pending_request: Optional[Tuple[int, Tuple[int, int]]] = None
        self._confirmed_tilde: int = -1
        self._r0 = _Rank0State() if self.rank == 0 else None
        comm.attach_detector(self)

    # ----------------------------------------------------------- inbound

    def on_message(self, wire) -> None:
        if wire.kind == COUNT:
            r, q, p = wire.meta
            prev = self._r0.latest.get(r)
            if prev is None or (q, p) > prev:  # monotone: keep greatest
                self._r0.latest[r] = (q, p)
        elif wire.kind == REQUEST:
            counts, tilde_t = wire.meta
            if self._pending_request is None or tilde_t > self._pending_request[0]:
                self._pending_request = (tilde_t, counts)  # largest t̃ wins
        elif wire.kind == CONFIRMATION:
            tilde_t = wire.meta
            if tilde_t == self._r0.tilde_t:
                self._r0.confirmations.add(wire.src)
        elif wire.kind == SHUTDOWN:
            self.comm.shutdown.set()

    # ------------------------------------------------------------- driver

    def step(self) -> None:
        self._step_count()
        self._step_confirm()
        if self.rank == 0:
            self._step_rank0()

    def _counts(self) -> Tuple[int, int]:
        return (self.comm.queued_count, self.comm.processed_count)

    def _step_count(self) -> None:
        """Step 1: idle + changed counts -> COUNT to rank 0 (t_r^-)."""
        if not self.comm.worker_idle():
            return
        counts = self._counts()
        if counts != self._last_sent:
            self._last_sent = counts
            if self.rank == 0:
                self.on_message(_wire(COUNT, 0, (0, *counts)))
            else:
                self.comm.protocol_send(0, COUNT, (self.rank, *counts))

    def _step_confirm(self) -> None:
        """Step 3: largest-t̃ REQUEST; counts unchanged at t_r^+ -> CONFIRM."""
        if self._pending_request is None:
            return
        tilde_t, echoed = self._pending_request
        if tilde_t <= self._confirmed_tilde:
            return
        if self.comm.worker_idle() and self._counts() == echoed:
            self._confirmed_tilde = tilde_t
            if self.rank == 0:
                self._r0.confirmations.add(0)
            else:
                self.comm.protocol_send(0, CONFIRMATION, tilde_t)

    def _step_rank0(self) -> None:
        r0 = self._r0
        if r0.sent_shutdown:
            return
        # Step 4: all ranks confirmed the latest t̃ -> SHUTDOWN.
        if r0.tilde_t > 0 and len(r0.confirmations) == self.n_ranks:
            r0.sent_shutdown = True
            for r in range(1, self.n_ranks):
                self.comm.protocol_send(r, SHUTDOWN, None)
            self.comm.shutdown.set()
            return
        # Step 2: sums equal & new -> REQUEST(t̃) with echoed counts.
        if len(r0.latest) < self.n_ranks:
            return
        sum_q = sum(q for q, _ in r0.latest.values())
        sum_p = sum(p for _, p in r0.latest.values())
        if sum_q != sum_p:
            return
        snapshot = dict(r0.latest)
        if snapshot == r0.requested and r0.last_requested_sum == sum_q:
            return  # nothing new since the last REQUEST round
        r0.tilde_t += 1
        r0.last_requested_sum = sum_q
        r0.requested = snapshot
        r0.confirmations = set()
        for r in range(1, self.n_ranks):
            self.comm.protocol_send(r, REQUEST, (snapshot[r], r0.tilde_t))
        # rank 0 "receives" its own request directly
        self._pending_request = (r0.tilde_t, snapshot[0])


def _wire(kind, src, meta):
    from .messages import _Wire

    return _Wire(kind, src, meta=meta)
