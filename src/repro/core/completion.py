"""Distributed completion detection — §II-B3 of the paper — extended with
membership: a lease-based failure detector and death declaration.

The difficulty: all taskflows being idle does *not* imply termination — AMs
may still be in flight, and a naive all-ranks-idle signal terminates early.
The paper's protocol (with correctness proof, Lemma 1 + Theorems 1-2):

every rank r tracks monotone counters ``q_r`` (user AMs queued) and ``p_r``
(user AMs processed); protocol messages are excluded from both.

1. COUNT        — when rank r's worker pool is idle and (q_r, p_r) differ
                  from the last values it sent, r sends (r, q_r, p_r) to 0.
2. REQUEST      — rank 0 keeps the *latest* counts per rank (they are
                  monotone, so greatest wins; stale ones are discarded).
                  When Σq == Σp and that sum differs from the last sum it
                  requested on, it sends (q_r, p_r, t̃) back to every rank,
                  echoing each rank's own counts, with a strictly increasing
                  integer tag t̃ (the synchronization time).
3. CONFIRMATION — rank r processes the REQUEST with the largest t̃ only; if
                  its counts are *unchanged* from the echoed ones (and its
                  workers are still idle), it replies (t̃).
4. SHUTDOWN     — once every rank confirmed the latest t̃ (rank 0 checking
                  itself directly), completion is certain: rank 0 broadcasts
                  SHUTDOWN.
5. ranks terminate on SHUTDOWN.

The two-phase check (COUNT then CONFIRMATION around the same t̃) is exactly
what Lemma 1 needs: counts stable across a synchronization time with equal
global sums ⇒ every queued message was processed ⇒ quiescence is permanent.

**Membership extension** (active when the world carries a
:class:`~repro.core.faults.FaultPlan`): every non-0 rank heartbeats rank 0
from its progress loop; rank 0 feeds a
:class:`~repro.train.elastic.HeartbeatMonitor` (the same lease logic the
elastic trainer uses at host granularity) and, when a lease expires,
*declares* the silent rank dead:

- the quiescence state moves to a new **epoch**; every protocol message
  carries its epoch, and stale-epoch COUNT/REQUEST/CONFIRMATION traffic is
  discarded (the one-shot counter adjustment at a death breaks cross-epoch
  monotonicity, so the fence is what keeps "greatest wins" sound);
- a DEATH message — (epoch, cumulative dead set, shard→adopter assignment)
  — is broadcast reliably to the survivors; it is idempotent and
  order-safe, so duplicated or reordered declarations converge;
- each survivor applies the death: physically fences the dead rank
  (``world.kill`` is idempotent), subtracts the dead rank's share from its
  effective counters (``Communicator.drop_rank_counts``), resets its
  per-epoch protocol state, and hands the assignment to the runtime's
  ``on_reconfigure`` hook (shard adoption + send replay; see
  ``linalg.host_exec``);
- the protocol then re-runs over the survivor set: Σq == Σp over survivors
  again implies permanent quiescence, because reliable delivery guarantees
  every survivor→survivor user AM is processed exactly once and the dead
  rank's traffic is excluded on both sides of the ledger.

Rank 0 is the arbiter and cannot die (FaultPlan enforces it) — the same
asymmetry the paper's protocol already has.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from .messages import Communicator
from repro.train.elastic import HeartbeatMonitor

COUNT, REQUEST, CONFIRMATION, SHUTDOWN = "COUNT", "REQUEST", "CONFIRMATION", "SHUTDOWN"
DEATH = "DEATH"


@dataclass
class _Rank0State:
    latest: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    tilde_t: int = 0
    last_requested_sum: Optional[int] = None
    requested: Dict[int, Tuple[int, int]] = field(default_factory=dict)
    confirmations: set = field(default_factory=set)
    sent_shutdown: bool = False


class CompletionDetector:
    """Drives the §II-B3 protocol for one rank; ``step()`` runs inside the
    main thread's progress loop ("continuously")."""

    def __init__(self, comm: Communicator):
        self.comm = comm
        self.rank = comm.rank
        self.n_ranks = comm.n_ranks
        self.epoch = 0
        self.alive = set(range(self.n_ranks))
        self.dead: set = set()
        self._last_sent: Optional[Tuple[int, int]] = None
        # REQUEST handling (all ranks, incl. 0 via direct path)
        self._pending_request: Optional[Tuple[int, Tuple[int, int]]] = None
        self._confirmed_tilde: int = -1
        self._r0 = _Rank0State() if self.rank == 0 else None
        # failure detection (rank 0, only under a FaultPlan)
        plan = comm.world.faults
        self._monitor: Optional[HeartbeatMonitor] = None
        if self.rank == 0 and plan is not None:
            self._monitor = HeartbeatMonitor(self.n_ranks,
                                             dead_after=plan.lease)
        comm.attach_detector(self)

    # ----------------------------------------------------------- inbound

    def on_heartbeat(self, src: int) -> None:
        if self._monitor is not None:
            self._monitor.beat(src)

    def on_message(self, wire) -> None:
        if wire.kind == DEATH:
            epoch, dead, assignment = wire.meta
            if epoch > self.epoch:
                self._apply_death(epoch, set(dead), dict(assignment))
            return
        if wire.kind == SHUTDOWN:
            self.comm.shutdown.set()
            return
        epoch = wire.meta[0]
        if epoch != self.epoch:
            return  # stale-epoch protocol traffic is fenced out
        if wire.kind == COUNT:
            r, q, p = wire.meta[1:]
            prev = self._r0.latest.get(r)
            if prev is None or (q, p) > prev:  # monotone: keep greatest
                self._r0.latest[r] = (q, p)
        elif wire.kind == REQUEST:
            counts, tilde_t = wire.meta[1:]
            if self._pending_request is None or tilde_t > self._pending_request[0]:
                self._pending_request = (tilde_t, counts)  # largest t̃ wins
        elif wire.kind == CONFIRMATION:
            tilde_t = wire.meta[1]
            if tilde_t == self._r0.tilde_t and wire.src in self.alive:
                self._r0.confirmations.add(wire.src)

    # ------------------------------------------------------------- driver

    def step(self) -> None:
        self._step_failures()
        self._step_count()
        self._step_confirm()
        if self.rank == 0:
            self._step_rank0()

    def poll_failures(self) -> None:
        """Failure detection *only* — no COUNT/REQUEST rounds. The resident
        scheduler's serve loop calls this: it must declare deaths between
        submissions, but must never run the quiescence steps, which would
        tear the world down at the first idle moment of the stream."""
        self._step_failures()

    def _counts(self) -> Tuple[int, int]:
        return self.comm.effective_counts()

    def _step_count(self) -> None:
        """Step 1: idle + changed counts -> COUNT to rank 0 (t_r^-)."""
        if not self.comm.worker_idle():
            return
        counts = self._counts()
        if counts != self._last_sent:
            self._last_sent = counts
            if self.rank == 0:
                self.on_message(_wire(COUNT, 0, (self.epoch, 0, *counts)))
            else:
                self.comm.protocol_send(0, COUNT, (self.epoch, self.rank,
                                                   *counts))

    def _step_confirm(self) -> None:
        """Step 3: largest-t̃ REQUEST; counts unchanged at t_r^+ -> CONFIRM."""
        if self._pending_request is None:
            return
        tilde_t, echoed = self._pending_request
        if tilde_t <= self._confirmed_tilde:
            return
        if self.comm.worker_idle() and self._counts() == echoed:
            self._confirmed_tilde = tilde_t
            if self.rank == 0:
                self._r0.confirmations.add(0)
            else:
                self.comm.protocol_send(0, CONFIRMATION,
                                        (self.epoch, tilde_t))

    def _step_rank0(self) -> None:
        r0 = self._r0
        if r0.sent_shutdown:
            return
        # Step 4: all live ranks confirmed the latest t̃ -> SHUTDOWN.
        if r0.tilde_t > 0 and self.alive <= r0.confirmations:
            r0.sent_shutdown = True
            self.comm.world.report.note_recovered(time.monotonic())
            for r in sorted(self.alive - {0}):
                self.comm.protocol_send(r, SHUTDOWN, (self.epoch,))
            self.comm.shutdown.set()
            return
        # Step 2: sums equal & new -> REQUEST(t̃) with echoed counts.
        if not self.alive <= set(r0.latest):
            return
        sum_q = sum(r0.latest[r][0] for r in self.alive)
        sum_p = sum(r0.latest[r][1] for r in self.alive)
        if sum_q != sum_p:
            return
        snapshot = {r: r0.latest[r] for r in self.alive}
        if snapshot == r0.requested and r0.last_requested_sum == sum_q:
            return  # nothing new since the last REQUEST round
        r0.tilde_t += 1
        r0.last_requested_sum = sum_q
        r0.requested = snapshot
        r0.confirmations = set()
        for r in sorted(self.alive - {0}):
            self.comm.protocol_send(r, REQUEST,
                                    (self.epoch, snapshot[r], r0.tilde_t))
        # rank 0 "receives" its own request directly
        self._pending_request = (r0.tilde_t, snapshot[0])

    # ----------------------------------------------------- failure handling

    def _step_failures(self) -> None:
        """Rank-0 lease check: declare silent ranks dead (one epoch bump per
        declaration round, cumulative dead set, full adoption assignment)."""
        if self._monitor is None:
            return
        now = time.monotonic()
        self._monitor.beat(0, now)
        # Physical deaths are authoritative (the in-proc world fences a
        # killed rank instantly; a real transport would surface connection
        # loss the same way). Lease expiry applies only to ranks heard from
        # at least once: a slow-starting rank that has never beaten is not
        # "silent", it is not up yet — COUNT/AM traffic also counts as a
        # beat (see Communicator.progress), so liveness credit does not
        # depend on the heartbeat path alone.
        phys = [r for r in sorted(self.comm.world.dead)
                if r not in self.dead and r != 0]
        lease = [r for r in self._monitor.dead_hosts(now)
                 if r in self._monitor.last_seen
                 and r not in self.dead and r != 0]
        newly = sorted(set(phys) | set(lease))
        if not newly:
            return
        dead = self.dead | set(newly)
        alive = set(range(self.n_ranks)) - dead
        assignment = {d: _adopter(d, alive, self.n_ranks)
                      for d in sorted(dead)}
        epoch = self.epoch + 1
        for d in newly:
            self.comm.world.report.note_death(d, now)
        for r in sorted(alive - {0}):
            self.comm.protocol_send(
                r, DEATH, (epoch, tuple(sorted(dead)), assignment))
        self._apply_death(epoch, dead, assignment)

    def _apply_death(self, epoch: int, dead: set, assignment: dict) -> None:
        """Apply a (possibly duplicated/reordered) death declaration: fence,
        adjust counters, reset per-epoch protocol state, hand the adoption
        assignment to the runtime. Idempotent per epoch."""
        newly = sorted(dead - self.dead)
        self.dead |= dead
        self.alive -= dead
        self.epoch = epoch
        now = time.monotonic()
        for d in newly:
            self.comm.world.kill(d)  # idempotent physical fence
            self.comm.world.report.note_death(d, now)
        self.comm.drop_rank_counts(newly)
        # per-epoch protocol state restarts over the survivor set
        self._last_sent = None
        self._pending_request = None
        if self._r0 is not None:
            self._r0.latest.clear()
            self._r0.requested = {}
            self._r0.last_requested_sum = None
            self._r0.confirmations = set()
        if self.comm.on_reconfigure is not None:
            self.comm.on_reconfigure(newly, dict(assignment), epoch)

    # ---------------------------------------------------------- diagnostics

    def snapshot(self) -> dict:
        snap = {
            "epoch": self.epoch,
            "alive": sorted(self.alive),
            "dead": sorted(self.dead),
            "last_count_sent": self._last_sent,
            "confirmed_tilde": self._confirmed_tilde,
            "pending_request": self._pending_request,
        }
        if self._r0 is not None:
            snap["rank0"] = {
                "tilde_t": self._r0.tilde_t,
                "latest": dict(self._r0.latest),
                "confirmations": sorted(self._r0.confirmations),
                "sent_shutdown": self._r0.sent_shutdown,
            }
        return snap


def _adopter(dead_rank: int, alive: set, n_ranks: int) -> int:
    """Deterministic adoption: the next live rank cyclically after the dead
    one — every survivor computes the same map from the same DEATH payload."""
    for off in range(1, n_ranks + 1):
        cand = (dead_rank + off) % n_ranks
        if cand in alive:
            return cand
    raise RuntimeError("no live ranks to adopt shards")


def _wire(kind, src, meta):
    from .messages import _Wire

    return _Wire(kind, src, meta=meta)
