"""Lowering a block-PTG to a lockstep SPMD program — TaskTorrent on TPU.

The host runtime executes the PTG asynchronously; a TPU pod is lockstep
SPMD, so we lower the *schedule produced by parallel discovery*
(`discovery.discover`) into data: per-(wavefront, task-type) index tables,
and a per-wavefront exchange plan. One generic `shard_map` executor then
runs *any* block PTG (GEMM, Cholesky, ...):

    wavefront w:  for each task type t:
                      gather operand blocks by table -> vmap(body_t) -> scatter
                  exchange: the blocks crossing shards at w
                      (all messages of a (src,dst) pair ride one buffer — the
                      compiled analogue of the paper's *large AM* batching)

The exchange is lowered *per wavefront* from the schedule's
:class:`~repro.core.discovery.CommPattern`: a sparse pair set becomes
point-to-point ``ppermute`` rounds (only active pairs touch the wire); a
dense pattern becomes one fused ``all_to_all``, padded to that wavefront's
own width — never a global maximum. ``overlap=True`` double-buffers: a
wavefront's exchange is *issued* before the next wavefront's
halo-independent tasks run and only *landed* before its halo-dependent
tasks, so XLA can run the collective concurrently with independent compute
— the compiled analogue of the paper's AM/compute overlap (§I-C, Fig 9).

Deep schedules get the same sparse wire without unrolled-HLO growth from
the **segmented scan**: the wavefront sequence is partitioned into maximal
runs of equal *comm signature* (same collective class; for ppermute, the
identical static round permutations — ``CommPattern.signature``), each run
becomes one ``jax.lax.scan`` padded to the run's own ``T_max``/``M_max``,
and the runs are stitched sequentially, with ``overlap`` carrying the
in-flight buffers across segment boundaries. ``auto_executor`` picks
between unrolled / segmented / pure dense scan per ``plan_lowering``.

Contract (checked at build time):
- every task writes exactly one block, owned by the task's shard
  ("owner computes" — the paper's 2D GEMM mapping rule);
- a block that crosses shards has exactly one writer (single assignment for
  communicated data; local blocks may be read-modify-written freely);
- operand reads always see the value produced at a strictly earlier
  wavefront (guaranteed by the leveling, re-checked here).

Padding goes to a *trash slot*: padded gathers read it, padded bodies write
it back, padded messages land in the receiver's trash. Real slots are never
aliased with trash, so garbage cannot contaminate results.
"""

from __future__ import annotations

import logging
from collections import defaultdict
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map  # jax >= 0.5
except ImportError:  # pragma: no cover — older jax keeps it experimental
    from jax.experimental.shard_map import shard_map

from .discovery import (PTG, CommPattern, WavefrontSchedule, discover,
                        discover_local, segment_runs, union_pattern)


def _shard_map(f, *, mesh, in_specs, out_specs):
    """``shard_map`` with the replication check off: task bodies may be
    Pallas kernels (``vmap(pallas_call)`` — one fused launch per wavefront),
    and ``pallas_call`` has no replication rule, so ``check_rep=True`` would
    reject them outright. Every executor output is sharded ``P(axis)``
    (nothing replicated), so the check carries no information here anyway.
    Newer jax renames/drops the flag — fall back to the plain call."""
    try:
        return shard_map(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, check_rep=False)
    except TypeError:  # pragma: no cover — future jax without check_rep
        return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def _narrow_tables(tree):
    """Index tables enter the jitted executor as constants, and StableHLO
    prints them as hex text — 8 chars per int32 element. Slot and exchange
    indices are bounded by ``n_slots`` (hundreds, not billions), so narrow
    each table to int16 when its values fit: the lowered program's constant
    footprint halves (jnp indexing re-widens on use, so the arithmetic is
    unchanged)."""
    def narrow(v):
        v = np.asarray(v)
        if (np.issubdtype(v.dtype, np.integer)
                and (v.size == 0 or v.max() < np.iinfo(np.int16).max)):
            return jnp.asarray(v.astype(np.int16))
        return jnp.asarray(v)

    return jax.tree.map(narrow, tree)


logger = logging.getLogger(__name__)

K = Hashable
B = Hashable  # block id


@dataclass(frozen=True)
class SparseRound:
    """One ``ppermute`` round of a sparse exchange: a partial permutation of
    shards, each active pair carrying up to ``width`` blocks.

    ``send[s]`` — the slots shard s contributes (trash-padded to ``width``);
    ``recv[d]`` — where shard d's arrivals land (trash for non-receivers:
    ppermute delivers zeros there, which the trash slot absorbs)."""

    perm: Tuple[Tuple[int, int], ...]   # active (src, dst) pairs
    send: np.ndarray                    # [n_shards, width]
    recv: np.ndarray                    # [n_shards, width]

    @property
    def width(self) -> int:
        return self.send.shape[-1]

    @property
    def wire_slots(self) -> int:
        """Block slots actually crossing the wire: only active pairs
        transmit in a collective permute."""
        return len(self.perm) * self.width


@dataclass(frozen=True)
class BlockPTGSpec:
    """Application -> executor contract for a block-structured PTG.

    ``ptg`` answers the edge/mapping queries; ``seeds`` are the
    zero-indegree roots in program order; ``block_of`` / ``operands`` /
    ``owner`` tie tasks to the block store. When ``views`` is set (one
    lazily derived per-shard view, ``repro.ptg.Graph.local_views``),
    discovery runs in local mode (:func:`~repro.core.discovery
    .discover_local`) and the other callables are expected to dispatch
    into the views — no global edge dicts exist anywhere in the lowering.
    Invariant: a spec with and without ``views`` over the same graph lowers
    to the identical program."""

    ptg: PTG
    seeds: Sequence[K]
    n_shards: int
    block_shape: Tuple[int, int]
    block_of: Callable[[K], B]            # block written by task k
    operands: Callable[[K], Sequence[B]]  # blocks read by k (fixed arity per type)
    owner: Callable[[B], int]             # shard owning block b
    dtype: object = jnp.float32
    views: Optional[Sequence] = None      # per-shard lazy views (local mode)


@dataclass
class BlockProgram:
    """Host-built schedule-as-data, ready to lower."""

    spec: BlockPTGSpec
    schedule: WavefrontSchedule
    slot_of: Dict[B, Tuple[int, int]]       # block -> (owner shard, slot)
    halo_slot: Dict[Tuple[int, B], int]     # (shard, block) -> halo copy slot
    n_slots: int                            # incl. trash slot (last)
    types: List[str]
    arity: Dict[str, int]
    # tables[w][t] = (ops_idx [n_shards, T, arity], out_idx [n_shards, T])
    tables: List[Dict[str, Tuple[np.ndarray, np.ndarray]]]
    # exchange[w] = (send_idx [src, dst, M], recv_idx [dst, src, M]) — the
    # dense (all_to_all) lowering, padded to wavefront w's own width M.
    exchange: List[Tuple[np.ndarray, np.ndarray]]
    # patterns[w]: the wavefront's *data-carrying* comm pattern (control-only
    # edges already dropped) — drives the sparse/dense choice.
    patterns: List[CommPattern]
    # sparse_exchange[w]: ppermute-round lowering of the same plan.
    sparse_exchange: List[List[SparseRound]]

    def __post_init__(self):
        # memo for host-side lowering products (stacked scan tables, segment
        # plans, halo splits) — executors rebuild O(W·n·T) numpy tables
        # otherwise on every construction of the same program.
        self._cache: Dict[Tuple, object] = {}

    # ------------------------------------------------------------ packing

    @property
    def trash(self) -> int:
        """The padding slot (always the last): padded gathers read it,
        padded writes and padded message arrivals land in it — real slots
        are never aliased with it, so garbage cannot contaminate results."""
        return self.n_slots - 1

    def pack(self, blocks: Dict[B, np.ndarray]) -> np.ndarray:
        """Host layout: {block id: array} -> [n_shards, n_slots, b0, b1],
        each block placed at its owner's slot (``slot_of``); unset slots —
        halo copies, trash — are zero. Inverse of :meth:`unpack`."""
        b0, b1 = self.spec.block_shape
        out = np.zeros((self.spec.n_shards, self.n_slots, b0, b1),
                       dtype=np.dtype(jnp.dtype(self.spec.dtype)))
        for blk, arr in blocks.items():
            s, slot = self.slot_of[blk]
            out[s, slot] = arr
        return out

    def unpack(self, packed) -> Dict[B, np.ndarray]:
        """Gather every block's *owned* copy back out of the packed
        [n_shards, n_slots, b0, b1] array (halo copies are ignored)."""
        packed = np.asarray(packed)
        return {blk: packed[s, slot] for blk, (s, slot) in self.slot_of.items()}

    # ------------------------------------------------------------- stats

    def lowered_pattern(self, w: int, comm: str = "auto",
                        density_threshold: float = 0.5) -> str:
        """The collective wavefront ``w``'s exchange lowers to under policy
        ``comm``: "all_to_all", "ppermute", or "none" (nothing crosses).

        "auto" takes the fused all_to_all when the pair set is dense enough
        (>= ``density_threshold`` of possible pairs) or when the ppermute
        rounds would put at least as many slots on the wire; otherwise the
        sparse rounds win — Cholesky's panel broadcasts, pipeline hand-offs.
        """
        if comm not in ("dense", "sparse", "auto"):
            raise ValueError(f"unknown comm policy {comm!r}")
        pat = self.patterns[w]
        if pat.total == 0:
            return "none"
        if comm == "dense":
            return "all_to_all"
        if comm == "sparse":
            return "ppermute"
        n = self.spec.n_shards
        dense_wire = n * n * self.exchange[w][0].shape[-1]
        sparse_wire = sum(r.wire_slots for r in self.sparse_exchange[w])
        if pat.density >= density_threshold or sparse_wire >= dense_wire:
            return "all_to_all"
        return "ppermute"

    # ------------------------------------------------------- segmentation

    def comm_signature(self, w: int, comm: str = "auto",
                       density_threshold: float = 0.5) -> Tuple:
        """Hashable comm signature of wavefront ``w`` under policy ``comm``
        (see :meth:`CommPattern.signature`): the segmentation key of the
        segmented-scan lowering. Wavefronts sharing a signature share a scan
        body — same collective, identical static ppermute rounds."""
        return self.patterns[w].signature(
            self.lowered_pattern(w, comm, density_threshold))

    def _segment_plan(self, comm: str, density_threshold: float,
                      cover: str = "exact"
                      ) -> Tuple[List[Tuple[int, int]], List[Tuple]]:
        if cover not in ("exact", "union"):
            raise ValueError(f"unknown signature cover {cover!r}")
        key = ("segments", comm, density_threshold, cover)
        if key not in self._cache:
            W = len(self.tables)
            if cover == "exact":
                sigs = [self.comm_signature(w, comm, density_threshold)
                        for w in range(W)]
            else:
                # union cover: group maximal runs of sparse-class wavefronts
                # (ppermute or silent) and give the whole run the *union*
                # pattern's static rounds — every wavefront in the run can
                # ride them (inactive pairs ship trash), so a fragmented run
                # still folds into one scan. Dense (all_to_all) wavefronts
                # keep their own class.
                choices = [self.lowered_pattern(w, comm, density_threshold)
                           for w in range(W)]
                cls = ["dense" if c == "all_to_all" else "sparse"
                       for c in choices]
                sigs: List[Tuple] = [()] * W
                for (s, e) in segment_runs(cls):
                    if cls[s] == "dense":
                        sig: Tuple = ("all_to_all",)
                    else:
                        union = union_pattern(
                            [self.patterns[w] for w in range(s, e)])
                        sig = (("ppermute", union.round_perms())
                               if union.total else ("none",))
                    for w in range(s, e):
                        sigs[w] = sig
            self._cache[key] = (segment_runs(sigs), sigs)
        return self._cache[key]  # type: ignore[return-value]

    def segments(self, comm: str = "auto",
                 density_threshold: float = 0.5,
                 cover: str = "exact") -> List[Tuple[int, int]]:
        """Partition the wavefront sequence into maximal ``[start, stop)``
        runs of equal comm signature — the segmented-scan executor emits one
        ``jax.lax.scan`` per run, with tables padded to each run's own
        ``T_max``/``M_max`` (never a global maximum).

        ``cover="exact"`` keys runs on each wavefront's own signature;
        ``cover="union"`` coarsens sparse runs to the union permutation
        cover (:func:`~repro.core.discovery.union_pattern`), trading trash
        padding for far fewer segments on fragmented schedules."""
        return self._segment_plan(comm, density_threshold, cover)[0]

    def _union_rounds(self, w: int, perms: Tuple) -> List[SparseRound]:
        """Realize wavefront ``w``'s exchange on the union cover's static
        ``perms``: each pair active at ``w`` ships its slots in the (single)
        union round containing it; pairs inactive at ``w`` pad with trash.
        Per-pair slot lists are rebuilt from the dense ``exchange[w]``
        tables (send and recv are aligned by message index)."""
        key = ("urounds", w, perms)
        if key in self._cache:
            return self._cache[key]  # type: ignore[return-value]
        n, trash = self.spec.n_shards, self.trash
        send, recv = self.exchange[w]            # [src, dst, M], [dst, src, M]
        covered = {p for perm in perms for p in perm}
        missing = set(self.patterns[w].pair_counts) - covered
        if missing:
            raise ValueError(
                f"union cover does not span wavefront {w}'s pairs "
                f"{sorted(missing)} — messages would be dropped")
        rounds: List[SparseRound] = []
        for perm in perms:
            pair_slots = {}
            for src, dst in perm:
                ss = [int(x) for x in send[src, dst] if x != trash]
                rs = [int(x) for x in recv[dst, src] if x != trash]
                assert len(ss) == len(rs)
                if ss:
                    pair_slots[(src, dst)] = (ss, rs)
            width = max((len(v[0]) for v in pair_slots.values()), default=0)
            r_send = np.full((n, width), trash, np.int32)
            r_recv = np.full((n, width), trash, np.int32)
            for (src, dst), (ss, rs) in pair_slots.items():
                for m in range(len(ss)):
                    r_send[src, m] = ss[m]
                    r_recv[dst, m] = rs[m]
            rounds.append(SparseRound(tuple(perm), r_send, r_recv))
        self._cache[key] = rounds
        return rounds

    def _rounds_for(self, w: int, sig: Tuple,
                    cover: str) -> List[SparseRound]:
        """The ppermute rounds wavefront ``w`` contributes to a segment with
        signature ``sig``: its own exact rounds, or its realization on the
        segment's union cover."""
        if cover == "union":
            return self._union_rounds(w, sig[1])
        return self.sparse_exchange[w]

    def comm_stats(self, *, comm: str = "dense",
                   density_threshold: float = 0.5,
                   segmented: bool = False,
                   cover: str = "exact") -> dict:
        """Bytes on the wire per wavefront under lowering policy ``comm``
        ("dense" | "sparse" | "auto") — feeds the roofline's collective term
        and the §Perf iteration log.

        ``real_bytes`` is the payload (cross-shard data blocks, one copy per
        (src, dst) pair); ``padded_bytes`` is the *wasted* wire (trash-slot
        padding the chosen collective ships on top); ``wire_efficiency`` =
        real / (real + padded).

        ``segmented=True`` accounts the segmented-scan lowering instead:
        each wavefront ships its *segment's* padded shape (per-segment
        ``M_max`` for all_to_all runs, per-round segment-max widths for
        ppermute runs), and the result gains ``n_segments`` plus a
        per-segment breakdown — what the benchmarks and the CI regression
        guard watch for the deep-schedule rows. ``cover="union"`` accounts
        the union-cover coarsening (see :meth:`segments`): every wavefront
        of a sparse run ships the *union* rounds, so the inactive
        (pair, wavefront) slots show up as ``padded_bytes`` — the padding
        is never hidden from the wire-efficiency trajectory.
        """
        b0, b1 = self.spec.block_shape
        block_bytes = b0 * b1 * np.dtype(jnp.dtype(self.spec.dtype)).itemsize
        n = self.spec.n_shards
        seg_wire: Dict[int, int] = {}
        seg_rows: List[dict] = []
        if segmented:
            runs, sigs = self._segment_plan(comm, density_threshold, cover)
            for (s, e) in runs:
                sig = sigs[s]
                if sig[0] == "all_to_all":
                    m_seg = max(self.exchange[w][0].shape[-1]
                                for w in range(s, e))
                    wire_w = n * n * m_seg
                elif sig[0] == "ppermute":
                    per_w = {w: self._rounds_for(w, sig, cover)
                             for w in range(s, e)}
                    widths = [max(per_w[w][r].width for w in range(s, e))
                              for r in range(len(sig[1]))]
                    wire_w = sum(len(p) * wd
                                 for p, wd in zip(sig[1], widths))
                else:
                    wire_w = 0
                for w in range(s, e):
                    seg_wire[w] = wire_w
                real_seg = sum(self.patterns[w].total for w in range(s, e))
                seg_rows.append({
                    "start": s, "stop": e, "wavefronts": e - s,
                    "pattern": sig[0],
                    "rounds": (len(sig[1]) if sig[0] == "ppermute"
                               else (1 if sig[0] == "all_to_all" else 0)),
                    "density": float(np.mean(
                        [self.patterns[w].density for w in range(s, e)])),
                    "real_bytes": real_seg * block_bytes,
                    "padded_bytes": (wire_w * (e - s) - real_seg)
                    * block_bytes,
                })
        per_wave = []
        for w, (send, _) in enumerate(self.exchange):
            real = self.patterns[w].total
            choice = self.lowered_pattern(w, comm, density_threshold)
            if segmented:
                wire = seg_wire[w]
            elif choice == "all_to_all":
                wire = n * n * send.shape[-1]
            elif choice == "ppermute":
                wire = sum(r.wire_slots for r in self.sparse_exchange[w])
            else:
                wire = 0
            per_wave.append({
                "pattern": choice,
                "real_blocks": real,
                "wire_blocks": wire,
                "padded_blocks": wire - real,
                "pairs": self.patterns[w].n_pairs,
                "density": self.patterns[w].density,
                "rounds": (len(self.sparse_exchange[w])
                           if choice == "ppermute" else
                           (1 if choice == "all_to_all" else 0)),
            })
        real_bytes = sum(w["real_blocks"] for w in per_wave) * block_bytes
        padded_bytes = sum(w["padded_blocks"] for w in per_wave) * block_bytes
        total = real_bytes + padded_bytes
        out = {
            "comm": comm,
            "block_bytes": block_bytes,
            "wavefronts": len(self.exchange),
            "real_bytes": real_bytes,
            "padded_bytes": padded_bytes,
            "total_wire_bytes": total,
            "wire_efficiency": real_bytes / total if total else 1.0,
            "per_wavefront": per_wave,
        }
        if segmented:
            out["segmented"] = True
            out["cover"] = cover
            out["n_segments"] = len(seg_rows)
            out["segments"] = seg_rows
        return out

    # ----------------------------------------------------------- lowering

    def _split_tables(self, w: int) -> Tuple[dict, Optional[dict]]:
        """Split ``tables[w]`` into (halo-independent, halo-dependent) parts
        wrt the arrivals of wavefront ``w - 1``'s exchange — the slot-level
        refinement of ``WavefrontSchedule.halo_split`` (control-only edges
        carry no block, so a message-level "dependent" task may still be
        slot-independent). Returns ``(tables[w], None)`` when nothing
        arrives. Memoized: both overlap lowerings (unrolled and segmented
        scan) share the split."""
        key = ("split", w)
        if key in self._cache:
            return self._cache[key]  # type: ignore[return-value]
        if w == 0 or self.patterns[w - 1].total == 0:
            self._cache[key] = (self.tables[w], None)
            return self.tables[w], None
        n = self.spec.n_shards
        recv_prev = self.exchange[w - 1][1]          # [dst, src, M]
        arriving = [set(recv_prev[s].ravel().tolist()) - {self.trash}
                    for s in range(n)]
        indep_tbl: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        dep_tbl: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        for t, (ops, out) in self.tables[w].items():
            rows: Dict[bool, List[List[int]]] = {False: [], True: []}
            for s in range(n):
                split: Dict[bool, List[int]] = {False: [], True: []}
                for i in range(out.shape[1]):
                    if out[s, i] == self.trash:
                        continue
                    dep = any(int(o) in arriving[s] for o in ops[s, i])
                    split[dep].append(i)
                for d in (False, True):
                    rows[d].append(split[d])
            for d, tbl in ((False, indep_tbl), (True, dep_tbl)):
                T = max(len(r) for r in rows[d])
                if T == 0:
                    continue
                o_np = np.full((n, T, ops.shape[-1]), self.trash, np.int32)
                u_np = np.full((n, T), self.trash, np.int32)
                for s in range(n):
                    for j, i in enumerate(rows[d][s]):
                        o_np[s, j] = ops[s, i]
                        u_np[s, j] = out[s, i]
                tbl[t] = (o_np, u_np)
        self._cache[key] = (indep_tbl, dep_tbl)
        return indep_tbl, dep_tbl

    # ------------------------------------------ lowering: shared building

    def _compute_fn(self, bodies: Dict[str, Callable[..., jnp.ndarray]]):
        """The per-wavefront compute step shared by every lowering."""

        def wavefront_compute(local, tbl):
            # local: [n_slots, b0, b1]; tbl[t] = (ops_idx [T, ar], out_idx [T])
            for t in self.types:
                if t not in tbl or tbl[t][0].shape[0] == 0:
                    continue
                ops_idx, out_idx = tbl[t]
                ops = local[ops_idx]                 # [T, arity, b0, b1]
                res = jax.vmap(lambda o, _t=t: bodies[_t](*jnp.unstack(o)))(ops)
                local = local.at[out_idx].set(res.astype(local.dtype))
            return local

        return wavefront_compute

    def _stack_tables(self, tabs: Dict[str, np.ndarray], prefix: str,
                      tbl_list: Sequence[Dict[str, Tuple[np.ndarray,
                                                         np.ndarray]]]):
        """Stack per-wavefront compute tables into shard-major arrays
        ``tabs[f"{t}:{prefix}ops"] [n, L, T_max, ar]`` (padded with trash to
        the *list's* own per-type T_max — never a global maximum)."""
        L, n = len(tbl_list), self.spec.n_shards
        for t in self.types:
            T = max((tbl[t][0].shape[1] for tbl in tbl_list if t in tbl),
                    default=0)
            if T == 0:
                continue
            ops = np.full((L, n, T, self.arity[t]), self.trash, np.int32)
            out = np.full((L, n, T), self.trash, np.int32)
            for j, tbl in enumerate(tbl_list):
                if t in tbl:
                    o, u = tbl[t]
                    ops[j, :, : o.shape[1]] = o
                    out[j, :, : u.shape[1]] = u
            tabs[f"{t}:{prefix}ops"] = np.swapaxes(ops, 0, 1).copy()
            tabs[f"{t}:{prefix}out"] = np.swapaxes(out, 0, 1).copy()

    def _stack_exchange(self, tabs: Dict[str, np.ndarray],
                        ws: Sequence[int], m_pad: int):
        """Stack the all_to_all exchange tables of wavefronts ``ws`` into
        shard-major ``tabs["send"/"recv"] [n, L, n, m_pad]`` (trash-padded)
        — shared by the dense scan (all wavefronts, global M_max) and the
        segmented scan (one run, the run's own M_max)."""
        n = self.spec.n_shards
        send = np.full((len(ws), n, n, m_pad), self.trash, np.int32)
        recv = np.full((len(ws), n, n, m_pad), self.trash, np.int32)
        for j, w in enumerate(ws):
            s_i, r_i = self.exchange[w]
            send[j, :, :, : s_i.shape[-1]] = s_i
            recv[j, :, :, : r_i.shape[-1]] = r_i
        tabs["send"] = np.swapaxes(send, 0, 1).copy()
        tabs["recv"] = np.swapaxes(recv, 0, 1).copy()

    def _dense_scan_tables(self) -> Tuple[Dict[str, np.ndarray], int]:
        """Memoized global stacking for the pure dense scan: tables padded
        to global T_max per type, exchanges to the global M_max."""
        key = ("dense_scan_tables",)
        if key in self._cache:
            return self._cache[key]  # type: ignore[return-value]
        M_max = max((e[0].shape[-1] for e in self.exchange), default=0)
        # Stack tables shard-major: [n_shards, W, ...]; a single P(axis)
        # sharding then hands each shard exactly its own rows.
        tabs_np: Dict[str, np.ndarray] = {}
        self._stack_tables(tabs_np, "", self.tables)
        if M_max:
            self._stack_exchange(tabs_np, range(len(self.tables)), M_max)
        self._cache[key] = (tabs_np, M_max)
        return self._cache[key]  # type: ignore[return-value]

    @staticmethod
    def _ex_keys(sig: Tuple) -> Tuple[List[str], List[str]]:
        """Exchange table keys of a segment with comm signature ``sig``."""
        if sig[0] == "all_to_all":
            return ["send"], ["recv"]
        if sig[0] == "ppermute":
            rr = range(len(sig[1]))
            return [f"send{r}" for r in rr], [f"recv{r}" for r in rr]
        return [], []

    def _segment_tables(self, comm: str, density_threshold: float,
                        overlap: bool, cover: str = "exact"
                        ) -> List[Tuple[int, int, Tuple,
                                        Dict[str, np.ndarray]]]:
        """Memoized per-segment stacked tables for the segmented-scan
        lowering: ``[(start, stop, signature, tabs)]``, with compute tables
        padded to the segment's T_max and exchange tables to the segment's
        M_max (all_to_all) / per-round max widths (ppermute).

        ``overlap=True`` stores the halo split instead: the segment head's
        exact (indep, dep) tables under ``h:*`` keys plus stacked splits for
        the scanned tail — landing wavefront w-1's arrivals *between* w's
        halo-independent and -dependent compute is what lets the collective
        run concurrently with compute inside the scan.

        ``cover="union"`` stacks each sparse segment's exchange from the
        union cover's rounds (:meth:`_rounds_for`) instead of each
        wavefront's own — same table shapes, same scan body, just more
        trash padding where a pair sits a wavefront out."""
        key = ("seg_tables", comm, density_threshold, overlap, cover)
        if key in self._cache:
            return self._cache[key]  # type: ignore[return-value]
        runs, sigs = self._segment_plan(comm, density_threshold, cover)
        n, trash = self.spec.n_shards, self.trash
        segs = []
        for (s, e) in runs:
            sig, L = sigs[s], e - s
            tabs: Dict[str, np.ndarray] = {}
            if not overlap:
                self._stack_tables(tabs, "", self.tables[s:e])
            else:
                splits = [self._split_tables(w) for w in range(s, e)]
                for t, (o, u) in splits[0][0].items():
                    tabs[f"h:{t}:iops"], tabs[f"h:{t}:iout"] = o, u
                for t, (o, u) in (splits[0][1] or {}).items():
                    tabs[f"h:{t}:dops"], tabs[f"h:{t}:dout"] = o, u
                if L > 1:
                    self._stack_tables(tabs, "i", [sp[0] for sp in splits[1:]])
                    self._stack_tables(tabs, "d",
                                       [sp[1] or {} for sp in splits[1:]])
            if sig[0] == "all_to_all":
                m_seg = max(self.exchange[w][0].shape[-1] for w in range(s, e))
                self._stack_exchange(tabs, range(s, e), m_seg)
            elif sig[0] == "ppermute":
                per_w = {w: self._rounds_for(w, sig, cover)
                         for w in range(s, e)}
                for r in range(len(sig[1])):
                    wr = max(per_w[w][r].width for w in range(s, e))
                    snd = np.full((L, n, wr), trash, np.int32)
                    rcv = np.full((L, n, wr), trash, np.int32)
                    for j, w in enumerate(range(s, e)):
                        rnd = per_w[w][r]
                        snd[j, :, : rnd.width] = rnd.send
                        rcv[j, :, : rnd.width] = rnd.recv
                    tabs[f"send{r}"] = np.swapaxes(snd, 0, 1).copy()
                    tabs[f"recv{r}"] = np.swapaxes(rcv, 0, 1).copy()
            segs.append((s, e, sig, tabs))
        self._cache[key] = segs
        return segs

    # ----------------------------------------------- lowering: executors

    def executor(
        self,
        bodies: Dict[str, Callable[..., jnp.ndarray]],
        mesh: Mesh,
        axis: str = "shards",
        *,
        scan: bool = True,
        comm: Optional[str] = None,
        overlap: bool = False,
        density_threshold: float = 0.5,
        cover: str = "exact",
    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """Build the jittable SPMD executor.

        ``bodies[t](*operand_blocks) -> out_block`` — pure per-block compute
        (jnp or a Pallas kernel). Three lowerings:

        - ``scan=False`` **unrolls**, choosing each wavefront's collective
          from its :class:`CommPattern` under policy ``comm`` ("dense" |
          "sparse" | "auto"; default "auto") with per-wavefront padding —
          HLO grows linearly with depth.
        - ``scan=True, comm="dense"`` (the ``scan`` default) is the **pure
          dense scan**: one ``jax.lax.scan`` over all wavefronts, tables
          padded to global maxima, every exchange the global all_to_all —
          minimal HLO, maximal padding.
        - ``scan=True, comm="sparse"|"auto"`` (or dense with ``overlap``) is
          the **segmented scan**: the wavefront sequence is partitioned into
          maximal runs of equal comm signature (:meth:`segments`) and each
          run becomes one scan carrying that run's sparse collective, padded
          to the run's own maxima — sparse wire at scan-sized HLO. With
          ``cover="union"`` the sparse runs are coarsened to the union
          permutation cover first (:meth:`segments`), so even a schedule
          whose exact signatures fragment (deep FFT's stride cycling) folds
          into a handful of scans — at the honestly-accounted cost of
          trash slots where a pair sits a wavefront out.

        ``overlap=True`` double-buffers the exchange in the unrolled and
        segmented lowerings: issue wavefront w's collective, run w+1's
        halo-independent tasks, land the arrivals, then run the
        halo-dependent tasks — compute/comm overlap, carried across segment
        boundaries in the segmented scan.

        All variants are numerically identical: same bodies over the same
        operand values, in a dependency-respecting order.

        Input/output: ``blocks [n_shards, n_slots, b0, b1]`` sharded P(axis).
        """
        n = self.spec.n_shards
        if mesh.shape[axis] != n:
            raise ValueError(f"mesh axis {axis}={mesh.shape[axis]} != {n} shards")
        if comm is None:
            comm = "dense" if scan else "auto"
        if comm not in ("dense", "sparse", "auto"):
            raise ValueError(f"unknown comm policy {comm!r}")
        if cover not in ("exact", "union"):
            raise ValueError(f"unknown signature cover {cover!r}")
        if scan:
            if comm == "dense" and not overlap:
                return self._dense_scan_executor(bodies, mesh, axis)
            return self._segmented_scan_executor(
                bodies, mesh, axis, comm=comm, overlap=overlap,
                density_threshold=density_threshold, cover=cover)
        return self._unrolled_executor(
            bodies, mesh, axis, comm=comm, overlap=overlap,
            density_threshold=density_threshold)

    def _dense_scan_executor(self, bodies, mesh, axis):
        """One global scan, dense all_to_all padded to global maxima."""
        wavefront_compute = self._compute_fn(bodies)
        tabs_np, M_max = self._dense_scan_tables()

        def run(local, tabs):
            # local: [1, n_slots, b0, b1]; tabs: {k: [1, W, ...]}
            tabs0 = {k: v[0] for k, v in tabs.items()}  # [W, ...]

            def step(loc, wtab):
                loc0 = loc[0]
                tbl = {t: (wtab[f"{t}:ops"], wtab[f"{t}:out"])
                       for t in self.types if f"{t}:ops" in wtab}
                loc0 = wavefront_compute(loc0, tbl)
                if M_max:
                    buf = loc0[wtab["send"]]         # [n, M, b0, b1]
                    buf = jax.lax.all_to_all(buf, axis, split_axis=0,
                                             concat_axis=0, tiled=True)
                    loc0 = loc0.at[wtab["recv"].reshape(-1)].set(
                        buf.reshape(-1, *loc0.shape[1:]))
                return loc0[None], None

            local, _ = jax.lax.scan(step, local, tabs0)
            return local

        shmapped = _shard_map(
            run, mesh=mesh,
            in_specs=(P(axis), {k: P(axis) for k in tabs_np}),
            out_specs=P(axis))

        def entry(blocks):
            return shmapped(blocks, _narrow_tables(tabs_np))

        return entry

    def _segmented_scan_executor(self, bodies, mesh, axis, *, comm,
                                 overlap, density_threshold,
                                 cover="exact"):
        """One ``jax.lax.scan`` per run of equal comm signature, stitched
        sequentially: sparse (ppermute-round) exchanges inside scans without
        unrolled-HLO growth. With ``overlap`` the scan carry holds the
        in-flight exchange buffers (double buffering), and each segment's
        head wavefront is unrolled so the pending buffers of the *previous*
        segment — a different carry shape — land across the boundary.
        ``cover="union"`` runs the same machinery over the union-cover
        segment plan — only the (static) perms and the table contents
        change, never the scan-body structure."""
        segs = self._segment_tables(comm, density_threshold, overlap, cover)
        wavefront_compute = self._compute_fn(bodies)

        def tbl_of(wtab, prefix=""):
            return {t: (wtab[f"{t}:{prefix}ops"], wtab[f"{t}:{prefix}out"])
                    for t in self.types if f"{t}:{prefix}ops" in wtab}

        def seg_issue(loc0, rows, sig):
            """Issue one wavefront's exchange from segment-padded tables;
            returns the in-flight buffers (the scan-carry pytree)."""
            if sig[0] == "all_to_all":
                buf = loc0[rows["send"]]             # [n, M_seg, b0, b1]
                buf = jax.lax.all_to_all(buf, axis, split_axis=0,
                                         concat_axis=0, tiled=True)
                return (buf.reshape(-1, *loc0.shape[1:]),)
            if sig[0] == "ppermute":
                return tuple(
                    jax.lax.ppermute(loc0[rows[f"send{r}"]], axis, list(perm))
                    for r, perm in enumerate(sig[1]))
            return ()

        def seg_land(loc0, rows, sig, bufs):
            if sig[0] == "all_to_all":
                return loc0.at[rows["recv"].reshape(-1)].set(
                    bufs[0].astype(loc0.dtype))
            for r in range(len(sig[1]) if sig[0] == "ppermute" else 0):
                loc0 = loc0.at[rows[f"recv{r}"]].set(
                    bufs[r].astype(loc0.dtype))
            return loc0

        def run(local, seg_tabs):
            loc = local                              # [1, n_slots, b0, b1]
            for (s, e, sig, _), tabs in zip(segs, seg_tabs):
                tabs0 = {k: v[0] for k, v in tabs.items()}   # [L, ...]

                def step(loc_, wtab, _sig=sig):
                    loc0 = wavefront_compute(loc_[0], tbl_of(wtab))
                    bufs = seg_issue(loc0, wtab, _sig)
                    loc0 = seg_land(loc0, wtab, _sig, bufs)
                    return loc0[None], None

                if e - s == 1:
                    loc, _ = step(loc, {k: v[0] for k, v in tabs0.items()})
                else:
                    loc, _ = jax.lax.scan(step, loc, tabs0)
            return loc

        def run_overlap(local, seg_tabs):
            loc0 = local[0]
            pending = None                # (sig, recv rows, in-flight bufs)
            for (s, e, sig, _), tabs in zip(segs, seg_tabs):
                t0 = {k: v[0] for k, v in tabs.items()}
                L = e - s
                send_keys, recv_keys = self._ex_keys(sig)
                # -- head wavefront (unrolled): lands the previous segment's
                # pending buffers between its indep and dep compute
                indep = {t: (t0[f"h:{t}:iops"], t0[f"h:{t}:iout"])
                         for t in self.types if f"h:{t}:iops" in t0}
                loc0 = wavefront_compute(loc0, indep)
                if pending is not None:
                    loc0 = seg_land(loc0, pending[1], pending[0], pending[2])
                    pending = None
                dep = {t: (t0[f"h:{t}:dops"], t0[f"h:{t}:dout"])
                       for t in self.types if f"h:{t}:dops" in t0}
                if dep:
                    loc0 = wavefront_compute(loc0, dep)
                bufs = seg_issue(loc0, {k: t0[k][0] for k in send_keys}, sig)
                if L > 1:
                    xs = {k: t0[k] for k in t0
                          if not k.startswith("h:")
                          and (":iops" in k or ":iout" in k
                               or ":dops" in k or ":dout" in k)}
                    xs.update({k: t0[k][1:] for k in send_keys})
                    xs.update({k: t0[k][: L - 1] for k in recv_keys})

                    def step(carry, wtab, _sig=sig):
                        c0, *c_bufs = carry
                        c0 = wavefront_compute(c0, tbl_of(wtab, "i"))
                        c0 = seg_land(c0, wtab, _sig, c_bufs)
                        c0 = wavefront_compute(c0, tbl_of(wtab, "d"))
                        return (c0, *seg_issue(c0, wtab, _sig)), None

                    carry, _ = jax.lax.scan(step, (loc0, *bufs), xs)
                    loc0, *bufs = carry
                if sig[0] != "none":
                    pending = (sig, {k: t0[k][L - 1] for k in recv_keys},
                               tuple(bufs))
            if pending is not None:       # W-1 never sends; safety net
                loc0 = seg_land(loc0, pending[1], pending[0], pending[2])
            return loc0[None]

        tabs_tree = [tabs for (_s, _e, _sig, tabs) in segs]
        shmapped = _shard_map(
            run_overlap if overlap else run, mesh=mesh,
            in_specs=(P(axis), jax.tree.map(lambda _: P(axis), tabs_tree)),
            out_specs=P(axis))

        def entry(blocks):
            return shmapped(blocks, _narrow_tables(tabs_tree))

        return entry

    def _unrolled_executor(self, bodies, mesh, axis, *, comm, overlap,
                           density_threshold):
        n = self.spec.n_shards
        wavefront_compute = self._compute_fn(bodies)
        # Each wavefront's exchange is *issued* as (recv_rows, buf) pairs and
        # *landed* by scattering; with overlap the landing is deferred past
        # the next wavefront's halo-independent compute, so the collectives
        # have no data dependency on it and XLA's scheduler can run both
        # concurrently.
        W = len(self.tables)
        choices = [self.lowered_pattern(w, comm, density_threshold)
                   for w in range(W)]

        def issue(loc0, idx, w):
            if choices[w] == "none":
                return []
            if choices[w] == "all_to_all":
                s_i, r_i = self.exchange[w]
                buf = loc0[_narrow_tables(s_i)[idx]]  # [n, M, b0, b1]
                buf = jax.lax.all_to_all(buf, axis, split_axis=0,
                                         concat_axis=0, tiled=True)
                recv = _narrow_tables(r_i)[idx].reshape(-1)
                return [(recv, buf.reshape(-1, *loc0.shape[1:]))]
            pending = []
            for rnd in self.sparse_exchange[w]:      # ppermute rounds
                buf = loc0[_narrow_tables(rnd.send)[idx]]  # [width, b0, b1]
                buf = jax.lax.ppermute(buf, axis, list(rnd.perm))
                pending.append((_narrow_tables(rnd.recv)[idx], buf))
            return pending

        def land(loc0, pending):
            for recv, buf in pending:
                loc0 = loc0.at[recv].set(buf.astype(loc0.dtype))
            return loc0

        def shard_tbl(tbl, idx):
            return {t: (_narrow_tables(o)[idx], _narrow_tables(u)[idx])
                    for t, (o, u) in tbl.items()}

        def run_unrolled(local):
            loc0 = local[0]
            idx = jax.lax.axis_index(axis)
            pending: list = []
            for w in range(W):
                if overlap and pending:
                    indep, dep = self._split_tables(w)
                    loc0 = wavefront_compute(loc0, shard_tbl(indep, idx))
                    loc0 = land(loc0, pending)
                    if dep:
                        loc0 = wavefront_compute(loc0, shard_tbl(dep, idx))
                else:
                    loc0 = land(loc0, pending)
                    loc0 = wavefront_compute(loc0,
                                             shard_tbl(self.tables[w], idx))
                pending = issue(loc0, idx, w)
            loc0 = land(loc0, pending)  # W-1 never sends; safety net
            return loc0[None]

        return _shard_map(run_unrolled, mesh=mesh, in_specs=(P(axis),),
                          out_specs=P(axis))

    def plan_lowering(
        self,
        *,
        unroll_cap: int = 64,
        comm: str = "auto",
        overlap: bool = True,
        segment_cap: Optional[int] = None,
        density_threshold: float = 0.5,
    ) -> dict:
        """Decide how :meth:`auto_executor` lowers this program — returned
        as data so tests and benchmarks can assert on the policy itself.

        - depth <= ``unroll_cap``: **unrolled** (per-wavefront collective
          choice, exact padding);
        - deeper, and the comm signatures form <= ``segment_cap`` (default
          ``unroll_cap``) runs: **segmented scan** — the caller's ``comm`` /
          ``overlap`` preference is preserved;
        - deeper and genuinely dense (no wavefront lowers to ppermute, no
          overlap asked): **pure dense scan** — there is no sparsity to
          keep, so take the single-scan minimal HLO;
        - deeper and too fragmented to segment exactly, but the **union
          permutation cover** fits the cap *and* its honestly-accounted
          wire efficiency still beats what the pure dense scan would ship:
          **union-cover scan** (``mode="union_cover"``) — fragmented runs
          fold into scans over the union rounds, trash-padding the inactive
          (pair, wavefront) slots;
        - otherwise: **dense scan** with ``discards=True`` — the caller's
          preference is dropped, which :meth:`auto_executor` reports loudly
          instead of silently.
        """
        W = self.schedule.n_wavefronts
        cap = unroll_cap if segment_cap is None else segment_cap
        plan = {"comm": comm, "overlap": overlap, "n_wavefronts": W,
                "discards": False, "cover": "exact"}
        if W <= unroll_cap:
            plan.update(mode="unrolled",
                        reason=f"depth {W} <= unroll_cap {unroll_cap}")
            return plan
        if comm == "dense" and not overlap:
            plan.update(mode="dense_scan", reason="dense lowering requested")
            return plan
        runs, _ = self._segment_plan(comm, density_threshold)
        plan["n_segments"] = len(runs)
        sparse_any = any(
            self.lowered_pattern(w, comm, density_threshold) == "ppermute"
            for w in range(W))
        if not sparse_any and not overlap:
            plan.update(mode="dense_scan",
                        reason="genuinely dense: no wavefront lowers to "
                               "ppermute under this policy")
        elif len(runs) <= cap:
            plan.update(mode="segmented_scan",
                        reason=f"{len(runs)} segments <= "
                               f"segment_cap {cap}")
        else:
            # exact signatures fragment; before discarding the sparse wire,
            # try the union permutation cover, keeping it only when the
            # padding it adds still undercuts the dense scan's.
            uruns, _ = self._segment_plan(comm, density_threshold, "union")
            ustats = self.comm_stats(comm=comm,
                                     density_threshold=density_threshold,
                                     segmented=True, cover="union")
            n = self.spec.n_shards
            m_max = max((e[0].shape[-1] for e in self.exchange), default=0)
            scan_wire = W * n * n * m_max
            real = sum(p.total for p in self.patterns)
            eff_dense_scan = real / scan_wire if scan_wire else 1.0
            plan["n_segments_union"] = len(uruns)
            plan["wire_efficiency_union"] = ustats["wire_efficiency"]
            plan["wire_efficiency_dense_scan"] = eff_dense_scan
            if (len(uruns) <= cap
                    and ustats["wire_efficiency"] > eff_dense_scan):
                plan.update(
                    mode="union_cover", cover="union",
                    reason=f"exact comm signatures too fragmented "
                           f"({len(runs)} segments > segment_cap {cap}); "
                           f"union cover folds them into {len(uruns)} "
                           f"segments at wire efficiency "
                           f"{ustats['wire_efficiency']:.3f} > dense scan's "
                           f"{eff_dense_scan:.3f}")
            else:
                why = (f"union cover still fragmented ({len(uruns)} "
                       f"segments > segment_cap {cap})"
                       if len(uruns) > cap else
                       f"union cover wire efficiency "
                       f"{ustats['wire_efficiency']:.3f} <= dense scan's "
                       f"{eff_dense_scan:.3f}")
                plan.update(mode="dense_scan", discards=True,
                            reason=f"comm signatures too fragmented: "
                                   f"{len(runs)} segments > segment_cap "
                                   f"{cap}, and {why}")
        return plan

    def auto_executor(
        self,
        bodies: Dict[str, Callable[..., jnp.ndarray]],
        mesh: Mesh,
        axis: str = "shards",
        *,
        unroll_cap: int = 64,
        density_threshold: float = 0.5,
        comm: str = "auto",
        overlap: bool = True,
        segment_cap: Optional[int] = None,
    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """The default lowering policy, shared by every consumer (linalg
        apps, benchmarks) — see :meth:`plan_lowering`: shallow schedules
        unroll with per-wavefront sparse/dense collective choice and
        compute/comm overlap; deeper schedules keep the sparse wire through
        the segmented scan (coarsened to the union permutation cover when
        the exact signatures fragment but the cover's wire still beats the
        dense scan's); only genuinely dense or hopelessly fragmented
        schedules take the pure dense scan. When that last fallback discards
        the caller's ``comm``/``overlap`` preference it is logged loudly —
        never silent."""
        plan = self.plan_lowering(
            unroll_cap=unroll_cap, comm=comm, overlap=overlap,
            segment_cap=segment_cap, density_threshold=density_threshold)
        if plan["mode"] == "unrolled":
            return self.executor(bodies, mesh, axis, scan=False, comm=comm,
                                 overlap=overlap,
                                 density_threshold=density_threshold)
        if plan["mode"] in ("segmented_scan", "union_cover"):
            return self.executor(bodies, mesh, axis, scan=True, comm=comm,
                                 overlap=overlap,
                                 density_threshold=density_threshold,
                                 cover=plan["cover"])
        if plan["discards"]:
            logger.warning(
                "auto_executor: depth %d > unroll_cap %d and %s; falling "
                "back to the pure dense scan and DISCARDING the caller's "
                "comm=%r/overlap=%r preference (raise segment_cap to force "
                "the segmented scan, or pass comm='dense' to silence this)",
                plan["n_wavefronts"], unroll_cap, plan["reason"],
                comm, overlap)
        return self.executor(bodies, mesh, axis, scan=True, comm="dense")


def build_block_program(spec: BlockPTGSpec, *,
                        validate: bool = False) -> BlockProgram:
    """Discover the schedule and build all index tables (host side, numpy).

    When ``spec.views`` is set (the lazy per-shard derivation,
    ``repro.ptg.Graph.to_block_spec(lazy=True)``), discovery runs in local
    mode: shard ``s`` expands through ``views[s]`` only, and every later
    per-task query dispatches to the owning shard's view — the schedule and
    all lowered tables are built from the union of per-shard views without
    the global edge dicts ever existing.

    ``validate=True`` additionally runs ``PTG.check_consistency`` over every
    discovered task (mutual-inverse in/out edges + mapping stability) —
    recommended for hand-written specs; :mod:`repro.ptg` graphs carry the
    guarantee by construction."""
    ptg, n = spec.ptg, spec.n_shards
    if spec.views is not None:
        sched = discover_local(spec.views, n, validate=validate)
    else:
        sched = discover(ptg, spec.seeds, n, validate=validate)
    sched.validate(ptg)

    # --- slot assignment: owned blocks first, then halo copies, then trash.
    owned: List[List[B]] = [[] for _ in range(n)]
    seen: set = set()
    all_tasks = [k for s in sched.shards for wf in s.wavefronts for k in wf]
    for k in all_tasks:
        for blk in list(spec.operands(k)) + [spec.block_of(k)]:
            if blk not in seen:
                seen.add(blk)
                owned[spec.owner(blk) % n].append(blk)
    for k in all_tasks:  # "owner computes" rule
        if spec.owner(spec.block_of(k)) % n != ptg.mapping(k) % n:
            raise ValueError(
                f"task {k!r} writes block {spec.block_of(k)!r} it does not own")

    halo_needed: Dict[int, List[B]] = defaultdict(list)
    writer_count: Dict[B, int] = defaultdict(int)
    messaged: set = set()
    for k in all_tasks:
        writer_count[spec.block_of(k)] += 1
        s = ptg.mapping(k) % n
        for blk in spec.operands(k):
            if spec.owner(blk) % n != s and blk not in halo_needed[s]:
                halo_needed[s].append(blk)
                messaged.add(blk)
    for blk in messaged:
        if writer_count[blk] > 1:
            raise ValueError(
                f"block {blk!r} crosses shards but has {writer_count[blk]} "
                "writers (communicated blocks must be single-assignment)")

    # Every remote read must be fed by a *direct* in-dep edge from the
    # block's writer — that edge is what carries the payload (the AM). A
    # remote read with no such edge would never be delivered.
    for k in all_tasks:
        s = ptg.mapping(k) % n
        producers = {spec.block_of(d) for d in ptg.in_deps(k)}
        for blk in spec.operands(k):
            if spec.owner(blk) % n != s and blk not in producers:
                raise ValueError(
                    f"task {k!r} reads remote block {blk!r} but no in-dep "
                    "produces it (missing send edge in the PTG)")

    slot_of: Dict[B, Tuple[int, int]] = {}
    halo_slot: Dict[Tuple[int, B], int] = {}
    counts = []
    for s in range(n):
        slot = 0
        for blk in owned[s]:
            slot_of[blk] = (s, slot)
            slot += 1
        for blk in halo_needed[s]:
            halo_slot[(s, blk)] = slot
            slot += 1
        counts.append(slot)
    n_slots = max(counts) + 1  # + trash
    trash = n_slots - 1

    def local_slot(s: int, blk: B) -> int:
        os_, slot = slot_of[blk]
        return slot if os_ == s else halo_slot[(s, blk)]

    # --- task type metadata
    types = sorted({ptg.type_of(k) for k in all_tasks})
    arity: Dict[str, int] = {}
    for k in all_tasks:
        t = ptg.type_of(k)
        a = len(spec.operands(k))
        if arity.setdefault(t, a) != a:
            raise ValueError(f"type {t!r} has inconsistent arity")

    # --- per-wavefront compute tables
    W = sched.n_wavefronts
    tables: List[Dict[str, Tuple[np.ndarray, np.ndarray]]] = []
    for w in range(W):
        by_shard_type: Dict[str, List[List[K]]] = defaultdict(
            lambda: [[] for _ in range(n)])
        for s in range(n):
            for k in sched.shards[s].wavefronts[w]:
                by_shard_type[ptg.type_of(k)][s].append(k)
        tbl: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        for t, rows in by_shard_type.items():
            T = max(len(r) for r in rows)
            if T == 0:
                continue
            ops = np.full((n, T, arity[t]), trash, np.int32)
            out = np.full((n, T), trash, np.int32)
            for s in range(n):
                outs = [local_slot(s, spec.block_of(k)) for k in rows[s]]
                assert len(set(outs)) == len(outs), (
                    f"wavefront {w} shard {s}: duplicate output slots")
                for i, k in enumerate(rows[s]):
                    for j, blk in enumerate(spec.operands(k)):
                        ops[s, i, j] = local_slot(s, blk)
                    out[s, i] = outs[i]
            tbl[t] = (ops, out)
        tables.append(tbl)

    # --- per-wavefront exchange tables, lowered from the schedule's fused
    # per-(src, dst) communication plan ("large AMs" — shared with
    # repro.dist.pipeline, which lowers the same plan to collective permutes)
    exchange: List[Tuple[np.ndarray, np.ndarray]] = []
    patterns: List[CommPattern] = []
    sparse_exchange: List[List[SparseRound]] = []
    for w in range(W):
        groups = sched.comm_plan(w)
        per_pair: Dict[Tuple[int, int], List[B]] = {}
        for (src, dst), msgs in groups.items():
            # Only data-carrying edges ride the wire (control-only edges are
            # implied by wavefront ordering). Multiple consumers of a block
            # on the same dst share one copy. Slot order is the stable sort
            # key: unique per block on its owner, integer-cheap, identical
            # across Python versions (repr ties are neither).
            blks = sorted(
                {spec.block_of(m.src_task) for m in msgs
                 if spec.block_of(m.src_task) in set(spec.operands(m.dst_task))},
                key=lambda blk: slot_of[blk][1])
            if blks:
                per_pair[(src, dst)] = blks
        M = max((len(v) for v in per_pair.values()), default=0)
        send = np.full((n, n, M), trash, np.int32)   # [src, dst, m]
        recv = np.full((n, n, M), trash, np.int32)   # [dst, src, m]
        for (src, dst), blks in per_pair.items():
            for m, blk in enumerate(blks):
                send[src, dst, m] = local_slot(src, blk)
                recv[dst, src, m] = halo_slot[(dst, blk)]
        exchange.append((send, recv))

        # the same plan as ppermute rounds (sparse lowering)
        pattern = CommPattern(
            level=w, n_shards=n,
            pair_counts={p: len(b) for p, b in sorted(per_pair.items())})
        patterns.append(pattern)
        rounds: List[SparseRound] = []
        for perm in pattern.rounds():
            width = max(len(per_pair[p]) for p in perm)
            r_send = np.full((n, width), trash, np.int32)
            r_recv = np.full((n, width), trash, np.int32)
            for src, dst in perm:
                for m, blk in enumerate(per_pair[(src, dst)]):
                    r_send[src, m] = local_slot(src, blk)
                    r_recv[dst, m] = halo_slot[(dst, blk)]
            rounds.append(SparseRound(tuple(perm), r_send, r_recv))
        sparse_exchange.append(rounds)

    return BlockProgram(spec, sched, slot_of, halo_slot, n_slots, types,
                        arity, tables, exchange, patterns, sparse_exchange)
