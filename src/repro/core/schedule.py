"""Lowering a block-PTG to a lockstep SPMD program — TaskTorrent on TPU.

The host runtime executes the PTG asynchronously; a TPU pod is lockstep
SPMD, so we lower the *schedule produced by parallel discovery*
(`discovery.discover`) into data: per-(wavefront, task-type) index tables,
and a per-wavefront exchange plan. One generic `shard_map` executor then
runs *any* block PTG (GEMM, Cholesky, ...):

    wavefront w:  for each task type t:
                      gather operand blocks by table -> vmap(body_t) -> scatter
                  exchange: all_to_all of the blocks crossing shards at w
                      (all messages of a (src,dst) pair ride one buffer — the
                      compiled analogue of the paper's *large AM* batching)

Contract (checked at build time):
- every task writes exactly one block, owned by the task's shard
  ("owner computes" — the paper's 2D GEMM mapping rule);
- a block that crosses shards has exactly one writer (single assignment for
  communicated data; local blocks may be read-modify-written freely);
- operand reads always see the value produced at a strictly earlier
  wavefront (guaranteed by the leveling, re-checked here).

Padding goes to a *trash slot*: padded gathers read it, padded bodies write
it back, padded messages land in the receiver's trash. Real slots are never
aliased with trash, so garbage cannot contaminate results.
"""

from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, List, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:
    from jax import shard_map  # jax >= 0.5
except ImportError:  # pragma: no cover — older jax keeps it experimental
    from jax.experimental.shard_map import shard_map

from .discovery import PTG, WavefrontSchedule, discover

K = Hashable
B = Hashable  # block id


@dataclass(frozen=True)
class BlockPTGSpec:
    """Application -> executor contract for a block-structured PTG."""

    ptg: PTG
    seeds: Sequence[K]
    n_shards: int
    block_shape: Tuple[int, int]
    block_of: Callable[[K], B]            # block written by task k
    operands: Callable[[K], Sequence[B]]  # blocks read by k (fixed arity per type)
    owner: Callable[[B], int]             # shard owning block b
    dtype: object = jnp.float32


@dataclass
class BlockProgram:
    """Host-built schedule-as-data, ready to lower."""

    spec: BlockPTGSpec
    schedule: WavefrontSchedule
    slot_of: Dict[B, Tuple[int, int]]       # block -> (owner shard, slot)
    halo_slot: Dict[Tuple[int, B], int]     # (shard, block) -> halo copy slot
    n_slots: int                            # incl. trash slot (last)
    types: List[str]
    arity: Dict[str, int]
    # tables[w][t] = (ops_idx [n_shards, T, arity], out_idx [n_shards, T])
    tables: List[Dict[str, Tuple[np.ndarray, np.ndarray]]]
    # exchange[w] = (send_idx [src, dst, M], recv_idx [dst, src, M])
    exchange: List[Tuple[np.ndarray, np.ndarray]]

    # ------------------------------------------------------------ packing

    @property
    def trash(self) -> int:
        return self.n_slots - 1

    def pack(self, blocks: Dict[B, np.ndarray]) -> np.ndarray:
        """Host layout: {block id: array} -> [n_shards, n_slots, b0, b1]."""
        b0, b1 = self.spec.block_shape
        out = np.zeros((self.spec.n_shards, self.n_slots, b0, b1),
                       dtype=np.dtype(jnp.dtype(self.spec.dtype)))
        for blk, arr in blocks.items():
            s, slot = self.slot_of[blk]
            out[s, slot] = arr
        return out

    def unpack(self, packed) -> Dict[B, np.ndarray]:
        packed = np.asarray(packed)
        return {blk: packed[s, slot] for blk, (s, slot) in self.slot_of.items()}

    # ------------------------------------------------------------- stats

    def comm_stats(self) -> dict:
        """Bytes on the wire per wavefront — feeds the roofline's collective
        term and the §Perf iteration log."""
        b0, b1 = self.spec.block_shape
        block_bytes = b0 * b1 * np.dtype(jnp.dtype(self.spec.dtype)).itemsize
        per_wave = []
        for send, _ in self.exchange:
            real = int((send != self.n_slots - 1).sum())
            padded = int(np.prod(send.shape))
            per_wave.append({"real_blocks": real, "padded_blocks": padded})
        return {
            "block_bytes": block_bytes,
            "wavefronts": len(self.exchange),
            "real_bytes": sum(w["real_blocks"] for w in per_wave) * block_bytes,
            "padded_bytes": sum(w["padded_blocks"] for w in per_wave) * block_bytes,
            "per_wavefront": per_wave,
        }

    # ----------------------------------------------------------- lowering

    def executor(
        self,
        bodies: Dict[str, Callable[..., jnp.ndarray]],
        mesh: Mesh,
        axis: str = "shards",
        *,
        scan: bool = True,
    ) -> Callable[[jnp.ndarray], jnp.ndarray]:
        """Build the jittable SPMD executor.

        ``bodies[t](*operand_blocks) -> out_block`` — pure per-block compute
        (jnp or a Pallas kernel). ``scan=True`` pads tables to uniform shapes
        and scans over wavefronts (small HLO — deep schedules);
        ``scan=False`` unrolls and skips empty types/exchanges per wavefront
        (tight comm — shallow schedules).

        Input/output: ``blocks [n_shards, n_slots, b0, b1]`` sharded P(axis).
        """
        n = self.spec.n_shards
        if mesh.shape[axis] != n:
            raise ValueError(f"mesh axis {axis}={mesh.shape[axis]} != {n} shards")

        def wavefront_compute(local, tbl):
            # local: [n_slots, b0, b1]; tbl[t] = (ops_idx [T, ar], out_idx [T])
            for t in self.types:
                if t not in tbl or tbl[t][0].shape[0] == 0:
                    continue
                ops_idx, out_idx = tbl[t]
                ops = local[ops_idx]                 # [T, arity, b0, b1]
                res = jax.vmap(lambda o, _t=t: bodies[_t](*jnp.unstack(o)))(ops)
                local = local.at[out_idx].set(res.astype(local.dtype))
            return local

        def wavefront_exchange(local, send_idx, recv_idx):
            # send_idx: [n_dst, M] my blocks for each dst;
            # recv_idx: [n_src, M] where arrivals from each src land.
            buf = local[send_idx]                    # [n, M, b0, b1]
            buf = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=0,
                                     tiled=True)     # row j <- from shard j
            return local.at[recv_idx.reshape(-1)].set(
                buf.reshape(-1, *local.shape[1:]))

        if scan:
            W = len(self.tables)
            ar = self.arity
            T_max = {t: max((self.tables[w][t][0].shape[1]
                             if t in self.tables[w] else 0) for w in range(W))
                     for t in self.types}
            M_max = max((e[0].shape[-1] for e in self.exchange), default=0)
            # Stack tables shard-major: [n_shards, W, ...]; a single P(axis)
            # sharding then hands each shard exactly its own rows.
            tabs_np: Dict[str, np.ndarray] = {}
            for t in self.types:
                if T_max[t] == 0:
                    continue
                ops = np.full((W, n, T_max[t], ar[t]), self.trash, np.int32)
                out = np.full((W, n, T_max[t]), self.trash, np.int32)
                for w in range(W):
                    if t in self.tables[w]:
                        o, u = self.tables[w][t]
                        ops[w, :, : o.shape[1]] = o
                        out[w, :, : u.shape[1]] = u
                tabs_np[f"{t}:ops"] = np.swapaxes(ops, 0, 1).copy()
                tabs_np[f"{t}:out"] = np.swapaxes(out, 0, 1).copy()
            if M_max:
                send = np.full((W, n, n, M_max), self.trash, np.int32)
                recv = np.full((W, n, n, M_max), self.trash, np.int32)
                for w, (s_i, r_i) in enumerate(self.exchange):
                    send[w, :, :, : s_i.shape[-1]] = s_i
                    recv[w, :, :, : r_i.shape[-1]] = r_i
                tabs_np["send"] = np.swapaxes(send, 0, 1).copy()
                tabs_np["recv"] = np.swapaxes(recv, 0, 1).copy()

            def run(local, tabs):
                # local: [1, n_slots, b0, b1]; tabs: {k: [1, W, ...]}
                tabs0 = {k: v[0] for k, v in tabs.items()}  # [W, ...]

                def step(loc, wtab):
                    loc0 = loc[0]
                    tbl = {t: (wtab[f"{t}:ops"], wtab[f"{t}:out"])
                           for t in self.types if f"{t}:ops" in wtab}
                    loc0 = wavefront_compute(loc0, tbl)
                    if M_max:
                        loc0 = wavefront_exchange(loc0, wtab["send"],
                                                  wtab["recv"])
                    return loc0[None], None

                local, _ = jax.lax.scan(step, local, tabs0)
                return local

            shmapped = shard_map(
                run, mesh=mesh,
                in_specs=(P(axis), {k: P(axis) for k in tabs_np}),
                out_specs=P(axis))

            def entry(blocks):
                return shmapped(
                    blocks, {k: jnp.asarray(v) for k, v in tabs_np.items()})

            return entry

        # ------------------------------------------------- unrolled variant
        def run_unrolled(local):
            loc0 = local[0]
            idx = jax.lax.axis_index(axis)
            for w in range(len(self.tables)):
                tbl = {t: (jnp.asarray(o)[idx], jnp.asarray(u)[idx])
                       for t, (o, u) in self.tables[w].items()}
                loc0 = wavefront_compute(loc0, tbl)
                s_i, r_i = self.exchange[w]
                if s_i.shape[-1]:
                    loc0 = wavefront_exchange(
                        loc0, jnp.asarray(s_i)[idx], jnp.asarray(r_i)[idx])
            return loc0[None]

        return shard_map(run_unrolled, mesh=mesh, in_specs=(P(axis),),
                             out_specs=P(axis))


def build_block_program(spec: BlockPTGSpec) -> BlockProgram:
    """Discover the schedule and build all index tables (host side, numpy)."""
    ptg, n = spec.ptg, spec.n_shards
    sched = discover(ptg, spec.seeds, n)
    sched.validate(ptg)

    # --- slot assignment: owned blocks first, then halo copies, then trash.
    owned: List[List[B]] = [[] for _ in range(n)]
    seen: set = set()
    all_tasks = [k for s in sched.shards for wf in s.wavefronts for k in wf]
    for k in all_tasks:
        for blk in list(spec.operands(k)) + [spec.block_of(k)]:
            if blk not in seen:
                seen.add(blk)
                owned[spec.owner(blk) % n].append(blk)
    for k in all_tasks:  # "owner computes" rule
        if spec.owner(spec.block_of(k)) % n != ptg.mapping(k) % n:
            raise ValueError(
                f"task {k!r} writes block {spec.block_of(k)!r} it does not own")

    halo_needed: Dict[int, List[B]] = defaultdict(list)
    writer_count: Dict[B, int] = defaultdict(int)
    messaged: set = set()
    for k in all_tasks:
        writer_count[spec.block_of(k)] += 1
        s = ptg.mapping(k) % n
        for blk in spec.operands(k):
            if spec.owner(blk) % n != s and blk not in halo_needed[s]:
                halo_needed[s].append(blk)
                messaged.add(blk)
    for blk in messaged:
        if writer_count[blk] > 1:
            raise ValueError(
                f"block {blk!r} crosses shards but has {writer_count[blk]} "
                "writers (communicated blocks must be single-assignment)")

    # Every remote read must be fed by a *direct* in-dep edge from the
    # block's writer — that edge is what carries the payload (the AM). A
    # remote read with no such edge would never be delivered.
    for k in all_tasks:
        s = ptg.mapping(k) % n
        producers = {spec.block_of(d) for d in ptg.in_deps(k)}
        for blk in spec.operands(k):
            if spec.owner(blk) % n != s and blk not in producers:
                raise ValueError(
                    f"task {k!r} reads remote block {blk!r} but no in-dep "
                    "produces it (missing send edge in the PTG)")

    slot_of: Dict[B, Tuple[int, int]] = {}
    halo_slot: Dict[Tuple[int, B], int] = {}
    counts = []
    for s in range(n):
        slot = 0
        for blk in owned[s]:
            slot_of[blk] = (s, slot)
            slot += 1
        for blk in halo_needed[s]:
            halo_slot[(s, blk)] = slot
            slot += 1
        counts.append(slot)
    n_slots = max(counts) + 1  # + trash
    trash = n_slots - 1

    def local_slot(s: int, blk: B) -> int:
        os_, slot = slot_of[blk]
        return slot if os_ == s else halo_slot[(s, blk)]

    # --- task type metadata
    types = sorted({ptg.type_of(k) for k in all_tasks})
    arity: Dict[str, int] = {}
    for k in all_tasks:
        t = ptg.type_of(k)
        a = len(spec.operands(k))
        if arity.setdefault(t, a) != a:
            raise ValueError(f"type {t!r} has inconsistent arity")

    # --- per-wavefront compute tables
    W = sched.n_wavefronts
    tables: List[Dict[str, Tuple[np.ndarray, np.ndarray]]] = []
    for w in range(W):
        by_shard_type: Dict[str, List[List[K]]] = defaultdict(
            lambda: [[] for _ in range(n)])
        for s in range(n):
            for k in sched.shards[s].wavefronts[w]:
                by_shard_type[ptg.type_of(k)][s].append(k)
        tbl: Dict[str, Tuple[np.ndarray, np.ndarray]] = {}
        for t, rows in by_shard_type.items():
            T = max(len(r) for r in rows)
            if T == 0:
                continue
            ops = np.full((n, T, arity[t]), trash, np.int32)
            out = np.full((n, T), trash, np.int32)
            for s in range(n):
                outs = [local_slot(s, spec.block_of(k)) for k in rows[s]]
                assert len(set(outs)) == len(outs), (
                    f"wavefront {w} shard {s}: duplicate output slots")
                for i, k in enumerate(rows[s]):
                    for j, blk in enumerate(spec.operands(k)):
                        ops[s, i, j] = local_slot(s, blk)
                    out[s, i] = outs[i]
            tbl[t] = (ops, out)
        tables.append(tbl)

    # --- per-wavefront exchange tables, lowered from the schedule's fused
    # per-(src, dst) communication plan ("large AMs" — shared with
    # repro.dist.pipeline, which lowers the same plan to collective permutes)
    exchange: List[Tuple[np.ndarray, np.ndarray]] = []
    for w in range(W):
        groups = sched.comm_plan(w)
        per_pair: Dict[Tuple[int, int], List[B]] = {}
        for (src, dst), msgs in groups.items():
            # Only data-carrying edges ride the wire (control-only edges are
            # implied by wavefront ordering). Multiple consumers of a block
            # on the same dst share one copy.
            blks = sorted(
                {spec.block_of(m.src_task) for m in msgs
                 if spec.block_of(m.src_task) in set(spec.operands(m.dst_task))},
                key=repr)
            if blks:
                per_pair[(src, dst)] = blks
        M = max((len(v) for v in per_pair.values()), default=0)
        send = np.full((n, n, M), trash, np.int32)   # [src, dst, m]
        recv = np.full((n, n, M), trash, np.int32)   # [dst, src, m]
        for (src, dst), blks in per_pair.items():
            for m, blk in enumerate(blks):
                send[src, dst, m] = local_slot(src, blk)
                recv[dst, src, m] = halo_slot[(dst, blk)]
        exchange.append((send, recv))

    return BlockProgram(spec, sched, slot_of, halo_slot, n_slots, types,
                        arity, tables, exchange)
