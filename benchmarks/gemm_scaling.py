"""Fig 7 analogue: distributed GEMM on the PTG runtime.

- weak/strong scaling over emulated ranks (host backend, real numpy work);
- block-size sweep (Fig 7g): task granularity vs wall time;
- small-vs-large-AM comparison via the compiled backend's comm plan
  (fused per-pair buffers = large AMs; per-edge message count = small AMs);
- concurrency-efficiency curve (Fig 7h): num_blocks^2 / n_ranks.
"""

from __future__ import annotations

import time

import numpy as np
import jax.numpy as jnp

from repro.core.schedule import build_block_program
from repro.linalg.gemm import assemble, gemm_2d_spec, gemm_bodies, make_blocks
from repro.linalg.host_exec import run_host_ptg


def _np_bodies():
    return {
        "sa": lambda a: a,
        "sb": lambda b: b,
        "gemm": lambda c, a, b: c + a @ b,
    }


def host_gemm(nb: int, pr: int, pc: int, b: int) -> float:
    spec = gemm_2d_spec(nb, pr, pc, b)
    blocks = make_blocks(None, nb, b)
    t0 = time.perf_counter()
    out = run_host_ptg(spec, blocks, _np_bodies(), n_threads=2)
    wall = time.perf_counter() - t0
    a = assemble(blocks, "A", nb, b)
    bm = assemble(blocks, "B", nb, b)
    np.testing.assert_allclose(assemble(out, "C", nb, b), a @ bm,
                               rtol=1e-3, atol=1e-3)
    return wall


def run(report) -> None:
    # strong scaling: fixed problem, more ranks
    n = 512
    for (pr, pc) in ((1, 1), (1, 2), (2, 2)):
        nb, b = 8, n // 8
        wall = host_gemm(nb, pr, pc, b)
        flops = 2 * n ** 3
        report(f"gemm/strong/N{n}/r{pr * pc}", wall * 1e6,
               f"gflops={flops / wall / 1e9:.2f}")

    # weak scaling: problem grows with ranks
    for (pr, pc), n in (((1, 1), 384), ((2, 1), 484), ((2, 2), 608)):
        b = n // 8
        wall = host_gemm(8, pr, pc, b)
        report(f"gemm/weak/r{pr * pc}/N{8 * b}", wall * 1e6,
               f"gflops_per_rank={2 * (8 * b) ** 3 / wall / 1e9 / (pr * pc):.2f}")

    # block-size sweep (Fig 7g): same matrix, varying task granularity
    n = 512
    for b in (32, 64, 128, 256):
        nb = n // b
        wall = host_gemm(nb, 2, 2, b)
        report(f"gemm/blocksweep/b{b}", wall * 1e6,
               f"ntasks={nb ** 3}")

    # small vs large AM: compiled comm plan (fused = large AM batching),
    # under the dense baseline and the classified sparse/dense lowering
    for staged, tag in ((False, "eager"), (True, "staged")):
        prog = build_block_program(gemm_2d_spec(8, 2, 2, 64, staged=staged))
        st = prog.comm_stats(comm="auto")
        dense = prog.comm_stats(comm="dense")
        n_groups = sum(1 for w in prog.exchange if w[0].shape[-1] > 0)
        report(f"gemm/large_am/{tag}", 0.0,
               f"fused_buffers={n_groups};real_MB="
               f"{st['real_bytes'] / 1e6:.2f};padded_MB="
               f"{st['padded_bytes'] / 1e6:.2f};eff={st['wire_efficiency']:.3f}"
               f";eff_dense={dense['wire_efficiency']:.3f}",
               extra={"wire_efficiency": st["wire_efficiency"],
                      "wire_efficiency_dense": dense["wire_efficiency"],
                      "staged": staged})

    # concurrency efficiency (Fig 7h)
    base = None
    n = 384
    for nb in (4, 8, 16):
        b = n // nb
        wall = host_gemm(nb, 2, 2, b)
        base = base or wall
        conc = nb ** 2 / 4
        report(f"gemm/concurrency/c{conc:.0f}", wall * 1e6,
               f"rel={base / wall:.3f}")
