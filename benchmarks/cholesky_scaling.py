"""Fig 9 analogue: distributed Cholesky on the PTG runtime.

- weak/strong scaling over emulated ranks;
- block-size sweep (Fig 9d): granularity vs wall;
- load-balance test (Fig 9e): random per-block *work* scaled by rho — the
  ratio of largest to average task cost — demonstrating work stealing's
  tolerance of non-uniform granularity (<~25% degradation at rho=2 in the
  paper).
"""

from __future__ import annotations

import time

import numpy as np

from repro.linalg.cholesky import (assemble_lower, cholesky_spec,
                                   make_spd_blocks)
from repro.linalg.host_exec import run_host_ptg


def np_bodies(work_scale=None):
    """numpy bodies; work_scale(shape) -> int repeats the gemm compute to
    emulate non-uniform task cost (the rho test); the result is unchanged."""
    def trsm(a, l_kk):
        return np.linalg.solve(l_kk, a.T).T

    def gemm(a, li, lj):
        reps = work_scale(li.shape) if work_scale else 1
        prod = li @ lj.T
        for _ in range(reps - 1):
            prod = li @ lj.T  # redundant work, identical result
        return a - prod

    return {
        "potrf": lambda a: np.linalg.cholesky(a),
        "trsm": trsm,
        "syrk": lambda a, l: a - l @ l.T,
        "gemm": gemm,
    }


def host_cholesky(nb: int, pr: int, pc: int, b: int, bodies=None) -> float:
    spec = cholesky_spec(nb, pr, pc, b)
    blocks, a = make_spd_blocks(nb, b)
    t0 = time.perf_counter()
    out = run_host_ptg(spec, blocks, bodies or np_bodies(), n_threads=2)
    wall = time.perf_counter() - t0
    l = assemble_lower(out, nb, b)
    np.testing.assert_allclose(l, np.linalg.cholesky(a), rtol=5e-3, atol=5e-3)
    return wall


def run(report) -> None:
    # strong scaling
    n = 512
    for (pr, pc) in ((1, 1), (2, 1), (2, 2)):
        nb = 8
        wall = host_cholesky(nb, pr, pc, n // nb)
        report(f"cholesky/strong/N{n}/r{pr * pc}", wall * 1e6,
               f"gflops={n ** 3 / 3 / wall / 1e9:.2f}")

    # weak scaling
    for (pr, pc), n in (((1, 1), 384), ((2, 1), 484), ((2, 2), 608)):
        nb = 8
        b = n // nb
        wall = host_cholesky(nb, pr, pc, b)
        report(f"cholesky/weak/r{pr * pc}/N{nb * b}", wall * 1e6, "")

    # block-size sweep (Fig 9d)
    n = 512
    for b in (32, 64, 128):
        nb = n // b
        wall = host_cholesky(nb, 2, 2, b)
        report(f"cholesky/blocksweep/b{b}", wall * 1e6,
               f"ntasks={nb ** 3 // 6}")

    # load balance (Fig 9e): rho = max/avg task cost via replicated gemm work
    rng = np.random.default_rng(0)
    base = None
    for rho in (1.0, 1.5, 2.0):
        def scale(shape, rho=rho):
            # uniform on (2-rho, rho) x average, in integer work replicas
            return max(1, int(rng.uniform(2 - rho, rho) * 2))

        wall = host_cholesky(8, 2, 2, 64,
                             bodies=np_bodies(work_scale=scale))
        base = base or wall
        report(f"cholesky/load_balance/rho{rho}", wall * 1e6,
               f"degradation={wall / base - 1:.3f}")
