"""Bench-regression guard: fail CI when wire efficiency regresses.

Compares a freshly produced ``BENCH_*.json`` (benchmarks/run.py --json)
against the committed baseline artifact, case by case (rows matched by
``name``), on a ratio metric — default ``wire_efficiency``, the tracked
trajectory of ROADMAP §Perf iteration log. A case that drops more than
``--tol`` (default 20%) below its baseline fails the job; new cases (no
baseline row) and timing rows (no metric) pass through. us-per-task is
deliberately NOT guarded: it is noisy on emulated-CPU CI, while wire
efficiency is a deterministic property of the comm-plan lowering.

    python benchmarks/check_regression.py BENCH_ci.json \
        --baseline BENCH_20260727.json [--metric wire_efficiency] [--tol 0.2]
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Sequence, Tuple


def metric_rows(rows: Sequence[dict], metric: str) -> Dict[str, float]:
    """name -> metric for rows that carry a numeric value for it."""
    out = {}
    for r in rows:
        v = r.get(metric)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            out[r["name"]] = float(v)
    return out


def find_regressions(new_rows: Sequence[dict], base_rows: Sequence[dict], *,
                     metric: str = "wire_efficiency",
                     tol: float = 0.2) -> Tuple[int, List[Tuple[str, float, float]]]:
    """Compare per-case metric values; a case regresses when
    ``new < base * (1 - tol)``. Returns (cases compared, regressions as
    (name, baseline, new))."""
    base = metric_rows(base_rows, metric)
    new = metric_rows(new_rows, metric)
    checked = 0
    regressions = []
    for name, v in new.items():
        if name not in base:
            continue
        checked += 1
        if v < base[name] * (1.0 - tol):
            regressions.append((name, base[name], v))
    return checked, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="freshly produced BENCH json")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline BENCH json")
    ap.add_argument("--metric", default="wire_efficiency")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="allowed fractional drop vs baseline (default 0.2)")
    args = ap.parse_args(argv)

    try:
        with open(args.baseline) as f:
            base_rows = json.load(f)["rows"]
    except FileNotFoundError:
        print(f"no baseline at {args.baseline}; nothing to guard", flush=True)
        return 0
    with open(args.new) as f:
        new_rows = json.load(f)["rows"]

    checked, regressions = find_regressions(
        new_rows, base_rows, metric=args.metric, tol=args.tol)
    print(f"{checked} case(s) compared on {args.metric} "
          f"(tol {args.tol:.0%})")
    if not checked:
        # zero overlap means the metric silently vanished from the rows (or
        # the baseline is stale) — that disarms the guard, so fail loudly
        # rather than stay green while the tracked trajectory disappears
        print(f"FAIL: no overlapping cases carry a numeric {args.metric}; "
              "the guard would be a no-op. Refresh the committed baseline "
              "or restore the metric field.", flush=True)
        return 1
    for name, b, v in regressions:
        print(f"REGRESSION {name}: {args.metric} {b:.4f} -> {v:.4f} "
              f"({v / b - 1.0:+.1%})", flush=True)
    return 1 if regressions else 0


if __name__ == "__main__":
    sys.exit(main())
