"""Bench-regression guard: fail CI when a tracked bench metric regresses.

Compares a freshly produced ``BENCH_*.json`` (benchmarks/run.py --json)
against the committed baseline artifact, case by case (rows matched by
``name``), on ratio metrics. Each ``--metric`` may carry a direction
suffix: ``name`` / ``name:higher`` guards a higher-is-better metric
(regression = drop below ``base * (1 - tol)``), ``name:lower`` a
lower-is-better one (regression = rise above ``base * (1 + tol)``).

Defaults guard ``wire_efficiency`` — the tracked trajectory of ROADMAP
§Perf iteration log; CI additionally passes ``hlo_frac:lower`` (segmented
/ unrolled StableHLO bytes of the deep Task-Bench rows) so the
segmented-scan executor's compile-size win cannot silently erode, and
``edge_frac:lower`` (max per-shard lazy derived edges / eager global
edges of the discovery rows) so the lazy derivation's locality win
cannot either. A case
that moves more than ``--tol`` (default 20%) past its baseline fails the
job; new cases (no baseline row) and timing rows (no metric) pass
through. us-per-task and compile_seconds are deliberately NOT guarded:
they are noisy on emulated-CPU CI, while wire efficiency and HLO-size
ratios are deterministic properties of the lowering. The one timing
metric that IS guarded — ``metg_us:lower``, the Task-Bench minimum
effective task granularity — runs as a separate CI invocation at
``--tol 1.0``: only an order-of-magnitude overhead regression (METG more
than doubling) fails, which scheduler noise cannot produce.

    python benchmarks/check_regression.py BENCH_ci.json \
        --baseline BENCH_20260727.json \
        [--metric wire_efficiency] [--metric hlo_frac:lower] [--tol 0.2]
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Sequence, Tuple


def metric_rows(rows: Sequence[dict], metric: str) -> Dict[str, float]:
    """name -> metric for rows that carry a numeric value for it."""
    out = {}
    for r in rows:
        v = r.get(metric)
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            out[r["name"]] = float(v)
    return out


def parse_metric(spec: str) -> Tuple[str, bool]:
    """``"name[:higher|:lower]"`` -> (name, lower_is_better)."""
    name, _, direction = spec.partition(":")
    if direction not in ("", "higher", "lower"):
        raise ValueError(f"bad metric direction {spec!r} "
                         "(use name, name:higher, or name:lower)")
    return name, direction == "lower"


def find_regressions(new_rows: Sequence[dict], base_rows: Sequence[dict], *,
                     metric: str = "wire_efficiency",
                     tol: float = 0.2,
                     lower_is_better: bool = False,
                     ) -> Tuple[int, List[Tuple[str, float, float]]]:
    """Compare per-case metric values; a case regresses when it moves more
    than ``tol`` past baseline in the bad direction — ``new < base * (1 -
    tol)`` for higher-is-better metrics, ``new > base * (1 + tol)`` for
    lower-is-better ones. Returns (cases compared, regressions as
    (name, baseline, new))."""
    base = metric_rows(base_rows, metric)
    new = metric_rows(new_rows, metric)
    checked = 0
    regressions = []
    for name, v in new.items():
        if name not in base:
            continue
        checked += 1
        if lower_is_better:
            bad = v > base[name] * (1.0 + tol)
        else:
            bad = v < base[name] * (1.0 - tol)
        if bad:
            regressions.append((name, base[name], v))
    return checked, regressions


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("new", help="freshly produced BENCH json")
    ap.add_argument("--baseline", required=True,
                    help="committed baseline BENCH json")
    ap.add_argument("--metric", action="append", default=None,
                    help="metric to guard, optionally ':higher' (default) "
                         "or ':lower'; repeatable")
    ap.add_argument("--tol", type=float, default=0.2,
                    help="allowed fractional move vs baseline (default 0.2)")
    args = ap.parse_args(argv)
    try:
        metrics = [parse_metric(m)
                   for m in (args.metric or ["wire_efficiency"])]
    except ValueError as e:
        ap.error(str(e))

    try:
        with open(args.baseline) as f:
            base_rows = json.load(f)["rows"]
    except FileNotFoundError:
        print(f"no baseline at {args.baseline}; nothing to guard", flush=True)
        return 0
    with open(args.new) as f:
        new_rows = json.load(f)["rows"]

    failed = False
    for metric, lower in metrics:
        checked, regressions = find_regressions(
            new_rows, base_rows, metric=metric, tol=args.tol,
            lower_is_better=lower)
        print(f"{checked} case(s) compared on {metric} "
              f"({'lower' if lower else 'higher'} is better, "
              f"tol {args.tol:.0%})")
        # baseline cases this run did not produce are unguarded (normal
        # when CI runs a module subset; suspicious when a row was renamed
        # or a metric field dropped) — say so instead of skipping silently
        gone = sorted(set(metric_rows(base_rows, metric))
                      - set(metric_rows(new_rows, metric)))
        if gone:
            print(f"note: {len(gone)} baseline case(s) not in this run "
                  f"(unguarded on {metric}), e.g. {gone[:3]}")
        if not checked:
            # zero overlap means the metric silently vanished from the rows
            # (or the baseline is stale) — that disarms the guard, so fail
            # loudly rather than stay green while the trajectory disappears
            print(f"FAIL: no overlapping cases carry a numeric {metric}; "
                  "the guard would be a no-op. Refresh the committed "
                  "baseline or restore the metric field.", flush=True)
            failed = True
            continue
        for name, b, v in regressions:
            print(f"REGRESSION {name}: {metric} {b:.4f} -> {v:.4f} "
                  f"({v / b - 1.0:+.1%})", flush=True)
        failed = failed or bool(regressions)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
