"""§Roofline: per (arch × shape) terms from the dry-run artifacts.

Sources:
- flops / bytes / collective bytes: the *unrolled* compile when present
  (XLA counts while bodies once — launch/flags.py), else the scan-form
  compile flagged `body_once` (lower bound);
- memory_analysis: scan-form compile (production HLO).

v5e constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
(collective term ≈ wire bytes / link bw; per-device bytes already).

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) for train (fwd+bwd);
2·N·D for prefill; 2·N_active per token for decode. The MODEL/HLO ratio
catches remat/redundancy waste.
"""

from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")


def model_flops(cell: dict) -> float:
    """Global model flops for the cell's step."""
    n_act = cell["n_active_params"]
    tokens = cell["seq_len"] * cell["global_batch"]
    if cell["kind"] == "train":
        return 6.0 * n_act * tokens
    if cell["kind"] == "prefill":
        return 2.0 * n_act * tokens
    return 2.0 * n_act * cell["global_batch"]  # decode: one token per seq


def load_cells(report_dir: str = REPORT_DIR):
    cells = {}
    for f in glob.glob(os.path.join(report_dir, "*__pod.json")):
        d = json.load(open(f))
        if d.get("status") != "ok":
            continue
        key = (d["arch"], d["shape"])
        unrolled = f.replace("__pod.json", "__pod_unrolled.json")
        src = "body_once"
        if os.path.exists(unrolled):
            du = json.load(open(unrolled))
            if du.get("status") == "ok":
                d["per_device"].update(
                    {k: du["per_device"][k] for k in
                     ("flops", "bytes_accessed", "collective_bytes",
                      "transcendentals")})
                src = "unrolled"
        d["cost_source"] = src
        cells[key] = d
    return cells


def roofline_row(d: dict) -> dict:
    pd = d["per_device"]
    chips = d["n_chips"]
    t_compute = pd["flops"] / PEAK_FLOPS
    t_memory = pd["bytes_accessed"] / HBM_BW
    t_coll = pd["collective_bytes"]["total"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(d)
    hlo_global = pd["flops"] * chips
    mem_gb = (pd["argument_bytes"] + pd["temp_bytes"]
              + pd["output_bytes"]) / 1e9
    return {
        "arch": d["arch"], "shape": d["shape"], "kind": d["kind"],
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "hbm_gb": mem_gb,
        "fits_16gb": mem_gb < 16.0,
        "cost_source": d["cost_source"],
        "step_s": max(terms.values()),
        "roofline_fraction": (t_compute / max(terms.values())
                              if max(terms.values()) > 0 else 0.0),
    }


def run(report) -> None:
    cells = load_cells()
    if not cells:
        report("roofline/no_data", 0.0, "run launch/dryrun sweep first")
        return
    for (arch, shape), d in sorted(cells.items()):
        r = roofline_row(d)
        report(
            f"roofline/{arch}/{shape}",
            r["step_s"] * 1e6,
            f"dom={r['dominant']};comp={r['compute_s']:.4f}s;"
            f"mem={r['memory_s']:.4f}s;coll={r['collective_s']:.4f}s;"
            f"useful={r['useful_ratio']:.2f};hbm={r['hbm_gb']:.1f}GB;"
            f"src={r['cost_source']}",
        )


def table() -> list:
    return [roofline_row(d) for _, d in sorted(load_cells().items())]
