"""Task-Bench-style scaling benchmark (Slaughter et al., 1908.05790) over
discovery -> comm_plan -> executor — the ROADMAP's fig. 4/5 analogue.

Task Bench parametrizes a runtime by its *dependence pattern*: the same
layered task grid is rerun under stencil / FFT / tree / random edges, and
the runtime's overhead (us per task) plus its communication behavior fall
out per pattern. Here each pattern is a block PTG fed through the same
pipeline every app uses:

    taskbench_spec -> discover (parallel, shard-local)
                   -> build_block_program (classified comm plan)
                   -> auto_executor (sparse/dense per-wavefront + overlap)

Reported per (pattern, n_shards):
- build_us_per_task: discovery + lowering cost (dependence management);
- host_us_per_task:  the faithful async host runtime executing the PTG;
- exec_us_per_task:  the compiled SPMD executor (when enough devices);
- wire_efficiency:   real / (real + padded) bytes under the chosen
  lowering, vs the dense all_to_all baseline — the tracked trajectory;
- compile_seconds / hlo_bytes: compile cost of the chosen lowering
  (``benchmarks.run.compile_metrics``).

The ``taskbench_deep/*`` rows run the ROADMAP segmented-scan acceptance
scenario (width 16, depth 48, 8 shards — depth past any sane unroll cap):
segmented scan vs unrolled ``comm="auto"`` vs pure dense scan, reporting
each lowering's wire efficiency plus ``hlo_frac`` = segmented hlo_bytes /
unrolled hlo_bytes (guarded lower-is-better by ``check_regression.py``),
and the ``plan_lowering`` decision for every pattern.

The ``taskbench_metg/*`` rows report METG (Minimum Effective Task
Granularity, Task Bench's headline metric): per pattern x shard count,
sweep the per-task compute grain and report the smallest task duration at
which the executor still reaches >=50% efficiency. Guarded lower-is-better
(at a loose tolerance — it's a timing metric) by ``check_regression.py``.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.schedule import BlockPTGSpec, build_block_program
from repro.linalg.host_exec import run_host_ptg
from repro.ptg import Graph, IndexSpace

PATTERNS = ("stencil", "fft", "tree", "random")


def pattern_parents(pattern: str, l: int, i: int, width: int, *,
                    fan: int = 3, seed: int = 0) -> List[int]:
    """Column indices in layer ``l - 1`` that task (l, i) consumes."""
    if pattern == "stencil":
        return [j for j in (i - 1, i, i + 1) if 0 <= j < width]
    if pattern == "fft":
        stride = 1 << ((l - 1) % max(width.bit_length() - 1, 1))
        return sorted({i, (i ^ stride) % width})
    if pattern == "tree":
        return sorted({(2 * i) % width, (2 * i + 1) % width})
    if pattern == "random":
        rng = np.random.default_rng((seed, l, i))
        k = min(fan, width)
        return sorted(int(j) for j in
                      rng.choice(width, size=k, replace=False))
    raise ValueError(f"unknown pattern {pattern!r}")


def taskbench_graph(pattern: str, width: int, depth: int, n_shards: int,
                    b: int = 8, *, fan: int = 3, seed: int = 0,
                    dtype=jnp.float32) -> Tuple[Graph, Dict]:
    """Layered task grid as a declarative ``repro.ptg`` graph: task (l, i)
    RMWs its own block and reads its parents' layer-(l-1) blocks — in/out
    edges, operands, and seeds all derive from those access patterns.
    Columns map to shards in contiguous chunks, so stencil comm stays
    neighbor-sparse while random comm approaches all-to-all — the two ends
    Task Bench sweeps. One task type per fan-in count (the block executor
    needs fixed arity per type); legacy (l, i) task keys are preserved via
    the ``key`` override."""
    deps: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for l in range(1, depth):
        for i in range(width):
            deps[(l, i)] = [(l - 1, j)
                            for j in pattern_parents(pattern, l, i, width,
                                                     fan=fan, seed=seed)]

    def owner(blk) -> int:
        return blk[1] * n_shards // width

    g = Graph(f"taskbench-{pattern}", n_shards=n_shards,
              owner=owner, block_shape=(b, b), dtype=dtype)
    for nfan in sorted({len(d) for d in deps.values()} | {0}):
        g.task_type(f"f{nfan}",
                    key=lambda l, i: (l, i),
                    writes=lambda l, i: (l, i),
                    reads=lambda l, i: [(l, i)] + deps.get((l, i), []))

    def entries():
        return ((f"f{len(deps.get((l, i), ()))}", l, i)
                for l in range(depth) for i in range(width))

    def owned(shard):
        # the width×depth grid partitions by column: shard s owns exactly
        # the columns whose blocks it owns — strip enumeration is O(owned)
        cols = [i for i in range(width) if i * n_shards // width == shard]
        return ((f"f{len(deps.get((l, i), ()))}", l, i)
                for l in range(depth) for i in cols)

    g.sequence(IndexSpace(entries, owned, size=depth * width))
    return g, deps


def taskbench_spec(pattern: str, width: int, depth: int, n_shards: int,
                   b: int = 8, *, fan: int = 3, seed: int = 0,
                   dtype=jnp.float32, lazy: bool = True
                   ) -> Tuple[BlockPTGSpec, Dict]:
    g, deps = taskbench_graph(pattern, width, depth, n_shards, b,
                              fan=fan, seed=seed, dtype=dtype)
    return g.to_block_spec(lazy=lazy), deps


def taskbench_bodies(max_fan: int = 8) -> Dict[str, object]:
    def body(*ops):
        out = ops[0] * 0.5
        for o in ops[1:]:
            out = out + o
        return out

    return {f"f{k}": body for k in range(max_fan + 1)}


def taskbench_blocks(width: int, depth: int, b: int = 8,
                     seed: int = 0) -> Dict[Tuple[int, int], np.ndarray]:
    rng = np.random.default_rng(seed)
    return {(l, i): rng.standard_normal((b, b)).astype(np.float32)
            for l in range(depth) for i in range(width)}


def taskbench_oracle(blocks, deps, width: int, depth: int):
    """Sequential layer-by-layer reference (same arithmetic as the bodies)."""
    vals = {blk: arr.copy() for blk, arr in blocks.items()}
    for l in range(depth):
        layer = {}
        for i in range(width):
            out = vals[(l, i)] * 0.5
            for d in deps.get((l, i), []):
                out = out + vals[d]
            layer[(l, i)] = out
        vals.update(layer)
    return vals


def _np_bodies(bodies):
    return {t: (lambda fn: (lambda *a: np.asarray(fn(*a))))(fn)
            for t, fn in bodies.items()}


def run(report) -> None:
    width, depth, b = 16, 12, 8
    n_tasks = width * depth
    for pattern in PATTERNS:
        for n_shards in (2, 4, 8):
            spec, deps = taskbench_spec(pattern, width, depth, n_shards, b)

            t0 = time.perf_counter()
            prog = build_block_program(spec)
            build_us = (time.perf_counter() - t0) / n_tasks * 1e6

            auto = prog.comm_stats(comm="auto")
            dense = prog.comm_stats(comm="dense")
            eff, eff_dense = auto["wire_efficiency"], dense["wire_efficiency"]

            blocks = taskbench_blocks(width, depth, b)
            t0 = time.perf_counter()
            run_host_ptg(spec, blocks, _np_bodies(taskbench_bodies()),
                         n_threads=2)
            host_us = (time.perf_counter() - t0) / n_tasks * 1e6

            exec_us = None
            cmetrics = {}
            if len(jax.devices()) >= n_shards:
                from benchmarks.run import compile_metrics

                mesh = jax.sharding.Mesh(
                    np.array(jax.devices()[:n_shards]), ("shards",))
                packed = jnp.asarray(prog.pack(blocks))
                with mesh:
                    step, cmetrics = compile_metrics(
                        prog.auto_executor(taskbench_bodies(), mesh), packed)
                    step(packed).block_until_ready()      # warm up
                    reps = 5
                    t0 = time.perf_counter()
                    for _ in range(reps):
                        out = step(packed)
                    out.block_until_ready()
                    exec_us = ((time.perf_counter() - t0) / reps
                               / n_tasks * 1e6)

            report(
                f"taskbench/{pattern}/s{n_shards}",
                exec_us if exec_us is not None else host_us,
                f"eff={eff:.3f};eff_dense={eff_dense:.3f};"
                f"build_us={build_us:.1f};host_us={host_us:.1f}",
                extra={
                    "pattern": pattern, "n_shards": n_shards,
                    "width": width, "depth": depth, "n_tasks": n_tasks,
                    "wire_efficiency": eff,
                    "wire_efficiency_dense": eff_dense,
                    "real_bytes": auto["real_bytes"],
                    "padded_bytes": auto["padded_bytes"],
                    "us_per_task_build": build_us,
                    "us_per_task_host": host_us,
                    "us_per_task_exec": exec_us,
                    **cmetrics,
                },
            )
    run_deep(report)


DEEP_WIDTH, DEEP_DEPTH, DEEP_SHARDS, DEEP_UNROLL_CAP = 16, 48, 8, 32


def run_deep(report) -> None:
    """Deep-schedule rows: depth past the unroll cap, where the choice used
    to cliff to the dense scan. The stencil row (exact segmented scan) and
    the fft row (fragmented exact signatures folded by the **union-cover**
    scan) both compile all three lowerings and report ``hlo_frac``
    (segmented / unrolled StableHLO bytes — the compile-cost win) next to
    each lowering's wire efficiency (the padding win); the other patterns
    report program-level stats plus the ``plan_lowering`` decision
    (random: genuinely dense — the honest dense-scan fallback)."""
    from benchmarks.run import compile_metrics

    width, depth, n_shards, b = DEEP_WIDTH, DEEP_DEPTH, DEEP_SHARDS, 8
    n_tasks = width * depth
    for pattern in PATTERNS:
        spec, _deps = taskbench_spec(pattern, width, depth, n_shards, b)
        t0 = time.perf_counter()
        prog = build_block_program(spec)
        build_us = (time.perf_counter() - t0) / n_tasks * 1e6
        plan = prog.plan_lowering(unroll_cap=DEEP_UNROLL_CAP)
        cover = plan.get("cover", "exact")
        seg = prog.comm_stats(comm="auto", segmented=True, cover=cover)
        auto = prog.comm_stats(comm="auto")
        dense = prog.comm_stats(comm="dense")
        # What the pure dense scan *actually* ships: every scan iteration
        # runs the all_to_all padded to the global M_max — worse than the
        # per-wavefront dense accounting above (which models the unrolled
        # dense lowering).
        n = prog.spec.n_shards
        m_max = max(e[0].shape[-1] for e in prog.exchange)
        scan_wire = (prog.schedule.n_wavefronts * n * n * m_max
                     * dense["block_bytes"])
        eff_dense_scan = (dense["real_bytes"] / scan_wire if scan_wire
                          else 1.0)
        # the efficiency the auto policy actually ships for this pattern
        eff_planned = (eff_dense_scan if plan["mode"] == "dense_scan"
                       else seg["wire_efficiency"])
        extra = {
            "pattern": pattern, "n_shards": n_shards,
            "width": width, "depth": depth, "n_tasks": n_tasks,
            "plan_mode": plan["mode"], "plan_reason": plan["reason"],
            "plan_cover": cover,
            "n_segments": seg["n_segments"],
            "segment_density_mean": float(np.mean(
                [s["density"] for s in seg["segments"]])),
            "wire_efficiency": eff_planned,
            "wire_efficiency_segmented": seg["wire_efficiency"],
            "wire_efficiency_unrolled": auto["wire_efficiency"],
            "wire_efficiency_dense": dense["wire_efficiency"],
            "wire_efficiency_dense_scan": eff_dense_scan,
            "real_bytes": seg["real_bytes"],
            "padded_bytes": seg["padded_bytes"],
            "us_per_task_build": build_us,
        }
        if "n_segments_union" in plan:
            extra["n_segments_union"] = plan["n_segments_union"]
            extra["wire_efficiency_union"] = plan["wire_efficiency_union"]
        exec_us = None
        # stencil exercises the exact segmented scan; fft the union cover
        scan_kw = dict(scan=True, comm="auto", overlap=True, cover=cover)
        if (pattern in ("stencil", "fft")
                and plan["mode"] in ("segmented_scan", "union_cover")
                and len(jax.devices()) >= n_shards):
            mesh = jax.sharding.Mesh(
                np.array(jax.devices()[:n_shards]), ("shards",))
            blocks = taskbench_blocks(width, depth, b)
            packed = jnp.asarray(prog.pack(blocks))
            bodies = taskbench_bodies()
            with mesh:
                lowerings = {
                    "segmented": scan_kw,
                    "unrolled": dict(scan=False, comm="auto", overlap=True),
                    "dense_scan": dict(scan=True),
                }
                for name, kw in lowerings.items():
                    step, cm = compile_metrics(
                        prog.executor(bodies, mesh, **kw), packed)
                    extra.update({f"{k}_{name}": v for k, v in cm.items()})
                    if name == "segmented":
                        step(packed).block_until_ready()
                        reps = 3
                        t0 = time.perf_counter()
                        for _ in range(reps):
                            out = step(packed)
                        out.block_until_ready()
                        exec_us = ((time.perf_counter() - t0) / reps
                                   / n_tasks * 1e6)
                        extra.update(cm)   # the shipped lowering's columns
                extra["hlo_frac"] = (extra["hlo_bytes_segmented"]
                                     / extra["hlo_bytes_unrolled"])
                extra["us_per_task_exec"] = exec_us
        report(
            f"taskbench_deep/{pattern}/s{n_shards}",
            exec_us if exec_us is not None else build_us,
            f"plan={plan['mode']};segs={seg['n_segments']};"
            f"eff={eff_planned:.3f};eff_unrolled="
            f"{auto['wire_efficiency']:.3f};"
            f"eff_dense_scan={eff_dense_scan:.3f}"
            + (f";hlo_frac={extra['hlo_frac']:.3f}"
               if "hlo_frac" in extra else ""),
            extra=extra,
        )
    run_metg(report)


# --------------------------------------------------------------- METG rows

METG_GRAINS = (1, 4, 16, 64)     # per-task compute repeats, geometric sweep
METG_GRAIN_MAX = 256             # adaptive extension cap (compile-bounded)
METG_TARGET_EFF = 0.5            # Task Bench's 50%-efficiency threshold


def metg_bodies(grain: int, max_fan: int = 8) -> Dict[str, object]:
    """Task-Bench bodies with a tunable compute grain: the baseline
    reduction plus ``grain`` MXU-sized matmul steps. ``tanh`` keeps the
    chain bounded and data-dependent (the compiler cannot fold it), and the
    ``1e-20`` mix-in keeps it live without perturbing the reduction."""
    def body(*ops):
        out = ops[0] * 0.5
        for o in ops[1:]:
            out = out + o
        extra = ops[0]
        for _ in range(grain):
            extra = jnp.tanh(extra @ ops[0])
        return out + 1e-20 * extra

    return {f"f{k}": body for k in range(max_fan + 1)}


def _ideal_us_per_task(body, mesh, n_shards: int, arity: int,
                       per_shard: int, depth: int, b: int) -> float:
    """Pure-compute cost of one task at this grain with zero runtime in the
    way, under the SAME resource split as the executor: a ``shard_map``
    over the same mesh (emulated devices share the host's cores, so a
    single-device baseline would overstate one shard's throughput), each
    shard scanning ``depth`` wavefront steps that vmap the body over its
    ``per_shard`` tasks — carry-coupled so XLA cannot parallelize across
    wavefronts (the executor can't either)."""
    from jax.sharding import PartitionSpec as P

    from repro.core.schedule import _shard_map

    rng = np.random.default_rng(0)
    xs = jnp.asarray(rng.standard_normal(
        (n_shards, depth, per_shard, arity, b, b)).astype(np.float32))

    def shardfn(x):
        def step(carry, t):
            t = t.at[:, 0].add(carry)
            y = jax.vmap(lambda o: body(*jnp.unstack(o)))(t)
            return y.mean(axis=0), ()

        carry, _ = jax.lax.scan(step, jnp.zeros((b, b), jnp.float32), x[0])
        return carry[None]

    with mesh:
        ideal = jax.jit(_shard_map(shardfn, mesh=mesh, in_specs=P("shards"),
                                   out_specs=P("shards")))
        ideal(xs).block_until_ready()
        reps = 10
        t0 = time.perf_counter()
        for _ in range(reps):
            out = ideal(xs)
        out.block_until_ready()
    return (time.perf_counter() - t0) / reps / (depth * per_shard) * 1e6


def run_metg(report) -> None:
    """METG rows (Task Bench §IV: Minimum Effective Task Granularity): for
    each dependence pattern × shard count, sweep the per-task compute grain
    and report ``metg_us`` — the smallest task duration (µs of pure
    compute) at which the end-to-end executor reaches ≥50% efficiency
    (efficiency = ideal compute time / measured wall time). Log-linear
    interpolation between the two bracketing grains turns the discrete
    sweep into a continuous metric; a pattern that never reaches 50% at
    the largest grain reports no ``metg_us`` (loud in the guard's
    missing-case note rather than a fake number)."""
    width, depth, b = 16, 12, 8
    for pattern in PATTERNS:
        for n_shards in (4, 8):
            if len(jax.devices()) < n_shards:
                continue
            spec, deps = taskbench_spec(pattern, width, depth, n_shards, b)
            prog = build_block_program(spec)
            mesh = jax.sharding.Mesh(
                np.array(jax.devices()[:n_shards]), ("shards",))
            blocks = taskbench_blocks(width, depth, b)
            packed = jnp.asarray(prog.pack(blocks))
            arity = 1 + max(len(d) for d in deps.values())
            per_shard = max(width // n_shards, 1)

            grains = list(METG_GRAINS)
            grains_us: List[float] = []
            effs: List[float] = []
            gi = 0
            while gi < len(grains):
                grain = grains[gi]
                bodies = metg_bodies(grain)
                ideal_us = _ideal_us_per_task(
                    bodies[f"f{arity - 1}"], mesh, n_shards, arity,
                    per_shard, depth, b)
                with mesh:
                    step = jax.jit(prog.auto_executor(bodies, mesh))
                    step(packed).block_until_ready()
                    reps = 5
                    t0 = time.perf_counter()
                    for _ in range(reps):
                        out = step(packed)
                    out.block_until_ready()
                wall_us = (time.perf_counter() - t0) / reps * 1e6
                # ideal wall time: every shard runs its own strip with no
                # runtime in the way (same mesh, so same resource split)
                eff = ideal_us * depth * per_shard / wall_us
                grains_us.append(ideal_us)
                effs.append(min(eff, 1.0))
                gi += 1
                # coarse-grain extension: a pattern that hasn't crossed 50%
                # by the end of the sweep gets one more (4x) notch, capped —
                # the overhead floor is real but the crossing still exists
                if (gi == len(grains) and max(effs) < METG_TARGET_EFF
                        and grain * 4 <= METG_GRAIN_MAX):
                    grains.append(grain * 4)

            metg_us = None
            for j, eff in enumerate(effs):
                if eff < METG_TARGET_EFF:
                    continue
                if j == 0 or effs[j - 1] >= METG_TARGET_EFF:
                    metg_us = grains_us[j]
                else:  # log-linear interpolation across the crossing
                    g0, g1 = np.log(grains_us[j - 1]), np.log(grains_us[j])
                    e0, e1 = effs[j - 1], effs[j]
                    frac = (METG_TARGET_EFF - e0) / (e1 - e0)
                    metg_us = float(np.exp(g0 + frac * (g1 - g0)))
                break

            extra = {
                "pattern": pattern, "n_shards": n_shards,
                "width": width, "depth": depth,
                "grain_us": [round(g, 3) for g in grains_us],
                "grain_efficiency": [round(e, 4) for e in effs],
            }
            if metg_us is not None:
                extra["metg_us"] = round(metg_us, 3)
            report(
                f"taskbench_metg/{pattern}/s{n_shards}",
                metg_us if metg_us is not None else grains_us[-1],
                (f"metg_us={metg_us:.1f};" if metg_us is not None
                 else "metg_us=none;")
                + f"eff={';'.join(f'{e:.2f}' for e in effs)}",
                extra=extra,
            )
