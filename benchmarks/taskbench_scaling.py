"""Task-Bench-style scaling benchmark (Slaughter et al., 1908.05790) over
discovery -> comm_plan -> executor — the ROADMAP's fig. 4/5 analogue.

Task Bench parametrizes a runtime by its *dependence pattern*: the same
layered task grid is rerun under stencil / FFT / tree / random edges, and
the runtime's overhead (us per task) plus its communication behavior fall
out per pattern. Here each pattern is a block PTG fed through the same
pipeline every app uses:

    taskbench_spec -> discover (parallel, shard-local)
                   -> build_block_program (classified comm plan)
                   -> auto_executor (sparse/dense per-wavefront + overlap)

Reported per (pattern, n_shards):
- build_us_per_task: discovery + lowering cost (dependence management);
- host_us_per_task:  the faithful async host runtime executing the PTG;
- exec_us_per_task:  the compiled SPMD executor (when enough devices);
- wire_efficiency:   real / (real + padded) bytes under the chosen
  lowering, vs the dense all_to_all baseline — the tracked trajectory.
"""

from __future__ import annotations

import time
from typing import Dict, List, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.schedule import BlockPTGSpec, build_block_program
from repro.linalg.host_exec import run_host_ptg
from repro.ptg import Graph

PATTERNS = ("stencil", "fft", "tree", "random")


def pattern_parents(pattern: str, l: int, i: int, width: int, *,
                    fan: int = 3, seed: int = 0) -> List[int]:
    """Column indices in layer ``l - 1`` that task (l, i) consumes."""
    if pattern == "stencil":
        return [j for j in (i - 1, i, i + 1) if 0 <= j < width]
    if pattern == "fft":
        stride = 1 << ((l - 1) % max(width.bit_length() - 1, 1))
        return sorted({i, (i ^ stride) % width})
    if pattern == "tree":
        return sorted({(2 * i) % width, (2 * i + 1) % width})
    if pattern == "random":
        rng = np.random.default_rng((seed, l, i))
        k = min(fan, width)
        return sorted(int(j) for j in
                      rng.choice(width, size=k, replace=False))
    raise ValueError(f"unknown pattern {pattern!r}")


def taskbench_graph(pattern: str, width: int, depth: int, n_shards: int,
                    b: int = 8, *, fan: int = 3, seed: int = 0,
                    dtype=jnp.float32) -> Tuple[Graph, Dict]:
    """Layered task grid as a declarative ``repro.ptg`` graph: task (l, i)
    RMWs its own block and reads its parents' layer-(l-1) blocks — in/out
    edges, operands, and seeds all derive from those access patterns.
    Columns map to shards in contiguous chunks, so stencil comm stays
    neighbor-sparse while random comm approaches all-to-all — the two ends
    Task Bench sweeps. One task type per fan-in count (the block executor
    needs fixed arity per type); legacy (l, i) task keys are preserved via
    the ``key`` override."""
    deps: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for l in range(1, depth):
        for i in range(width):
            deps[(l, i)] = [(l - 1, j)
                            for j in pattern_parents(pattern, l, i, width,
                                                     fan=fan, seed=seed)]

    def owner(blk) -> int:
        return blk[1] * n_shards // width

    g = Graph(f"taskbench-{pattern}", n_shards=n_shards,
              owner=owner, block_shape=(b, b), dtype=dtype)
    for nfan in sorted({len(d) for d in deps.values()} | {0}):
        g.task_type(f"f{nfan}",
                    key=lambda l, i: (l, i),
                    writes=lambda l, i: (l, i),
                    reads=lambda l, i: [(l, i)] + deps.get((l, i), []))
    g.sequence(lambda: ((f"f{len(deps.get((l, i), ()))}", l, i)
                        for l in range(depth) for i in range(width)))
    return g, deps


def taskbench_spec(pattern: str, width: int, depth: int, n_shards: int,
                   b: int = 8, *, fan: int = 3, seed: int = 0,
                   dtype=jnp.float32) -> Tuple[BlockPTGSpec, Dict]:
    g, deps = taskbench_graph(pattern, width, depth, n_shards, b,
                              fan=fan, seed=seed, dtype=dtype)
    return g.to_block_spec(), deps


def taskbench_bodies(max_fan: int = 8) -> Dict[str, object]:
    def body(*ops):
        out = ops[0] * 0.5
        for o in ops[1:]:
            out = out + o
        return out

    return {f"f{k}": body for k in range(max_fan + 1)}


def taskbench_blocks(width: int, depth: int, b: int = 8,
                     seed: int = 0) -> Dict[Tuple[int, int], np.ndarray]:
    rng = np.random.default_rng(seed)
    return {(l, i): rng.standard_normal((b, b)).astype(np.float32)
            for l in range(depth) for i in range(width)}


def taskbench_oracle(blocks, deps, width: int, depth: int):
    """Sequential layer-by-layer reference (same arithmetic as the bodies)."""
    vals = {blk: arr.copy() for blk, arr in blocks.items()}
    for l in range(depth):
        layer = {}
        for i in range(width):
            out = vals[(l, i)] * 0.5
            for d in deps.get((l, i), []):
                out = out + vals[d]
            layer[(l, i)] = out
        vals.update(layer)
    return vals


def _np_bodies(bodies):
    return {t: (lambda fn: (lambda *a: np.asarray(fn(*a))))(fn)
            for t, fn in bodies.items()}


def run(report) -> None:
    width, depth, b = 16, 12, 8
    n_tasks = width * depth
    for pattern in PATTERNS:
        for n_shards in (2, 4, 8):
            spec, deps = taskbench_spec(pattern, width, depth, n_shards, b)

            t0 = time.perf_counter()
            prog = build_block_program(spec)
            build_us = (time.perf_counter() - t0) / n_tasks * 1e6

            auto = prog.comm_stats(comm="auto")
            dense = prog.comm_stats(comm="dense")
            eff, eff_dense = auto["wire_efficiency"], dense["wire_efficiency"]

            blocks = taskbench_blocks(width, depth, b)
            t0 = time.perf_counter()
            run_host_ptg(spec, blocks, _np_bodies(taskbench_bodies()),
                         n_threads=2)
            host_us = (time.perf_counter() - t0) / n_tasks * 1e6

            exec_us = None
            if len(jax.devices()) >= n_shards:
                mesh = jax.sharding.Mesh(
                    np.array(jax.devices()[:n_shards]), ("shards",))
                packed = jnp.asarray(prog.pack(blocks))
                with mesh:
                    step = jax.jit(prog.auto_executor(taskbench_bodies(),
                                                      mesh))
                    step(packed).block_until_ready()      # compile
                    reps = 5
                    t0 = time.perf_counter()
                    for _ in range(reps):
                        out = step(packed)
                    out.block_until_ready()
                    exec_us = ((time.perf_counter() - t0) / reps
                               / n_tasks * 1e6)

            report(
                f"taskbench/{pattern}/s{n_shards}",
                exec_us if exec_us is not None else host_us,
                f"eff={eff:.3f};eff_dense={eff_dense:.3f};"
                f"build_us={build_us:.1f};host_us={host_us:.1f}",
                extra={
                    "pattern": pattern, "n_shards": n_shards,
                    "width": width, "depth": depth, "n_tasks": n_tasks,
                    "wire_efficiency": eff,
                    "wire_efficiency_dense": eff_dense,
                    "real_bytes": auto["real_bytes"],
                    "padded_bytes": auto["padded_bytes"],
                    "us_per_task_build": build_us,
                    "us_per_task_host": host_us,
                    "us_per_task_exec": exec_us,
                },
            )
