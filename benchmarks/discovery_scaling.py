"""Discovery-cost scaling: lazy per-shard derivation vs the eager global
scan — the graph-build half of the Task-Bench scaling wall (1908.05790).

TaskTorrent's claim is that no rank ever materializes the global task
graph: the DAG is "completely distributed and discovered in parallel".
``repro.ptg.Graph`` honors that since the lazy redesign —
``derive_local(shard)`` scans only the shard's owned tasks plus their halo
(one ``reads``/``writes`` overlap away) — while ``Graph.build`` remains
the eager oracle that materializes everything. This module measures both,
per (pattern, width, depth, n_shards):

- ``eager_seconds`` / ``eager_edges`` — the global scan: wall time and
  edge-list entries it materializes (the O(width x depth) wall);
- ``lazy_seconds_max`` / ``lazy_edges_max`` — the *slowest / largest
  single shard* of the lazy derivation: what one rank of a real
  distributed run would pay (each rank derives only its own view; the
  sweep over shards here is the single-host emulation of all ranks);
- ``owned_halo_max`` — max over shards of owned + halo task count, the
  quantity the lazy cost is supposed to track;
- ``edge_frac`` = lazy_edges_max / eager_edges (lower is better; guarded
  by CI via ``check_regression.py --metric edge_frac:lower``);
- ``edges_per_owned_halo`` = lazy_edges_max / owned_halo_max — the
  scaling witness: it stays flat across shard counts and graph sizes
  while ``edge_frac`` falls, i.e. per-shard cost follows owned + halo,
  not the global index space;
- ``pass1_scanned_max`` / ``pass1_frac`` — how many index-space entries
  pass 1 (relevance filtering) touched on the worst shard, and that count
  over the global task count. With typed partitionable index spaces
  (``IndexSpace.enumerate_owned``) each shard enumerates only its own
  strip, so ``pass1_frac`` falls ~1/S across the shard sweep; an opaque
  space would pin it at 1.0 (the full-scan fallback).

Two sweeps make that visible: ``shards`` grows the shard count at a fixed
global graph (per-shard state must shrink ~1/S), and ``depth`` grows the
global graph at a fixed shard grid with a fixed per-shard strip (per-shard
state must grow with the strip, staying a constant fraction of eager).
The eager-vs-lazy *correctness* oracle lives in
``tests/test_lazy_discovery.py`` (edge-for-edge identity); this module
only accounts cost.
"""

from __future__ import annotations

import time

from benchmarks.taskbench_scaling import taskbench_graph

# (tag, pattern, width, depth, shard counts) — ≥4 shard counts per the
# acceptance scenario; sizes chosen to stay CI-cheap (< a few seconds).
SHARD_SWEEP = ("shards", "stencil", 32, 24, (2, 4, 8, 16))
DEPTH_SWEEP = ("depth", "stencil", 16, (16, 32, 64, 128), 8)


def eager_cost(pattern, width, depth, n_shards, b=4):
    """(seconds, edge-list entries) of the eager global scan."""
    g, _ = taskbench_graph(pattern, width, depth, n_shards, b)
    t0 = time.perf_counter()
    g.build()
    secs = time.perf_counter() - t0
    edges = sum(len(g.in_deps(k)) + len(g.out_deps(k)) for k in g.tasks)
    return secs, edges


def lazy_cost(pattern, width, depth, n_shards, b=4):
    """Per-shard derivation cost: list of (seconds, stats) over shards,
    each on a fresh graph so no cross-shard caching flatters the numbers."""
    out = []
    for s in range(n_shards):
        g, _ = taskbench_graph(pattern, width, depth, n_shards, b)
        t0 = time.perf_counter()
        view = g.derive_local(s)
        out.append((time.perf_counter() - t0, view.stats))
    return out


def _row(report, tag, pattern, width, depth, n_shards):
    n_tasks = width * depth
    eager_s, eager_e = eager_cost(pattern, width, depth, n_shards)
    per_shard = lazy_cost(pattern, width, depth, n_shards)
    lazy_s_max = max(s for s, _ in per_shard)
    lazy_s_mean = sum(s for s, _ in per_shard) / len(per_shard)
    lazy_e_max = max(st["derived_edges"] for _, st in per_shard)
    owned_halo = [st["n_owned"] + st["n_halo"] for _, st in per_shard]
    edge_frac = lazy_e_max / eager_e if eager_e else 0.0
    pass1_max = max(st["pass1_scanned"] for _, st in per_shard)
    pass1_frac = pass1_max / n_tasks
    report(
        f"discovery/{tag}/{pattern}/w{width}d{depth}s{n_shards}",
        lazy_s_max * 1e6,
        f"edge_frac={edge_frac:.3f};pass1_frac={pass1_frac:.3f};"
        f"lazy_edges_max={lazy_e_max};"
        f"eager_edges={eager_e};owned_halo_max={max(owned_halo)}",
        extra={
            "pattern": pattern, "width": width, "depth": depth,
            "n_shards": n_shards, "n_tasks": n_tasks,
            "eager_seconds": eager_s, "eager_edges": eager_e,
            "lazy_seconds_max": lazy_s_max,
            "lazy_seconds_mean": lazy_s_mean,
            "lazy_edges_max": lazy_e_max,
            "owned_halo_max": max(owned_halo),
            "owned_halo_mean": sum(owned_halo) / len(owned_halo),
            "edge_frac": edge_frac,
            "edges_per_owned_halo": lazy_e_max / max(owned_halo),
            "pass1_scanned_max": pass1_max,
            "pass1_frac": pass1_frac,
        },
    )
    return edge_frac, pass1_frac


def run(report) -> None:
    tag, pattern, width, depth, shard_counts = SHARD_SWEEP
    rows = [_row(report, tag, pattern, width, depth, s)
            for s in shard_counts]
    fracs = [e for e, _ in rows]
    assert fracs == sorted(fracs, reverse=True), (
        "per-shard derived edges must shrink as shards grow "
        f"(got edge_frac {fracs} over shards {shard_counts})")
    p1 = [p for _, p in rows]
    # strip enumeration: pass 1 scans exactly the owned strip, so the
    # scanned fraction is exactly 1/S on the column-partitioned grid
    assert all(abs(p - 1 / s) < 1e-9 for p, s in zip(p1, shard_counts)), (
        f"pass-1 scanned fraction must fall as 1/S (got {p1} "
        f"over shards {shard_counts})")

    tag, pattern, width, depths, n_shards = DEPTH_SWEEP
    for d in depths:
        _row(report, tag, pattern, width, d, n_shards)
