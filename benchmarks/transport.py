"""Transport benchmark: what each comm backend charges per active message.

One row pair per registered backend (``repro.core.comm``):

- ``transport/<backend>/rtt`` — rank 0 ping-pongs a small AM with rank 1
  through the full reliable-delivery stack (sequencing, dedup windows,
  ACKs); the paper's one-sided-latency microbenchmark. ``am_rtt_us`` is
  guarded lower-is-better at the loose ``--tol 1.0`` CI leg: inproc RTT
  is queue hand-off cost, multiproc RTT adds two localhost TCP hops and
  two cloudpickle round trips, and only an order-of-magnitude blow-up
  (a progress-loop or framing regression) fails the job;
- ``transport/<backend>/bandwidth`` — windowed one-way stream of 1 MiB
  payload AMs rank 0 -> rank 1, closed by a single done-reply;
  ``am_mb_s`` is reported, not guarded (pure memory/loopback throughput,
  noisy on shared CI).

The ping-pong main drives ``ctx.comm.progress()`` explicitly between
sends — the §II-B2 model where the main thread is the progress thread —
so the row measures the transport, not a scheduler hand-off.
"""

from __future__ import annotations

import time

RTT_WARMUP = 10
RTT_ROUNDS = 200
BW_CHUNK = 1 << 20   # 1 MiB per send
BW_SENDS = 32


def _pingpong_main(ctx):
    """Both ranks register the same AMs in the same order (§II-B2 AM
    identity); only rank 0 drives the measurement loops."""
    import numpy as np

    pongs = []
    done = []

    # registration order: ping, pong, sink, fin — identical on every rank
    ping = ctx.comm.make_active_msg(lambda i: pong.send(0, i))
    pong = ctx.comm.make_active_msg(lambda i: pongs.append(i))
    sink = ctx.comm.make_active_msg(lambda blob: None)
    fin_reply = ctx.comm.make_active_msg(lambda n: done.append(n))
    recvd = []
    fin = ctx.comm.make_active_msg(lambda n: (recvd.append(n),
                                              fin_reply.send(0, n)))

    out = None
    if ctx.rank == 0:
        for i in range(-RTT_WARMUP, RTT_ROUNDS):
            if i == 0:
                t0 = time.perf_counter()
            ping.send(1, i)
            want = i + RTT_WARMUP + 1
            while len(pongs) < want:
                ctx.comm.progress()
                # yield the GIL: a tight spin starves the peer/receiver
                # thread for a whole 5ms switch interval per hand-off
                time.sleep(1e-5)
        rtt_us = (time.perf_counter() - t0) / RTT_ROUNDS * 1e6

        blob = np.zeros(BW_CHUNK, np.uint8)
        t0 = time.perf_counter()
        for _ in range(BW_SENDS):
            sink.send(1, blob)
        fin.send(1, BW_SENDS)
        while not done:
            ctx.comm.progress()
            time.sleep(1e-5)
        mb_s = BW_SENDS * BW_CHUNK / (time.perf_counter() - t0) / 1e6
        out = (rtt_us, mb_s)
    ctx.barrier_free_join()
    return out


def _measure(backend: str):
    from repro.core import run_ranks

    return run_ranks(2, _pingpong_main, n_threads=1, transport=backend)[0]


def run(report) -> None:
    from repro.core import backend_names

    for backend in sorted(backend_names()):
        rtt_us, mb_s = _measure(backend)
        report(f"transport/{backend}/rtt", rtt_us,
               f"{RTT_ROUNDS} small-AM round trips rank0<->rank1",
               extra={"backend": backend, "am_rtt_us": round(rtt_us, 3)})
        report(f"transport/{backend}/bandwidth",
               BW_CHUNK / mb_s if mb_s else 0.0,
               f"{BW_SENDS}x{BW_CHUNK >> 20}MiB one-way, windowed",
               extra={"backend": backend, "am_mb_s": round(mb_s, 1)})
