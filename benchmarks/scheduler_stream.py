"""Scheduler-service stream benchmark: what the resident multi-tenant
path costs on top of the one-shot runtime, and whether retirement keeps
memory on the live frontier.

Two rows, both through the full service (submission bus -> per-rank lazy
assimilation via ``derive_local`` -> namespace binding -> retirement):

- ``sched_stream/overhead`` — N concurrent clients x M submissions of a
  Task-Bench stencil with near-empty bodies: wall time divided by total
  tasks is ``sched_overhead_us``, the per-task cost of admission, bus
  consumption, assimilation, fair ordering, fulfillment, and retirement
  (the scheduler-side METG analogue). Guarded lower-is-better at the
  loose ``--tol 1.0`` (it is a timing metric: only an
  order-of-magnitude regression fails);
- ``sched_stream/chained`` — one client streaming M submissions chained
  through one namespace (each reads the previous one's final writes):
  reports ``submissions_per_s`` and ``live_frac`` = blocks high-water /
  blocks ever materialized. ``live_frac`` is the retirement guard
  (deterministic up to watermark/assimilation races — guarded at the
  loose tolerance): near 1.0 means the service is accumulating history
  instead of retiring it;
- ``sched_stream/recovery`` — the same chained stream with a resident
  rank killed mid-stream by a seeded fault plan (plus loss+dup under
  ``REPRO_CHAOS_EXTRA=lossdup``): ``sched_recover_ms`` is DEATH
  declaration -> the at-death in-flight set drained (how long clients
  feel the epoch change), and ``replay_frac`` is bus commands replayed
  during adoption / commands ever posted (how much of the stream's
  history recovery had to re-read — bounded by the unresolved window,
  not the stream length). Both are guarded lower-is-better at the loose
  timing tolerance.
"""

from __future__ import annotations

import os
import time

from benchmarks.taskbench_scaling import (taskbench_blocks, taskbench_bodies,
                                          taskbench_graph)

N_SHARDS = 2
WIDTH, DEPTH = 8, 6


def _stream(n_clients: int, n_subs: int, bodies, *, chained: bool,
            faults=None):
    """Run the stream; returns (wall_seconds, total_tasks, svc)."""
    import threading

    from repro.sched import SchedulerService

    blocks = taskbench_blocks(WIDTH, DEPTH, seed=11)
    total_tasks = n_clients * n_subs * WIDTH * DEPTH
    t0 = time.perf_counter()
    with SchedulerService(N_SHARDS, timeout=300.0, faults=faults) as svc:
        def client_thread(i: int) -> None:
            c = svc.client(f"c{i}", weight=float(i + 1))
            futs = []
            for j in range(n_subs):
                g, _ = taskbench_graph("stencil", WIDTH, DEPTH, N_SHARDS,
                                       seed=11)
                ns = None if chained else f"c{i}/{j}"
                seed = blocks if (j == 0 or not chained) else {}
                futs.append(c.submit(g, seed, bodies, namespace=ns))
            for f in futs:
                f.result(300.0)

        threads = [threading.Thread(target=client_thread, args=(i,),
                                    daemon=True) for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    wall = time.perf_counter() - t0
    return wall, total_tasks, svc


def run(report) -> None:
    # near-empty bodies: the row measures the scheduler, not the math
    noop_bodies = {name: (lambda *ops: ops[0])
                   for name in taskbench_bodies()}
    wall, n_tasks, svc = _stream(4, 6, noop_bodies, chained=False)
    stats = svc.stats()
    overhead_us = wall / n_tasks * 1e6
    report("sched_stream/overhead", overhead_us,
           f"{4}x{6} subs, {n_tasks} tasks",
           extra={"sched_overhead_us": round(overhead_us, 3),
                  "submissions_per_s": round(4 * 6 / wall, 2),
                  "live_frac": round(stats["live_frac"], 4)})

    wall, n_tasks, svc = _stream(1, 10, taskbench_bodies(), chained=True)
    stats = svc.stats()
    report("sched_stream/chained", wall / n_tasks * 1e6,
           f"10 chained subs, live {stats['blocks_hwm']}/"
           f"{stats['blocks_total']}",
           extra={"submissions_per_s": round(10 / wall, 2),
                  "live_frac": round(stats["live_frac"], 4)})

    # survivability: kill rank 1 mid-stream; the chained stream must drain
    # through adoption (replay from the frozen cursor + re-execution)
    from repro.core.faults import FaultPlan

    p = 0.1 if os.environ.get("REPRO_CHAOS_EXTRA") == "lossdup" else 0.0
    plan = FaultPlan(seed=11, drop=p, duplicate=p, kill={1: 30},
                     lease=0.4, heartbeat_every=0.02)
    wall, n_tasks, svc = _stream(1, 10, taskbench_bodies(), chained=True,
                                 faults=plan)
    rep = svc.recovery_report.to_dict()
    recover_ms = svc.capacity()["sched_recover_ms"]
    if recover_ms is None:
        recover_ms = 0.0   # the kill point was never reached
    replay_frac = rep["bus_replayed"] / max(svc.bus.posted, 1)
    report("sched_stream/recovery", recover_ms,
           f"kill rank1@30, replayed {rep['bus_replayed']}/"
           f"{svc.bus.posted} bus cmds, {rep['reexecuted_tasks']} tasks "
           "re-executed",
           extra={"sched_recover_ms": round(recover_ms, 2),
                  "replay_frac": round(replay_frac, 4),
                  "bus_replayed": rep["bus_replayed"],
                  "reexecuted_tasks": rep["reexecuted_tasks"],
                  "replayed_sends": rep["replayed_sends"],
                  "submissions_per_s": round(10 / wall, 2)})
