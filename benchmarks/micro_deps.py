"""Fig 6 analogue: dependency-management overhead.

2D grid of nrows x ncols tasks; task (i,j) fulfills ndeps tasks
((i+k) % nrows, j+1) — the paper's many-dependencies micro-benchmark —
for TTor (PTG) and the STF baseline (deps inferred from data accesses).
"""

from __future__ import annotations

import time

from repro.core import STFGraph, Taskflow, Threadpool


def ttor_grid(nrows: int, ncols: int, ndeps: int, n_threads: int,
              spin: float) -> float:
    tp = Threadpool(n_threads, start=False)
    tf = Taskflow(tp, "grid")
    tf.set_indegree(lambda ij: 1 if ij[1] == 0 else ndeps)
    tf.set_mapping(lambda ij: ij[0] % n_threads)

    def body(ij):
        time.sleep(spin)
        i, j = ij
        if j + 1 < ncols:
            for k in range(ndeps):
                tf.fulfill_promise(((i + k) % nrows, j + 1))

    tf.set_task(body)
    t0 = time.perf_counter()
    tp.start()
    for i in range(nrows):
        tf.fulfill_promise((i, 0))
    tp.join()
    return time.perf_counter() - t0


def stf_grid(nrows: int, ncols: int, ndeps: int, n_threads: int,
             spin: float) -> float:
    tp = Threadpool(n_threads)
    g = STFGraph(tp)
    t0 = time.perf_counter()
    for j in range(ncols):
        for i in range(nrows):
            accesses = [((i, j), "W")]
            if j > 0:
                accesses += [(((i - k) % nrows, j - 1), "R")
                             for k in range(ndeps)]
            g.submit(lambda: time.sleep(spin), accesses,
                     mapping=i % n_threads)
    g.execute()
    wall = time.perf_counter() - t0
    tp.join()
    return wall


def run(report) -> None:
    from benchmarks.micro_overhead import calibrated_spin

    nrows, spin = 32, 10e-6
    eff_spin = calibrated_spin(spin)
    for ndeps in (1, 4):
        for n_threads in (2, 4):
            ncols = 60
            n_tasks = nrows * ncols
            ideal = eff_spin * n_tasks / n_threads
            for name, fn in (("ttor", ttor_grid), ("stf", stf_grid)):
                wall = fn(nrows, ncols, ndeps, n_threads, spin)
                report(
                    f"micro_deps/{name}/ndeps{ndeps}/t{n_threads}",
                    wall / n_tasks * 1e6,
                    f"efficiency={ideal / wall:.3f}",
                )
