"""Fig 5 analogue: shared-memory serial overhead of the runtime.

(a) TTor, insertion excluded (tasks pre-fulfilled, then tp.start());
(b) TTor, insertion included, vs the STF baseline (sequential submission +
    inferred deps through an artificial READWRITE datum per task).

Efficiency = ideal_time / wall = (spin x ntasks / nthreads) / wall.
Python-thread caveat: spin is time.sleep (releases the GIL), so overheads
measure the *runtime bookkeeping* (queues, dep maps, steals), which is the
paper's quantity of interest.
"""

from __future__ import annotations

import time

from repro.core import STFGraph, Task, Taskflow, Threadpool


def _spin(seconds: float):
    time.sleep(seconds)


def calibrated_spin(spin: float, n: int = 300) -> float:
    """time.sleep overshoots by the timer slack (~50-100us on Linux);
    efficiency must be computed against the *achievable* per-task time."""
    t0 = time.perf_counter()
    for _ in range(n):
        time.sleep(spin)
    return (time.perf_counter() - t0) / n


def ttor_no_insertion(n_tasks: int, n_threads: int, spin: float) -> float:
    tp = Threadpool(n_threads, start=False)
    tf = Taskflow(tp, "bench")
    tf.set_indegree(lambda k: 1)
    tf.set_mapping(lambda k: k % n_threads)
    tf.set_task(lambda k: _spin(spin))
    for k in range(n_tasks):
        tf.fulfill_promise(k)
    t0 = time.perf_counter()
    tp.start()
    tp.join()
    return time.perf_counter() - t0


def ttor_with_insertion(n_tasks: int, n_threads: int, spin: float) -> float:
    tp = Threadpool(n_threads, start=False)
    tf = Taskflow(tp, "bench")
    tf.set_indegree(lambda k: 1)
    tf.set_mapping(lambda k: k % n_threads)
    tf.set_task(lambda k: _spin(spin))
    t0 = time.perf_counter()
    tp.start()
    for k in range(n_tasks):
        tf.fulfill_promise(k)
    tp.join()
    return time.perf_counter() - t0


def stf_with_insertion(n_tasks: int, n_threads: int, spin: float) -> float:
    tp = Threadpool(n_threads)
    g = STFGraph(tp)
    t0 = time.perf_counter()
    for k in range(n_tasks):
        # artificial independent read-write datum per task (paper's setup)
        g.submit(lambda: _spin(spin), [(f"d{k}", "RW")], mapping=k % n_threads)
    g.execute()
    wall = time.perf_counter() - t0
    tp.join()
    return wall


def run(report) -> None:
    for spin in (100e-6, 10e-6):
        eff_spin = calibrated_spin(spin)
        for n_threads in (1, 2, 4):
            n_tasks = max(200, int(0.25 / max(spin, 20e-6)) * n_threads)
            ideal = eff_spin * n_tasks / n_threads
            for name, fn in (("ttor_noins", ttor_no_insertion),
                             ("ttor_ins", ttor_with_insertion),
                             ("stf_ins", stf_with_insertion)):
                wall = fn(n_tasks, n_threads, spin)
                report(
                    f"micro_overhead/{name}/spin{int(spin * 1e6)}us"
                    f"/t{n_threads}",
                    wall / n_tasks * 1e6,
                    f"efficiency={ideal / wall:.3f}",
                )
