"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  micro_overhead    Fig 5  (no-dependency overhead, TTor vs STF)
  micro_deps        Fig 6  (dependency-management overhead)
  gemm_scaling      Fig 7  (distributed GEMM: scaling, block sweep, AMs)
  cholesky_scaling  Fig 9  (distributed Cholesky: scaling, block, rho)
  roofline          §Roofline (reads reports/dryrun JSONs)
"""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()

    from benchmarks import (cholesky_scaling, gemm_scaling, micro_deps,
                            micro_overhead, roofline)

    modules = {
        "micro_overhead": micro_overhead,
        "micro_deps": micro_deps,
        "gemm_scaling": gemm_scaling,
        "cholesky_scaling": cholesky_scaling,
        "roofline": roofline,
    }
    if args.only:
        modules = {k: v for k, v in modules.items() if k in args.only}

    print("name,us_per_call,derived")
    failed = []

    def report(name: str, us: float, derived: str = "") -> None:
        print(f"{name},{us:.3f},{derived}", flush=True)

    for name, mod in modules.items():
        try:
            mod.run(report)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        sys.exit(f"benchmark module(s) failed: {failed}")


if __name__ == "__main__":
    main()
