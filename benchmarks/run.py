"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Modules:
  micro_overhead     Fig 5  (no-dependency overhead, TTor vs STF)
  micro_deps         Fig 6  (dependency-management overhead)
  gemm_scaling       Fig 7  (distributed GEMM: scaling, block sweep, AMs)
  cholesky_scaling   Fig 9  (distributed Cholesky: scaling, block, rho)
  taskbench_scaling  Task Bench (1908.05790): dependence-pattern sweep over
                     discovery -> comm_plan -> executor, wire efficiency
  discovery_scaling  graph-build cost: lazy per-shard derivation (owned +
                     halo) vs the eager global scan, edge_frac guarded
  recovery           fault-recovery cost: Cholesky under seeded loss/dup/
                     rank-kill plans; recovery_seconds + rederived_frac
                     (guarded lower) from the RecoveryReport
  scheduler_stream   resident multi-tenant scheduler: per-task overhead of
                     the submission-stream path (sched_overhead_us) and
                     retirement health (live_frac), both guarded lower
  transport          per-comm-backend AM ping-pong latency (am_rtt_us,
                     guarded lower at the loose tol) and 1 MiB one-way
                     bandwidth, inproc threads vs multiproc OS processes
  roofline           §Roofline (reads reports/dryrun JSONs)

``--json [PATH]`` additionally writes a ``BENCH_<utc>.json`` artifact with
every row (plus each module's structured ``extra`` payload), so
us-per-task, wire-efficiency, and — since the segmented-scan executor —
``compile_seconds`` / ``hlo_bytes`` become a tracked trajectory across
PRs — see ROADMAP §Perf iteration log.
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time
import traceback


def compile_metrics(fn, *args):
    """Lower and compile a jittable callable, measuring the compile-cost
    columns the BENCH rows track: ``lower_seconds`` (trace + StableHLO
    emission), ``compile_seconds`` (XLA), and ``hlo_bytes`` (StableHLO
    module text size — the depth-proportional quantity the segmented-scan
    lowering exists to bound). Returns ``(compiled_callable, metrics)``.

    ``hlo_bytes`` is deterministic for a given jax version, so ratios of it
    between two lowerings of the same program (``hlo_frac`` in the deep
    Task-Bench rows) are guard-stable across machines.
    """
    import jax

    t0 = time.perf_counter()
    lowered = jax.jit(fn).lower(*args)
    lower_s = time.perf_counter() - t0
    hlo_bytes = len(lowered.as_text())
    t0 = time.perf_counter()
    compiled = lowered.compile()
    compile_s = time.perf_counter() - t0
    return compiled, {
        "lower_seconds": round(lower_s, 4),
        "compile_seconds": round(compile_s, 4),
        "hlo_bytes": hlo_bytes,
    }

# `python benchmarks/run.py` puts benchmarks/ (not the repo root) on
# sys.path; fix it up so the `benchmarks.*` imports resolve either way.
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    ap.add_argument("--json", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="write rows to PATH (default BENCH_<utc>.json)")
    args = ap.parse_args()

    from benchmarks import (cholesky_scaling, discovery_scaling,
                            gemm_scaling, micro_deps, micro_overhead,
                            recovery, roofline, scheduler_stream,
                            taskbench_scaling, transport)

    modules = {
        "micro_overhead": micro_overhead,
        "micro_deps": micro_deps,
        "gemm_scaling": gemm_scaling,
        "cholesky_scaling": cholesky_scaling,
        "taskbench_scaling": taskbench_scaling,
        "discovery_scaling": discovery_scaling,
        "recovery": recovery,
        "scheduler_stream": scheduler_stream,
        "transport": transport,
        "roofline": roofline,
    }
    if args.only:
        modules = {k: v for k, v in modules.items() if k in args.only}

    print("name,us_per_call,derived")
    failed = []
    rows = []

    def report(name: str, us: float, derived: str = "", extra=None) -> None:
        print(f"{name},{us:.3f},{derived}", flush=True)
        row = {"name": name, "us_per_call": us, "derived": derived}
        if extra:
            row.update(extra)
        rows.append(row)

    for name, mod in modules.items():
        try:
            mod.run(report)
        except Exception:
            failed.append(name)
            traceback.print_exc()

    if args.json is not None:
        path = args.json or time.strftime("BENCH_%Y%m%dT%H%M%SZ.json",
                                          time.gmtime())
        payload = {
            "created_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "python": platform.python_version(),
            "modules": sorted(modules),
            "failed": failed,
            "rows": rows,
        }
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {path} ({len(rows)} rows)", file=sys.stderr)

    if failed:
        sys.exit(f"benchmark module(s) failed: {failed}")


if __name__ == "__main__":
    main()
