"""Recovery-cost benchmark: what a fault costs the host runtime.

The ISSUE's framing (via the Task Bench methodology and the Charm++/HPX
overhead study): robustness features must be *measured*, not just
asserted. This module runs the 8-rank Cholesky host run under three
seeded fault plans and emits the recovery trajectory into ``BENCH_*.json``:

- ``loss10`` / ``dup10`` — 10% message loss / duplication, no deaths:
  the reliable layer's steady-state overhead (``retries``,
  ``dup_suppressed``); the result must stay bit-identical, so the row
  doubles as an end-to-end check.
- ``kill1`` — the acceptance scenario: 10% loss + 10% duplication + one
  mid-run rank kill. Emits ``recovery_seconds`` (death declared -> back
  to quiescence) and ``rederived_frac`` (re-derived edge entries after
  the death / full eager edge entries — the lazy-discovery payoff:
  adoption re-derives only the moved shard, so this should track
  ~1/n_shards + halo, not O(global)). ``rederived_frac`` is
  deterministic for a given plan seed and is guarded by CI via
  ``check_regression.py --metric rederived_frac:lower``;
  ``recovery_seconds`` is a timing and stays unguarded.
"""

from __future__ import annotations

import time

import numpy as np


def _cholesky_case():
    from repro.linalg.cholesky import (cholesky_bodies, cholesky_graph,
                                       make_spd_blocks)

    nb, b, pr, pc = 6, 4, 4, 2
    g = cholesky_graph(nb, pr, pc, b)
    blocks, _ = make_spd_blocks(nb, b, seed=0)
    return g, blocks, cholesky_bodies()


def _check_identical(ref, out, tag):
    if set(out) != set(ref):
        raise AssertionError(f"{tag}: block set diverged under faults")
    for k in ref:
        if not np.array_equal(np.asarray(ref[k]), np.asarray(out[k])):
            raise AssertionError(f"{tag}: block {k} not bit-identical")


def run(report) -> None:
    from repro.core import FaultPlan

    g, blocks, bodies = _cholesky_case()
    ref = g.run_host(dict(blocks), bodies, n_threads=2)

    plans = [
        ("loss10", FaultPlan(seed=5, drop=0.10)),
        ("dup10", FaultPlan(seed=5, duplicate=0.10)),
        ("kill1", FaultPlan(seed=5, drop=0.10, duplicate=0.10,
                            kill={3: 2})),
    ]
    for tag, plan in plans:
        t0 = time.perf_counter()
        out, rep = g.run_host(dict(blocks), bodies, n_threads=2,
                              faults=plan, timeout=120.0)
        wall = time.perf_counter() - t0
        _check_identical(ref, out, tag)
        extra = {
            "retries": rep.retries,
            "injected_drops": rep.injected_drops,
            "injected_dups": rep.injected_dups,
            "dup_suppressed": rep.dup_suppressed,
            "deaths": list(rep.deaths),
        }
        derived = f"retries={rep.retries}"
        if rep.deaths:
            extra.update(
                recovery_seconds=round(rep.recovery_seconds, 4),
                rederived_frac=round(rep.rederived_frac, 4),
                rederived_shards=list(rep.rederived_shards),
                reexecuted_tasks=rep.reexecuted_tasks,
                replayed_sends=rep.replayed_sends,
            )
            derived = (f"recovery={rep.recovery_seconds:.3f}s "
                       f"rederived_frac={rep.rederived_frac:.3f}")
        report(f"recovery/cholesky8_{tag}", wall * 1e6, derived, extra)
