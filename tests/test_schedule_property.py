"""Property tests: random layered block-PTGs through the full pipeline —
discovery locality, schedule validity, and host-runtime execution vs a
direct topological oracle. (The compiled executor is covered by the linalg
multi-device cases; here hypothesis hammers the scheduling invariants.)"""

import numpy as np
from hypothesis import HealthCheck, given, settings, strategies as st

import jax.numpy as jnp

from repro.core.discovery import PTG, discover
from repro.core.schedule import BlockPTGSpec, build_block_program
from repro.linalg.host_exec import run_host_ptg


def random_layered_ptg(rng, n_layers, width, n_shards, fan_in):
    """Tasks (l, i): layer l, index i. Task (l, i) reads the outputs of
    `fan_in` tasks in layer l-1 plus RMW of its own block; owner-computes
    holds by construction. Returns (spec, oracle_fn, blocks)."""
    deps = {}
    for l in range(1, n_layers):
        for i in range(width):
            k = int(fan_in)
            srcs = sorted(set(int(rng.integers(0, width))
                              for _ in range(k)))
            deps[(l, i)] = [(l - 1, j) for j in srcs]

    def in_deps(t):
        return deps.get(t, [])

    def out_deps(t):
        l, i = t
        return [d for d, srcs in deps.items() if t in srcs and d[0] == l + 1]

    def mapping(t):
        return (t[1] * 7 + t[0]) % n_shards

    def block_of(t):
        return t  # one output block per task

    def operands(t):
        return [t] + list(deps.get(t, []))  # RMW own block + read parents

    def owner(blk):
        return mapping(blk)

    ptg = PTG(in_deps, out_deps, mapping,
              type_of=lambda t: f"f{len(deps.get(t, []))}")
    seeds = [(0, i) for i in range(width)]
    spec = BlockPTGSpec(ptg=ptg, seeds=seeds, n_shards=n_shards,
                        block_shape=(4, 4), block_of=block_of,
                        operands=operands, owner=owner, dtype=jnp.float32)
    blocks = {(l, i): rng.standard_normal((4, 4)).astype(np.float32)
              for l in range(n_layers) for i in range(width)}

    def body(*ops):
        out = ops[0] * 0.5
        for o in ops[1:]:
            out = out + o
        return out

    bodies = {f"f{k}": body for k in range(0, 9)}

    def oracle():
        vals = {blk: arr.copy() for blk, arr in blocks.items()}
        for l in range(n_layers):
            for i in range(width):
                t = (l, i)
                if l == 0:
                    vals[t] = body(vals[t])
                else:
                    vals[t] = body(vals[t], *[vals[d] for d in deps[t]])
        return vals

    return spec, bodies, blocks, oracle


@settings(deadline=None, max_examples=10,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_layers=st.integers(2, 5),
    width=st.integers(1, 5),
    n_shards=st.integers(1, 4),
    fan_in=st.integers(1, 3),
    seed=st.integers(0, 2**31),
)
def test_random_ptg_schedule_and_host_execution(n_layers, width, n_shards,
                                                fan_in, seed):
    rng = np.random.default_rng(seed)
    spec, bodies, blocks, oracle = random_layered_ptg(
        rng, n_layers, width, n_shards, fan_in)

    # schedule invariants
    prog = build_block_program(spec)
    prog.schedule.validate(spec.ptg)
    total = sum(len(wf) for s in prog.schedule.shards for wf in s.wavefronts)
    assert total == n_layers * width

    # discovery locality: every shard touches O(its tasks), not O(DAG)
    for s in prog.schedule.shards:
        own = sum(len(wf) for wf in s.wavefronts)
        assert s.expanded <= own * (fan_in + 2) + width

    # host-runtime execution matches the sequential oracle
    np_bodies = {t: (lambda fn: lambda *a: np.asarray(fn(*a)))(fn)
                 for t, fn in bodies.items()}
    out = run_host_ptg(spec, blocks, np_bodies, n_threads=2, timeout=60.0)
    want = oracle()
    for blk, arr in want.items():
        np.testing.assert_allclose(out[blk], arr, rtol=1e-5, atol=1e-5)
