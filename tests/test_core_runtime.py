"""Unit tests for the TaskTorrent host runtime: threadpool, taskflow, AMs."""

import threading
import time

import numpy as np
import pytest

from repro.core import (
    READWRITE,
    STFGraph,
    Task,
    Taskflow,
    Threadpool,
    run_ranks,
    view,
)


# --------------------------------------------------------------- threadpool

def test_threadpool_runs_all_tasks():
    tp = Threadpool(4)
    done = []
    lock = threading.Lock()
    for i in range(200):
        tp.insert(Task(run=lambda i=i: (lock.acquire(), done.append(i),
                                        lock.release())), i % 4)
    tp.join()
    assert sorted(done) == list(range(200))


def test_threadpool_deferred_start():
    """Paper's micro-benchmark setup: insert everything, then start."""
    tp = Threadpool(2, start=False)
    done = []
    lock = threading.Lock()
    for i in range(50):
        tp.insert(Task(run=lambda i=i: (lock.acquire(), done.append(i),
                                        lock.release())), i % 2)
    assert done == []  # nothing ran yet
    tp.start()
    tp.join()
    assert len(done) == 50


def test_threadpool_priority_order():
    """Higher priority runs first within one thread (max-heap semantics)."""
    tp = Threadpool(1, start=False)
    order = []
    for i, prio in enumerate([1.0, 5.0, 3.0]):
        tp.insert(Task(run=lambda i=i: order.append(i), priority=prio), 0,
                  bound=True)
    tp.start()
    tp.join()
    assert order == [1, 2, 0]


def test_work_stealing_balances_load():
    """All tasks mapped to thread 0, stealable: other threads must steal."""
    tp = Threadpool(4)
    n = 64
    counter = {"done": 0}
    lock = threading.Lock()

    def body():
        time.sleep(0.002)
        with lock:
            counter["done"] += 1

    for _ in range(n):
        tp.insert(Task(run=body), 0, bound=False)
    tp.join()
    assert counter["done"] == n
    assert tp.stats["steals"] > 0, "expected work stealing to kick in"


def test_bound_tasks_never_stolen():
    tp = Threadpool(4)
    executed_on = []
    lock = threading.Lock()

    def body():
        from repro.core.threadpool import current_thread_id
        with lock:
            executed_on.append(current_thread_id())
        time.sleep(0.001)

    for _ in range(32):
        tp.insert(Task(run=body), 1, bound=True)
    tp.join()
    assert set(executed_on) == {1}


# ----------------------------------------------------------------- taskflow

def test_taskflow_chain():
    """k -> k+1 chain: strict sequential dependency ordering."""
    tp = Threadpool(4)
    tf = Taskflow(tp, "chain")
    order = []
    n = 100

    tf.set_indegree(lambda k: 1)
    tf.set_mapping(lambda k: k % 4)

    def body(k):
        order.append(k)
        if k + 1 < n:
            tf.fulfill_promise(k + 1)

    tf.set_task(body)
    tf.fulfill_promise(0)
    tp.join()
    assert order == list(range(n))


def test_taskflow_2d_wavefront():
    """Paper Fig 6 dependency pattern: (i,j) -> ((i+k)%nrows, j+1)."""
    nrows, ncols, ndeps = 8, 12, 3
    tp = Threadpool(4)
    tf = Taskflow(tp, "wave")
    done = set()
    lock = threading.Lock()

    tf.set_indegree(lambda ij: 1 if ij[1] == 0 else ndeps)
    tf.set_mapping(lambda ij: ij[0] % 4)

    def body(ij):
        i, j = ij
        with lock:
            # all in-deps must have completed
            if j > 0:
                for k in range(ndeps):
                    src = ((i - k) % nrows, j - 1)
                    assert src in done, f"{ij} ran before {src}"
            done.add(ij)
        if j + 1 < ncols:
            for k in range(ndeps):
                tf.fulfill_promise(((i + k) % nrows, j + 1))

    tf.set_task(body)
    for i in range(nrows):
        tf.fulfill_promise((i, 0))
    tp.join()
    assert len(done) == nrows * ncols


def test_taskflow_forgets_completed_tasks():
    tp = Threadpool(2)
    tf = Taskflow(tp, "mem")
    tf.set_indegree(lambda k: 1)
    tf.set_mapping(lambda k: 0)
    tf.set_task(lambda k: None)
    for k in range(64):
        tf.fulfill_promise(k)
    tp.join()
    assert tf.pending() == 0  # O(live tasks) state, all forgotten


def test_taskflow_indegree_must_be_positive():
    tp = Threadpool(1)
    tf = Taskflow(tp, "bad")
    tf.set_indegree(lambda k: 0)
    tf.set_mapping(lambda k: 0)
    tf.set_task(lambda k: None)
    tf.fulfill_promise(7)
    with pytest.raises(ValueError, match="indegree"):
        tp.join()


# ------------------------------------------------------------ distributed AM

def test_active_message_roundtrip():
    """Rank 0 sends AMs to rank 1; payload arrives intact, fn runs remotely."""

    def main(ctx):
        received = []
        am = ctx.comm.make_active_msg(lambda k, x: received.append((k, x)))
        if ctx.rank == 0:
            for k in range(10):
                am.send(1, k, k * k)
        ctx.tp.join()
        return received

    res = run_ranks(2, main, n_threads=2)
    assert res[0] == []
    assert sorted(res[1]) == [(k, k * k) for k in range(10)]


def test_payload_reusable_after_send():
    """send() serializes immediately: mutating the arg after send is safe."""

    def main(ctx):
        received = []
        am = ctx.comm.make_active_msg(lambda arr: received.append(np.array(arr)))
        if ctx.rank == 0:
            buf = np.arange(8)
            am.send(1, view(buf))
            buf[:] = -1  # mutate after send; receiver must see 0..7
        ctx.tp.join()
        return received

    res = run_ranks(2, main)
    np.testing.assert_array_equal(res[1][0], np.arange(8))


def test_large_am_three_callbacks():
    """Large AM: alloc on receiver, process on receiver, complete on sender."""

    def main(ctx):
        state = {"buf": None, "processed": False, "sender_done": False}

        def alloc(n):
            state["buf"] = np.zeros(n, dtype=np.float64)
            return state["buf"]

        def process(n):
            state["processed"] = True

        def complete():
            state["sender_done"] = True

        lam = ctx.comm.make_large_active_msg(process, alloc, complete)
        if ctx.rank == 0:
            data = np.linspace(0.0, 1.0, 32)
            lam.send(1, 32, view(data))
        ctx.tp.join()
        return state

    res = run_ranks(2, main)
    assert res[0]["sender_done"] is True
    assert res[1]["processed"] is True
    np.testing.assert_allclose(res[1]["buf"], np.linspace(0.0, 1.0, 32))


def test_am_triggers_remote_taskflow():
    """The paper's canonical pattern: AM stores data + fulfills a promise."""

    def main(ctx):
        data = {}
        tf = ctx.taskflow("remote")
        out = []
        tf.set_indegree(lambda k: 1)
        tf.set_mapping(lambda k: k % 2)
        tf.set_task(lambda k: out.append((k, data[k])))

        am = ctx.comm.make_active_msg(
            lambda d, payload: (data.__setitem__(d, payload),
                                tf.fulfill_promise(d)))
        if ctx.rank == 0:
            for d in range(6):
                am.send(1, d, d * 10)
        ctx.tp.join()
        return sorted(out)

    res = run_ranks(2, main)
    assert res[1] == [(d, d * 10) for d in range(6)]


def test_am_registration_order_mismatch_detected():
    def main(ctx):
        if ctx.rank == 0:
            ctx.comm.make_active_msg(lambda: None)
        else:
            def other(): pass
            ctx.comm.make_active_msg(other)
        # Let both ranks register before failing the assertion window.
        time.sleep(0.05)
        ctx.comm.make_active_msg(lambda: None)  # triggers cross-check
        ctx.tp.join()

    with pytest.raises(RuntimeError):
        run_ranks(2, main)


# ---------------------------------------------------------------- STF model

def test_stf_infers_raw_war_waw():
    tp = Threadpool(2)
    g = STFGraph(tp)
    log = []
    lock = threading.Lock()

    def mk(name):
        def fn():
            with lock:
                log.append(name)
        return fn

    g.submit(mk("w1"), [("x", "W")])
    g.submit(mk("r1"), [("x", "R")])
    g.submit(mk("r2"), [("x", "R")])
    g.submit(mk("w2"), [("x", "W")])          # WAR on r1/r2, WAW on w1
    g.submit(mk("rw"), [("x", READWRITE)])    # RAW on w2
    g.execute()
    tp.join()
    assert log.index("w1") < log.index("r1")
    assert log.index("w1") < log.index("r2")
    assert log.index("r1") < log.index("w2")
    assert log.index("r2") < log.index("w2")
    assert log.index("w2") < log.index("rw")


def test_stf_execute_is_one_shot():
    """A second execute() must raise loudly: the first run consumed the
    indegree counters, so silently re-running would release the whole DAG
    at once, ignoring every dependency."""
    tp = Threadpool(2)
    g = STFGraph(tp)
    ran = []
    g.submit(lambda: ran.append("a"), [("x", "W")])
    g.submit(lambda: ran.append("b"), [("x", "R")])
    g.execute()
    tp.join()
    assert ran == ["a", "b"]
    with pytest.raises(RuntimeError, match="already ran"):
        g.execute()
    assert ran == ["a", "b"]  # nothing re-ran


def test_stf_reset_reexecutes_with_dependencies():
    """reset() restores the submitted indegree counters, so a re-run
    observes every edge again — the orderings hold on both passes."""
    tp = Threadpool(2)
    g = STFGraph(tp)
    log = []
    lock = threading.Lock()

    def mk(name):
        def fn():
            with lock:
                log.append(name)
        return fn

    g.submit(mk("w"), [("x", "W")])
    g.submit(mk("r"), [("x", "R")])
    g.submit(mk("w2"), [("x", "W")])   # WAR on r, WAW on w
    for _ in range(3):                 # execute() blocks until done
        g.execute()
        assert log == ["w", "r", "w2"], log
        log.clear()
        # the one-shot guard arms after every run, and reset() disarms it
        with pytest.raises(RuntimeError, match="already ran"):
            g.execute()
        assert log == []               # the guard really ran nothing
        g.reset()
    tp.join()
