"""Property tests for the distributed completion protocol (§II-B3).

Theorem 1 (correctness): SHUTDOWN is sent iff completion was reached — i.e.
no message is lost: every queued AM is processed before the world shuts down.
Theorem 2 (finiteness): the protocol terminates.

We stress both with adversarial message delivery: random per-message delays
(which reorder delivery arbitrarily across (src, dst) pairs) and random task
topologies, including long chains of AM ping-pong that repeatedly make ranks
*look* idle while messages are still in flight — the exact failure mode of
the naive "everyone says IDLE once" strategy the paper warns about.
"""

import random
import threading

import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import run_ranks


def _delay_fn(seed: float, max_delay: float):
    rng = random.Random(seed)
    lock = threading.Lock()

    def fn(src, dst, kind):
        with lock:
            return rng.uniform(0.0, max_delay)

    return fn


@settings(deadline=None, max_examples=15,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_ranks=st.integers(2, 4),
    n_msgs=st.integers(1, 25),
    seed=st.integers(0, 2**31),
    max_delay=st.sampled_from([0.0, 0.002, 0.02]),
)
def test_no_early_termination_scatter(n_ranks, n_msgs, seed, max_delay):
    """Rank 0 scatters n_msgs AMs; delayed delivery must not cause early
    SHUTDOWN: every rank must have processed all its messages at join."""

    def main(ctx):
        received = []
        am = ctx.comm.make_active_msg(lambda i: received.append(i))
        if ctx.rank == 0:
            for i in range(n_msgs):
                am.send(1 + (i % (ctx.n_ranks - 1)), i)
        ctx.tp.join()
        return received

    res = run_ranks(n_ranks, main, delay_fn=_delay_fn(seed, max_delay),
                    timeout=60.0)
    got = sorted(x for r in res for x in r)
    assert got == list(range(n_msgs)), "messages lost => early termination"


@settings(deadline=None, max_examples=10,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_ranks=st.integers(2, 4),
    hops=st.integers(1, 30),
    seed=st.integers(0, 2**31),
)
def test_ping_pong_chain(n_ranks, hops, seed):
    """An AM chain hopping rank-to-rank: between hops *all* ranks are idle
    and a message is in flight — the adversarial case for completion. The
    chain must complete all hops before shutdown (Theorem 1), and the run
    must terminate (Theorem 2, enforced by the timeout)."""

    def main(ctx):
        count = [0]
        am_holder = {}

        def on_hop(i):
            count[0] += 1
            if i + 1 < hops:
                am_holder["am"].send((ctx.rank + 1) % ctx.n_ranks, i + 1)

        am_holder["am"] = ctx.comm.make_active_msg(on_hop)
        if ctx.rank == 0:
            am_holder["am"].send(1 % ctx.n_ranks, 0)
        ctx.tp.join()
        return count[0]

    res = run_ranks(n_ranks, main, delay_fn=_delay_fn(seed, 0.005), timeout=60.0)
    assert sum(res) == hops


@settings(deadline=None, max_examples=8,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_ranks=st.integers(2, 3),
    width=st.integers(1, 6),
    depth=st.integers(1, 6),
    seed=st.integers(0, 2**31),
)
def test_task_cascade_across_ranks(n_ranks, width, depth, seed):
    """AMs fulfill remote taskflow promises which send more AMs — tasks and
    messages interleave; completion must wait for the whole cascade."""

    def main(ctx):
        done = []
        tf = ctx.taskflow("cascade")
        am_holder = {}

        tf.set_indegree(lambda k: 1)
        tf.set_mapping(lambda k: k[1] % ctx.tp.n_threads)

        def body(k):
            level, i = k
            done.append(k)
            if level + 1 < depth:
                am_holder["am"].send((ctx.rank + 1) % ctx.n_ranks,
                                     (level + 1, i))

        tf.set_task(body)
        am_holder["am"] = ctx.comm.make_active_msg(
            lambda k: tf.fulfill_promise(tuple(k)))
        if ctx.rank == 0:
            for i in range(width):
                tf.fulfill_promise((0, i))
        ctx.tp.join()
        return len(done)

    res = run_ranks(n_ranks, main, delay_fn=_delay_fn(seed, 0.003), timeout=60.0)
    assert sum(res) == width * depth


def test_empty_program_terminates():
    """No AMs at all: the protocol must still shut down (q=p=0)."""

    def main(ctx):
        ctx.tp.join()
        return True

    assert run_ranks(3, main, timeout=30.0) == [True, True, True]


def test_counters_exclude_protocol_traffic():
    """q_r / p_r must count only user AMs, never COUNT/REQUEST/... traffic."""

    def main(ctx):
        am = ctx.comm.make_active_msg(lambda: None)
        if ctx.rank == 0:
            am.send(1)
        ctx.tp.join()
        return (ctx.comm.queued_count, ctx.comm.processed_count)

    res = run_ranks(2, main, timeout=30.0)
    assert res[0] == (1, 0)
    assert res[1] == (0, 1)
