"""Lazy per-shard derivation is edge-for-edge identical to the eager scan.

The acceptance bar of the distributed-discovery redesign: for every app
graph family, ``Graph.derive_local`` (owned tasks + halo only) unioned
across shards must reproduce *exactly* what the eager global access scan
(``Graph.build``) derives — same edges, same order, same seeds — and the
``discover_local`` schedule plus the full lowered program must match the
eager path array-for-array. The eager path is kept precisely to be this
oracle (``to_block_spec(lazy=False)``).

Also covered: per-shard locality of the derived state (edges scale with
owned + halo, not the global index space), ragged owner maps (hypothesis:
random skewed block distributions, including shards owning nothing),
``derive_local(shard, owner_map=...)`` overrides, and the local error
surface (non-owned queries, duplicate keys, forward after-edges).
"""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.discovery import discover_local, union_ptg
from repro.core.schedule import build_block_program
from repro.dist.pipeline import pipeline_graph
from repro.linalg.cholesky import cholesky_graph, cholesky_spec
from repro.linalg.gemm import (gemm_2d_graph, gemm_2d_spec, gemm_3d_graph,
                               gemm_3d_spec)
from repro.ptg import Graph
from benchmarks.taskbench_scaling import taskbench_graph, taskbench_spec

from tests.test_ptg_builder import (assert_programs_identical,
                                    assert_schedules_identical)


def assert_views_match_eager(make_graph):
    """The core identity: per-shard lazy views, unioned, equal the eager
    global derivation edge-for-edge (values AND order), task-for-task."""
    eager = make_graph().build()
    lazy = make_graph()
    views = lazy.local_views()

    all_owned = [k for v in views for k in v.tasks]
    assert sorted(map(repr, all_owned)) == sorted(map(repr, eager.tasks))
    assert len(all_owned) == eager.n_tasks  # disjoint ownership

    for v in views:
        for k in v.tasks:
            assert v.in_deps(k) == eager.in_deps(k), k
            assert v.out_deps(k) == eager.out_deps(k), k
            assert v.operands(k) == eager.operands(k), k
            assert v.block_of(k) == eager.block_of(k), k
            assert v.type_of(k) == eager.type_of(k), k
            assert v.mapping(k) == eager.mapping(k), k
        # halo mapping agrees wherever it is defined
        for k, m in v._map.items():
            assert m == eager.mapping(k), k
    return eager, views


GRAPH_FAMILIES = {
    "gemm2d": lambda: gemm_2d_graph(5, 2, 2, 4),
    "gemm2d_staged": lambda: gemm_2d_graph(5, 2, 2, 4, staged=True),
    "gemm3d": lambda: gemm_3d_graph(4, 2, 4),
    "cholesky": lambda: cholesky_graph(6, 2, 2, 4),
    "pipeline": lambda: pipeline_graph(4, 6),
    "tb_stencil": lambda: taskbench_graph("stencil", 8, 6, 4, 4, fan=2)[0],
    "tb_fft": lambda: taskbench_graph("fft", 8, 6, 4, 4, fan=2)[0],
    "tb_tree": lambda: taskbench_graph("tree", 8, 6, 4, 4, fan=2)[0],
    "tb_random": lambda: taskbench_graph("random", 8, 6, 4, 4, fan=2)[0],
}


@pytest.mark.parametrize("family", sorted(GRAPH_FAMILIES))
def test_lazy_views_match_eager_per_family(family):
    make = GRAPH_FAMILIES[family]
    eager, views = assert_views_match_eager(make)
    # seeds: merged per-view seeds reproduce the eager program order
    merged = [k for _, k in sorted(((v.pos[k], k)
                                    for v in views for k in v.seeds),
                                   key=lambda e: e[0])]
    assert merged == eager.seeds
    # and the local-mode schedule equals global discovery
    sn = make().to_schedule(validate=True, lazy=True)
    so = make().to_schedule(validate=True, lazy=False)
    assert_schedules_identical(sn, so)


SPEC_FAMILIES = {
    "gemm2d": lambda lazy: gemm_2d_spec(5, 2, 2, 4, lazy=lazy),
    "gemm2d_staged": lambda lazy: gemm_2d_spec(5, 2, 2, 4, staged=True,
                                               lazy=lazy),
    "gemm3d": lambda lazy: gemm_3d_spec(4, 2, 4, lazy=lazy),
    "cholesky": lambda lazy: cholesky_spec(6, 2, 2, 4, lazy=lazy),
    "tb_stencil": lambda lazy: taskbench_spec("stencil", 8, 6, 4, 4,
                                              fan=2, lazy=lazy)[0],
    "tb_random": lambda lazy: taskbench_spec("random", 8, 6, 4, 4,
                                             fan=2, lazy=lazy)[0],
}


@pytest.mark.parametrize("family", sorted(SPEC_FAMILIES))
def test_lazy_program_identical_to_eager_per_family(family):
    """Full lowered-program identity (schedule, slot maps, every index and
    exchange table array-for-array): the executors emit identical HLO."""
    make = SPEC_FAMILIES[family]
    lazy_spec = make(True)
    assert lazy_spec.views is not None and len(lazy_spec.views) == \
        lazy_spec.n_shards
    eager_spec = make(False)
    assert eager_spec.views is None
    assert_programs_identical(lazy_spec, eager_spec)


# ------------------------------------------------------------- locality

def test_derived_state_scales_with_owned_plus_halo():
    """The point of the redesign: per-shard derived edges shrink as the
    graph is spread over more shards, while the eager edge count (the
    global graph) stays fixed."""
    width, depth = 32, 8

    def eager_edges(g):
        g.build()
        return sum(len(g.in_deps(k)) + len(g.out_deps(k)) for k in g.tasks)

    totals = {}
    peaks = {}
    for n_shards in (2, 4, 8, 16):
        g, _ = taskbench_graph("stencil", width, depth, n_shards, 4)
        views = g.local_views()
        peaks[n_shards] = max(v.stats["derived_edges"] for v in views)
        ge, _ = taskbench_graph("stencil", width, depth, n_shards, 4)
        totals[n_shards] = eager_edges(ge)
        # owned+halo bound: a stencil shard's halo is its boundary columns
        for v in views:
            assert v.stats["n_halo"] <= 2 * depth + v.stats["n_owned"]
            assert (v.stats["n_owned"] + v.stats["n_halo"]
                    < v.stats["n_tasks_global"])
    # the global graph does not depend on the shard count...
    assert len(set(totals.values())) == 1
    # ...but the per-shard derived state does, monotonically
    assert peaks[16] < peaks[8] < peaks[4] < peaks[2] < totals[2]


def test_view_rejects_non_owned_queries():
    g = cholesky_graph(4, 2, 2, 4)
    views = g.local_views()
    foreign = views[1].tasks[0]
    with pytest.raises(KeyError, match="not an owned task"):
        views[0].in_deps(foreign)
    with pytest.raises(KeyError, match="owned by no shard"):
        g.to_block_spec().ptg.in_deps(("potrf", 99))   # == union_ptg(views)
    with pytest.raises(KeyError, match="unknown task"):
        g.to_block_spec().operands(("potrf", 99))
    with pytest.raises(KeyError, match="owned by no shard"):
        union_ptg(views).in_deps(("potrf", 99))


def test_lazy_derivation_freezes_declarations():
    """A lazy lowering must freeze the graph exactly like the eager build:
    a task type declared afterwards would be silently absent from the
    cached views otherwise."""
    g = cholesky_graph(4, 2, 2, 4)
    g.to_schedule()                       # lazy default: derives + caches
    with pytest.raises(RuntimeError, match="already derived"):
        g.task_type("late", writes=lambda i: ("x", i))
    with pytest.raises(RuntimeError, match="already derived"):
        g.sequence(lambda: [])


def test_derive_local_error_surface():
    g = Graph("dup", n_shards=1, owner=lambda blk: 0)
    g.task_type("t", space=lambda: ((0,), (0,)), writes=lambda i: ("x", i))
    with pytest.raises(ValueError, match="duplicate task key"):
        g.derive_local(0)

    g2 = Graph("fwd", n_shards=1, owner=lambda blk: 0)
    g2.task_type("t", space=lambda: ((i,) for i in range(3)),
                 writes=lambda i: ("x", i),
                 after=lambda i: [("t", i + 1)] if i == 0 else [])
    with pytest.raises(ValueError, match="earlier task"):
        g2.derive_local(0)


# ------------------------------------------------- ragged owner maps

def _ragged_layered_graph(rng, n_layers, width, n_shards, fan_in,
                          owner_of=None):
    """Random layered graph with a random *ragged* block distribution:
    shard weights drawn skewed, so some shards own most blocks and others
    may own none — the worst case for any balance assumption in the
    per-shard derivation."""
    deps = {}
    for l in range(1, n_layers):
        for i in range(width):
            srcs = sorted(set(int(rng.integers(0, width))
                              for _ in range(fan_in)))
            deps[(l, i)] = [(l - 1, j) for j in srcs]

    if owner_of is None:
        weights = rng.random(n_shards) ** 3 + 1e-9   # heavily skewed
        weights /= weights.sum()
        assign = {(l, i): int(rng.choice(n_shards, p=weights))
                  for l in range(n_layers) for i in range(width)}
        owner_of = assign.__getitem__

    g = Graph("ragged", n_shards=n_shards, owner=owner_of,
              block_shape=(4, 4))
    for nfan in sorted({len(d) for d in deps.values()} | {0}):
        g.task_type(f"f{nfan}",
                    key=lambda l, i: (l, i),
                    writes=lambda l, i: (l, i),
                    reads=lambda l, i: [(l, i)] + deps.get((l, i), []))
    g.sequence(lambda: ((f"f{len(deps.get((l, i), ()))}", l, i)
                        for l in range(n_layers) for i in range(width)))
    return g, owner_of


@settings(deadline=None, max_examples=15,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_layers=st.integers(2, 5),
    width=st.integers(1, 6),
    n_shards=st.integers(1, 5),
    fan_in=st.integers(1, 3),
    seed=st.integers(0, 2**31),
)
def test_lazy_matches_eager_on_ragged_owner_maps(n_layers, width, n_shards,
                                                 fan_in, seed):
    rng = np.random.default_rng(seed)
    g_lazy, owner_of = _ragged_layered_graph(rng, n_layers, width, n_shards,
                                             fan_in)
    rng2 = np.random.default_rng(seed)
    g_eager, _ = _ragged_layered_graph(rng2, n_layers, width, n_shards,
                                       fan_in, owner_of=owner_of)
    assert_views_match_eager(lambda: g_lazy)  # one-shot: graphs are stateful

    # full program identity, lazy vs eager, on the ragged distribution
    assert_programs_identical(g_lazy.to_block_spec(lazy=True),
                              g_eager.to_block_spec(lazy=False))


def test_derive_local_owner_map_override():
    """derive_local(s, owner_map=O) on a graph declared with a different
    owner equals derive_local(s) on a graph declared with O itself."""
    rng = np.random.default_rng(7)
    g_base, _ = _ragged_layered_graph(rng, 4, 5, 3, 2,
                                      owner_of=lambda blk: 0)
    ragged = {(l, i): (l * 5 + i) % 3 if i else 0
              for l in range(4) for i in range(5)}
    rng2 = np.random.default_rng(7)
    g_ref, _ = _ragged_layered_graph(rng2, 4, 5, 3, 2,
                                     owner_of=ragged.__getitem__)
    for s in range(3):
        vo = g_base.derive_local(s, owner_map=ragged.__getitem__)
        vr = g_ref.derive_local(s)
        assert vo.tasks == vr.tasks and vo.seeds == vr.seeds
        for k in vo.tasks:
            assert vo.in_deps(k) == vr.in_deps(k)
            assert vo.out_deps(k) == vr.out_deps(k)
            assert vo.mapping(k) == vr.mapping(k)


def test_grow_rederives_only_moved_shards():
    """Elastic grow: when new capacity joins and blocks rebalance, only
    the shards whose owned set actually changed need re-derivation. A
    shard untouched by the new owner map produces an edge-for-edge
    identical view (halo mapping included) under the old and the new map
    — the O(moved shards) re-mesh cost the lazy derivation buys, vs the
    eager path's rebuild-the-world on any ownership change."""
    width, depth, n_shards = 8, 6, 4
    g, _ = taskbench_graph("stencil", width, depth, n_shards, 4)

    def full(blk):                       # post-grow: the declared spread
        return blk[1] * n_shards // width

    def clamped(blk):                    # pre-grow: shard 3 not joined yet
        return min(full(blk), 2)

    def snap(v):
        return {k: (v.in_deps(k), v.out_deps(k), v.operands(k),
                    v.block_of(k), v.type_of(k), v.mapping(k))
                for k in v.tasks}

    before = [g.derive_local(s, owner_map=clamped) for s in range(n_shards)]
    after = [g.derive_local(s, owner_map=full) for s in range(n_shards)]

    # shard 3 joins and takes over exactly the tasks shard 2 gives up
    assert before[3].tasks == [] and after[3].tasks != []
    moved = set(before[2].tasks) - set(after[2].tasks)
    assert moved == set(after[3].tasks)

    # unmoved shards: identical views — nothing to re-derive on grow
    for s in (0, 1):
        assert before[s].tasks == after[s].tasks
        assert before[s].seeds == after[s].seeds
        assert snap(before[s]) == snap(after[s])
        assert before[s]._map == after[s]._map   # halo owners unchanged too


def test_discover_local_handles_empty_shards():
    """A shard owning nothing (fully ragged) yields an empty view; the
    local-mode schedule still matches global discovery."""
    rng = np.random.default_rng(3)
    g, owner_of = _ragged_layered_graph(rng, 3, 4, 4, 2,
                                        owner_of=lambda blk: blk[1] % 2)
    views = g.local_views()
    assert [len(v.tasks) for v in views[2:]] == [0, 0]
    sched = discover_local(views, 4, validate=True)
    rng2 = np.random.default_rng(3)
    g2, _ = _ragged_layered_graph(rng2, 3, 4, 4, 2, owner_of=owner_of)
    assert_schedules_identical(sched, g2.to_schedule(lazy=False))
