"""Frozen pre-redesign hand-written PTG specs (PR-2 state), kept verbatim as
the bit-identity reference for the declarative ``repro.ptg`` builder.

These are NOT used by the library any more — ``repro.linalg`` /
``repro.dist.pipeline`` / ``benchmarks.taskbench_scaling`` all build their
graphs through ``repro.ptg.Graph``. ``tests/test_ptg_builder.py`` asserts
the builder-derived graphs reproduce these specs task-for-task,
edge-for-edge, wavefront-for-wavefront, and table-for-table.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core.discovery import PTG
from repro.core.schedule import BlockPTGSpec


# --------------------------------------------------- GEMM 2D (block-cyclic)

def legacy_gemm_2d_spec(nb: int, pr: int, pc: int, b: int, *,
                        staged: bool = False,
                        dtype=jnp.float32) -> BlockPTGSpec:
    """nb×nb blocks of size b×b on a pr×pc shard grid."""

    def owner(blk) -> int:
        kind, r, c = blk
        return (r % pr) * pc + (c % pc)

    def mapping(k):
        if k[0] == "gemm":                       # ("gemm", i, kk, j)
            _, i, _, j = k
            return owner(("C", i, j))
        _, i, kk = k                             # ("sa"|"sb", row, col)
        return owner(("A" if k[0] == "sa" else "B", i, kk))

    def _step(t) -> int:
        return t[2] if t[0] == "sa" else t[1]

    def in_deps(t):
        if t[0] == "gemm":
            _, i, kk, j = t
            deps = [("sa", i, kk), ("sb", kk, j)]
            if kk > 0:
                deps.append(("gemm", i, kk - 1, j))
            return deps
        if staged and _step(t) > 0:              # send chain: step k waits k-1
            return [("sa", t[1], t[2] - 1) if t[0] == "sa"
                    else ("sb", t[1] - 1, t[2])]
        return []

    def out_deps(t):
        if t[0] == "gemm":
            _, i, kk, j = t
            return [("gemm", i, kk + 1, j)] if kk + 1 < nb else []
        if t[0] == "sa":
            _, i, kk = t
            out = [("gemm", i, kk, j) for j in range(nb)]
            if staged and kk + 1 < nb:
                out.append(("sa", i, kk + 1))
        else:
            _, kk, j = t
            out = [("gemm", i, kk, j) for i in range(nb)]
            if staged and kk + 1 < nb:
                out.append(("sb", kk + 1, j))
        return out

    def block_of(t):
        if t[0] == "gemm":
            return ("C", t[1], t[3])
        return ("A", t[1], t[2]) if t[0] == "sa" else ("B", t[1], t[2])

    def operands(t):
        if t[0] == "gemm":
            _, i, kk, j = t
            return [("C", i, j), ("A", i, kk), ("B", kk, j)]
        return [block_of(t)]                     # identity "send" body

    def type_of(t):
        return t[0]

    if staged:
        seeds = [("sa", i, 0) for i in range(nb)] + \
                [("sb", 0, j) for j in range(nb)]
    else:
        seeds = [("sa", i, kk) for i in range(nb) for kk in range(nb)] + \
                [("sb", kk, j) for kk in range(nb) for j in range(nb)]

    return BlockPTGSpec(
        ptg=PTG(in_deps, out_deps, mapping, type_of),
        seeds=seeds, n_shards=pr * pc, block_shape=(b, b),
        block_of=block_of, operands=operands, owner=owner, dtype=dtype)


# ------------------------------------------------------------ GEMM 3D (DNS)

def legacy_gemm_3d_spec(nb: int, q: int, b: int, *,
                        dtype=jnp.float32) -> BlockPTGSpec:
    """DNS mapping on a q×q×q grid: slab l owns k in [l·nb/q, (l+1)·nb/q)."""
    assert nb % q == 0, "nb must divide into q slabs"
    kb = nb // q  # blocks per slab

    def shard(l, r, c) -> int:
        return l * q * q + (r % q) * q + (c % q)

    def slab(kk: int) -> int:
        return kk // kb

    def owner(blk) -> int:
        kind = blk[0]
        if kind == "A":
            _, i, kk = blk
            return shard(slab(kk), i, kk)
        if kind == "B":
            _, kk, j = blk
            return shard(slab(kk), kk, j)
        if kind in ("P", "Pf"):                  # partial C per slab
            _, i, j, l = blk
            return shard(l, i, j)
        _, i, j = blk                            # final C on slab 0
        return shard(0, i, j)

    def mapping(t):
        return owner(block_of(t))

    def block_of(t):
        tt = t[0]
        if tt == "gemm":
            _, i, kk, j = t
            return ("P", i, j, slab(kk))
        if tt == "sa":
            return ("A", t[1], t[2])
        if tt == "sb":
            return ("B", t[1], t[2])
        if tt == "fin":                          # ("fin", i, j, l)
            return ("Pf", t[1], t[2], t[3])
        return ("C", t[1], t[2])                 # ("red", i, j, l)

    def operands(t):
        tt = t[0]
        if tt == "gemm":
            _, i, kk, j = t
            return [("P", i, j, slab(kk)), ("A", i, kk), ("B", kk, j)]
        if tt in ("sa", "sb"):
            return [block_of(t)]
        if tt == "fin":
            return [("P", t[1], t[2], t[3])]
        _, i, j, l = t                           # red: C += Pf_l
        return [("C", i, j), ("Pf", i, j, l)]

    def in_deps(t):
        tt = t[0]
        if tt == "gemm":
            _, i, kk, j = t
            deps = [("sa", i, kk), ("sb", kk, j)]
            if kk % kb > 0:
                deps.append(("gemm", i, kk - 1, j))
            return deps
        if tt in ("sa", "sb"):
            return []
        if tt == "fin":
            _, i, j, l = t
            return [("gemm", i, (l + 1) * kb - 1, j)]
        _, i, j, l = t                           # red
        deps = [("fin", i, j, l)]
        if l > 0:
            deps.append(("red", i, j, l - 1))
        return deps

    def out_deps(t):
        tt = t[0]
        if tt == "gemm":
            _, i, kk, j = t
            if kk % kb + 1 < kb:
                return [("gemm", i, kk + 1, j)]
            return [("fin", i, j, slab(kk))]
        if tt == "sa":
            _, i, kk = t
            return [("gemm", i, kk, j) for j in range(nb)]
        if tt == "sb":
            _, kk, j = t
            return [("gemm", i, kk, j) for i in range(nb)]
        if tt == "fin":
            _, i, j, l = t
            return [("red", i, j, l)]
        _, i, j, l = t                           # red
        return [("red", i, j, l + 1)] if l + 1 < q else []

    def type_of(t):
        return t[0]

    seeds = [("sa", i, kk) for i in range(nb) for kk in range(nb)] + \
            [("sb", kk, j) for kk in range(nb) for j in range(nb)]
    return BlockPTGSpec(
        ptg=PTG(in_deps, out_deps, mapping, type_of),
        seeds=seeds, n_shards=q ** 3, block_shape=(b, b),
        block_of=block_of, operands=operands, owner=owner, dtype=dtype)


# ----------------------------------------------------------------- Cholesky

def legacy_cholesky_spec(nb: int, pr: int, pc: int, b: int,
                         dtype=jnp.float32) -> BlockPTGSpec:
    def owner(blk) -> int:
        _, i, j = blk
        return (i % pr) * pc + (j % pc)

    def block_of(t):
        tt = t[0]
        if tt == "potrf":                        # ("potrf", k)
            return ("L", t[1], t[1])
        if tt == "trsm":                         # ("trsm", i, k)
            return ("L", t[1], t[2])
        if tt == "syrk":                         # ("syrk", k, i)
            return ("A", t[2], t[2])
        _, k, i, j = t                           # ("gemm", k, i, j)
        return ("A", i, j)

    def mapping(t):
        return owner(block_of(t))

    def operands(t):
        tt = t[0]
        if tt == "potrf":
            k = t[1]
            return [("A", k, k)]
        if tt == "trsm":
            _, i, k = t
            return [("A", i, k), ("L", k, k)]
        if tt == "syrk":
            _, k, i = t
            return [("A", i, i), ("L", i, k)]
        _, k, i, j = t
        return [("A", i, j), ("L", i, k), ("L", j, k)]

    def in_deps(t):
        tt = t[0]
        if tt == "potrf":
            k = t[1]
            return [] if k == 0 else [("syrk", k - 1, k)]
        if tt == "trsm":
            _, i, k = t
            deps = [("potrf", k)]
            if k > 0:
                deps.append(("gemm", k - 1, i, k))
            return deps
        if tt == "syrk":
            _, k, i = t
            deps = [("trsm", i, k)]
            if k > 0:
                deps.append(("syrk", k - 1, i))
            return deps
        _, k, i, j = t
        deps = [("trsm", i, k), ("trsm", j, k)]
        if k > 0:
            deps.append(("gemm", k - 1, i, j))
        return deps

    def out_deps(t):
        tt = t[0]
        out = []
        if tt == "potrf":
            k = t[1]
            out = [("trsm", i, k) for i in range(k + 1, nb)]
        elif tt == "trsm":
            _, i, k = t
            out.append(("syrk", k, i))
            out.extend(("gemm", k, i, j) for j in range(k + 1, i))
            out.extend(("gemm", k, i2, i) for i2 in range(i + 1, nb))
        elif tt == "syrk":
            _, k, i = t
            out.append(("potrf", i) if i == k + 1 else ("syrk", k + 1, i))
        else:
            _, k, i, j = t
            out.append(("trsm", i, j) if j == k + 1 else ("gemm", k + 1, i, j))
        return out

    def type_of(t):
        return t[0]

    return BlockPTGSpec(
        ptg=PTG(in_deps, out_deps, mapping, type_of),
        seeds=[("potrf", 0)], n_shards=pr * pc, block_shape=(b, b),
        block_of=block_of, operands=operands, owner=owner, dtype=dtype)


# --------------------------------------------------------------- Task Bench

def legacy_taskbench_spec(pattern: str, width: int, depth: int,
                          n_shards: int, b: int = 8, *, fan: int = 3,
                          seed: int = 0,
                          dtype=jnp.float32) -> Tuple[BlockPTGSpec, Dict]:
    from benchmarks.taskbench_scaling import pattern_parents

    deps: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    children: Dict[Tuple[int, int], List[Tuple[int, int]]] = {}
    for l in range(1, depth):
        for i in range(width):
            ps = [(l - 1, j)
                  for j in pattern_parents(pattern, l, i, width,
                                           fan=fan, seed=seed)]
            deps[(l, i)] = ps
            for p in ps:
                children.setdefault(p, []).append((l, i))

    def mapping(t):
        return t[1] * n_shards // width

    def block_of(t):
        return t

    def operands(t):
        return [t] + deps.get(t, [])

    ptg = PTG(
        in_deps=lambda t: deps.get(t, []),
        out_deps=lambda t: children.get(t, []),
        mapping=mapping,
        type_of=lambda t: f"f{len(deps.get(t, []))}")
    spec = BlockPTGSpec(
        ptg=ptg, seeds=[(0, i) for i in range(width)], n_shards=n_shards,
        block_shape=(b, b), block_of=block_of, operands=operands,
        owner=mapping, dtype=dtype)
    return spec, deps


# ----------------------------------------------------------------- pipeline

def legacy_pipeline_ptg(n_stages: int, n_micro: int) -> PTG:
    """The pipeline's parametrized task graph; task keys are (stage, micro)."""

    def in_deps(k):
        s, m = k
        return ([(s - 1, m)] if s > 0 else []) + ([(s, m - 1)] if m > 0 else [])

    def out_deps(k):
        s, m = k
        return ([(s + 1, m)] if s + 1 < n_stages else []) \
            + ([(s, m + 1)] if m + 1 < n_micro else [])

    return PTG(in_deps=in_deps, out_deps=out_deps, mapping=lambda k: k[0],
               type_of=lambda k: "stage")
