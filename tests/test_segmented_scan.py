"""Property tests for the segmented-scan lowering's host-side machinery:
comm signatures, run segmentation, segment-padded wire accounting, table
memoization, and the ``plan_lowering`` policy (unrolled / segmented scan /
dense scan, with the loud fragmented fallback).

Hypothesis (real in CI, deterministic stub locally) hammers random layered
block-PTGs — bit-identity of the segmented executors vs the unrolled and
dense-scan references runs on 8 emulated devices in
``tests/multi_device_cases.py`` (cases ``lowering_identity`` and
``segmented_identity``).
"""

import logging

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core.discovery import segment_runs
from repro.core.schedule import build_block_program

from tests.test_schedule_property import random_layered_ptg


# --------------------------------------------------------- segment_runs

@settings(deadline=None, max_examples=25)
@given(items=st.lists(st.integers(0, 3), min_size=0, max_size=30))
def test_segment_runs_partitions_into_maximal_runs(items):
    runs = segment_runs(items)
    # exact partition of [0, len), in order
    assert [i for s, e in runs for i in range(s, e)] == list(range(len(items)))
    for s, e in runs:
        assert e > s
        assert all(items[i] == items[s] for i in range(s, e))  # constant
    for (s1, e1), (s2, e2) in zip(runs, runs[1:]):             # maximal
        assert e1 == s2
        assert items[s1] != items[s2]


# ------------------------------------------- signatures and segmentation

@settings(deadline=None, max_examples=15,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_layers=st.integers(2, 6),
    width=st.integers(1, 6),
    n_shards=st.integers(1, 5),
    fan_in=st.integers(1, 4),
    comm=st.sampled_from(["dense", "sparse", "auto"]),
    seed=st.integers(0, 2**31),
)
def test_segments_partition_by_signature(n_layers, width, n_shards,
                                         fan_in, comm, seed):
    rng = np.random.default_rng(seed)
    spec, _bodies, _blocks, _oracle = random_layered_ptg(
        rng, n_layers, width, n_shards, fan_in)
    prog = build_block_program(spec)
    W = prog.schedule.n_wavefronts
    sigs = [prog.comm_signature(w, comm) for w in range(W)]
    segs = prog.segments(comm)

    # exact partition, constant within, different across boundaries
    assert [w for s, e in segs for w in range(s, e)] == list(range(W))
    for s, e in segs:
        assert all(sigs[w] == sigs[s] for w in range(s, e))
    for (s1, _e1), (s2, _e2) in zip(segs, segs[1:]):
        assert sigs[s1] != sigs[s2]

    for w, sig in enumerate(sigs):
        choice = prog.lowered_pattern(w, comm)
        assert sig[0] == choice  # signature kind == lowering choice
        if sig[0] == "ppermute":
            # the static scan-body structure: the wavefront's own rounds
            assert sig[1] == tuple(tuple(r.perm)
                                   for r in prog.sparse_exchange[w])
        if comm == "dense":
            assert sig[0] in ("all_to_all", "none")


@settings(deadline=None, max_examples=15,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_layers=st.integers(2, 6),
    width=st.integers(1, 6),
    n_shards=st.integers(2, 5),
    fan_in=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_segmented_comm_stats_accounting(n_layers, width, n_shards,
                                         fan_in, seed):
    rng = np.random.default_rng(seed)
    spec, _bodies, _blocks, _oracle = random_layered_ptg(
        rng, n_layers, width, n_shards, fan_in)
    prog = build_block_program(spec)

    auto = prog.comm_stats(comm="auto")
    seg = prog.comm_stats(comm="auto", segmented=True)
    # same payload, only padding differs; per-segment padding can never
    # undercut the per-wavefront exact padding of the unrolled lowering
    assert seg["real_bytes"] == auto["real_bytes"]
    assert seg["n_segments"] == len(prog.segments("auto"))
    assert seg["total_wire_bytes"] >= auto["total_wire_bytes"]
    if seg["total_wire_bytes"]:
        assert 0.0 < seg["wire_efficiency"] <= 1.0
    for row_seg, row_auto in zip(seg["per_wavefront"],
                                 auto["per_wavefront"]):
        assert row_seg["pattern"] == row_auto["pattern"]
        assert row_seg["wire_blocks"] >= row_auto["wire_blocks"]
        assert row_seg["real_blocks"] == row_auto["real_blocks"]
    # the per-segment breakdown re-sums to the totals
    assert sum(r["real_bytes"] for r in seg["segments"]) == seg["real_bytes"]
    assert (sum(r["padded_bytes"] for r in seg["segments"])
            == seg["padded_bytes"])
    for r in seg["segments"]:
        assert 0 <= r["start"] < r["stop"]
        assert r["wavefronts"] == r["stop"] - r["start"]
        assert r["padded_bytes"] >= 0


# ----------------------------------------------------------- memoization

def test_lowered_tables_are_memoized():
    """The O(W·n·T) numpy stacking runs once per (schedule, mode): repeat
    calls return the *same objects* from the program's cache."""
    rng = np.random.default_rng(7)
    spec, _bodies, _blocks, _oracle = random_layered_ptg(rng, 5, 4, 3, 2)
    prog = build_block_program(spec)

    assert prog._dense_scan_tables() is prog._dense_scan_tables()
    assert (prog._segment_tables("auto", 0.5, False)
            is prog._segment_tables("auto", 0.5, False))
    assert (prog._segment_tables("auto", 0.5, True)
            is prog._segment_tables("auto", 0.5, True))
    # distinct modes get distinct cache entries
    assert (prog._segment_tables("auto", 0.5, False)
            is not prog._segment_tables("auto", 0.5, True))
    for w in range(prog.schedule.n_wavefronts):
        assert prog._split_tables(w) == prog._split_tables(w)
        assert prog._split_tables(w)[0] is prog._split_tables(w)[0]


# -------------------------------------------------- plan_lowering policy

def _taskbench(pattern, width, depth, n_shards):
    from benchmarks.taskbench_scaling import taskbench_spec

    spec, _deps = taskbench_spec(pattern, width, depth, n_shards, 4)
    return build_block_program(spec)


def test_plan_shallow_unrolls():
    prog = _taskbench("stencil", 8, 6, 4)
    plan = prog.plan_lowering(unroll_cap=64)
    assert plan["mode"] == "unrolled" and not plan["discards"]


def test_plan_deep_sparse_segments():
    """Past the unroll cap, a stencil schedule keeps its sparse wire via
    the segmented scan — the old dense-scan cliff is gone."""
    prog = _taskbench("stencil", 16, 70, 8)
    plan = prog.plan_lowering(unroll_cap=64)
    assert plan["mode"] == "segmented_scan"
    assert plan["n_segments"] <= 4
    assert not plan["discards"]
    # and the segmented wire matches the unrolled auto reference
    seg = prog.comm_stats(comm="auto", segmented=True)
    auto = prog.comm_stats(comm="auto")
    assert seg["wire_efficiency"] >= 0.9 * auto["wire_efficiency"]


def test_plan_deep_fragmented_takes_union_cover():
    """fft's stride cycling gives every wavefront a different ppermute
    signature: too fragmented to segment *exactly* — but the union
    permutation cover folds the whole sparse run into a handful of scans,
    so the policy keeps the sparse wire instead of warning-and-falling-back
    to the dense scan. Also exercises ragged shapes: the exact fft run list
    contains single-wavefront segments."""
    prog = _taskbench("fft", 16, 70, 8)
    plan = prog.plan_lowering(unroll_cap=64)
    assert plan["mode"] == "union_cover"
    assert plan["cover"] == "union"
    assert not plan["discards"]
    assert plan["n_segments"] > 64            # exact cover fragments...
    assert plan["n_segments_union"] <= 4      # ...the union cover does not
    assert (plan["wire_efficiency_union"]
            > plan["wire_efficiency_dense_scan"])
    assert any(e - s == 1 for s, e in prog.segments("auto"))

    # every wavefront's pairs are spanned by its union segment's rounds
    # (realization would raise otherwise), and the padding is accounted:
    # union wire >= exact wire, same payload
    union = prog.comm_stats(comm="auto", segmented=True, cover="union")
    exact = prog.comm_stats(comm="auto")
    assert union["real_bytes"] == exact["real_bytes"]
    assert union["total_wire_bytes"] >= exact["total_wire_bytes"]
    assert union["n_segments"] == plan["n_segments_union"]


def test_plan_hopeless_fragmentation_falls_back_loudly(caplog):
    """When even the union cover cannot fit the segment cap, the policy
    still falls back to the dense scan — explicitly (discards=True + a
    logged warning), never silently."""
    prog = _taskbench("fft", 16, 70, 8)
    plan = prog.plan_lowering(unroll_cap=64, segment_cap=0)
    assert plan["mode"] == "dense_scan"
    assert plan["discards"]
    assert "fragmented" in plan["reason"]

    # auto_executor logs the discard before touching the mesh; a 1-device
    # mesh then fails the shard-count check, which is fine — the warning
    # must already be out.
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("shards",))
    with caplog.at_level(logging.WARNING, logger="repro.core.schedule"):
        with pytest.raises(ValueError, match="shards"):
            prog.auto_executor({}, mesh, unroll_cap=64, segment_cap=0)
    assert any("DISCARDING" in r.message for r in caplog.records)


def test_plan_dense_request_and_genuinely_dense():
    # explicit dense ask -> pure dense scan, no discard
    prog = _taskbench("stencil", 16, 70, 8)
    plan = prog.plan_lowering(unroll_cap=64, comm="dense", overlap=False)
    assert plan["mode"] == "dense_scan" and not plan["discards"]
    # random at 4 shards classifies dense everywhere: with no overlap asked
    # there is no sparsity to keep -> pure dense scan, not a discard
    prog = _taskbench("random", 16, 70, 4)
    plan = prog.plan_lowering(unroll_cap=64, overlap=False)
    assert plan["mode"] == "dense_scan" and not plan["discards"]
    assert "genuinely dense" in plan["reason"]
    # but with overlap (the default) the segmented scan carries it
    plan = prog.plan_lowering(unroll_cap=64)
    assert plan["mode"] == "segmented_scan"


def test_executor_rejects_unknown_comm():
    prog = _taskbench("stencil", 4, 3, 1)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("shards",))
    with pytest.raises(ValueError, match="unknown comm policy"):
        prog.executor({}, mesh, comm="bogus")
