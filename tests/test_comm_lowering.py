"""Property tests for the classified exchange lowering.

Hypothesis (real in CI, deterministic stub locally) hammers random layered
block-PTGs through discovery + ``build_block_program`` and checks, against
a brute-force walk of the PTG's cross-shard edges:

- ``comm_stats`` byte accounting: real bytes == distinct (block, dst shard)
  cross edges per producer wavefront, under every lowering policy;
- pattern classification: per-pair counts, density, and the ppermute round
  decomposition (partial permutations covering each pair exactly once);
- the halo split: independent + dependent partitions each wavefront, and
  dependent tasks are exactly the message targets of the previous one.

(Bit-identity of the sparse/overlap executors vs the unrolled dense
reference runs on 8 emulated devices in ``tests/multi_device_cases.py`` —
cases ``lowering_identity`` and ``taskbench_identity``.)
"""

from collections import defaultdict

import numpy as np
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.schedule import build_block_program

from tests.test_schedule_property import random_layered_ptg


def brute_force_cross_edges(spec, level_of):
    """{producer wavefront: {(src, dst): set(blocks)}} walked directly off
    the PTG — one copy per (block, dst shard), the large-AM contract."""
    n = spec.n_shards
    edges = defaultdict(lambda: defaultdict(set))
    tasks = list(level_of)
    for k in tasks:
        dst = spec.ptg.mapping(k) % n
        ops = set(spec.operands(k))
        for d in spec.ptg.in_deps(k):
            src = spec.ptg.mapping(d) % n
            blk = spec.block_of(d)
            if src != dst and blk in ops:
                edges[level_of[d]][(src, dst)].add(blk)
    return edges


@settings(deadline=None, max_examples=15,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_layers=st.integers(2, 5),
    width=st.integers(1, 6),
    n_shards=st.integers(1, 5),
    fan_in=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_comm_accounting_matches_brute_force(n_layers, width, n_shards,
                                             fan_in, seed):
    rng = np.random.default_rng(seed)
    spec, _bodies, _blocks, _oracle = random_layered_ptg(
        rng, n_layers, width, n_shards, fan_in)
    prog = build_block_program(spec)
    edges = brute_force_cross_edges(spec, prog.schedule.level_of)

    block_bytes = prog.comm_stats()["block_bytes"]
    want_real = {w: sum(len(b) for b in pairs.values())
                 for w, pairs in edges.items()}

    for comm in ("dense", "sparse", "auto"):
        st_ = prog.comm_stats(comm=comm)
        assert st_["real_bytes"] == sum(want_real.values()) * block_bytes
        assert st_["padded_bytes"] >= 0
        assert (st_["real_bytes"] + st_["padded_bytes"]
                == st_["total_wire_bytes"])
        if st_["total_wire_bytes"]:
            assert 0.0 < st_["wire_efficiency"] <= 1.0
        for w, row in enumerate(st_["per_wavefront"]):
            assert row["real_blocks"] == want_real.get(w, 0)
            assert row["wire_blocks"] >= row["real_blocks"]

    # sparse never ships more wire than dense (it may tie)
    sp = prog.comm_stats(comm="sparse")
    de = prog.comm_stats(comm="dense")
    au = prog.comm_stats(comm="auto")
    assert sp["total_wire_bytes"] <= de["total_wire_bytes"]
    assert au["total_wire_bytes"] <= de["total_wire_bytes"]


@settings(deadline=None, max_examples=15,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_layers=st.integers(2, 5),
    width=st.integers(1, 6),
    n_shards=st.integers(2, 5),
    fan_in=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_pattern_classification_and_rounds(n_layers, width, n_shards,
                                           fan_in, seed):
    rng = np.random.default_rng(seed)
    spec, _bodies, _blocks, _oracle = random_layered_ptg(
        rng, n_layers, width, n_shards, fan_in)
    prog = build_block_program(spec)
    edges = brute_force_cross_edges(spec, prog.schedule.level_of)

    for w, pat in enumerate(prog.patterns):
        want = {pair: len(blks)
                for pair, blks in edges.get(w, {}).items() if blks}
        assert pat.pair_counts == want
        assert pat.n_pairs == len(want)
        assert 0.0 <= pat.density <= 1.0
        assert pat.total == sum(want.values())

        # round decomposition: partial permutations, each pair exactly once
        seen = []
        for rnd in prog.sparse_exchange[w]:
            srcs = [p[0] for p in rnd.perm]
            dsts = [p[1] for p in rnd.perm]
            assert len(set(srcs)) == len(srcs)
            assert len(set(dsts)) == len(dsts)
            assert rnd.width == max(want[p] for p in rnd.perm)
            seen.extend(rnd.perm)
        assert sorted(seen) == sorted(want)

        # sparse wire slots account exactly: rounds x active pairs x width
        sp_row = prog.comm_stats(comm="sparse")["per_wavefront"][w]
        assert sp_row["wire_blocks"] == sum(
            r.wire_slots for r in prog.sparse_exchange[w])


@settings(deadline=None, max_examples=15,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_layers=st.integers(2, 5),
    width=st.integers(1, 6),
    n_shards=st.integers(1, 5),
    fan_in=st.integers(1, 4),
    seed=st.integers(0, 2**31),
)
def test_halo_split_partitions_wavefronts(n_layers, width, n_shards,
                                          fan_in, seed):
    rng = np.random.default_rng(seed)
    spec, _bodies, _blocks, _oracle = random_layered_ptg(
        rng, n_layers, width, n_shards, fan_in)
    prog = build_block_program(spec)
    sched = prog.schedule

    for w in range(sched.n_wavefronts):
        arriving = {m.dst_task
                    for pairs in sched.messages.get(w - 1, {}).values()
                    for m in pairs if sched.level_of[m.dst_task] == w}
        for s, (indep, dep) in enumerate(sched.halo_split(w)):
            tasks = sched.shards[s].wavefronts[w]
            assert sorted(map(repr, indep + dep)) == sorted(map(repr, tasks))
            assert all(k in arriving for k in dep)
            assert all(k not in arriving for k in indep)
