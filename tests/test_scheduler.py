"""The persistent scheduler service: streams of PTGs from concurrent
clients must be *exactly* the one-shot executions, interleaved.

The contract under test, end to end:

- bit-identity: every submission's ``result()`` equals the one-shot
  ``Graph.run_host`` of the same graph on the same inputs — for a single
  submission, for a chained stream through one namespace (each submission
  reading the previous one's final writes), and for the acceptance
  scenario (4 clients x 8 mixed Task-Bench + Cholesky submissions,
  concurrent);
- isolation: clients in different namespaces never observe each other,
  under arbitrary interleavings (hypothesis over patterns/shapes/seeds);
- retirement: live state tracks the frontier, not history — the block
  high-water mark stays flat as the stream length grows, and nothing is
  live once the stream drains;
- admission: a client past its in-flight cap *blocks in submit* until
  earlier work completes (backpressure, not rejection);
- failure: a raising task body fails exactly its own submission, poisons
  the blocks it never produced (dependent readers fail loudly), and
  leaves every other client untouched;
- fairness: the weighted-fair policy is deterministic and orders ready
  tasks by weighted virtual time;
- survivability: a resident rank killed mid-stream is adopted — the bus
  is replayed from its frozen cursor, lost tasks re-execute, and every
  surviving future resolves bit-identically (the kill-point sweep
  property-tests this at arbitrary message indices, chained namespaces
  included); deadlines shed cleanly (:class:`DeadlineExceeded`, never a
  hang) and ``retries=`` resubmits shed attempts.

These tests run unmodified under ``REPRO_CHAOS=loss|dup`` (the sched-soak
CI leg): reliable delivery keeps a resident, lossy world correct. The
kill tests use explicit seeded fault plans instead (blanket kill
injection would break stream-shape assertions like
``ns_live_versions == 0``); ``REPRO_CHAOS_EXTRA=lossdup`` layers 10%
loss+duplication onto those plans — the sched-soak ``kill+loss+dup`` leg.
"""

import os
import threading
import time

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.faults import FaultPlan
from repro.ptg import Graph, IndexSpace
from repro.sched import (DeadlineExceeded, FairPolicy, SchedulerService,
                         SubmissionError)
from repro.linalg.cholesky import (cholesky_bodies, cholesky_graph,
                                   make_spd_blocks)
from benchmarks.taskbench_scaling import (taskbench_blocks, taskbench_bodies,
                                          taskbench_graph)

W, D, S = 4, 3, 2   # small stencil grid: 12 tasks, 12 blocks, 2 shards


def chained_refs(pattern, blocks, m, *, seed=0):
    """Sequential one-shot executions, each seeded with everything the
    previous runs wrote — the oracle for a chained submission stream."""
    bodies = taskbench_bodies()
    refs, store = [], dict(blocks)
    for _ in range(m):
        g, _ = taskbench_graph(pattern, W, D, S, seed=seed)
        out = g.run_host(store, bodies, n_threads=2)
        refs.append(out)
        store.update(out)
    return refs


def assert_blocks_equal(out, ref):
    assert set(out) == set(ref)
    for blk in ref:
        assert np.array_equal(np.asarray(out[blk]), np.asarray(ref[blk])), blk


# ------------------------------------------------------------ bit-identity

def test_single_submission_matches_one_shot():
    blocks = taskbench_blocks(W, D, seed=1)
    (ref,) = chained_refs("stencil", blocks, 1)
    with SchedulerService(S, timeout=60.0) as svc:
        c = svc.client("alice")
        g, _ = taskbench_graph("stencil", W, D, S)
        out = c.submit(g, blocks, taskbench_bodies()).result(60.0)
    assert_blocks_equal(out, ref)
    assert c.stats["completed"] == 1 and c.stats["tasks"] == W * D


def test_chained_stream_matches_sequential_one_shots():
    """Submissions 2..m pass no blocks at all: their external reads bind
    to the namespace, i.e. to the previous submission's final writes."""
    m = 4
    blocks = taskbench_blocks(W, D, seed=2)
    refs = chained_refs("stencil", blocks, m)
    with SchedulerService(S, timeout=60.0) as svc:
        c = svc.client("alice")
        futs = []
        for j in range(m):
            g, _ = taskbench_graph("stencil", W, D, S)
            futs.append(c.submit(g, blocks if j == 0 else {},
                                 taskbench_bodies()))
        outs = [f.result(60.0) for f in futs]
    for out, ref in zip(outs, refs):
        assert_blocks_equal(out, ref)


def test_map_returns_ordered_results():
    with SchedulerService(S, timeout=60.0) as svc:
        c = svc.client("mapper")
        r = c.map(lambda x: x * 2 + 1, np.arange(9, dtype=np.int64))
        assert [int(v) for v in r.result(60.0)] == \
            [2 * i + 1 for i in range(9)]


def test_repeated_map_uses_fresh_inputs_and_drops_namespaces():
    """Regression: each map call must get its own namespace — a shared
    one would bind the second call's ("x", i) reads to the FIRST call's
    seeds (seed_initial honors only virgin timelines) and silently map fn
    over stale inputs. The throwaway namespaces are also dropped once
    resolved, so a map-heavy stream leaves no namespace residue."""
    with SchedulerService(S, timeout=60.0) as svc:
        c = svc.client("mapper")
        a = c.map(lambda x: x + 1, np.arange(4, dtype=np.int64)).result(60.0)
        b = c.map(lambda x: x * 10,
                  np.arange(4, 8, dtype=np.int64)).result(60.0)
        third = c.map(lambda x: -x, np.arange(2, dtype=np.int64)).result(60.0)
    assert [int(v) for v in a] == [1, 2, 3, 4]
    assert [int(v) for v in b] == [40, 50, 60, 70]   # not 0,10,20,30
    assert [int(v) for v in third] == [0, -1]
    # ephemeral namespaces were dropped after their watermark passed
    assert all(s["ns_live_versions"] == 0 for s in svc.rank_summaries)


# ----------------------------------------------------- isolation (property)

@settings(deadline=None, max_examples=4,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    pattern=st.sampled_from(["stencil", "fft", "tree", "random"]),
    n_clients=st.integers(2, 3),
    m=st.integers(1, 3),
    seed=st.integers(0, 1000),
)
def test_interleaved_client_streams_are_isolated(pattern, n_clients, m, seed):
    """K clients x M chained submissions, round-robin interleaved into the
    service: each client's stream must equal its own isolated sequential
    one-shot executions — namespaces never leak across tenants."""
    bodies = taskbench_bodies()
    blocks = [taskbench_blocks(W, D, seed=seed + i) for i in range(n_clients)]
    with SchedulerService(S, timeout=90.0) as svc:
        clients = [svc.client(f"c{i}", weight=float(i + 1))
                   for i in range(n_clients)]
        futs = [[] for _ in range(n_clients)]
        for j in range(m):
            for i, c in enumerate(clients):
                g, _ = taskbench_graph(pattern, W, D, S, seed=seed)
                futs[i].append(c.submit(g, blocks[i] if j == 0 else {},
                                        bodies))
        outs = [[f.result(90.0) for f in fs] for fs in futs]
    for i in range(n_clients):
        refs = chained_refs(pattern, blocks[i], m, seed=seed)
        for out, ref in zip(outs[i], refs):
            assert_blocks_equal(out, ref)


# ---------------------------------------------------------------- retirement

def _stream_hwm(m):
    blocks = taskbench_blocks(W, D, seed=3)
    with SchedulerService(S, timeout=90.0) as svc:
        c = svc.client("alice")
        for j in range(m):
            g, _ = taskbench_graph("stencil", W, D, S)
            c.submit(g, blocks if j == 0 else {},
                     taskbench_bodies()).result(90.0)
    return svc.stats()


def test_retirement_keeps_live_blocks_flat_across_stream_length():
    """The whole point of reference-counted retirement: a 3x longer
    stream materializes ~3x the blocks in total, but the high-water mark
    of *live* blocks barely moves — memory tracks the frontier."""
    s3, s9 = _stream_hwm(3), _stream_hwm(9)
    assert s9["blocks_total"] >= 2 * s3["blocks_total"]
    # slack of one submission's blocks: the watermark that retires sub j
    # races the assimilation of sub j+1
    assert s9["blocks_hwm"] <= s3["blocks_hwm"] + W * D
    assert s9["live_frac"] < s3["live_frac"]   # total grows, frontier doesn't
    assert all(r["tasks_live"] == 0 for r in s9["ranks"])


# ----------------------------------------------------------------- admission

def _single_type_graph(name, n_tasks, n_shards=1):
    g = Graph(name, n_shards=n_shards, owner=lambda blk: blk[1] % n_shards)
    g.task_type("t",
                writes=lambda i: ("g", i),
                reads=lambda i: [("g", i)],
                space=IndexSpace(lambda: range(n_tasks),
                                 lambda s: [i for i in range(n_tasks)
                                            if i % n_shards == s],
                                 size=n_tasks))
    return g


def test_admission_backpressure_blocks_submit_until_capacity():
    gate = threading.Event()
    bodies = {"t": lambda x: (gate.wait(60.0), x + 1.0)[1]}
    blocks = {("g", i): np.float64(i) for i in range(2)}
    state = {"admitted": False, "fut": None}
    with SchedulerService(1, timeout=90.0) as svc:
        c = svc.client("capped", max_inflight_tasks=2)
        f1 = c.submit(_single_type_graph("a", 2), blocks, bodies)

        def second():
            state["fut"] = c.submit(_single_type_graph("b", 2), blocks,
                                    bodies)
            state["admitted"] = True

        t = threading.Thread(target=second, daemon=True)
        t.start()
        time.sleep(0.4)
        # 2 tasks in flight, 2 more would exceed the cap: submit() blocks
        assert not state["admitted"]
        gate.set()
        t.join(60.0)
        assert state["admitted"]
        out1 = f1.result(60.0)
        out2 = state["fut"].result(60.0)
    assert out1[("g", 1)] == 2.0
    assert out2[("g", 1)] == 3.0   # chained through the namespace


def test_admission_timeout_raises():
    gate = threading.Event()
    bodies = {"t": lambda x: (gate.wait(60.0), x + 1.0)[1]}
    blocks = {("g", 0): np.float64(0)}
    with SchedulerService(1, timeout=90.0) as svc:
        c = svc.client("capped", max_inflight_tasks=1)
        f1 = c.submit(_single_type_graph("a", 1), blocks, bodies)
        with pytest.raises(TimeoutError, match="admission blocked"):
            c.submit(_single_type_graph("b", 1), blocks, bodies, timeout=0.2)
        gate.set()
        f1.result(60.0)


# ------------------------------------------------------------------- failure

def test_failed_submission_is_isolated_and_poisons_dependents():
    def boom(x):
        raise ValueError("boom")

    blocks_a = {("g", i): np.float64(i) for i in range(2)}
    blocks_b = taskbench_blocks(W, D, seed=4)
    (ref_b,) = chained_refs("stencil", blocks_b, 1)
    with SchedulerService(S, timeout=90.0) as svc:
        a, b = svc.client("a"), svc.client("b")
        fa = a.submit(_single_type_graph("bad", 2, S), blocks_a, {"t": boom})
        g, _ = taskbench_graph("stencil", W, D, S)
        fb = b.submit(g, blocks_b, taskbench_bodies())
        with pytest.raises(SubmissionError):
            fa.result(60.0)
        # a's failure poisoned the blocks it never produced: a dependent
        # submission in a's namespace fails loudly instead of hanging
        fdep = a.submit(_single_type_graph("dep", 2, S), {},
                        {"t": lambda x: x + 1.0})
        with pytest.raises(SubmissionError, match="upstream"):
            fdep.result(60.0)
        # ...while the other tenant is untouched
        assert_blocks_equal(fb.result(60.0), ref_b)
    assert a.stats["failed"] == 2 and a.stats["completed"] == 0
    assert b.stats["failed"] == 0 and b.stats["completed"] == 1


# ------------------------------------------- resolution finality + memory

def test_publish_never_unpoisons_a_version():
    """A straggler task of a failed submission finishing on another rank
    publishes after the fail command poisoned the version: readers must
    still see the failure — resolution is bus-order, not timing."""
    from repro.sched.namespace import NamespaceShard
    from repro.sched.state import LiveStats

    ns = NamespaceShard(LiveStats())
    ns.ensure_pending("n", "b", 1)
    ns.poison_sub(1)
    ns.publish("n", "b", 1, np.int64(5))   # late straggler
    got = []
    ns.bind("n", "b", 2, lambda v, p: got.append((v, p)))
    assert got == [(None, True)]


def test_publish_after_retirement_is_discarded():
    """A publish whose (sub_id, 1) version retirement already dropped as
    superseded must not re-insert it, and must not skew the block
    counters the live_frac guard reads."""
    from repro.sched.namespace import NamespaceShard
    from repro.sched.state import LiveStats

    stats = LiveStats()
    ns = NamespaceShard(stats)
    ns.ensure_pending("n", "b", 1)
    ns.ensure_pending("n", "b", 2)
    ns.publish("n", "b", 2, np.int64(7))
    ns.retire_through(2)                    # drops the PENDING (1, 1)
    before = stats.to_dict()
    ns.publish("n", "b", 1, np.int64(3))    # straggler of a retired sub
    ns.publish("n", "b", 2, np.int64(7))    # duplicate re-publish
    assert ns.live_versions() == 1          # only the (2, 1) survivor
    assert stats.to_dict() == before        # no double block_up
    got = []
    ns.bind("n", "b", 3, lambda v, p: got.append((int(v), p)))
    assert got == [(7, False)]


def test_bus_trims_prefix_all_readers_consumed():
    from repro.sched.service import _Bus

    bus = _Bus(2)
    for i in range(10):
        bus.post(("x", i))
    assert bus.read_from(0, 0)[0] == ("x", 0)
    assert len(bus.read_from(10, 0)) == 0   # reader 0 caught up
    assert len(bus._items) == 10            # reader 1 still at 0
    assert [i for _, i in bus.read_from(0, 1)] == list(range(10))
    bus.read_from(10, 1)
    assert len(bus._items) == 0             # both past: prefix trimmed
    bus.post(("x", 10))
    assert bus.read_from(10, 0) == [("x", 10)]   # absolute cursors still work


def test_frontdoor_evicts_resolved_records():
    """The service must not retain the stream's history: once the
    watermark passes a submission, its frontdoor record (initial blocks,
    published results) is gone — only the client-held future keeps the
    result alive."""
    blocks = taskbench_blocks(W, D, seed=5)
    with SchedulerService(S, timeout=60.0) as svc:
        c = svc.client("alice")
        for j in range(3):
            g, _ = taskbench_graph("stencil", W, D, S)
            c.submit(g, blocks if j == 0 else {},
                     taskbench_bodies()).result(60.0)
    with svc._lock:
        assert svc._subs == {}
    assert svc.stats()["resolved_through"] == 3


# ------------------------------------------------------------------ fairness

def test_fair_policy_is_deterministic_weighted_round_robin():
    def run(seq):
        p = FairPolicy()
        return [p.priority_for(c, w) for c, w in seq]

    seq = [("a", 2.0), ("b", 1.0)] * 6
    first = run(seq)
    assert first == run(seq)                      # fully deterministic
    pa, pb = first[0::2], first[1::2]
    # priorities decay along each lane (later spawns run later)...
    assert pa == sorted(pa, reverse=True)
    assert pb == sorted(pb, reverse=True)
    # ...and the weight-2 lane's virtual time advances half as fast, so
    # after equal spawn counts its tasks still outrank the weight-1 lane's
    assert all(x >= y for x, y in zip(pa, pb))
    assert pa[-1] > pb[-1]
    # explicit priority is a bias on top of the fair start
    p = FairPolicy()
    assert p.priority_for("c", 1.0, 5.0) == pytest.approx(5.0)


# ---------------------------------------------------------------- acceptance

def test_acceptance_four_clients_eight_mixed_submissions():
    """ISSUE acceptance: >=4 concurrent clients x >=8 submissions each
    (all four Task-Bench patterns + the Cholesky linalg family), every
    result bit-identical to an independent one-shot execution, and
    nothing left live once the stream drains."""
    patterns = ("stencil", "fft", "tree", "random")
    tb_blocks = taskbench_blocks(W, D, seed=7)
    tb_bodies = taskbench_bodies()
    ch_blocks, _ = make_spd_blocks(4, 4, seed=7)
    ch_bodies = cholesky_bodies()

    def written_ref(make_graph, blocks, bodies):
        # run_host gathers every owned block, read-only inputs included
        # (cholesky's ("A", i, 0) column is never written); the future's
        # contract is the submission's *writes*, so restrict the oracle
        out = make_graph().run_host(blocks, bodies, n_threads=2)
        eager = make_graph().build()
        written = {eager.block_of(k) for k in eager.tasks}
        return {blk: v for blk, v in out.items() if blk in written}

    refs = {}
    for p in patterns:
        refs[p] = written_ref(
            lambda p=p: taskbench_graph(p, W, D, S, seed=7)[0],
            tb_blocks, tb_bodies)
    refs["cholesky"] = written_ref(lambda: cholesky_graph(4, 2, 1, 4),
                                   ch_blocks, ch_bodies)

    results = {}
    with SchedulerService(S, timeout=120.0) as svc:
        def run_client(name, weight):
            c = svc.client(name, weight=weight)
            futs = []
            for j in range(8):
                ns = f"{name}/{j}"   # fresh namespace: independent subs
                if j == 7:
                    futs.append(("cholesky", c.submit(
                        cholesky_graph(4, 2, 1, 4), ch_blocks, ch_bodies,
                        namespace=ns)))
                else:
                    p = patterns[j % 4]
                    g, _ = taskbench_graph(p, W, D, S, seed=7)
                    futs.append((p, c.submit(g, tb_blocks, tb_bodies,
                                             namespace=ns)))
            results[name] = [(kind, f.result(120.0)) for kind, f in futs]

        threads = [threading.Thread(target=run_client,
                                    args=(f"t{i}", float(i + 1)), daemon=True)
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120.0)

    assert sorted(results) == [f"t{i}" for i in range(4)]
    for name, rows in results.items():
        assert len(rows) == 8
        for kind, out in rows:
            assert_blocks_equal(out, refs[kind])
    stats = svc.stats()
    assert all(r["tasks_live"] == 0 for r in stats["ranks"])
    assert all(stats["clients"][f"t{i}"]["completed"] == 8 for i in range(4))
    assert stats["live_frac"] < 1.0   # retirement did retire


# ------------------------------------------------------------ survivability

def _extra_chaos() -> float:
    """The sched-soak ``kill+loss+dup`` CI leg layers transport chaos on
    top of the explicit kill plans via the environment."""
    return 0.1 if os.environ.get("REPRO_CHAOS_EXTRA") == "lossdup" else 0.0


def _kill_plan(rank: int, at: int, seed: int = 0) -> FaultPlan:
    p = _extra_chaos()
    return FaultPlan(seed=seed, drop=p, duplicate=p, kill={rank: at},
                     lease=0.4, heartbeat_every=0.02)


def test_kill_midstream_chained_results_bit_identical():
    """The tentpole, directly: a chained-namespace stream (each submission
    reads the previous one's writes) survives a resident rank dying
    mid-stream — the adopter replays the bus from the frozen cursor,
    re-executes the lost tasks, and every future resolves to exactly the
    sequential one-shot oracle."""
    m = 4
    blocks = taskbench_blocks(W, D, seed=11)
    refs = chained_refs("stencil", blocks, m, seed=11)
    with SchedulerService(S, timeout=90.0,
                          faults=_kill_plan(1, 8, seed=11)) as svc:
        c = svc.client("alice")
        futs = []
        for j in range(m):
            g, _ = taskbench_graph("stencil", W, D, S, seed=11)
            futs.append(c.submit(g, blocks if j == 0 else {},
                                 taskbench_bodies()))
        outs = [f.result(90.0) for f in futs]
    for out, ref in zip(outs, refs):
        assert_blocks_equal(out, ref)
    r = svc.recovery_report.to_dict()
    assert r["deaths"] == [1]
    assert r["bus_replayed"] > 0          # adoption replayed the bus
    cap = svc.capacity()
    assert cap["degraded"] and cap["live_ranks"] == S - 1
    assert cap["sched_recover_ms"] is not None


@settings(deadline=None, max_examples=6,
          suppress_health_check=[HealthCheck.too_slow])
@given(at=st.integers(1, 60), seed=st.integers(0, 100))
def test_kill_point_sweep_no_hang_any_message_index(at, seed):
    """Property: kill rank 1 at ANY user-AM send index during a chained
    stream. Whatever the cut point — mid-assimilation, mid-fetch, between
    submissions, or never reached — the stream must drain with every
    result bit-identical (no deadlines are set, so nothing may shed, and
    a hang fails the future timeout loudly)."""
    m = 3
    blocks = taskbench_blocks(W, D, seed=seed)
    refs = chained_refs("stencil", blocks, m, seed=seed)
    with SchedulerService(S, timeout=60.0,
                          faults=_kill_plan(1, at, seed=seed)) as svc:
        c = svc.client("alice")
        futs = []
        for j in range(m):
            g, _ = taskbench_graph("stencil", W, D, S, seed=seed)
            futs.append(c.submit(g, blocks if j == 0 else {},
                                 taskbench_bodies()))
        outs = [f.result(60.0) for f in futs]
    for out, ref in zip(outs, refs):
        assert_blocks_equal(out, ref)


def test_acceptance_kill_four_clients_eight_mixed_submissions():
    """ISSUE acceptance, adversarial edition: the 4 clients x 8 mixed
    submissions scenario with a resident rank killed mid-stream (plus 10%
    loss+dup under REPRO_CHAOS_EXTRA=lossdup). Independent namespaces, no
    deadlines: every single result must be bit-identical to its one-shot
    oracle."""
    patterns = ("stencil", "fft", "tree", "random")
    tb_blocks = taskbench_blocks(W, D, seed=7)
    tb_bodies = taskbench_bodies()
    ch_blocks, _ = make_spd_blocks(4, 4, seed=7)
    ch_bodies = cholesky_bodies()

    def written_ref(make_graph, blocks, bodies):
        out = make_graph().run_host(blocks, bodies, n_threads=2)
        eager = make_graph().build()
        written = {eager.block_of(k) for k in eager.tasks}
        return {blk: v for blk, v in out.items() if blk in written}

    refs = {p: written_ref(
        lambda p=p: taskbench_graph(p, W, D, S, seed=7)[0],
        tb_blocks, tb_bodies) for p in patterns}
    refs["cholesky"] = written_ref(lambda: cholesky_graph(4, 2, 1, 4),
                                   ch_blocks, ch_bodies)

    results = {}
    with SchedulerService(S, timeout=180.0,
                          faults=_kill_plan(1, 40, seed=7)) as svc:
        def run_client(name, weight):
            c = svc.client(name, weight=weight)
            futs = []
            for j in range(8):
                ns = f"{name}/{j}"
                if j == 7:
                    futs.append(("cholesky", c.submit(
                        cholesky_graph(4, 2, 1, 4), ch_blocks, ch_bodies,
                        namespace=ns)))
                else:
                    p = patterns[j % 4]
                    g, _ = taskbench_graph(p, W, D, S, seed=7)
                    futs.append((p, c.submit(g, tb_blocks, tb_bodies,
                                             namespace=ns)))
            results[name] = [(kind, f.result(180.0)) for kind, f in futs]

        threads = [threading.Thread(target=run_client,
                                    args=(f"t{i}", float(i + 1)),
                                    daemon=True)
                   for i in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(180.0)

    assert sorted(results) == [f"t{i}" for i in range(4)]
    for name, rows in results.items():
        assert len(rows) == 8
        for kind, out in rows:
            assert_blocks_equal(out, refs[kind])
    assert svc.recovery_report.to_dict()["deaths"] == [1]


def test_deadline_sheds_cleanly_and_stream_continues():
    """An over-deadline submission is shed through the FAIL path: the
    future raises DeadlineExceeded (never hangs), its namespace versions
    are poisoned (dependents fail loudly), and an unrelated later
    submission on the same client still runs."""
    gate = threading.Event()
    bodies = {"t": lambda x: (gate.wait(30.0), x + 1.0)[1]}
    blocks = {("g", 0): np.float64(1.0)}
    with SchedulerService(1, timeout=60.0) as svc:
        c = svc.client("slow")
        f = c.submit(_single_type_graph("stuck", 1), blocks, bodies,
                     namespace="stuck", deadline=0.25)
        with pytest.raises(DeadlineExceeded):
            f.result(30.0)
        # the shed poisoned what it never produced: a dependent reader in
        # the same namespace fails loudly instead of waiting forever
        fdep = c.submit(_single_type_graph("dep", 1), {},
                        {"t": lambda x: x + 1.0}, namespace="stuck")
        with pytest.raises(SubmissionError, match="upstream"):
            fdep.result(30.0)
        gate.set()   # release the stuck worker so close() can drain
        # an unrelated namespace is untouched by the shed
        ok = c.submit(_single_type_graph("ok", 1), blocks,
                      {"t": lambda x: x + 1.0}, namespace="fresh")
        assert ok.result(30.0)[("g", 0)] == 2.0
    assert c.stats["failed"] == 2 and c.stats["completed"] == 1


def test_retry_resubmits_after_deadline_shed():
    """``retries=`` turns a shed into a backoff + resubmission: a body
    that is slow exactly once gets shed on the first attempt and completes
    on the second, under a fresh ephemeral namespace."""
    calls = []

    def fn(x):
        if not calls:
            calls.append(1)
            time.sleep(1.0)
        return x + 1

    with SchedulerService(1, timeout=60.0) as svc:
        c = svc.client("retrier")
        fut = c.map(fn, np.arange(3, dtype=np.int64), deadline=0.3,
                    retries=2)
        assert [int(v) for v in fut.result(30.0)] == [1, 2, 3]
        assert fut.attempts >= 2


def test_degraded_admission_cap_tightens_to_survivors():
    """Graceful degradation: with half the ranks dead, a client's
    effective in-flight cap halves (floor 1) — backpressure matches the
    surviving capacity instead of queueing at full speed."""
    svc = SchedulerService(4)
    assert svc._effective_cap(None) is None
    assert svc._effective_cap(8) == 8
    svc._dead_ranks = {1, 3}
    assert svc._effective_cap(8) == 4
    assert svc._effective_cap(1) == 1      # floor: progress stays possible
    svc._dead_ranks = {1, 2, 3}
    assert svc._effective_cap(8) == 2


def test_future_timeout_dumps_protocol_snapshot():
    """A future timeout names the stuck side: per-rank serve-loop state,
    bus cursors, and the unresolved map ride along with the error."""
    gate = threading.Event()
    bodies = {"t": lambda x: (gate.wait(30.0), x + 1.0)[1]}
    blocks = {("g", 0): np.float64(0)}
    with SchedulerService(1, timeout=60.0) as svc:
        c = svc.client("alice")
        f = c.submit(_single_type_graph("a", 1), blocks, bodies)
        with pytest.raises(TimeoutError) as ei:
            f.result(0.3)
        msg = str(ei.value)
        assert "scheduler snapshot" in msg
        assert "bus:" in msg and "unresolved" in msg and "rank 0:" in msg
        gate.set()
        f.result(30.0)


def test_bus_freeze_pins_trim_until_adoption_votes():
    """The bus-trim invariant behind adoption replay: a frozen (dead)
    reader's cursor pins the prefix — fast survivors cannot trim past it —
    until every adopter has voted ``retire_reader``; then the prefix goes,
    and a replay below the trimmed base fails loudly instead of silently
    skipping commands."""
    from repro.sched.service import _Bus

    bus = _Bus(3)
    for i in range(6):
        bus.post(("x", i))
    bus.read_from(2, 1)               # the doomed reader got through 2
    bus.freeze(1)
    assert bus.read_from(5, 1) == []  # a zombie read neither advances...
    assert bus.frozen_cursor(1) == 2  # ...nor moves the frozen cursor
    bus.read_from(6, 0)
    bus.read_from(6, 2)               # both survivors fully caught up
    assert bus._base == 2             # trim stopped AT the frozen cursor
    assert [i for _, i in bus.read_range(2, 6)] == [2, 3, 4, 5]
    # two adopters split the dead rank's shards: the first vote must not
    # unpin the prefix the second still needs
    bus.retire_reader(1, votes_needed=2)
    assert bus._base == 2
    assert [i for _, i in bus.read_range(2, 6)] == [2, 3, 4, 5]
    bus.retire_reader(1, votes_needed=2)
    assert bus._base == 6             # last vote: prefix released
    with pytest.raises(RuntimeError, match="trimmed prefix"):
        bus.read_range(2, 6)
    # the floor pins the trim the same way (oldest unresolved SUBMIT)
    bus2 = _Bus(1)
    bus2.post(("a",), pin=True)
    bus2.post(("b",))
    bus2.read_from(2, 0)
    assert bus2._base == 0            # floor held the prefix
    bus2.set_floor(None)
    bus2.read_from(2, 0)
    assert bus2._base == 2
