"""repro.dist layer tests: mesh context round-trips, spec sanitization at
annotation sites, and param_specs acceptance by jax.jit in_shardings.

Runs on however many host devices the main pytest process has (usually 1) —
the mesh is sized to the device count, so these are layout-contract tests,
not multi-device execution tests (those live in multi_device_cases.py).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.dist import ctx
from repro.dist.sharding import (batch_axis, named_shardings, param_specs,
                                 sanitize_specs)
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tfm


def _host_mesh():
    n = len(jax.devices())
    model = 2 if n >= 2 else 1
    data = 2 if n >= 4 else 1
    return make_host_mesh(model=model, data=data)


def test_annotate_is_identity_without_mesh():
    x = jnp.ones((4, 8, 16))
    y = ctx.annotate(x, P("data", None, None))
    assert y is x
    assert ctx.get_mesh() is None


def test_use_mesh_round_trips_act_spec():
    mesh = _host_mesh()
    ctx.set_batch_axes(batch_axis(mesh, 8))
    ctx.set_seq_shard(True)
    try:
        x = jnp.ones((8, 16, 32))
        with ctx.use_mesh(mesh):
            assert ctx.get_mesh() is mesh
            assert ctx.data_rows() == mesh.shape["data"]
            y = jax.jit(lambda a: ctx.annotate(a, ctx.act_spec()))(x)
            # the constraint materializes as a NamedSharding on this mesh
            # whose spec is the sanitized act_spec
            from repro.dist.sharding import sanitize_spec
            want = NamedSharding(mesh, sanitize_spec(
                ctx.act_spec(), x.shape, dict(mesh.shape)))
            assert y.sharding.is_equivalent_to(want, x.ndim)
            np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
        assert ctx.get_mesh() is None
    finally:
        ctx.set_batch_axes(None)
        ctx.set_seq_shard(False)


def test_annotate_drops_axes_shape_cannot_divide():
    mesh = _host_mesh()
    with ctx.use_mesh(mesh):
        # 5 rows cannot shard over any axis of size > 1; 5 % 1 == 0 keeps it
        x = jnp.ones((5, 7))
        y = jax.jit(lambda a: ctx.annotate(a, P("model", "data")))(x)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v3-671b", "mamba2-1.3b"])
def test_param_specs_accepted_by_jit_in_shardings(arch):
    """param_specs -> sanitize -> NamedSharding must be a valid in_shardings
    for jax.jit (lowered abstractly: full configs, no allocation)."""
    cfg = get_config(arch)
    mesh = _host_mesh()
    abstract = tfm.abstract_params(cfg)
    specs = sanitize_specs(
        param_specs(cfg, model_axis=mesh.shape["model"]), abstract, mesh)
    shardings = named_shardings(mesh, specs)
    fn = jax.jit(lambda p: jax.tree.map(lambda a: a.sum(), p),
                 in_shardings=(shardings,))
    lowered = fn.lower(abstract)  # raises if any spec/sharding is rejected
    assert lowered is not None


@pytest.mark.parametrize("arch", ["qwen3-14b", "zamba2-1.2b",
                                  "seamless-m4t-large-v2"])
def test_param_specs_compile_reduced(arch):
    """End-to-end on-device check at reduced scale: sharded init executes."""
    cfg = reduced(get_config(arch))
    mesh = _host_mesh()
    abstract = tfm.abstract_params(cfg)
    specs = sanitize_specs(
        param_specs(cfg, model_axis=mesh.shape["model"]), abstract, mesh)
    shardings = named_shardings(mesh, specs)
    with ctx.use_mesh(mesh):
        params = jax.jit(lambda k: tfm.init_params(cfg, k),
                         out_shardings=shardings)(jax.random.key(0))
    for leaf in jax.tree.leaves(params):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
