"""Fault tolerance: lossy transport, reliable delivery, rank-death recovery.

The load-bearing property (tentpole acceptance): under ANY seeded
drop/duplicate/reorder schedule, the completion protocol must never shut
the world down while a user AM is undelivered (no early SHUTDOWN — the
quiescence proof of §II-B3 must survive an unreliable transport), and the
run must terminate within the retry budget (no hang) — 200 examples.

Rank death goes further: a killed rank's shard is adopted by a survivor,
re-derived lazily (only the moved shard), and re-executed from upstream
block state; the result must be bit-identical to the fault-free run.
"""

import random
import threading
import time

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import FaultPlan, run_ranks


def _delay_fn(seed: float, max_delay: float):
    rng = random.Random(seed)
    lock = threading.Lock()

    def fn(src, dst, kind):
        with lock:
            return rng.uniform(0.0, max_delay)

    return fn


# ------------------------ property: no early SHUTDOWN, no hang (200 ex)

@settings(deadline=None, max_examples=200,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_ranks=st.integers(2, 4),
    n_msgs=st.integers(1, 12),
    seed=st.integers(0, 2**31),
    drop=st.sampled_from([0.0, 0.05, 0.15, 0.3]),
    dup=st.sampled_from([0.0, 0.05, 0.15, 0.3]),
    max_delay=st.sampled_from([0.0, 0.001]),
)
def test_lossy_schedule_never_early_shutdown(n_ranks, n_msgs, seed, drop,
                                             dup, max_delay):
    """Rank 0 scatters AMs under seeded loss + duplication + reorder; at
    shutdown every message must have been processed exactly once. A lost
    message means SHUTDOWN fired while delivery was still owed (early
    termination); a doubled one means receiver dedup failed; a hang means
    the retry/ack loop does not terminate (caught by the timeout)."""
    plan = FaultPlan(seed=seed, drop=drop, duplicate=dup)

    def main(ctx):
        received = []
        am = ctx.comm.make_active_msg(lambda i: received.append(i))
        if ctx.rank == 0:
            for i in range(n_msgs):
                am.send(1 + (i % (ctx.n_ranks - 1)), i)
        ctx.tp.join()
        return received

    res, report = run_ranks(n_ranks, main, faults=plan, timeout=60.0,
                            delay_fn=_delay_fn(seed, max_delay))
    got = sorted(x for r in res for x in r)
    assert got == list(range(n_msgs)), (
        f"drop/dup schedule broke exactly-once delivery: {got} "
        f"(report: {report.to_dict()})")


# ----------------------------------------------- exactly-once accounting

def test_counters_count_each_user_am_once_under_faults():
    """q_r/p_r stay exact under heavy loss + duplication: retries and dup
    deliveries are transport-level and must not leak into the §II-B3
    counters (a leak would desynchronize the quiescence proof)."""
    n_msgs = 40
    plan = FaultPlan(seed=7, drop=0.3, duplicate=0.3)

    def main(ctx):
        am = ctx.comm.make_active_msg(lambda i: None)
        if ctx.rank == 0:
            for i in range(n_msgs):
                am.send(1, i)
        ctx.tp.join()
        return ctx.comm.effective_counts()

    res, report = run_ranks(2, main, faults=plan, timeout=60.0)
    assert res[0] == (n_msgs, 0)
    assert res[1] == (0, n_msgs)
    assert report.retries > 0  # the plan actually dropped
    assert report.injected_drops > 0


def test_duplicates_suppressed_by_seq_dedup():
    plan = FaultPlan(seed=3, drop=0.0, duplicate=0.5)

    def main(ctx):
        received = []
        am = ctx.comm.make_active_msg(lambda i: received.append(i))
        if ctx.rank == 0:
            for i in range(30):
                am.send(1, i)
        ctx.tp.join()
        return received

    res, report = run_ranks(2, main, faults=plan, timeout=60.0)
    assert sorted(res[1]) == list(range(30))
    assert report.injected_dups > 0
    assert report.dup_suppressed > 0


# ------------------------------------------------------------ rank death

def test_rank_death_declared_and_survivors_finish():
    """Kill rank 2 after its 3rd user send: the lease detector must
    declare the death, survivors must drain and shut down, and the killed
    rank's result slot is None (it never returned)."""
    plan = FaultPlan(seed=11, drop=0.05, duplicate=0.05, kill={2: 3})

    def main(ctx):
        received = []
        am = ctx.comm.make_active_msg(lambda i: received.append(i))
        if ctx.rank != 0:
            for i in range(10):
                am.send(0, ctx.rank * 100 + i)
        ctx.tp.join()
        return received

    res, report = run_ranks(3, main, faults=plan, timeout=60.0)
    assert res[2] is None  # killed mid-run
    assert report.deaths == [2]
    # rank 1 survives: its stream is delivered exactly once. Rank 2 died
    # at its 3rd send (dropped mid-send; queued-but-undelivered wires are
    # purged like a crashed process's socket buffer), so at most its first
    # two sends arrive — and never as duplicates.
    got = sorted(res[0])
    assert [x for x in got if x < 200] == [100 + i for i in range(10)]
    from_dead = [x for x in got if x >= 200]
    assert set(from_dead) <= {200, 201}
    assert len(from_dead) == len(set(from_dead))


def test_fault_plan_validates():
    with pytest.raises(ValueError):
        FaultPlan(drop=1.5)
    with pytest.raises(ValueError):
        FaultPlan(kill={0: 2})  # rank 0 arbitrates; it cannot be killed


# --------------------------------------- timeout forensics (runtime.py)

def test_timeout_reports_stuck_ranks_with_protocol_state():
    """A rank that never enters the completion protocol deadlocks the
    world; the timeout must name the stuck ranks and include their
    protocol snapshots instead of a bare 'timed out'."""

    def main(ctx):
        if ctx.rank == 1:
            # block until the driver poisons the world (simulated wedge)
            while not ctx.comm.world.poison.is_set():
                time.sleep(0.002)
        ctx.tp.join()

    with pytest.raises(TimeoutError) as ei:
        run_ranks(2, main, timeout=1.5)
    msg = str(ei.value)
    assert "deadlock" in msg
    assert "rank 1" in msg
    assert "queued" in msg  # communicator snapshot made it into the report


def test_rank_exception_propagates_with_traceback():
    def main(ctx):
        if ctx.rank == 1:
            raise ValueError("boom at rank 1")
        ctx.tp.join()

    with pytest.raises(RuntimeError) as ei:
        run_ranks(2, main, timeout=30.0)
    msg = str(ei.value)
    assert "rank 1 failed" in msg
    assert "ValueError: boom at rank 1" in msg
    assert "in main" in msg  # the original traceback, not just the repr
    assert isinstance(ei.value.__cause__, ValueError)


# ------------------------------- acceptance: Cholesky kill + recovery

def test_cholesky_bit_identical_under_loss_dup_and_kill():
    """The ISSUE acceptance scenario: 10% loss + 10% duplication + one
    mid-run rank kill on the 8-rank Cholesky host run. The result must be
    bit-identical to the fault-free run, and re-derivation confined to the
    moved shard (rederived_frac < 0.5)."""
    from repro.linalg.cholesky import cholesky_bodies, cholesky_graph, \
        make_spd_blocks

    nb, b, pr, pc = 6, 4, 4, 2
    g = cholesky_graph(nb, pr, pc, b)
    blocks, _ = make_spd_blocks(nb, b, seed=0)
    ref = g.run_host(dict(blocks), cholesky_bodies(), n_threads=2)

    plan = FaultPlan(seed=5, drop=0.10, duplicate=0.10, kill={3: 2})
    out, report = g.run_host(dict(blocks), cholesky_bodies(), n_threads=2,
                             faults=plan, timeout=120.0)

    assert set(out) == set(ref)
    for k in ref:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(ref[k]))
    assert report.deaths == [3]
    assert report.rederived_shards == [3]
    assert report.rederived_frac is not None and report.rederived_frac < 0.5
    assert report.reexecuted_tasks > 0
    assert report.recovery_seconds is not None
