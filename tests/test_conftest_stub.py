"""Guard for the conftest hypothesis stand-in (slim CI images).

The stub's strategy surface must cover every ``st.<name>`` the test suite
actually uses — checked statically so the guard holds whether or not the
real hypothesis is installed — and a strategy the stub does NOT provide
must fail loudly at the use site, never collect as a silent no-op.
"""

import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
# look-behind keeps `pytest.raises(...)` etc. from matching: only a bare
# `st.` counts, not `<anything>st.` or `x.st.`
ST_USE = re.compile(r"(?<![\w.])st\.(\w+)")


def _stubbed_names():
    src = (REPO / "conftest.py").read_text()
    return set(re.findall(r"st_mod\.(\w+) = ", src)) - {"__getattr__"}


def _used_names():
    # this file deliberately mentions an unstubbed strategy in a code
    # literal below — exclude it from the audit
    return {name
            for path in (REPO / "tests").glob("test_*.py")
            if path.name != Path(__file__).name
            for name in ST_USE.findall(path.read_text())}


def test_stub_surface_covers_suite_usage():
    stubbed = _stubbed_names()
    assert stubbed, "could not parse the stub surface out of conftest.py"
    used = _used_names()
    assert used, "could not find any st.<strategy> usage to audit"
    assert used <= stubbed, (
        f"tests use unstubbed hypothesis strategies {sorted(used - stubbed)}; "
        "extend the stand-in in conftest.py")


def test_stub_has_no_dead_surface():
    """Every stubbed strategy is actually exercised by some test — dead
    stub code is untested code that rots."""
    assert _stubbed_names() <= _used_names()


def test_stub_fails_loudly_on_unstubbed_strategy():
    """With hypothesis truly absent, asking the stub for a strategy it
    doesn't provide must raise at the attribute lookup with a pointer to
    conftest.py (run in a subprocess so this works regardless of whether
    the real package is installed here)."""
    code = (
        "import sys; sys.modules['hypothesis'] = None\n"
        "exec(open('conftest.py').read())\n"
        "import hypothesis\n"
        "assert getattr(hypothesis, '_is_repro_stub', False)\n"
        "from hypothesis import strategies as st\n"
        "assert st.integers(min_value=0, max_value=3) is not None\n"
        "try:\n"
        "    st.floats\n"
        "except AttributeError as e:\n"
        "    assert 'not stubbed' in str(e), e\n"
        "    print('LOUD OK')\n"
        "else:\n"
        "    raise SystemExit('unstubbed strategy did not raise')\n"
    )
    proc = subprocess.run([sys.executable, "-c", code], cwd=REPO,
                          capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stderr
    assert "LOUD OK" in proc.stdout
