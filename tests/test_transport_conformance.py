"""Cross-backend transport conformance: every registered comm backend
must present the same contract to the runtime.

The backend registry (``repro.core.comm``) is only worth having if the
backends are interchangeable — same delivery semantics (per-pair FIFO,
exactly-once under loss and duplication), same failure surfacing
(``RankKilled`` -> ``None`` result + DEATH in the report, rank errors ->
``RuntimeError`` with the remote traceback), same channel lifecycle
(clean listener shutdown refuses new connects loudly). This suite runs
one body of assertions against every backend, in three flavors per the
world-level legs: plain, with a seeded loss+dup FaultPlan, and (for
``multiproc``) across real OS process boundaries.

The bit-identity tests are the PR's acceptance: the Task-Bench
dependence-pattern sweep and the blocked Cholesky must produce exactly
the same blocks whether the ranks are threads (``inproc``) or forked
processes wired over loopback TCP (``multiproc``) — same bodies on both
sides, so any divergence is a transport bug, not float noise.
"""

import os
import threading
import time

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import FaultPlan, run_ranks
from repro.core.comm import (CommClosedError, Wire, backend_names,
                             get_backend)
from repro.ptg import Graph, IndexSpace
from repro.linalg.cholesky import (assemble_lower, cholesky_bodies_numpy,
                                   cholesky_graph, make_spd_blocks)
from benchmarks.taskbench_scaling import (taskbench_blocks, taskbench_bodies,
                                          taskbench_graph)

BACKENDS = sorted(backend_names())
PATTERNS = ("stencil", "fft", "tree", "random")

# world-level legs: every backend plain AND under a seeded loss+dup plan.
# A plan is always passed explicitly (zero rates on the plain legs) so the
# REPRO_CHAOS conftest wrapper never stacks a second plan on top and the
# return shape is uniformly (results, report).
LEGS = [pytest.param(b, p, id=b if not p else f"{b}-lossdup")
        for b in BACKENDS for p in (0.0, 0.15)]


def _plan(p: float, seed: int = 5, **kw) -> FaultPlan:
    return FaultPlan(seed=seed, drop=p, duplicate=p, **kw)


# ------------------------------------------------------------- the registry

def test_registry_lists_both_backends():
    assert {"inproc", "multiproc"} <= set(backend_names())


def test_registry_unknown_backend_fails_loudly():
    with pytest.raises(KeyError, match="carrier-pigeon"):
        get_backend("carrier-pigeon")
    # the error names what IS registered, so the fix is in the message
    with pytest.raises(KeyError, match="inproc"):
        get_backend("carrier-pigeon")


def test_registry_env_var_is_the_default(monkeypatch):
    monkeypatch.delenv("REPRO_TRANSPORT", raising=False)
    assert get_backend(None).name == "inproc"
    monkeypatch.setenv("REPRO_TRANSPORT", "multiproc")
    assert get_backend(None).name == "multiproc"
    # an explicit argument always beats the environment
    assert get_backend("inproc").name == "inproc"


# ------------------------------------------- channel-level contract (Comm)

@pytest.mark.parametrize("backend_name", BACKENDS)
def test_channel_echo_roundtrip_and_clean_listener_shutdown(backend_name):
    """listener/connector/Comm alone, no world on top: payloads round-trip
    unchanged (including Wire dataclasses carrying ndarrays), and a
    stopped listener refuses new connects with CommClosedError instead of
    hanging."""
    backend = get_backend(backend_name)
    served = threading.Event()

    def echo(ch):
        served.set()
        try:
            while True:
                ch.write(ch.read(timeout=5.0))
        except (CommClosedError, TimeoutError):
            ch.close()

    lis = backend.listener(echo)
    lis.start()
    try:
        ch = backend.connector().connect(lis.address)
        for i in range(5):
            ch.write(("ping", i))
            assert ch.read(timeout=5.0) == ("ping", i)
        wire = Wire(kind="am", src=3, am_id=1, blob=b"\x00payload",
                    raw=np.arange(6, dtype=np.float32), seq=9)
        ch.write(wire)
        back = ch.read(timeout=5.0)
        assert (back.kind, back.src, back.am_id, back.blob, back.seq) == \
            ("am", 3, 1, b"\x00payload", 9)
        assert np.array_equal(back.raw, wire.raw)
        ch.close()
        assert ch.closed
    finally:
        lis.stop()
    # a stopped listener services nothing: connect either refuses loudly
    # (inproc; TCP usually too) or — loopback TCP can self-connect to a
    # dead ephemeral port — yields a channel no handler will ever serve
    served.clear()
    try:
        orphan = backend.connector().connect(lis.address, timeout=0.5)
    except CommClosedError:
        return
    time.sleep(0.2)
    assert not served.is_set()
    orphan.close()


# ------------------------------------------- world-level delivery semantics

@pytest.mark.parametrize("transport,p", LEGS)
def test_per_pair_fifo_exactly_once(transport, p):
    """Every rank streams sequence numbers to every other rank; each
    receiver must observe each source's stream complete and duplicate-
    free, and — on a fault-free transport — IN ORDER, the per-(src,dst)
    FIFO the §II-B2 AM model assumes. Under seeded loss the guarantee
    deliberately weakens to exactly-once: a dropped message is
    retransmitted after its successors were already processed (dedup is
    a cumulative seen-window, not a hold-back queue), which is exactly
    the reordering the completion counters must tolerate."""
    n, m = 3, 15

    def main(ctx):
        got = {}
        am = ctx.comm.make_active_msg(
            lambda src, i: got.setdefault(src, []).append(i))
        for dst in range(ctx.n_ranks):
            if dst != ctx.rank:
                for i in range(m):
                    am.send(dst, ctx.rank, i)
        ctx.tp.join()
        return got

    res, report = run_ranks(n, main, faults=_plan(p), timeout=90.0,
                            transport=transport)
    for r, got in enumerate(res):
        assert sorted(got) == [s for s in range(n) if s != r]
        for src, seqs in got.items():
            if p:
                assert sorted(seqs) == list(range(m)), \
                    f"rank {r} lost/doubled src {src}'s stream: {seqs}"
            else:
                assert seqs == list(range(m)), \
                    f"rank {r} saw src {src} out of order: {seqs}"
    if p:
        assert report.injected_drops + report.injected_dups > 0


@pytest.mark.parametrize("backend_name", BACKENDS)
def test_duplicates_suppressed_exactly_once(backend_name):
    plan = FaultPlan(seed=3, drop=0.0, duplicate=0.5)

    def main(ctx):
        received = []
        am = ctx.comm.make_active_msg(lambda i: received.append(i))
        if ctx.rank == 0:
            for i in range(30):
                am.send(1, i)
        ctx.tp.join()
        return received

    res, report = run_ranks(2, main, faults=plan, timeout=90.0,
                            transport=backend_name)
    assert res[1] == list(range(30))
    assert report.injected_dups > 0
    assert report.dup_suppressed > 0


@pytest.mark.parametrize("backend_name", BACKENDS)
def test_drops_recovered_by_retransmit(backend_name):
    plan = FaultPlan(seed=7, drop=0.3, duplicate=0.0)

    def main(ctx):
        received = []
        am = ctx.comm.make_active_msg(lambda i: received.append(i))
        if ctx.rank == 0:
            for i in range(30):
                am.send(1, i)
        ctx.tp.join()
        return received

    res, report = run_ranks(2, main, faults=plan, timeout=90.0,
                            transport=backend_name)
    # retransmits reorder but never lose or double (exactly-once)
    assert sorted(res[1]) == list(range(30))
    assert report.injected_drops > 0
    assert report.retries > 0


# --------------------------------------------------------- failure surfacing

@pytest.mark.parametrize("backend_name", BACKENDS)
def test_rank_kill_surfaces_death_and_survivors_drain(backend_name):
    """kill={1: 3}: the killed rank's result slot is None, the report
    carries the DEATH declaration, and the survivors' own streams are
    still delivered exactly once (no poisoning, no hang)."""
    plan = FaultPlan(seed=11, drop=0.05, duplicate=0.05, kill={1: 3})

    def main(ctx):
        received = []
        am = ctx.comm.make_active_msg(lambda i: received.append(i))
        if ctx.rank != 0:
            for i in range(10):
                am.send(0, ctx.rank * 100 + i)
        ctx.tp.join()
        return received

    res, report = run_ranks(3, main, faults=plan, timeout=90.0,
                            transport=backend_name)
    assert res[1] is None
    assert report.deaths == [1]
    got = sorted(res[0])
    # rank 2 survives: delivered exactly once; rank 1 died at its 3rd
    # send, so at most its first two arrive — never duplicated
    assert [x for x in got if x >= 200] == [200 + i for i in range(10)]
    from_dead = [x for x in got if x < 200]
    assert set(from_dead) <= {100, 101}
    assert len(from_dead) == len(set(from_dead))


@pytest.mark.parametrize("backend_name", BACKENDS)
def test_rank_error_propagates_with_remote_traceback(backend_name):
    def main(ctx):
        if ctx.rank == 1:
            raise ValueError("boom-evidence-42")
        ctx.tp.join()

    with pytest.raises(RuntimeError, match="rank 1 failed") as ei:
        run_ranks(2, main, faults=_plan(0.0), timeout=60.0,
                  transport=backend_name)
    # the failing rank's own traceback crosses the process boundary
    assert "boom-evidence-42" in str(ei.value)
    assert "ValueError" in str(ei.value)


def test_multiproc_ranks_are_real_processes():
    """The backend's whole point: ranks are OS processes, not threads."""
    def main(ctx):
        ctx.tp.join()
        return os.getpid()

    pids, _ = run_ranks(3, main, faults=_plan(0.0), timeout=60.0,
                        transport="multiproc")
    assert len(set(pids)) == 3
    assert os.getpid() not in pids


# ------------------------------------------------- cross-backend bit-identity

@pytest.mark.parametrize("pattern", PATTERNS)
def test_taskbench_sweep_bit_identical_across_backends(pattern):
    blocks = taskbench_blocks(4, 3, seed=7)
    outs = {}
    for t in BACKENDS:
        g, _ = taskbench_graph(pattern, 4, 3, 2, seed=7)
        outs[t] = g.run_host(blocks, taskbench_bodies(), n_threads=2,
                             transport=t)
    ref = outs["inproc"]
    for t in BACKENDS:
        assert outs[t].keys() == ref.keys()
        for blk in ref:
            assert np.array_equal(np.asarray(outs[t][blk]),
                                  np.asarray(ref[blk])), (t, pattern, blk)


def test_cholesky_bit_identical_across_backends():
    """Same numpy bodies on both sides (the jax bodies are fork-hostile:
    a forked child must not call into the parent's XLA runtime), so the
    factor blocks must match bit for bit — and actually factorize A."""
    nb, b = 4, 4
    blocks, a = make_spd_blocks(nb, b, seed=7)
    outs = {t: cholesky_graph(nb, 2, 1, b).run_host(
                blocks, cholesky_bodies_numpy(), n_threads=2, transport=t)
            for t in BACKENDS}
    ref = outs["inproc"]
    for t in BACKENDS:
        assert outs[t].keys() == ref.keys()
        for blk in ref:
            assert np.array_equal(np.asarray(outs[t][blk]),
                                  np.asarray(ref[blk])), (t, blk)
    low = assemble_lower(ref, nb, b)
    np.testing.assert_allclose(low @ low.T, a, atol=1e-3)


# ------------------------------------- the resident scheduler, cross-process

def _mixed_stream_acceptance(n_clients: int, n_subs: int) -> None:
    """N clients x M mixed submissions (Task-Bench patterns + Cholesky)
    into a resident multiproc service; every result must be bit-identical
    to its one-shot inproc oracle (same bodies both sides)."""
    from repro.launch.scheduler import run_stream
    from repro.sched import SchedulerService

    width, depth, nb = 4, 3, 4
    with SchedulerService(2, n_threads=2, timeout=240.0,
                          transport="multiproc") as svc:
        results = run_stream(svc, n_clients, n_subs, width=width,
                             depth=depth, nb=nb)

    tb_blocks = taskbench_blocks(width, depth, seed=7)
    ch_blocks, _ = make_spd_blocks(nb, 4, seed=7)
    refs = {}
    for kind in {k for rows in results.values() for k, _ in rows}:
        if kind == "cholesky":
            refs[kind] = cholesky_graph(nb, 2, 1, 4).run_host(
                ch_blocks, cholesky_bodies_numpy(), n_threads=2)
        else:
            g, _ = taskbench_graph(kind, width, depth, 2, seed=7)
            refs[kind] = g.run_host(tb_blocks, taskbench_bodies(),
                                    n_threads=2)
    assert sorted(results) == [f"client{i}" for i in range(n_clients)]
    for name, rows in results.items():
        assert len(rows) == n_subs
        for kind, out in rows:
            assert out is not None
            for blk, v in out.items():
                assert np.array_equal(np.asarray(v),
                                      np.asarray(refs[kind][blk])), \
                    (name, kind, blk)


def test_multiproc_scheduler_mixed_stream_small():
    _mixed_stream_acceptance(2, 4)


@pytest.mark.skipif(not os.environ.get("REPRO_TRANSPORT_SOAK"),
                    reason="full 4x8 acceptance runs on the CI "
                           "transport-soak leg (REPRO_TRANSPORT_SOAK=1)")
def test_multiproc_scheduler_acceptance_4x8():
    """The ISSUE's acceptance scenario verbatim, cross-process: 4 clients
    x 8 mixed submissions on resident multiproc ranks."""
    _mixed_stream_acceptance(4, 8)


def _single_task_graph(name: str) -> Graph:
    g = Graph(name, n_shards=1, owner=lambda blk: 0)
    g.task_type("t", writes=lambda i: ("g", i), reads=lambda i: [("g", i)],
                space=IndexSpace(lambda: range(1), lambda s: [0], size=1))
    return g


def test_future_timeout_snapshot_crosses_the_process_boundary():
    """Satellite: SubmissionFuture.result's forensic snapshot used to
    read the rank runtimes through shared memory — impossible when the
    ranks are processes. It now rides a SNAPSHOT control message, so a
    timed-out future still names the stuck side cross-process."""
    bodies = {"t": lambda x: (time.sleep(1.2), x + 1.0)[1]}
    blocks = {("g", 0): np.float64(0)}
    from repro.sched import SchedulerService

    with SchedulerService(1, timeout=60.0, transport="multiproc") as svc:
        c = svc.client("alice")
        f = c.submit(_single_task_graph("slow"), blocks, bodies)
        with pytest.raises(TimeoutError) as ei:
            f.result(0.3)
        msg = str(ei.value)
        assert "scheduler snapshot" in msg
        assert "bus:" in msg and "unresolved" in msg
        assert "rank 0:" in msg       # fetched from the child process
        out = f.result(30.0)          # and the submission still completes
    assert float(out[("g", 0)]) == 1.0


# -------------------------- kill-point sweep, cross-process (hypothesis)

@settings(deadline=None, max_examples=3,
          suppress_health_check=[HealthCheck.too_slow])
@given(at=st.integers(1, 40))
def test_multiproc_kill_point_sweep_stream_bit_identical(at):
    """Property (extends the PR-9 sweep across the process boundary):
    kill resident rank 1 at ANY user-AM send index during a chained
    3-submission stream over ``multiproc`` — whatever the cut point, the
    stream drains bit-identical to the sequential one-shot oracle."""
    from repro.sched import SchedulerService

    m, W, D, S = 3, 4, 3, 2
    bodies = taskbench_bodies()
    blocks = taskbench_blocks(W, D, seed=at)
    refs, store = [], dict(blocks)
    for _ in range(m):
        g, _ = taskbench_graph("stencil", W, D, S, seed=at)
        out = g.run_host(store, bodies, n_threads=2)
        refs.append(out)
        store.update(out)

    plan = FaultPlan(seed=at, kill={1: at}, lease=0.4, heartbeat_every=0.02)
    with SchedulerService(S, timeout=90.0, faults=plan,
                          transport="multiproc") as svc:
        c = svc.client("alice")
        futs = []
        for j in range(m):
            g, _ = taskbench_graph("stencil", W, D, S, seed=at)
            futs.append(c.submit(g, blocks if j == 0 else {}, bodies))
        outs = [f.result(90.0) for f in futs]
    for out, ref in zip(outs, refs):
        assert set(out) == set(ref)
        for blk in ref:
            assert np.array_equal(np.asarray(out[blk]),
                                  np.asarray(ref[blk])), (at, blk)
