"""Unit tests for the CI bench-regression guard (wire-efficiency trend +
the lower-is-better compile-size metrics of the segmented-scan rows)."""

import json
import subprocess
import sys

import pytest

from benchmarks.check_regression import (find_regressions, metric_rows,
                                         parse_metric)


def _rows(**eff):
    return [{"name": n, "us_per_call": 1.0, "wire_efficiency": v}
            for n, v in eff.items()]


def test_metric_rows_skips_non_numeric():
    rows = _rows(a=0.5) + [{"name": "b", "us_per_call": 2.0},
                           {"name": "c", "wire_efficiency": None},
                           {"name": "d", "wire_efficiency": True}]
    assert metric_rows(rows, "wire_efficiency") == {"a": 0.5}


def test_within_tolerance_passes():
    base = _rows(x=1.0, y=0.5)
    new = _rows(x=0.85, y=0.41)          # -15%, -18%: inside 20%
    checked, reg = find_regressions(new, base)
    assert checked == 2 and reg == []


def test_regression_detected_and_named():
    base = _rows(x=1.0, y=0.5)
    new = _rows(x=0.79, y=0.5)           # x drops 21%
    checked, reg = find_regressions(new, base)
    assert checked == 2
    assert reg == [("x", 1.0, 0.79)]


def test_new_cases_and_missing_metric_pass_through():
    base = _rows(x=1.0)
    new = _rows(x=1.0, brand_new=0.01) + [{"name": "timing", "us_per_call": 9}]
    checked, reg = find_regressions(new, base)
    assert checked == 1 and reg == []


def test_improvements_never_fail():
    checked, reg = find_regressions(_rows(x=0.9), _rows(x=0.1))
    assert checked == 1 and reg == []


def _frac_rows(**frac):
    return [{"name": n, "us_per_call": 1.0, "hlo_frac": v}
            for n, v in frac.items()]


def test_parse_metric_directions():
    assert parse_metric("wire_efficiency") == ("wire_efficiency", False)
    assert parse_metric("hlo_frac:lower") == ("hlo_frac", True)
    assert parse_metric("hlo_frac:higher") == ("hlo_frac", False)
    with pytest.raises(ValueError):
        parse_metric("hlo_frac:sideways")


def test_lower_is_better_regression_is_an_increase():
    base = _frac_rows(x=0.10, y=0.10)
    new = _frac_rows(x=0.13, y=0.115)       # +30% fails, +15% passes
    checked, reg = find_regressions(new, base, metric="hlo_frac",
                                    lower_is_better=True)
    assert checked == 2
    assert reg == [("x", 0.10, 0.13)]
    # a *drop* of a lower-is-better metric is an improvement, never a fail
    checked, reg = find_regressions(_frac_rows(x=0.01), _frac_rows(x=0.5),
                                    metric="hlo_frac", lower_is_better=True)
    assert checked == 1 and reg == []


def test_cli_exit_codes(tmp_path):
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    base.write_text(json.dumps({"rows": _rows(x=1.0)}))

    new.write_text(json.dumps({"rows": _rows(x=0.95)}))
    ok = subprocess.run(
        [sys.executable, "benchmarks/check_regression.py", str(new),
         "--baseline", str(base)], capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr

    new.write_text(json.dumps({"rows": _rows(x=0.5)}))
    bad = subprocess.run(
        [sys.executable, "benchmarks/check_regression.py", str(new),
         "--baseline", str(base)], capture_output=True, text=True)
    assert bad.returncode == 1
    assert "REGRESSION x" in bad.stdout

    # a missing baseline must not fail the job (first run on a branch)
    gone = subprocess.run(
        [sys.executable, "benchmarks/check_regression.py", str(new),
         "--baseline", str(tmp_path / "nope.json")],
        capture_output=True, text=True)
    assert gone.returncode == 0

    # ...but zero metric overlap disarms the guard and must fail loudly
    new.write_text(json.dumps({"rows": [{"name": "t", "us_per_call": 1.0}]}))
    empty = subprocess.run(
        [sys.executable, "benchmarks/check_regression.py", str(new),
         "--baseline", str(base)], capture_output=True, text=True)
    assert empty.returncode == 1
    assert "no-op" in empty.stdout


def test_cli_multi_metric_directions(tmp_path):
    """One invocation guards wire_efficiency (higher) AND hlo_frac (lower),
    exactly as the CI bench-smoke step invokes it."""
    def rows(eff, frac):
        return {"rows": [{"name": "deep", "us_per_call": 1.0,
                          "wire_efficiency": eff, "hlo_frac": frac}]}

    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    base.write_text(json.dumps(rows(1.0, 0.10)))
    cmd = [sys.executable, "benchmarks/check_regression.py", str(new),
           "--baseline", str(base),
           "--metric", "wire_efficiency", "--metric", "hlo_frac:lower"]

    new.write_text(json.dumps(rows(0.95, 0.11)))
    ok = subprocess.run(cmd, capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr

    new.write_text(json.dumps(rows(1.0, 0.20)))      # HLO doubled
    bad = subprocess.run(cmd, capture_output=True, text=True)
    assert bad.returncode == 1
    assert "REGRESSION deep: hlo_frac" in bad.stdout

    new.write_text(json.dumps(rows(0.5, 0.10)))      # efficiency halved
    bad = subprocess.run(cmd, capture_output=True, text=True)
    assert bad.returncode == 1
    assert "REGRESSION deep: wire_efficiency" in bad.stdout
