"""Unit tests for the CI bench-regression guard (wire-efficiency trend)."""

import json
import subprocess
import sys

from benchmarks.check_regression import find_regressions, metric_rows


def _rows(**eff):
    return [{"name": n, "us_per_call": 1.0, "wire_efficiency": v}
            for n, v in eff.items()]


def test_metric_rows_skips_non_numeric():
    rows = _rows(a=0.5) + [{"name": "b", "us_per_call": 2.0},
                           {"name": "c", "wire_efficiency": None},
                           {"name": "d", "wire_efficiency": True}]
    assert metric_rows(rows, "wire_efficiency") == {"a": 0.5}


def test_within_tolerance_passes():
    base = _rows(x=1.0, y=0.5)
    new = _rows(x=0.85, y=0.41)          # -15%, -18%: inside 20%
    checked, reg = find_regressions(new, base)
    assert checked == 2 and reg == []


def test_regression_detected_and_named():
    base = _rows(x=1.0, y=0.5)
    new = _rows(x=0.79, y=0.5)           # x drops 21%
    checked, reg = find_regressions(new, base)
    assert checked == 2
    assert reg == [("x", 1.0, 0.79)]


def test_new_cases_and_missing_metric_pass_through():
    base = _rows(x=1.0)
    new = _rows(x=1.0, brand_new=0.01) + [{"name": "timing", "us_per_call": 9}]
    checked, reg = find_regressions(new, base)
    assert checked == 1 and reg == []


def test_improvements_never_fail():
    checked, reg = find_regressions(_rows(x=0.9), _rows(x=0.1))
    assert checked == 1 and reg == []


def test_cli_exit_codes(tmp_path):
    base = tmp_path / "base.json"
    new = tmp_path / "new.json"
    base.write_text(json.dumps({"rows": _rows(x=1.0)}))

    new.write_text(json.dumps({"rows": _rows(x=0.95)}))
    ok = subprocess.run(
        [sys.executable, "benchmarks/check_regression.py", str(new),
         "--baseline", str(base)], capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr

    new.write_text(json.dumps({"rows": _rows(x=0.5)}))
    bad = subprocess.run(
        [sys.executable, "benchmarks/check_regression.py", str(new),
         "--baseline", str(base)], capture_output=True, text=True)
    assert bad.returncode == 1
    assert "REGRESSION x" in bad.stdout

    # a missing baseline must not fail the job (first run on a branch)
    gone = subprocess.run(
        [sys.executable, "benchmarks/check_regression.py", str(new),
         "--baseline", str(tmp_path / "nope.json")],
        capture_output=True, text=True)
    assert gone.returncode == 0

    # ...but zero metric overlap disarms the guard and must fail loudly
    new.write_text(json.dumps({"rows": [{"name": "t", "us_per_call": 1.0}]}))
    empty = subprocess.run(
        [sys.executable, "benchmarks/check_regression.py", str(new),
         "--baseline", str(base)], capture_output=True, text=True)
    assert empty.returncode == 1
    assert "no-op" in empty.stdout
