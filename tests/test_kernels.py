"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels.block_gemm.block_gemm import block_gemm
from repro.kernels.block_gemm.ref import block_gemm_ref
from repro.kernels.decode_attention.decode_attention import decode_attention
from repro.kernels.decode_attention.ref import decode_ref
from repro.kernels.flash_attention.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import mha_ref
from repro.kernels.ssd_scan.ref import ssd_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape).astype(dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ------------------------------------------------------------- block_gemm

@pytest.mark.parametrize("m,n,k,bm,bn,bk", [
    (64, 64, 64, 32, 32, 32),
    (128, 64, 96, 64, 32, 32),
    (32, 128, 64, 32, 128, 64),   # single tile in two dims
    (256, 256, 128, 128, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_gemm_sweep(m, n, k, bm, bn, bk, dtype):
    k1, k2 = jax.random.split(jax.random.key(0))
    a = _rand(k1, (m, k), dtype)
    b = _rand(k2, (k, n), dtype)
    got = block_gemm(a, b, bm=bm, bn=bn, bk=bk, interpret=True)
    want = block_gemm_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# -------------------------------------------------------- flash_attention

@pytest.mark.parametrize("b,hq,hkv,lq,lk,d,bq,bk", [
    (1, 4, 4, 128, 128, 64, 64, 64),     # MHA
    (2, 8, 2, 128, 128, 64, 64, 64),     # GQA 4:1
    (1, 4, 1, 64, 256, 32, 64, 64),      # MQA, kv longer than q
    (1, 2, 2, 256, 256, 128, 128, 64),   # uneven q/kv tiles
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, hq, hkv, lq, lk, d, bq, bk, causal, dtype):
    keys = jax.random.split(jax.random.key(1), 3)
    q = _rand(keys[0], (b, hq, lq, d), dtype)
    k = _rand(keys[1], (b, hkv, lk, d), dtype)
    v = _rand(keys[2], (b, hkv, lk, d), dtype)
    got = flash_attention(q, k, v, causal=causal, bq=bq, bk=bk,
                          interpret=True)
    want = mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_attention_matches_oracle_on_long_seq():
    q = _rand(jax.random.key(2), (1, 2, 512, 64), jnp.float32)
    k = _rand(jax.random.key(3), (1, 2, 512, 64), jnp.float32)
    v = _rand(jax.random.key(4), (1, 2, 512, 64), jnp.float32)
    got = flash_attention(q, k, v, causal=True, bq=128, bk=128,
                          interpret=True)
    np.testing.assert_allclose(got, mha_ref(q, k, v, causal=True),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------- task-body wrappers

def test_task_matmul_vmaps_to_fused_grid():
    """`task_matmul` is the executor's per-task body form: vmapping it (what
    the wavefront compute step does over the task table) folds the batch
    into a leading pallas grid dimension and still matches the oracle."""
    from repro.kernels.block_gemm.ops import task_matmul

    keys = jax.random.split(jax.random.key(9), 2)
    a = _rand(keys[0], (5, 16, 16), jnp.float32)
    b = _rand(keys[1], (5, 16, 16), jnp.float32)
    got = jax.vmap(task_matmul)(a, b)
    np.testing.assert_allclose(got, jnp.einsum("bij,bjk->bik", a, b),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
def test_task_attention_matches_ref(causal):
    """2D-block single-head attention body vs the jnp oracle, including
    under vmap (the executor's batching over a wavefront's task table)."""
    from repro.kernels.flash_attention.ops import task_attention

    keys = jax.random.split(jax.random.key(10), 3)
    q = _rand(keys[0], (3, 32, 16), jnp.float32)
    k = _rand(keys[1], (3, 32, 16), jnp.float32)
    v = _rand(keys[2], (3, 32, 16), jnp.float32)
    got = jax.vmap(lambda q_, k_, v_: task_attention(
        q_, k_, v_, causal=causal))(q, k, v)
    want = mha_ref(q[:, None], k[:, None], v[:, None], causal=causal)[:, 0]
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# -------------------------------------------------------- decode_attention

@pytest.mark.parametrize("b,hq,hkv,s,d,bs", [
    (2, 8, 2, 256, 64, 64),
    (1, 4, 4, 512, 128, 128),
    (4, 16, 1, 128, 64, 64),  # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(b, hq, hkv, s, d, bs, dtype):
    keys = jax.random.split(jax.random.key(5), 3)
    q = _rand(keys[0], (b, hq, d), dtype)
    k = _rand(keys[1], (b, hkv, s, d), dtype)
    v = _rand(keys[2], (b, hkv, s, d), dtype)
    got = decode_attention(q, k, v, bs=bs, interpret=True)
    want = decode_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_ragged_lengths():
    b, hq, hkv, s, d = 3, 4, 2, 256, 64
    keys = jax.random.split(jax.random.key(6), 3)
    q = _rand(keys[0], (b, hq, d), jnp.float32)
    k = _rand(keys[1], (b, hkv, s, d), jnp.float32)
    v = _rand(keys[2], (b, hkv, s, d), jnp.float32)
    kv_len = jnp.array([256, 100, 17], jnp.int32)
    got = decode_attention(q, k, v, kv_len, bs=64, interpret=True)
    want = decode_ref(q, k, v, kv_len)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------- ssd_scan

@pytest.mark.parametrize("b,l,h,g,p,n,q", [
    (1, 128, 2, 1, 32, 16, 64),
    (2, 256, 4, 2, 64, 32, 128),
    (1, 64, 8, 8, 16, 16, 32),   # one head per group
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_sweep(b, l, h, g, p, n, q, dtype):
    keys = jax.random.split(jax.random.key(7), 5)
    x = _rand(keys[0], (b, l, h, p), dtype)
    dt = jax.nn.softplus(_rand(keys[1], (b, l, h), jnp.float32)) * 0.1
    a = -jnp.exp(_rand(keys[2], (h,), jnp.float32) * 0.5)
    bmat = _rand(keys[3], (b, l, g, n), dtype) * 0.5
    cmat = _rand(keys[4], (b, l, g, n), dtype) * 0.5
    d = jnp.ones((h,), jnp.float32) * 0.5
    got = ssd_scan(x, dt.astype(dtype), a, bmat, cmat, d, q_chunk=q,
                   interpret=True)
    want = ssd_ref(x, dt.astype(dtype), a, bmat, cmat, d)
    tol = dict(rtol=5e-2, atol=5e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_ssd_scan_state_carries_across_chunks():
    """Chunked result must match the recurrence even when L >> chunk."""
    b, l, h, g, p, n = 1, 256, 2, 1, 16, 8
    keys = jax.random.split(jax.random.key(8), 5)
    x = _rand(keys[0], (b, l, h, p), jnp.float32)
    dt = jax.nn.softplus(_rand(keys[1], (b, l, h), jnp.float32)) * 0.2
    a = -jnp.exp(_rand(keys[2], (h,), jnp.float32) * 0.3)
    bmat = _rand(keys[3], (b, l, g, n), jnp.float32) * 0.5
    cmat = _rand(keys[4], (b, l, g, n), jnp.float32) * 0.5
    got32 = ssd_scan(x, dt, a, bmat, cmat, None, q_chunk=32, interpret=True)
    got128 = ssd_scan(x, dt, a, bmat, cmat, None, q_chunk=128, interpret=True)
    want = ssd_ref(x, dt, a, bmat, cmat, None)
    np.testing.assert_allclose(got32, want, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(got128, want, rtol=2e-4, atol=2e-4)
