"""Multi-device validation cases, run in a *subprocess* so the forced host
device count never leaks into the main pytest process (smoke tests and
benches must keep seeing 1 device).

Usage:  python -m tests.multi_device_cases <case> [<case> ...]
Prints "CASE <name> OK" per passing case; non-zero exit on failure.
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


class SkipCase(Exception):
    """Raised by a case that cannot run in this process (too few devices);
    main() reports ``CASE <name> SKIP`` and exits 0, and the pytest
    dispatcher in test_ptg_linalg turns that into a pytest skip."""


def _require_devices(n):
    have = len(jax.devices())
    if have < n:
        raise SkipCase(f"needs {n} devices, have {have}")


def _mesh(n):
    _require_devices(n)
    return jax.sharding.Mesh(np.array(jax.devices()[:n]), ("shards",))


def case_gemm_2d():
    from repro.core.schedule import build_block_program
    from repro.linalg.gemm import (assemble, gemm_2d_spec, gemm_bodies,
                                   make_blocks)

    for staged in (False, True):
        nb, pr, pc, b = 4, 2, 2, 8
        spec = gemm_2d_spec(nb, pr, pc, b, staged=staged)
        prog = build_block_program(spec)
        blocks = make_blocks(None, nb, b)
        mesh = _mesh(spec.n_shards)
        with mesh:
            run = jax.jit(prog.executor(gemm_bodies(), mesh))
            out = prog.unpack(run(jnp.asarray(prog.pack(blocks))))
        a = assemble(blocks, "A", nb, b)
        bm = assemble(blocks, "B", nb, b)
        c = assemble(out, "C", nb, b)
        np.testing.assert_allclose(c, a @ bm, rtol=2e-4, atol=2e-4)


def case_gemm_3d():
    from repro.core.schedule import build_block_program
    from repro.linalg.gemm import (assemble, gemm_3d_spec, gemm_bodies,
                                   make_blocks)

    nb, q, b = 4, 2, 8
    spec = gemm_3d_spec(nb, q, b)
    prog = build_block_program(spec)
    blocks = make_blocks(None, nb, b, with_partials=tuple(range(q)))
    mesh = _mesh(spec.n_shards)
    with mesh:
        run = jax.jit(prog.executor(gemm_bodies(), mesh))
        out = prog.unpack(run(jnp.asarray(prog.pack(blocks))))
    a = assemble(blocks, "A", nb, b)
    bm = assemble(blocks, "B", nb, b)
    c = assemble(out, "C", nb, b)
    np.testing.assert_allclose(c, a @ bm, rtol=2e-4, atol=2e-4)


def case_gemm_unrolled_matches_scan():
    from repro.core.schedule import build_block_program
    from repro.linalg.gemm import gemm_2d_spec, gemm_bodies, make_blocks

    nb, pr, pc, b = 3, 2, 2, 4
    spec = gemm_2d_spec(nb, pr, pc, b)
    prog = build_block_program(spec)
    blocks = make_blocks(None, nb, b)
    packed = jnp.asarray(prog.pack(blocks))
    mesh = _mesh(spec.n_shards)
    with mesh:
        out_scan = prog.unpack(jax.jit(prog.executor(
            gemm_bodies(), mesh, scan=True))(packed))
        out_unrl = prog.unpack(jax.jit(prog.executor(
            gemm_bodies(), mesh, scan=False))(packed))
    for key in out_scan:
        np.testing.assert_allclose(out_scan[key], out_unrl[key],
                                   rtol=1e-5, atol=1e-5)


def case_cholesky():
    from repro.core.schedule import build_block_program
    from repro.linalg.cholesky import (assemble_lower, cholesky_bodies,
                                       cholesky_spec, make_spd_blocks)

    nb, pr, pc, b = 5, 2, 2, 8
    spec = cholesky_spec(nb, pr, pc, b)
    prog = build_block_program(spec)
    blocks, a = make_spd_blocks(nb, b)
    mesh = _mesh(spec.n_shards)
    with mesh:
        run = jax.jit(prog.executor(cholesky_bodies(), mesh))
        out = prog.unpack(run(jnp.asarray(prog.pack(blocks))))
    l = assemble_lower(out, nb, b)
    np.testing.assert_allclose(l, np.linalg.cholesky(a), rtol=5e-3, atol=5e-3)


def case_cholesky_host_matches_compiled():
    from repro.core.schedule import build_block_program
    from repro.linalg.cholesky import (cholesky_bodies, cholesky_spec,
                                       make_spd_blocks)
    from repro.linalg.host_exec import run_host_ptg

    def np_bodies(bodies):
        return {t: (lambda fn: (lambda *args: np.asarray(
            fn(*map(jnp.asarray, args)))))(fn) for t, fn in bodies.items()}

    nb, pr, pc, b = 4, 2, 2, 4
    spec = cholesky_spec(nb, pr, pc, b)
    blocks, _ = make_spd_blocks(nb, b)
    host = run_host_ptg(spec, blocks, np_bodies(cholesky_bodies()))
    prog = build_block_program(spec)
    mesh = _mesh(spec.n_shards)
    with mesh:
        run = jax.jit(prog.executor(cholesky_bodies(), mesh))
        comp = prog.unpack(run(jnp.asarray(prog.pack(blocks))))
    for key, arr in host.items():
        if key[0] == "L":
            np.testing.assert_allclose(arr, comp[key], rtol=1e-5, atol=1e-5)




def case_pipeline_matches_sequential():
    from functools import reduce

    from repro.dist.pipeline import (pipeline_apply, pipeline_loss_fn,
                                     schedule_depth, split_microbatches)

    assert schedule_depth(4, 6) == 4 + 6 - 1  # PTG-derived GPipe bubble

    n_stages, n_micro, mb, d = 4, 8, 4, 16
    _require_devices(n_stages)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n_stages]), ("pipe",))
    key = jax.random.key(0)
    params = jax.random.normal(key, (n_stages, d, d)) * (d ** -0.5)

    def stage_fn(p, x):
        return jnp.tanh(x @ p)

    xs = jax.random.normal(jax.random.key(1), (n_micro, mb, d))
    with mesh:
        ys = pipeline_apply(stage_fn, params, xs, mesh=mesh)
    want = xs
    for s in range(n_stages):
        want = jnp.tanh(want @ params[s])
    np.testing.assert_allclose(np.asarray(ys), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    # gradients flow through the (reversed) pipeline — bwd by autodiff
    batch_x = xs.reshape(n_micro * mb, d)
    batch_y = jax.random.normal(jax.random.key(2), (n_micro * mb, d))
    loss = pipeline_loss_fn(stage_fn, lambda yh, y: jnp.mean((yh - y) ** 2),
                            mesh=mesh, n_micro=n_micro)

    def ref_loss(p, x, y):
        h = x
        for s in range(n_stages):
            h = jnp.tanh(h @ p[s])
        return jnp.mean((h - y) ** 2)

    with mesh:
        g_pipe = jax.grad(loss)(params, batch_x, batch_y)
    g_ref = jax.grad(ref_loss)(params, batch_x, batch_y)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-5)


def case_elastic_restore_smaller_mesh():
    """Checkpoint on a 2x4 mesh, restore re-sharded onto 1x4 (node loss)."""
    import tempfile

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.train import checkpoint as ckpt
    from repro.train.elastic import plan_remesh

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones((8,), jnp.float32)}
    _require_devices(8)
    mesh8 = jax.sharding.Mesh(np.array(jax.devices()[:8]).reshape(2, 4),
                              ("data", "model"))
    sh8 = {"w": NamedSharding(mesh8, P("data", "model")),
           "b": NamedSharding(mesh8, P("model"))}
    tree8 = jax.tree.map(jax.device_put, tree, sh8)

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, tree8)
        assert ckpt.latest_step(d) == 7
        plan = plan_remesh(n_hosts=2, failed=[1], chips_per_host=4,
                           model_axis=4, latest_ckpt=7)
        assert plan.mesh_shape == (1, 4)
        mesh4 = jax.sharding.Mesh(
            np.array(jax.devices()[:4]).reshape(1, 4), ("data", "model"))
        sh4 = {"w": NamedSharding(mesh4, P("data", "model")),
               "b": NamedSharding(mesh4, P("model"))}
        restored = ckpt.restore(d, 7, tree, shardings=sh4)
        np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
        np.testing.assert_array_equal(np.asarray(restored["b"]), tree["b"])


ALL = {name[5:]: fn for name, fn in list(globals().items())
       if name.startswith("case_")}


def main(argv):
    names = argv or sorted(ALL)
    for name in names:
        try:
            ALL[name]()
        except SkipCase as e:
            print(f"CASE {name} SKIP ({e})", flush=True)
            continue
        print(f"CASE {name} OK", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:])
