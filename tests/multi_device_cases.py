"""Multi-device validation cases, run in a *subprocess* so the forced host
device count never leaks into the main pytest process (smoke tests and
benches must keep seeing 1 device).

Usage:  python -m tests.multi_device_cases <case> [<case> ...]
Prints "CASE <name> OK" per passing case; non-zero exit on failure.
"""

import os
import sys

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402


class SkipCase(Exception):
    """Raised by a case that cannot run in this process (too few devices);
    main() reports ``CASE <name> SKIP`` and exits 0, and the pytest
    dispatcher in test_ptg_linalg turns that into a pytest skip."""


def _require_devices(n):
    have = len(jax.devices())
    if have < n:
        raise SkipCase(f"needs {n} devices, have {have}")


def _mesh(n):
    _require_devices(n)
    return jax.sharding.Mesh(np.array(jax.devices()[:n]), ("shards",))


def case_gemm_2d():
    from repro.linalg.gemm import (assemble, gemm_2d_program, gemm_executor,
                                   gemm_bodies, make_blocks)

    for staged in (False, True):
        nb, pr, pc, b = 4, 2, 2, 8
        prog = gemm_2d_program(nb, pr, pc, b, staged=staged)
        blocks = make_blocks(None, nb, b)
        mesh = _mesh(prog.spec.n_shards)
        with mesh:
            run = jax.jit(gemm_executor(prog, mesh))
            out = prog.unpack(run(jnp.asarray(prog.pack(blocks))))
        a = assemble(blocks, "A", nb, b)
        bm = assemble(blocks, "B", nb, b)
        c = assemble(out, "C", nb, b)
        np.testing.assert_allclose(c, a @ bm, rtol=2e-4, atol=2e-4)


def case_gemm_3d():
    from repro.linalg.gemm import (assemble, gemm_3d_program, gemm_executor,
                                   make_blocks)

    nb, q, b = 4, 2, 8
    prog = gemm_3d_program(nb, q, b)
    blocks = make_blocks(None, nb, b, with_partials=tuple(range(q)))
    mesh = _mesh(prog.spec.n_shards)
    with mesh:
        run = jax.jit(gemm_executor(prog, mesh))
        out = prog.unpack(run(jnp.asarray(prog.pack(blocks))))
    a = assemble(blocks, "A", nb, b)
    bm = assemble(blocks, "B", nb, b)
    c = assemble(out, "C", nb, b)
    np.testing.assert_allclose(c, a @ bm, rtol=2e-4, atol=2e-4)


def case_gemm_unrolled_matches_scan():
    from repro.core.schedule import build_block_program
    from repro.linalg.gemm import gemm_2d_spec, gemm_bodies, make_blocks

    nb, pr, pc, b = 3, 2, 2, 4
    spec = gemm_2d_spec(nb, pr, pc, b)
    prog = build_block_program(spec)
    blocks = make_blocks(None, nb, b)
    packed = jnp.asarray(prog.pack(blocks))
    mesh = _mesh(spec.n_shards)
    with mesh:
        out_scan = prog.unpack(jax.jit(prog.executor(
            gemm_bodies(), mesh, scan=True))(packed))
        out_unrl = prog.unpack(jax.jit(prog.executor(
            gemm_bodies(), mesh, scan=False))(packed))
    for key in out_scan:
        np.testing.assert_allclose(out_scan[key], out_unrl[key],
                                   rtol=1e-5, atol=1e-5)


def case_cholesky():
    from repro.linalg.cholesky import (assemble_lower, cholesky_executor,
                                       cholesky_program, make_spd_blocks)

    nb, pr, pc, b = 5, 2, 2, 8
    prog = cholesky_program(nb, pr, pc, b)
    blocks, a = make_spd_blocks(nb, b)
    mesh = _mesh(prog.spec.n_shards)
    with mesh:
        run = jax.jit(cholesky_executor(prog, mesh))
        out = prog.unpack(run(jnp.asarray(prog.pack(blocks))))
    l = assemble_lower(out, nb, b)
    np.testing.assert_allclose(l, np.linalg.cholesky(a), rtol=5e-3, atol=5e-3)


def case_lowering_identity():
    """Every lowering of the same program — dense scan, segmented scan
    (sparse/auto, with and without overlap), unrolled dense, sparse, auto,
    and the double-buffered overlap modes — is bit-identical on GEMM and
    Cholesky (same bodies over the same operand values)."""
    from repro.core.schedule import build_block_program
    from repro.linalg.cholesky import (cholesky_bodies, cholesky_spec,
                                       make_spd_blocks)
    from repro.linalg.gemm import gemm_2d_spec, gemm_bodies, make_blocks

    cases = []
    spec = cholesky_spec(6, 2, 2, 4)
    blocks, _ = make_spd_blocks(6, 4)
    cases.append((spec, cholesky_bodies(), blocks))
    for staged in (False, True):
        spec = gemm_2d_spec(4, 2, 2, 4, staged=staged)
        cases.append((spec, gemm_bodies(), make_blocks(None, 4, 4)))

    variants = (
        dict(scan=True),
        dict(scan=True, comm="sparse"),
        dict(scan=True, comm="auto"),
        dict(scan=True, comm="auto", overlap=True),
        dict(scan=True, comm="sparse", overlap=True),
        dict(scan=True, comm="dense", overlap=True),
        dict(scan=False, comm="sparse"),
        dict(scan=False, comm="auto"),
        dict(scan=False, comm="dense", overlap=True),
        dict(scan=False, comm="sparse", overlap=True),
        dict(scan=False, comm="auto", overlap=True),
    )
    for spec, bodies, blocks in cases:
        prog = build_block_program(spec)
        mesh = _mesh(prog.spec.n_shards)
        packed = jnp.asarray(prog.pack(blocks))
        with mesh:
            ref = np.asarray(jax.jit(prog.executor(
                bodies, mesh, scan=False, comm="dense"))(packed))
            for kw in variants:
                got = np.asarray(jax.jit(prog.executor(
                    bodies, mesh, **kw))(packed))
                # compare real slots only (trash accumulates padded writes)
                for blk, (s, slot) in prog.slot_of.items():
                    np.testing.assert_array_equal(
                        ref[s, slot], got[s, slot], err_msg=f"{kw} {blk}")
                for (s, blk), slot in prog.halo_slot.items():
                    np.testing.assert_array_equal(
                        ref[s, slot], got[s, slot], err_msg=f"{kw} halo {blk}")


def case_cholesky_host_matches_compiled():
    from repro.core.schedule import build_block_program
    from repro.linalg.cholesky import (cholesky_bodies, cholesky_spec,
                                       make_spd_blocks)
    from repro.linalg.host_exec import as_numpy_bodies, run_host_ptg

    nb, pr, pc, b = 4, 2, 2, 4
    spec = cholesky_spec(nb, pr, pc, b)
    blocks, _ = make_spd_blocks(nb, b)
    host = run_host_ptg(spec, blocks, as_numpy_bodies(cholesky_bodies()))
    prog = build_block_program(spec)
    mesh = _mesh(spec.n_shards)
    with mesh:
        run = jax.jit(prog.executor(cholesky_bodies(), mesh))
        comp = prog.unpack(run(jnp.asarray(prog.pack(blocks))))
    for key, arr in host.items():
        if key[0] == "L":
            np.testing.assert_allclose(arr, comp[key], rtol=1e-5, atol=1e-5)




def case_taskbench_identity():
    """Every Task-Bench dependence pattern, executed by the sparse/overlap
    executor, matches the sequential oracle and the dense unrolled
    reference bit-for-bit."""
    from repro.core.schedule import build_block_program
    from benchmarks.taskbench_scaling import (taskbench_blocks,
                                              taskbench_bodies,
                                              taskbench_oracle,
                                              taskbench_spec)

    width, depth, n_shards, b = 8, 6, 4, 4
    mesh = _mesh(n_shards)
    for pattern in ("stencil", "fft", "tree", "random"):
        spec, deps = taskbench_spec(pattern, width, depth, n_shards, b,
                                    fan=2)
        prog = build_block_program(spec)
        blocks = taskbench_blocks(width, depth, b)
        packed = jnp.asarray(prog.pack(blocks))
        bodies = taskbench_bodies()
        with mesh:
            ref = prog.unpack(jax.jit(prog.executor(
                bodies, mesh, scan=False, comm="dense"))(packed))
            got = prog.unpack(jax.jit(prog.auto_executor(
                bodies, mesh))(packed))
        want = taskbench_oracle(blocks, deps, width, depth)
        for blk in want:
            np.testing.assert_allclose(got[blk], want[blk],
                                       rtol=1e-5, atol=1e-5,
                                       err_msg=f"{pattern} {blk}")
            np.testing.assert_array_equal(np.asarray(got[blk]),
                                          np.asarray(ref[blk]),
                                          err_msg=f"{pattern} {blk}")


def case_segmented_identity():
    """The segmented-scan executor is bit-identical to the unrolled
    ``comm="auto"`` reference AND to the pure dense scan across Task-Bench
    dependence patterns x shard counts x depths — including ragged
    boundaries (depth not a multiple of any segment length, single-
    wavefront segments from fft's stride cycling, and random's all-dense
    schedules degenerating to one all_to_all run)."""
    from repro.core.schedule import build_block_program
    from benchmarks.taskbench_scaling import (taskbench_blocks,
                                              taskbench_bodies,
                                              taskbench_spec)

    width, b = 8, 4
    bodies = taskbench_bodies()
    for pattern in ("stencil", "fft", "tree", "random"):
        for n_shards, depth in ((2, 7), (4, 5), (4, 13)):
            mesh = _mesh(n_shards)
            spec, _deps = taskbench_spec(pattern, width, depth, n_shards, b,
                                         fan=2)
            prog = build_block_program(spec)
            segs = prog.segments()
            assert segs[0][0] == 0 and segs[-1][1] == depth
            blocks = taskbench_blocks(width, depth, b)
            packed = jnp.asarray(prog.pack(blocks))
            with mesh:
                ref = np.asarray(jax.jit(prog.executor(
                    bodies, mesh, scan=False, comm="auto"))(packed))
                for kw in (dict(scan=True),                    # dense scan
                           dict(scan=True, comm="auto"),
                           dict(scan=True, comm="auto", overlap=True),
                           dict(scan=True, comm="sparse", overlap=True)):
                    got = np.asarray(jax.jit(prog.executor(
                        bodies, mesh, **kw))(packed))
                    # compare real slots only (trash accumulates padding)
                    for blk, (s, slot) in prog.slot_of.items():
                        np.testing.assert_array_equal(
                            ref[s, slot], got[s, slot],
                            err_msg=f"{pattern}/s{n_shards}/d{depth} "
                                    f"{kw} {blk}")
                    for (s, blk), slot in prog.halo_slot.items():
                        np.testing.assert_array_equal(
                            ref[s, slot], got[s, slot],
                            err_msg=f"{pattern}/s{n_shards}/d{depth} "
                                    f"{kw} halo {blk}")


def case_unified_graph():
    """The one-API story, executed: a single declarative ``repro.ptg``
    Graph (Cholesky) runs on BOTH back-ends — the async host Taskflow
    runtime and the compiled block executor — and agrees with the oracle;
    and the builder-derived program's executor output is bit-identical to
    the frozen legacy hand-written spec's."""
    from repro.core.schedule import build_block_program
    from repro.linalg.cholesky import (assemble_lower, cholesky_bodies,
                                       cholesky_graph, make_spd_blocks)
    from repro.linalg.host_exec import as_numpy_bodies
    from tests.legacy_specs import legacy_cholesky_spec

    nb, pr, pc, b = 4, 2, 2, 4
    graph = cholesky_graph(nb, pr, pc, b)
    blocks, a = make_spd_blocks(nb, b)
    mesh = _mesh(graph.n_shards)

    # lowering (a): host runtime — Taskflow + AM wiring from derived edges
    host = graph.run_host(blocks, as_numpy_bodies(cholesky_bodies()))

    # lowering (b): compiled block executor from the same definition
    prog = graph.to_program(validate=True)
    with mesh:
        run = jax.jit(prog.auto_executor(cholesky_bodies(), mesh))
        comp = prog.unpack(run(jnp.asarray(prog.pack(blocks))))

    l_host = assemble_lower(host, nb, b)
    l_comp = assemble_lower(comp, nb, b)
    want = np.linalg.cholesky(a)
    np.testing.assert_allclose(l_host, l_comp, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(l_comp, want, rtol=5e-3, atol=5e-3)

    # and bit-identity vs the pre-redesign hand-written spec's executor
    legacy = build_block_program(legacy_cholesky_spec(nb, pr, pc, b))
    with mesh:
        ref = legacy.unpack(jax.jit(
            legacy.auto_executor(cholesky_bodies(), mesh))(
                jnp.asarray(legacy.pack(blocks))))
    for key, arr in comp.items():
        np.testing.assert_array_equal(np.asarray(arr),
                                      np.asarray(ref[key]), err_msg=str(key))


def case_pallas_bodies():
    """Pallas kernels as task bodies, end to end under the block executor:
    (a) GEMM and Cholesky with ``task_matmul`` (the fused per-wavefront
    ``vmap(pallas_call)`` launch) match the jnp-body lowering within f32
    tolerance, across the unrolled AND scan policies; (b) an attention
    chain runs ``task_attention`` (flash attention re-shaped to the 2D
    block form) against an ``mha_ref``-bodied lowering of the same PTG."""
    from repro.kernels.block_gemm.ops import task_matmul
    from repro.kernels.flash_attention.ops import task_attention
    from repro.kernels.flash_attention.ref import mha_ref
    from repro.linalg.cholesky import (assemble_lower, cholesky_executor,
                                       cholesky_program, make_spd_blocks)
    from repro.linalg.gemm import (assemble, gemm_2d_program, gemm_executor,
                                   make_blocks)
    from repro.ptg import Graph

    nb, pr, pc, b = 4, 2, 2, 8
    mesh = _mesh(pr * pc)

    # (a) GEMM: pallas body vs jnp body, unrolled and forced-scan policies
    prog = gemm_2d_program(nb, pr, pc, b, staged=True)
    blocks = make_blocks(None, nb, b)
    packed = jnp.asarray(prog.pack(blocks))
    a = assemble(blocks, "A", nb, b)
    bm = assemble(blocks, "B", nb, b)
    for policy in ({}, dict(unroll_cap=2)):        # unrolled / segmented scan
        with mesh:
            got = prog.unpack(jax.jit(gemm_executor(
                prog, mesh, matmul=task_matmul, **policy))(packed))
            ref = prog.unpack(jax.jit(gemm_executor(
                prog, mesh, **policy))(packed))
        c_p = assemble(got, "C", nb, b)
        c_j = assemble(ref, "C", nb, b)
        np.testing.assert_allclose(c_p, c_j, rtol=2e-5, atol=2e-5,
                                   err_msg=f"policy={policy}")
        np.testing.assert_allclose(c_p, a @ bm, rtol=2e-4, atol=2e-4)

    # Cholesky: trailing updates (syrk/gemm) through the pallas matmul
    progc = cholesky_program(nb, pr, pc, b)
    blkc, a_spd = make_spd_blocks(nb, b)
    packed_c = jnp.asarray(progc.pack(blkc))
    with mesh:
        got = progc.unpack(jax.jit(cholesky_executor(
            progc, mesh, matmul=task_matmul))(packed_c))
        ref = progc.unpack(jax.jit(cholesky_executor(progc, mesh))(packed_c))
    l_p = assemble_lower(got, nb, b)
    np.testing.assert_allclose(l_p, assemble_lower(ref, nb, b),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(l_p, np.linalg.cholesky(a_spd),
                               rtol=5e-3, atol=5e-3)

    # (b) attention chain: task (l) self-attends the previous layer's block
    depth, seq, dim = 6, 32, 16
    n_sh = 2
    mesh2 = _mesh(n_sh)

    def attn_graph():
        g = Graph("attnchain", n_shards=n_sh, owner=lambda blk: blk[1] % n_sh,
                  block_shape=(seq, dim))
        g.task_type("src",                    # publish the input as a task
                    space=lambda: ((0,),),    # output (communicated blocks
                    writes=lambda l: ("x", 0),  # are single-assignment)
                    reads=lambda l: [("in", 0)])
        g.task_type("attn",
                    space=lambda: ((l,) for l in range(1, depth + 1)),
                    writes=lambda l: ("x", l),
                    reads=lambda l: [("x", l - 1)] * 3)
        return g

    rng = np.random.default_rng(7)
    ablocks = {("in", 0): rng.standard_normal((seq, dim)).astype(np.float32)}
    for l in range(depth + 1):
        ablocks[("x", l)] = np.zeros((seq, dim), np.float32)

    aprog = attn_graph().to_program()
    apacked = jnp.asarray(aprog.pack(ablocks))
    jnp_body = {"src": lambda x: x,
                "attn": lambda q, k, v: mha_ref(
                    q[None, None], k[None, None], v[None, None],
                    causal=True)[0, 0]}
    pl_body = {"src": lambda x: x, "attn": task_attention}
    with mesh2:
        got = aprog.unpack(jax.jit(
            aprog.auto_executor(pl_body, mesh2))(apacked))
        ref = aprog.unpack(jax.jit(
            aprog.auto_executor(jnp_body, mesh2))(apacked))
    for l in range(1, depth + 1):
        np.testing.assert_allclose(got[("x", l)], ref[("x", l)],
                                   rtol=2e-5, atol=2e-5, err_msg=f"x{l}")


def case_pipeline_train_step():
    """Stage-parallel training on a ("pipe", "data", "model") mesh: the
    pipelined loss equals the sequential lm_loss, and two steps run with
    finite metrics (the launch.train --pipeline path)."""
    from repro.configs.base import reduced
    from repro.configs.registry import get_config
    from repro.models.transformer import lm_loss
    from repro.train.data import SyntheticLM
    from repro.train.train_step import (init_train_state,
                                        make_pipeline_train_step)

    _require_devices(4)
    cfg = reduced(get_config("starcoder2-3b"), n_layers=4, vocab_size=128)
    mesh = jax.make_mesh((2, 2, 1), ("pipe", "data", "model"))
    params, opt = init_train_state(cfg, jax.random.key(0))
    ds = SyntheticLM(cfg.vocab_size, 32, 8, learnable=True)
    batch = {k: jnp.asarray(v) for k, v in ds.batch_at(0).items()}

    step = jax.jit(make_pipeline_train_step(cfg, mesh, lr=1e-3, n_micro=4))
    p1, o1, m1 = step(params, opt, batch)
    ref = float(lm_loss(cfg, params, batch))
    got = float(m1["loss"])
    assert abs(got - ref) <= 1e-3 * max(1.0, abs(ref)), (got, ref)
    p1, o1, m2 = step(p1, o1, batch)
    assert np.isfinite(float(m2["loss"])) and float(m2["loss"]) < got

    # unsupported family fails loudly, not silently sequentially
    moe = reduced(get_config("deepseek-v3-671b"))
    try:
        make_pipeline_train_step(moe, mesh, n_micro=4)
    except ValueError as e:
        assert "dense family" in str(e)
    else:
        raise AssertionError("moe config should be rejected")


def case_pipeline_matches_sequential():
    from functools import reduce

    from repro.dist.pipeline import (pipeline_apply, pipeline_loss_fn,
                                     schedule_depth, split_microbatches)

    assert schedule_depth(4, 6) == 4 + 6 - 1  # PTG-derived GPipe bubble

    n_stages, n_micro, mb, d = 4, 8, 4, 16
    _require_devices(n_stages)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:n_stages]), ("pipe",))
    key = jax.random.key(0)
    params = jax.random.normal(key, (n_stages, d, d)) * (d ** -0.5)

    def stage_fn(p, x):
        return jnp.tanh(x @ p)

    xs = jax.random.normal(jax.random.key(1), (n_micro, mb, d))
    with mesh:
        ys = pipeline_apply(stage_fn, params, xs, mesh=mesh)
    want = xs
    for s in range(n_stages):
        want = jnp.tanh(want @ params[s])
    np.testing.assert_allclose(np.asarray(ys), np.asarray(want),
                               rtol=1e-5, atol=1e-5)

    # gradients flow through the (reversed) pipeline — bwd by autodiff
    batch_x = xs.reshape(n_micro * mb, d)
    batch_y = jax.random.normal(jax.random.key(2), (n_micro * mb, d))
    loss = pipeline_loss_fn(stage_fn, lambda yh, y: jnp.mean((yh - y) ** 2),
                            mesh=mesh, n_micro=n_micro)

    def ref_loss(p, x, y):
        h = x
        for s in range(n_stages):
            h = jnp.tanh(h @ p[s])
        return jnp.mean((h - y) ** 2)

    with mesh:
        g_pipe = jax.grad(loss)(params, batch_x, batch_y)
    g_ref = jax.grad(ref_loss)(params, batch_x, batch_y)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_ref),
                               rtol=1e-4, atol=1e-5)


def case_elastic_restore_smaller_mesh():
    """Checkpoint on a 2x4 mesh, restore re-sharded onto 1x4 (node loss)."""
    import tempfile

    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.train import checkpoint as ckpt
    from repro.train.elastic import plan_remesh

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
            "b": jnp.ones((8,), jnp.float32)}
    _require_devices(8)
    mesh8 = jax.sharding.Mesh(np.array(jax.devices()[:8]).reshape(2, 4),
                              ("data", "model"))
    sh8 = {"w": NamedSharding(mesh8, P("data", "model")),
           "b": NamedSharding(mesh8, P("model"))}
    tree8 = jax.tree.map(jax.device_put, tree, sh8)

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 7, tree8)
        assert ckpt.latest_step(d) == 7
        plan = plan_remesh(n_hosts=2, failed=[1], chips_per_host=4,
                           model_axis=4, latest_ckpt=7)
        assert plan.mesh_shape == (1, 4)
        mesh4 = jax.sharding.Mesh(
            np.array(jax.devices()[:4]).reshape(1, 4), ("data", "model"))
        sh4 = {"w": NamedSharding(mesh4, P("data", "model")),
               "b": NamedSharding(mesh4, P("model"))}
        restored = ckpt.restore(d, 7, tree, shardings=sh4)
        np.testing.assert_array_equal(np.asarray(restored["w"]), tree["w"])
        np.testing.assert_array_equal(np.asarray(restored["b"]), tree["b"])


ALL = {name[5:]: fn for name, fn in list(globals().items())
       if name.startswith("case_")}


def main(argv):
    names = argv or sorted(ALL)
    for name in names:
        try:
            ALL[name]()
        except SkipCase as e:
            print(f"CASE {name} SKIP ({e})", flush=True)
            continue
        print(f"CASE {name} OK", flush=True)


if __name__ == "__main__":
    main(sys.argv[1:])
