"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train-grad step + one decode step on CPU; asserts output
shapes and absence of NaNs. The FULL configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import reduced, shapes_for
from repro.configs.registry import all_archs, get_config
from repro.models import transformer as tfm

B, S = 2, 32


def _batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {}
    if cfg.embed_inputs:
        batch["embeds"] = jax.random.normal(ks[0], (B, S, cfg.d_model),
                                            jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    if cfg.family == "encdec":
        batch["enc_embeds"] = jax.random.normal(ks[1], (B, S, cfg.d_model),
                                                jnp.float32)
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", all_archs())
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    params = tfm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    logits, _ = jax.jit(lambda p, b: tfm.forward(
        cfg, p, tokens=b.get("tokens"), embeds=b.get("embeds"),
        enc_embeds=b.get("enc_embeds")))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch


@pytest.mark.parametrize("arch", all_archs())
def test_train_grad_step(arch):
    cfg = reduced(get_config(arch))
    params = tfm.init_params(cfg, jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))

    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: tfm.lm_loss(cfg, p, batch)))(params)
    assert np.isfinite(float(loss)), arch
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.square(g.astype(jnp.float32)))),
        grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0.0, arch
    # one SGD step must reduce... no guarantee in 1 step; check finiteness of
    # updated params instead
    new = jax.tree.map(lambda p, g: p - 1e-3 * g.astype(p.dtype), params, grads)
    l2, _ = jax.jit(jax.value_and_grad(
        lambda p: tfm.lm_loss(cfg, p, batch)))(new)
    assert np.isfinite(float(l2)), arch


@pytest.mark.parametrize("arch", all_archs())
def test_decode_step(arch):
    cfg = reduced(get_config(arch))
    params = tfm.init_params(cfg, jax.random.key(0))
    enc_out = None
    if cfg.family == "encdec":
        # precompute cross K/V from a tiny "encoder output" stub
        hd, hkv = cfg.head_dim, cfg.n_kv_heads
        enc_out = (jnp.zeros((cfg.n_layers, B, hkv, S, hd), jnp.bfloat16),
                   jnp.zeros((cfg.n_layers, B, hkv, S, hd), jnp.bfloat16))
    cache = tfm.init_cache(cfg, B, 64, enc_out=enc_out)

    step = jax.jit(lambda p, t, c: tfm.decode_step(cfg, p, t, c))
    tok = jnp.array([1, 2], jnp.int32)
    if cfg.embed_inputs:
        tok = jax.random.normal(jax.random.key(2), (B, cfg.d_model),
                                jnp.float32)
    logits, cache = step(params, tok, cache)
    assert logits.shape == (B, cfg.vocab_size)
    assert int(cache.pos) == 1
    logits2, cache = step(params, tok, cache)
    assert int(cache.pos) == 2
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), arch


def test_param_counts_match_published_scale():
    """Full configs must land near the published parameter counts."""
    expect = {
        "yi-34b": 34e9, "yi-6b": 6e9, "qwen3-14b": 14e9,
        "starcoder2-3b": 3e9, "deepseek-v3-671b": 671e9,
        "grok-1-314b": 314e9, "mamba2-1.3b": 1.3e9, "zamba2-1.2b": 1.2e9,
        "llava-next-34b": 34e9,
    }
    for arch, target in expect.items():
        n = get_config(arch).n_params()
        assert 0.6 * target < n < 1.6 * target, (arch, n, target)


def test_moe_active_params_below_total():
    cfg = get_config("deepseek-v3-671b")
    assert cfg.n_active_params() < 0.1 * cfg.n_params()


def test_shape_cells_skip_rules():
    """long_500k runs only for subquadratic archs (DESIGN.md)."""
    for arch in all_archs():
        cfg = get_config(arch)
        names = [c.name for c in shapes_for(cfg)]
        if arch in ("mamba2-1.3b", "zamba2-1.2b"):
            assert "long_500k" in names, arch
        else:
            assert "long_500k" not in names, arch
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(names)
