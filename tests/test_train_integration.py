"""End-to-end integration: train steps reduce loss on a learnable task;
checkpoint/restore resumes identically; serve decodes greedily from a cache.
"""

import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import reduced
from repro.configs.registry import get_config
from repro.models import transformer as tfm
from repro.serve.decode import make_serve_step
from repro.train import checkpoint as ckpt
from repro.train.data import SyntheticLM
from repro.train.train_step import init_train_state, make_train_step


def test_loss_decreases_on_learnable_task():
    cfg = reduced(get_config("starcoder2-3b"), n_layers=2, vocab_size=128)
    ds = SyntheticLM(cfg.vocab_size, 64, 8, learnable=True)
    params, opt = init_train_state(cfg, jax.random.key(0))
    step_fn = jax.jit(make_train_step(cfg, lr=2e-3))
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        params, opt, m = step_fn(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.8, losses[::6]
    assert np.isfinite(losses).all()


def test_checkpoint_resume_is_bitwise():
    cfg = reduced(get_config("yi-6b"), n_layers=1)
    ds = SyntheticLM(cfg.vocab_size, 32, 4, learnable=True)
    params, opt = init_train_state(cfg, jax.random.key(1))
    step_fn = jax.jit(make_train_step(cfg, lr=1e-3))

    for step in range(3):
        batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
        params, opt, _ = step_fn(params, opt, batch)

    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, {"params": params, "opt": opt})
        # continue two more steps
        p1, o1 = params, opt
        for step in (3, 4):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
            p1, o1, m1 = step_fn(p1, o1, batch)
        # restore and replay: deterministic data -> identical result
        state = ckpt.restore(d, 3, {"params": params, "opt": opt})
        p2, o2 = state["params"], state["opt"]
        for step in (3, 4):
            batch = {k: jnp.asarray(v) for k, v in ds.batch_at(step).items()}
            p2, o2, m2 = step_fn(p2, o2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-6)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("arch", ["yi-6b", "deepseek-v3-671b", "zamba2-1.2b"])
def test_serve_greedy_decode(arch):
    cfg = reduced(get_config(arch))
    params = tfm.init_params(cfg, jax.random.key(0))
    cache = tfm.init_cache(cfg, 2, 32)
    step = jax.jit(lambda p, t, c: make_serve_step(cfg)(p, t, c))
    tok = jnp.array([3, 5], jnp.int32)
    seen = []
    for _ in range(4):
        tok, logits, cache = step(params, tok, cache)
        seen.append(np.asarray(tok))
        assert tok.shape == (2,)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert int(cache.pos) == 4


def test_elastic_launcher_survives_fake_host_kill(tmp_path):
    """End-to-end --elastic path: 2 fake hosts on 4 host devices, host 1
    stops heartbeating at step 5. The controller must declare the death,
    the survivors must re-mesh (2x2 -> 1x2), restore the latest
    checkpoint, and finish the remaining steps."""
    import os
    import subprocess
    import sys

    env = {**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"}
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train",
         "--arch", "starcoder2-3b", "--reduced", "--steps", "8",
         "--host-devices", "4", "--elastic", "--fake-hosts", "2",
         "--kill-host", "1@5", "--lease", "2",
         "--ckpt-dir", str(tmp_path / "ck"), "--ckpt-every", "3",
         "--global-batch", "4", "--seq", "16"],
        capture_output=True, text=True, timeout=600,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = proc.stdout
    assert "host failure: survivors [0], re-mesh (1, 2)" in out
    assert "elastic restore from step" in out
    assert out.count("mesh: ") == 2  # one mesh per epoch: before + after
    assert "done" in out
