"""Substrate tests: checkpointing, data pipeline, elastic control,
optimizers, sharding rules (single device)."""

import os
import tempfile

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import reduced
from repro.configs.registry import all_archs, get_config
from repro.dist.sharding import param_specs
from repro.models import transformer as tfm
from repro.train import checkpoint as ckpt
from repro.train.data import PackedBinaryDataset, SyntheticLM
from repro.train.elastic import HeartbeatMonitor, StragglerDetector, plan_remesh
from repro.train.optimizer import (adafactor_init, adafactor_update,
                                   adamw_init, adamw_update)


# ------------------------------------------------------------- checkpoint

def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)}}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 3, tree)
        out = ckpt.restore(d, 3, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]),
                                      np.asarray(tree["a"]))
        assert np.asarray(out["nested"]["b"]).dtype == np.dtype("bfloat16") \
            or out["nested"]["b"].dtype == jnp.bfloat16


def test_checkpoint_atomic_publish_and_gc():
    tree = {"w": jnp.zeros((4,))}
    with tempfile.TemporaryDirectory() as d:
        c = ckpt.AsyncCheckpointer(d, keep=2)
        for step in (1, 2, 3, 4):
            c.save(step, tree)
        c.wait()
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [3, 4]  # gc kept last 2, no .tmp residue
        assert not any(x.endswith(".tmp") for x in os.listdir(d))
        assert ckpt.latest_step(d) == 4


def test_async_checkpoint_quiesces():
    tree = {"w": jnp.ones((256, 256))}
    with tempfile.TemporaryDirectory() as d:
        c = ckpt.AsyncCheckpointer(d)
        c.save(1, tree)
        c.wait()  # the completion-protocol role: no in-flight writes after
        out = ckpt.restore(d, 1, tree)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))


# ------------------------------------------------------------------- data

def test_synthetic_data_deterministic_in_step():
    ds = SyntheticLM(vocab_size=100, seq_len=16, global_batch=4, seed=7)
    b1, b2 = ds.batch_at(5), ds.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch_at(6)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token
    assert b1["tokens"].shape == b1["labels"].shape


def test_packed_binary_dataset_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tokens.bin")
        toks = np.arange(1000, dtype=np.uint32) % 50
        PackedBinaryDataset.write(path, toks)
        ds = PackedBinaryDataset(path, seq_len=16, global_batch=4)
        b = ds.batch_at(0)
        assert b["tokens"].shape == (4, 16)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])


# ---------------------------------------------------------------- elastic

def test_heartbeat_detects_dead_host():
    m = HeartbeatMonitor(n_hosts=3, dead_after=10.0)
    m.beat(0, now=100.0)
    m.beat(1, now=100.0)
    m.beat(2, now=95.0)
    assert m.dead_hosts(now=106.0) == [2]
    assert m.dead_hosts(now=100.0) == []


def test_straggler_needs_persistence():
    s = StragglerDetector(straggler_factor=1.5, patience=3)
    for step in range(10):
        for h in range(4):
            s.record(h, 1.0)
    # one slow step is not enough
    s.record(0, 10.0)
    assert s.stragglers() == []
    s.record(0, 10.0)
    assert s.stragglers() == []
    s.record(0, 10.0)
    assert 0 in s.stragglers()


def test_elastic_controller_declares_death_once():
    from repro.train.elastic import ElasticController

    c = ElasticController(n_hosts=4, chips_per_host=2, model_axis=2,
                          dead_after=2.0)
    for step in range(3):
        for h in range(4):
            c.beat(h, 0.1, now=float(step))
    assert c.poll(latest_ckpt=None, now=3.0) is None
    # host 3 goes silent from step 3 on
    for step in range(3, 7):
        for h in range(3):
            c.beat(h, 0.1, now=float(step))
        plan = c.poll(latest_ckpt=10, now=float(step))
        if step < 5:
            assert plan is None  # lease not yet expired
        elif step == 5:
            assert plan is not None and plan.survivors == [0, 1, 2]
            assert plan.restore_step == 10
        else:
            assert plan is None  # deaths are declared exactly once
    assert c.failed == [3]
    assert c.alive() == [0, 1, 2]


def test_elastic_controller_grows_mesh_on_admit():
    """Grow path: an admitted host produces a grow plan exactly when it
    proves alive (first heartbeat), and a re-admitted previously-failed
    host must re-arm its lease — no stale-heartbeat resurrection."""
    from repro.train.elastic import ElasticController

    c = ElasticController(n_hosts=3, chips_per_host=2, model_axis=2,
                          dead_after=2.0)
    for h in range(3):
        c.beat(h, 0.1, now=0.0)
    assert c.poll(latest_ckpt=None, now=1.0) is None

    # admit a brand-new host: no plan until it heartbeats...
    c.admit(3)
    assert c.n_hosts == 4
    assert c.poll(latest_ckpt=5, now=1.5) is None
    # ...and its silence is not a death either (lease unarmed), no matter
    # how long it stays quiet while the rest of the fleet keeps beating
    for h in range(3):
        c.beat(h, 0.1, now=50.0)
    assert c.poll(latest_ckpt=5, now=50.0) is None
    c.beat(3, 0.1, now=50.5)
    plan = c.poll(latest_ckpt=5, now=50.5)
    assert plan is not None and plan.survivors == [0, 1, 2, 3]
    assert plan.mesh_shape == (4, 2)       # data axis grew 3 -> 4
    assert plan.restore_step == 5

    # now host 3 dies, then is re-admitted: shrink plan, then grow again
    for step in range(51, 55):
        for h in range(3):
            c.beat(h, 0.1, now=float(step))
    plan = c.poll(latest_ckpt=7, now=54.0)
    assert plan is not None and plan.survivors == [0, 1, 2]
    c.admit(3)
    assert c.failed == []
    # stale pre-death heartbeat must not count as proof of life
    assert c.poll(latest_ckpt=7, now=54.1) is None
    c.beat(3, 0.1, now=54.5)
    plan = c.poll(latest_ckpt=7, now=54.5)
    assert plan is not None and plan.survivors == [0, 1, 2, 3]


def test_elastic_controller_ignores_never_seen_hosts():
    """A host that never heartbeat is a slow cold start, not a failure
    (same arming rule as the runtime's lease detector)."""
    from repro.train.elastic import ElasticController

    c = ElasticController(n_hosts=3, chips_per_host=1, model_axis=1,
                          dead_after=1.0)
    c.beat(0, now=4.5)
    c.beat(1, now=4.5)
    # host 2 has never beaten; even far past the lease it is not failed
    assert c.poll(latest_ckpt=None, now=5.0) is None
    assert c.failed == []
    # but once it beats and then goes silent, the lease arms
    c.beat(2, now=5.0)
    c.beat(0, now=7.0)
    c.beat(1, now=7.0)
    plan = c.poll(latest_ckpt=None, now=7.0)
    assert plan is not None and plan.survivors == [0, 1]


def test_plan_remesh_shrinks_data_axis():
    plan = plan_remesh(n_hosts=64, failed=[3, 17], chips_per_host=4,
                       model_axis=16, latest_ckpt=1200)
    assert plan.mesh_shape == ((62 * 4) // 16, 16)
    assert plan.restore_step == 1200
    with pytest.raises(RuntimeError):
        plan_remesh(n_hosts=4, failed=[0, 1, 2], chips_per_host=4,
                    model_axis=16, latest_ckpt=None)


# -------------------------------------------------------------- optimizer

@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 2**31))
def test_adamw_reduces_quadratic(seed):
    key = jax.random.key(seed)
    target = jax.random.normal(key, (8,))
    params = {"w": jnp.zeros((8,))}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = loss(params)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state = adamw_update(params, g, state, lr=5e-2,
                                     weight_decay=0.0)
    assert loss(params) < l0 * 0.5


def test_adafactor_factored_state_is_small():
    params = {"w": jnp.zeros((256, 512)), "b": jnp.zeros((512,))}
    state = adafactor_init(params)
    assert state.vr["w"].shape == (256,)
    assert state.vc["w"].shape == (512,)
    assert state.vr["b"].shape == (512,)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 1.0) ** 2)

    g = jax.grad(loss)(params)
    new, state = adafactor_update(params, g, state, lr=1e-2)
    assert all(np.isfinite(np.asarray(x)).all()
               for x in jax.tree.leaves(new))


# --------------------------------------------------------------- sharding

@pytest.mark.parametrize("arch", all_archs())
def test_param_specs_cover_tree(arch):
    """Every param leaf gets a spec of matching rank; large matrices are
    actually sharded (not silently replicated)."""
    cfg = get_config(arch)
    specs = param_specs(cfg)
    abstract = tfm.abstract_params(cfg)
    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    flat_p = jax.tree.leaves(abstract)
    assert len(flat_s) == len(flat_p)
    big_sharded = 0
    for s, p in zip(flat_s, flat_p):
        assert len(s) <= p.ndim, (s, p.shape)
        if p.size > 1e6:
            assert any(e is not None for e in s), (s, p.shape)
            big_sharded += 1
    assert big_sharded > 0


# ------------------------------------------------- spec sanitization rules

def test_sanitize_spec_drops_nondivisible_axes():
    from repro.dist.sharding import sanitize_spec

    sizes = {"data": 16, "model": 16, "pod": 2}
    # vocab 50280 cannot split 16 ways -> drop; 2048 can
    s = sanitize_spec(P("model", "data"), (50280, 2048), sizes)
    assert s == P(None, "data")
    # tuple entries drop rightmost-first: batch 32 divides pod*data=32
    s = sanitize_spec(P(("pod", "data"), None), (32, 128), sizes)
    assert s == P(("pod", "data"), None)
    # batch 16 divides pod(2)*... no: 16 % 32 != 0 -> drop "data", keep pod
    s = sanitize_spec(P(("pod", "data"), None), (16, 128), sizes)
    assert s == P("pod", None)
    # rank padding: spec shorter than shape
    s = sanitize_spec(P("model"), (64, 32, 16), sizes)
    assert s == P("model", None, None)


def test_cache_specs_seq_fallback_for_small_kv_heads():
    """yi-6b: Hkv=4 < 16. Unpadded the cache falls back to sequence
    sharding; with the kv_head_pad replication factor the head dim reaches
    the model axis and keeps head sharding (the launch paths pass it)."""
    import jax as _jax
    from repro.dist.sharding import cache_specs, kv_head_pad
    from repro.models import transformer as tfm

    cfg = get_config("yi-6b")
    cache = _jax.eval_shape(lambda: tfm.init_cache(cfg, 128, 32768))
    specs = cache_specs(cfg, cache, ("data",), model_axis=16)
    kv_spec = specs.layers["dense"][0]
    assert kv_spec == P(None, ("data",), None, "model", None)

    pad = kv_head_pad(cfg, 16)
    assert pad == 16 // cfg.n_kv_heads
    padded = _jax.eval_shape(
        lambda: tfm.init_cache(cfg, 128, 32768, kv_head_pad=pad))
    assert padded.layers["dense"][0].shape[2] == 16
    specs_p = cache_specs(cfg, padded, ("data",), model_axis=16)
    assert specs_p.layers["dense"][0] == P(None, ("data",), "model",
                                           None, None)

    cfg2 = get_config("seamless-m4t-large-v2")  # Hkv=16 -> head sharding
    assert kv_head_pad(cfg2, 16) == 1          # already divisible: no pad
    enc = (_jax.ShapeDtypeStruct((24, 8, 16, 64, 64), jnp.bfloat16),) * 2
    cache2 = _jax.eval_shape(
        lambda: tfm.init_cache(cfg2, 8, 64, enc_out=enc))
    specs2 = cache_specs(cfg2, cache2, ("data",), model_axis=16)
    assert specs2.layers["cross_self"][0] == P(None, ("data",), "model",
                                               None, None)


def test_kv_head_pad_decode_equivalence():
    """A padded (head-replicated) cache decodes bit-identically to the
    unpadded one — replication mirrors GQA's own head repeat."""
    import jax as _jax
    from repro.configs.base import reduced
    from repro.models import transformer as tfm
    from repro.serve.decode import make_serve_step

    cfg = reduced(get_config("yi-6b"))      # n_heads=4, n_kv_heads=2
    params = tfm.init_params(cfg, _jax.random.key(0))
    step = make_serve_step(cfg)
    tok = jnp.arange(2, dtype=jnp.int32)

    outs = []
    for pad in (1, 2):
        cache = tfm.init_cache(cfg, 2, 16, kv_head_pad=pad)
        t = tok
        toks = []
        for _ in range(4):
            t, logits, cache = step(params, t, cache)
            toks.append(np.asarray(logits))
        outs.append(toks)
    for a, b in zip(*outs):
        np.testing.assert_array_equal(a, b)


def test_moe_row_dispatch_matches_global():
    """Row-decomposed dispatch == single-row dispatch (same capacity math
    when rows=1); validated numerically at tiny scale."""
    from repro.configs.base import MoEConfig
    from repro.models.moe import moe_ffn, moe_params_shapes
    from repro.models.layers import dense_init

    cfg_moe = MoEConfig(n_experts=4, experts_per_token=2, d_ff=16,
                        capacity_factor=8.0)  # high cap: no drops
    d = 8
    shapes = moe_params_shapes(cfg_moe, d, "swiglu")
    key = jax.random.key(0)
    ks = jax.random.split(key, len(shapes))
    p = {n: (jnp.zeros(s) if n.endswith("bias")
             else dense_init(k, s, 0, jnp.float32))
         for k, (n, s) in zip(ks, sorted(shapes.items()))}
    x = jax.random.normal(jax.random.key(1), (4, 6, d))
    y = moe_ffn(x, p, cfg_moe, "swiglu", jnp.float32)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y)).all()
    # permutation invariance across the batch (row-local dispatch must not
    # leak across tokens): permuting batch permutes outputs identically
    perm = jnp.array([2, 0, 3, 1])
    y_perm = moe_ffn(x[perm], p, cfg_moe, "swiglu", jnp.float32)
    np.testing.assert_allclose(np.asarray(y_perm), np.asarray(y[perm]),
                               rtol=1e-5, atol=1e-5)
