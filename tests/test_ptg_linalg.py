"""The reproduction's core correctness claim: one PTG, two runtimes.

The same BlockPTGSpec (GEMM 2D/3D, Cholesky) must produce oracle-correct
results on (a) the faithful host runtime (async tasks + active messages)
and (b) the compiled SPMD executor (shard_map + fused all_to_all).

Host-runtime + schedule-construction tests run inline (single device);
compiled multi-device cases are dispatched to ``tests/multi_device_cases.py``
in a subprocess so the forced device count never leaks into this process.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.discovery import discover
from repro.core.schedule import build_block_program
from repro.linalg.cholesky import (assemble_lower, cholesky_bodies,
                                   cholesky_spec, make_spd_blocks)
from repro.linalg.gemm import (assemble, gemm_2d_spec, gemm_3d_spec,
                               gemm_bodies, make_blocks)
from repro.linalg.host_exec import run_host_ptg

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _np_bodies(bodies):
    return {t: (lambda fn: (lambda *a: np.asarray(fn(*map(jnp.asarray, a)))))(fn)
            for t, fn in bodies.items()}


# ------------------------------------------------------------- discovery

def test_discovery_locality_gemm():
    """No shard expands more than its own tasks + halo (never the full DAG)."""
    nb, pr, pc = 8, 2, 2
    spec = gemm_2d_spec(nb, pr, pc, b=4)
    sched = discover(spec.ptg, spec.seeds, spec.n_shards)
    total_tasks = sum(len(wf) for s in sched.shards for wf in s.wavefronts)
    assert total_tasks == nb * nb * nb + 2 * nb * nb  # gemm + sends
    for s in sched.shards:
        own = sum(len(wf) for wf in s.wavefronts)
        # `expanded` counts fulfill events: own tasks' deps + seeds; must be
        # O(own tasks), never O(total DAG)
        assert s.expanded <= 4 * own + 1, (s.shard, s.expanded, own)


def test_discovery_wavefront_depth_gemm():
    spec = gemm_2d_spec(6, 2, 2, b=4)
    sched = discover(spec.ptg, spec.seeds, spec.n_shards)
    assert sched.n_wavefronts == 6 + 1  # sends at level 0, gemm k at k+1


def test_discovery_staged_spreads_messages():
    """Staged sends move comm out of wavefront 0 into the k-progression."""
    base = build_block_program(gemm_2d_spec(6, 2, 2, b=4, staged=False))
    staged = build_block_program(gemm_2d_spec(6, 2, 2, b=4, staged=True))
    m0_base = base.exchange[0][0]
    m0_staged = staged.exchange[0][0]
    assert m0_staged.shape[-1] < m0_base.shape[-1]
    # same total data crosses the wire
    assert staged.comm_stats()["real_bytes"] == base.comm_stats()["real_bytes"]


def test_schedule_validates_cholesky():
    spec = cholesky_spec(5, 2, 2, b=4)
    prog = build_block_program(spec)
    prog.schedule.validate(spec.ptg)
    assert prog.n_slots > 1
    assert prog.comm_stats()["real_bytes"] > 0


def test_cholesky_sparse_lowering_wire_efficiency():
    """The PR-2 acceptance bar: on the 8-shard Cholesky block PTG the
    classified (sparse) lowering carries >= 2x less padding than the dense
    all_to_all — panel broadcasts activate O(grid) of the 64 pairs."""
    prog = build_block_program(cholesky_spec(8, 4, 2, b=4))
    dense = prog.comm_stats(comm="dense")
    auto = prog.comm_stats(comm="auto")
    assert dense["real_bytes"] == auto["real_bytes"]  # same payload
    assert auto["wire_efficiency"] >= 2 * dense["wire_efficiency"]
    # and at least one wavefront actually chose the sparse path
    assert any(w["pattern"] == "ppermute" for w in auto["per_wavefront"])


def test_schedule_task_counts_cholesky():
    nb = 6
    spec = cholesky_spec(nb, 2, 2, b=4)
    prog = build_block_program(spec)
    total = sum(len(wf) for s in prog.schedule.shards for wf in s.wavefronts)
    n_potrf = nb
    n_trsm = nb * (nb - 1) // 2
    n_syrk = nb * (nb - 1) // 2
    n_gemm = sum(max(i - k - 1, 0) for k in range(nb) for i in range(k + 1, nb))
    assert total == n_potrf + n_trsm + n_syrk + n_gemm


# ----------------------------------------------------- host-runtime checks

def test_gemm_2d_host_matches_oracle():
    nb, pr, pc, b = 3, 2, 1, 8
    spec = gemm_2d_spec(nb, pr, pc, b)
    blocks = make_blocks(None, nb, b)
    out = run_host_ptg(spec, blocks, _np_bodies(gemm_bodies()), n_threads=2)
    a = assemble(blocks, "A", nb, b)
    bm = assemble(blocks, "B", nb, b)
    c = assemble(out, "C", nb, b)
    np.testing.assert_allclose(c, a @ bm, rtol=2e-4, atol=2e-4)


def test_cholesky_host_matches_oracle():
    nb, pr, pc, b = 4, 2, 1, 8
    spec = cholesky_spec(nb, pr, pc, b)
    blocks, a = make_spd_blocks(nb, b)
    out = run_host_ptg(spec, blocks, _np_bodies(cholesky_bodies()),
                       n_threads=2)
    l = assemble_lower(out, nb, b)
    np.testing.assert_allclose(l, np.linalg.cholesky(a), rtol=5e-3, atol=5e-3)


# ------------------------------------------------- compiled (subprocess)

@pytest.mark.parametrize("case", [
    "gemm_2d", "gemm_3d", "gemm_unrolled_matches_scan", "cholesky",
    "cholesky_host_matches_compiled", "pipeline_matches_sequential",
    "elastic_restore_smaller_mesh", "lowering_identity",
    "taskbench_identity", "segmented_identity", "unified_graph",
    "pipeline_train_step", "pallas_bodies",
])
def test_compiled_multi_device(case):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "tests.multi_device_cases", case],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, f"\nstdout:{proc.stdout}\nstderr:{proc.stderr}"
    if f"CASE {case} SKIP" in proc.stdout:
        pytest.skip(proc.stdout.strip().splitlines()[-1])
    assert f"CASE {case} OK" in proc.stdout
