"""The unified front-end's acceptance bar: builder-derived graphs are
bit-identical to the frozen pre-redesign hand-written specs.

For every app family (GEMM 2D eager/staged, GEMM 3D, Cholesky, the four
Task-Bench patterns, the pipeline stage graph) the declaratively-built
graph must reproduce the legacy spec *exactly*: same seeds, same wavefront
task lists per shard, same fused message plan, same slot maps, and the same
lowered index/exchange tables array-for-array — so the compiled executor
emits literally identical HLO and the host runtime fires literally
identical active messages. Also covered: the mutual-inverse guarantee
(``PTG.check_consistency`` catching a silently-dropped send edge), builder
error paths, and a hypothesis sweep building random layered PTGs both ways.

(Host-vs-compiled execution from one Graph runs on 8 emulated devices in
``tests/multi_device_cases.py`` — case ``unified_graph``.)
"""

import numpy as np
import pytest
from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.discovery import PTG, discover
from repro.core.schedule import build_block_program
from repro.dist.pipeline import _stage_perms, pipeline_graph
from repro.linalg.cholesky import cholesky_graph, cholesky_spec
from repro.linalg.gemm import gemm_2d_graph, gemm_2d_spec, gemm_3d_spec
from repro.linalg.host_exec import as_numpy_bodies, run_host_ptg
from repro.ptg import Graph, checked_ptg
from benchmarks.taskbench_scaling import taskbench_spec

from tests.legacy_specs import (legacy_cholesky_spec, legacy_gemm_2d_spec,
                                legacy_gemm_3d_spec, legacy_pipeline_ptg,
                                legacy_taskbench_spec)


def assert_schedules_identical(sn, so):
    assert [s.wavefronts for s in sn.shards] == \
        [s.wavefronts for s in so.shards]
    assert sn.level_of == so.level_of
    for w in range(sn.n_wavefronts):
        gn, go = sn.comm_plan(w), so.comm_plan(w)
        assert list(gn) == list(go), w
        for pair in gn:
            assert [(m.src_task, m.dst_task) for m in gn[pair]] == \
                   [(m.src_task, m.dst_task) for m in go[pair]], (w, pair)


def assert_programs_identical(new_spec, old_spec):
    """Schedule wavefronts, comm plans, slot maps, and every lowered table
    must match array-for-array — the executors then emit identical HLO."""
    assert list(new_spec.seeds) == list(old_spec.seeds)
    pn = build_block_program(new_spec, validate=True)
    po = build_block_program(old_spec, validate=True)
    assert_schedules_identical(pn.schedule, po.schedule)
    assert pn.slot_of == po.slot_of
    assert pn.halo_slot == po.halo_slot
    assert pn.n_slots == po.n_slots
    assert pn.types == po.types and pn.arity == po.arity
    for w in range(len(pn.tables)):
        assert set(pn.tables[w]) == set(po.tables[w]), w
        for t in pn.tables[w]:
            for a, b in zip(pn.tables[w][t], po.tables[w][t]):
                np.testing.assert_array_equal(a, b, err_msg=f"{w}/{t}")
        for a, b in zip(pn.exchange[w], po.exchange[w]):
            np.testing.assert_array_equal(a, b, err_msg=f"exchange {w}")
        assert pn.patterns[w].pair_counts == po.patterns[w].pair_counts
        assert len(pn.sparse_exchange[w]) == len(po.sparse_exchange[w])
        for rn, ro in zip(pn.sparse_exchange[w], po.sparse_exchange[w]):
            assert rn.perm == ro.perm
            np.testing.assert_array_equal(rn.send, ro.send)
            np.testing.assert_array_equal(rn.recv, ro.recv)
    for comm in ("dense", "sparse", "auto"):
        assert pn.comm_stats(comm=comm) == po.comm_stats(comm=comm)
    return pn, po


# ----------------------------------------------------- app-family identity

def test_gemm_2d_eager_matches_legacy():
    assert_programs_identical(legacy_gemm_2d_spec(5, 2, 2, 4),
                              gemm_2d_spec(5, 2, 2, 4))


def test_gemm_2d_staged_matches_legacy():
    assert_programs_identical(
        legacy_gemm_2d_spec(5, 2, 2, 4, staged=True),
        gemm_2d_spec(5, 2, 2, 4, staged=True))


def test_gemm_3d_matches_legacy():
    assert_programs_identical(legacy_gemm_3d_spec(4, 2, 4),
                              gemm_3d_spec(4, 2, 4))


def test_cholesky_matches_legacy():
    assert_programs_identical(legacy_cholesky_spec(6, 2, 2, 4),
                              cholesky_spec(6, 2, 2, 4))


@pytest.mark.parametrize("pattern", ["stencil", "fft", "tree", "random"])
def test_taskbench_matches_legacy(pattern):
    new_spec, new_deps = taskbench_spec(pattern, 8, 6, 4, 4, fan=2)
    old_spec, old_deps = legacy_taskbench_spec(pattern, 8, 6, 4, 4, fan=2)
    assert new_deps == old_deps
    assert_programs_identical(new_spec, old_spec)


def test_pipeline_stage_graph_matches_legacy():
    for n_stages, n_micro in ((4, 6), (2, 8), (3, 3)):
        g = pipeline_graph(n_stages, n_micro)
        assert g.seeds == [(0, 0)]
        sn = g.to_schedule(validate=True)
        so = discover(legacy_pipeline_ptg(n_stages, n_micro), [(0, 0)],
                      n_stages)
        assert_schedules_identical(sn, so)
        assert _stage_perms(sn) == _stage_perms(so)
        assert sn.n_wavefronts == n_stages + n_micro - 1


# ------------------------------------------------ derived-edge guarantees

def test_builder_edges_are_mutual_inverses_by_construction():
    g = cholesky_graph(5, 2, 2, 4).build()
    ptg = g.to_ptg()
    assert ptg.check_consistency(g.tasks) > 0
    # indegree/in_deps agree and seeds are exactly the zero-indegree tasks
    for k in g.tasks:
        assert g.indegree(k) == len(g.in_deps(k))
    assert g.seeds == [k for k in g.tasks if g.indegree(k) == 0]


def test_check_consistency_catches_dropped_send_edge():
    """The silent-message-drop hazard: out_deps forgets one edge in_deps
    declares — the producer would never send the payload. The schedule-level
    validate() cannot see this (the task never becomes ready, or discovery
    stalls); check_consistency names the exact broken edge."""
    spec = legacy_cholesky_spec(4, 2, 2, 4)
    victim = ("trsm", 2, 0)

    def broken_out(t):
        return [d for d in spec.ptg.out_deps(t) if d != victim]

    broken = PTG(spec.ptg.in_deps, broken_out, spec.ptg.mapping,
                 spec.ptg.type_of)
    with pytest.raises(ValueError, match="silently dropped"):
        broken.check_consistency([victim])
    with pytest.raises(ValueError):
        discover(broken, spec.seeds, spec.n_shards, validate=True)


def test_check_consistency_catches_spurious_out_edge():
    ptg = PTG(in_deps=lambda k: [],
              out_deps=lambda k: [k + 1] if k < 2 else [],
              mapping=lambda k: 0)
    with pytest.raises(ValueError, match="over-decrement"):
        ptg.check_consistency([0, 1, 2])


def test_check_consistency_catches_unstable_mapping():
    state = {"n": 0}

    def jumpy_mapping(k):
        state["n"] += 1
        return state["n"]

    ptg = PTG(in_deps=lambda k: [], out_deps=lambda k: [],
              mapping=jumpy_mapping)
    with pytest.raises(ValueError, match="unstable"):
        ptg.check_consistency([0])


def test_checked_ptg_validates_samples():
    ok = checked_ptg(
        in_deps=lambda k: [k - 1] if k > 0 else [],
        out_deps=lambda k: [k + 1] if k < 9 else [],
        mapping=lambda k: k % 2,
        sample_keys=range(10))
    assert ok.in_deps(3) == [2]
    with pytest.raises(ValueError):
        checked_ptg(
            in_deps=lambda k: [k - 1] if k > 0 else [],
            out_deps=lambda k: [],          # inverse rule forgotten
            mapping=lambda k: 0,
            sample_keys=range(3))


# -------------------------------------------------- builder error surface

def _tiny_graph():
    g = Graph("tiny", n_shards=1, owner=lambda blk: 0)
    g.task_type("t", space=lambda: ((i,) for i in range(3)),
                writes=lambda i: ("x", i),
                reads=lambda i: [("x", i - 1)] if i else [])
    return g


def test_builder_rejects_forward_after_edges():
    g = Graph("fwd", n_shards=1, owner=lambda blk: 0)
    g.task_type("t", space=lambda: ((i,) for i in range(3)),
                writes=lambda i: ("x", i),
                after=lambda i: [("t", i + 1)] if i == 0 else [])
    with pytest.raises(ValueError, match="earlier task"):
        g.build()


def test_builder_rejects_duplicate_keys_and_types():
    g = Graph("dup", n_shards=1, owner=lambda blk: 0)
    g.task_type("t", space=lambda: ((0,), (0,)),
                writes=lambda i: ("x", i))
    with pytest.raises(ValueError, match="duplicate task key"):
        g.build()
    g2 = Graph("dup2", n_shards=1, owner=lambda blk: 0)
    g2.task_type("t", writes=lambda i: ("x", i))
    with pytest.raises(ValueError, match="already registered"):
        g2.task_type("t", writes=lambda i: ("y", i))


def test_builder_requires_enumeration():
    g = Graph("nospace", n_shards=1, owner=lambda blk: 0)
    g.task_type("t", writes=lambda i: ("x", i))
    with pytest.raises(ValueError, match="index space"):
        g.build()


def test_built_graph_is_frozen_and_queryable():
    g = _tiny_graph().build()
    assert g.n_tasks == 3 and g.seeds == [("t", 0)]
    assert g.out_deps(("t", 0)) == [("t", 1)]
    assert g.operands(("t", 2)) == [("x", 1)]
    assert g.block_of(("t", 1)) == ("x", 1)
    assert g.type_of(("t", 1)) == "t" and g.mapping(("t", 1)) == 0
    with pytest.raises(KeyError, match="unknown task"):
        g.in_deps(("t", 99))
    with pytest.raises(RuntimeError, match="already built"):
        g.task_type("u", writes=lambda i: ("y", i))
    with pytest.raises(RuntimeError, match="already built"):
        g.sequence(lambda: [])


# ------------------------------------------- property sweep (random PTGs)

def _layered_graph_two_ways(rng, n_layers, width, n_shards, fan_in):
    """The same random layered PTG built (a) by hand like
    tests/test_schedule_property.random_layered_ptg and (b) through the
    declarative builder; returns both specs + blocks + oracle."""
    from tests.test_schedule_property import random_layered_ptg

    spec, bodies, blocks, oracle = random_layered_ptg(
        rng, n_layers, width, n_shards, fan_in)

    # reconstruct the identical deps dict from the hand spec
    deps = {(l, i): list(spec.ptg.in_deps((l, i)))
            for l in range(1, n_layers) for i in range(width)}

    def owner(blk):
        return (blk[1] * 7 + blk[0]) % n_shards

    g = Graph("layered", n_shards=n_shards, owner=owner, block_shape=(4, 4))
    for nfan in sorted({len(d) for d in deps.values()} | {0}):
        g.task_type(f"f{nfan}",
                    key=lambda l, i: (l, i),
                    writes=lambda l, i: (l, i),
                    reads=lambda l, i: [(l, i)] + deps.get((l, i), []))
    g.sequence(lambda: ((f"f{len(deps.get((l, i), ()))}", l, i)
                        for l in range(n_layers) for i in range(width)))
    return g, spec, bodies, blocks, oracle


@settings(deadline=None, max_examples=10,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    n_layers=st.integers(2, 5),
    width=st.integers(1, 5),
    n_shards=st.integers(1, 4),
    fan_in=st.integers(1, 3),
    seed=st.integers(0, 2**31),
)
def test_random_layered_builder_matches_hand_spec(n_layers, width, n_shards,
                                                  fan_in, seed):
    rng = np.random.default_rng(seed)
    g, hand_spec, bodies, blocks, oracle = _layered_graph_two_ways(
        rng, n_layers, width, n_shards, fan_in)
    new_spec = g.to_block_spec()

    # identical schedules + lowered tables, except task ORDER within a
    # wavefront may differ (the hand spec's out_deps enumerates dict order);
    # compare the invariant structure instead
    pn = build_block_program(new_spec, validate=True)
    po = build_block_program(hand_spec, validate=True)
    assert pn.schedule.level_of == po.schedule.level_of
    assert pn.slot_of.keys() == po.slot_of.keys()
    for w in range(pn.schedule.n_wavefronts):
        assert pn.patterns[w].pair_counts == po.patterns[w].pair_counts
        for s in range(n_shards):
            assert sorted(map(repr, pn.schedule.shards[s].wavefronts[w])) \
                == sorted(map(repr, po.schedule.shards[s].wavefronts[w]))

    # and host execution of the builder graph matches the oracle
    np_bodies = {t: (lambda fn: lambda *a: np.asarray(fn(*a)))(fn)
                 for t, fn in bodies.items()}
    out = run_host_ptg(new_spec, blocks, np_bodies, n_threads=2,
                       timeout=60.0)
    want = oracle()
    for blk, arr in want.items():
        np.testing.assert_allclose(out[blk], arr, rtol=1e-5, atol=1e-5)


# --------------------------------------------------- one graph, two specs

def test_graph_lowers_to_consistent_spec_and_host_run():
    """One small Graph: to_block_spec feeds both build_block_program and
    run_host_ptg, and both see the same derived structure (the single-
    device slice of the one-definition-two-backends claim; the multi-device
    executor half runs in multi_device_cases.case_unified_graph)."""
    g = gemm_2d_graph(3, 2, 1, 4)
    spec = g.to_block_spec()
    prog = build_block_program(spec, validate=True)
    total = sum(len(wf) for s in prog.schedule.shards for wf in s.wavefronts)
    assert total == g.n_tasks == 3 * 3 * 3 + 2 * 3 * 3

    from repro.linalg.gemm import assemble, gemm_bodies, make_blocks
    blocks = make_blocks(None, 3, 4)
    out = g.run_host(blocks, as_numpy_bodies(gemm_bodies()))
    a = assemble(blocks, "A", 3, 4)
    bm = assemble(blocks, "B", 3, 4)
    np.testing.assert_allclose(assemble(out, "C", 3, 4), a @ bm,
                               rtol=2e-4, atol=2e-4)
